"""AOT export tests: HLO text is well-formed and carries KV donation aliases."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile import model as M


def test_to_hlo_text_roundtrippable():
    lowered = jax.jit(lambda x, y: (x @ y + 1.0,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32), jax.ShapeDtypeStruct((4, 4), jnp.float32)
    )
    txt = aot.to_hlo_text(lowered)
    assert "HloModule" in txt and "ENTRY" in txt
    assert "f32[4,4]" in txt


def test_export_writes_and_caches(tmp_path):
    os.makedirs(tmp_path / "hlo", exist_ok=True)
    p = aot.export(str(tmp_path), "toy", lambda x: (x + 1,), [aot.spec((3,), jnp.float32)])
    assert os.path.exists(p)
    mtime = os.path.getmtime(p)
    p2 = aot.export(str(tmp_path), "toy", lambda x: (x + 2,), [aot.spec((3,), jnp.float32)])
    assert os.path.getmtime(p2) == mtime  # cached, not re-lowered


def test_donated_kv_alias_in_hlo(tmp_path):
    """decode programs must carry input_output_alias for the KV args."""
    os.makedirs(tmp_path / "hlo", exist_ok=True)

    def fn(kv, x):
        return (x, kv.at[0].add(x[0]))

    lowered = jax.jit(fn, donate_argnums=(0,)).lower(
        aot.spec((8, 4), jnp.float32), aot.spec((4,), jnp.float32)
    )
    txt = aot.to_hlo_text(lowered)
    assert "input_output_alias" in txt


def test_merge_pairs_cover_variant_grid():
    pairs = aot.merge_pairs([4, 8, 16, 32, 64])
    # ordered largest-first, and every dst is the smallest variant >= a+b
    for a, b, c in pairs:
        assert a >= b
        assert c >= a + b
        smaller = [v for v in [4, 8, 16, 32, 64] if a + b <= v < c]
        assert not smaller, f"dst {c} not minimal for {a}+{b}"
    # the extremes: two smallest merge into the next variant up; anything
    # past the largest variant is simply not exported
    assert (4, 4, 8) in pairs
    assert (32, 32, 64) in pairs
    assert all(a + b <= 64 for a, b, _ in pairs)
    assert not any(a == 64 for a, b, _ in pairs), "64+anything cannot fit"


def test_kv_merge_concats_then_gathers():
    a = jnp.arange(2 * 1 * 3 * 2, dtype=jnp.float32).reshape(2, 1, 3, 2)
    b = a + 100.0
    idx = jnp.array([1, 2, 0, 3], dtype=jnp.int32)  # [a1, b0, a0, b1]
    (k_out, v_out) = M.kv_merge(idx, a, a * 2, b, b * 2)
    cat = np.concatenate([a, b], axis=0)
    np.testing.assert_array_equal(np.asarray(k_out), cat[np.asarray(idx)])
    cat2 = np.concatenate([a * 2, b * 2], axis=0)
    np.testing.assert_array_equal(np.asarray(v_out), cat2[np.asarray(idx)])


def test_merge_program_lowers_with_both_cache_arg_sets(tmp_path):
    """A merge program must take idx + 2 * n_kv cache args and emit the
    dst-batch shapes, so the Rust engine can feed two requests' caches."""
    os.makedirs(tmp_path / "hlo", exist_ok=True)
    cfg = M.PRM_SMALL_CFG
    a, b, c = 4, 4, 8
    kv_a = [aot.spec(sh) for sh in M.kv_shapes(cfg, a)]
    kv_b = [aot.spec(sh) for sh in M.kv_shapes(cfg, b)]
    p = aot.export(
        str(tmp_path), f"toy_merge_b{a}_b{b}_to_b{c}",
        M.kv_merge, [aot.spec((c,), jnp.int32)] + kv_a + kv_b,
    )
    txt = open(p).read()
    assert "HloModule" in txt and "ENTRY" in txt
    h, s, d = cfg.n_heads, cfg.cache_len, cfg.head_dim
    assert f"f32[{a},{h},{s},{d}]" in txt  # source cache params
    assert f"f32[{c},{h},{s},{d}]" in txt  # merged outputs


def test_export_merge_registers_manifest_entries(tmp_path):
    os.makedirs(tmp_path / "hlo", exist_ok=True)
    programs = {}
    aot.export_merge(str(tmp_path), M.PRM_SMALL_CFG, programs)
    assert "merge_b4_b4_to_b8" in programs
    assert "merge_b32_b32_to_b64" in programs
    assert "merge_b4_b8_to_b16" not in programs  # only a >= b exported
    assert "merge_b8_b4_to_b16" in programs
    for name, path in programs.items():
        assert name.startswith("merge_b")
        assert os.path.exists(path)


def test_kv_compact_packs_along_cache_axis():
    """Host semantics: out[b, h, p, d] = kv[b, h, idx[b, p], d]."""
    B, H, S, D = 2, 1, 4, 2
    kv = jnp.arange(B * H * S * D, dtype=jnp.float32).reshape(B, H, S, D)
    # slot0 packs positions {1, 3} down; slot1 packs {2} down
    idx = jnp.array([[1, 3, 0, 0], [2, 0, 0, 0]], dtype=jnp.int32)
    (out,) = M.kv_compact(idx, kv)
    ref = np.asarray(kv)
    got = np.asarray(out)
    for b in range(B):
        for p in range(S):
            np.testing.assert_array_equal(got[b, :, p], ref[b, :, int(idx[b, p])])


def test_compact_program_lowers_with_donated_kv(tmp_path):
    """compact_bN must take a [N, S] index matrix + n_kv donated cache
    args and emit same-shape outputs (in-place repack under aliasing)."""
    os.makedirs(tmp_path / "hlo", exist_ok=True)
    cfg = M.PRM_SMALL_CFG
    b = 4
    nkv = 2 * cfg.n_layers
    kv = [aot.spec(sh) for sh in M.kv_shapes(cfg, b)]
    p = aot.export(
        str(tmp_path), f"toy_compact_b{b}",
        M.kv_compact, [aot.spec((b, cfg.cache_len), jnp.int32)] + kv,
        donate=range(1, 1 + nkv),
    )
    txt = open(p).read()
    assert "HloModule" in txt and "ENTRY" in txt
    h, s, d = cfg.n_heads, cfg.cache_len, cfg.head_dim
    assert f"s32[{b},{s}]" in txt  # index matrix param
    assert f"f32[{b},{h},{s},{d}]" in txt  # cache params/outputs, same shape
    assert "input_output_alias" in txt, "KV donation must survive lowering"


def test_export_compact_registers_manifest_entries(tmp_path):
    os.makedirs(tmp_path / "hlo", exist_ok=True)
    programs = {}
    aot.export_compact(str(tmp_path), M.PRM_SMALL_CFG, programs)
    for b in aot.BATCHES:
        assert f"compact_b{b}" in programs, "every batch variant gets a compactor"
        assert os.path.exists(programs[f"compact_b{b}"])
    assert len(programs) == len(aot.BATCHES)


def test_write_weights_bin_order(tmp_path):
    cfg = M.PRM_SMALL_CFG
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    path = str(tmp_path / "w.bin")
    n = aot.write_weights_bin(path, cfg, params)
    assert n == cfg.param_count()
    flat = np.fromfile(path, dtype="<f4")
    assert flat.size == n
    # first tensor is the embedding, row-major
    emb = np.asarray(params["emb"]).ravel()
    np.testing.assert_array_equal(flat[: emb.size], emb)
    # last is head_b
    np.testing.assert_array_equal(flat[-1:], np.asarray(params["head_b"]))
