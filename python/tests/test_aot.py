"""AOT export tests: HLO text is well-formed and carries KV donation aliases."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile import model as M


def test_to_hlo_text_roundtrippable():
    lowered = jax.jit(lambda x, y: (x @ y + 1.0,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32), jax.ShapeDtypeStruct((4, 4), jnp.float32)
    )
    txt = aot.to_hlo_text(lowered)
    assert "HloModule" in txt and "ENTRY" in txt
    assert "f32[4,4]" in txt


def test_export_writes_and_caches(tmp_path):
    os.makedirs(tmp_path / "hlo", exist_ok=True)
    p = aot.export(str(tmp_path), "toy", lambda x: (x + 1,), [aot.spec((3,), jnp.float32)])
    assert os.path.exists(p)
    mtime = os.path.getmtime(p)
    p2 = aot.export(str(tmp_path), "toy", lambda x: (x + 2,), [aot.spec((3,), jnp.float32)])
    assert os.path.getmtime(p2) == mtime  # cached, not re-lowered


def test_donated_kv_alias_in_hlo(tmp_path):
    """decode programs must carry input_output_alias for the KV args."""
    os.makedirs(tmp_path / "hlo", exist_ok=True)

    def fn(kv, x):
        return (x, kv.at[0].add(x[0]))

    lowered = jax.jit(fn, donate_argnums=(0,)).lower(
        aot.spec((8, 4), jnp.float32), aot.spec((4,), jnp.float32)
    )
    txt = aot.to_hlo_text(lowered)
    assert "input_output_alias" in txt


def test_write_weights_bin_order(tmp_path):
    cfg = M.PRM_SMALL_CFG
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    path = str(tmp_path / "w.bin")
    n = aot.write_weights_bin(path, cfg, params)
    assert n == cfg.param_count()
    flat = np.fromfile(path, dtype="<f4")
    assert flat.size == n
    # first tensor is the embedding, row-major
    emb = np.asarray(params["emb"]).ravel()
    np.testing.assert_array_equal(flat[: emb.size], emb)
    # last is head_b
    np.testing.assert_array_equal(flat[-1:], np.asarray(params["head_b"]))
