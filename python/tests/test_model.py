"""L2 model tests: the serving-cache discipline must match teacher forcing.

The key invariants the Rust coordinator depends on:
  * prefill logits == full-sequence logits at the prompt boundary
  * incremental PRM scoring (lockstep physical frontier + validity mask +
    logical-position RoPE) == full-sequence scoring, even when slots
    diverge and junk blocks are interleaved
  * kv_gather / kv_broadcast permute slots exactly
  * weight_specs round-trips params and matches param_count
"""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import grammar as g
from compile import model as M


@pytest.fixture(scope="module")
def lm():
    cfg = M.LM_CFG
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def prm():
    cfg = M.PRM_SMALL_CFG
    return cfg, M.init_params(cfg, jax.random.PRNGKey(1))


def _problem(seed=0, bench="satmath-s"):
    return g.gen_problem(random.Random(seed), bench)


def _pad_prompt(prompt):
    toks = jnp.array([prompt + [g.PAD] * (g.PROMPT_PAD - len(prompt))], jnp.int32)
    return toks, jnp.array([len(prompt)], jnp.int32)


# ------------------------------------------------------------- param specs


@pytest.mark.parametrize("cfg", [M.LM_CFG, M.PRM_LARGE_CFG, M.PRM_SMALL_CFG])
def test_param_count_matches_specs(cfg):
    total = sum(int(np.prod(s)) for _, s in M.weight_specs(cfg))
    assert total == cfg.param_count()


def test_params_args_roundtrip(lm):
    cfg, params = lm
    args = M.params_to_args(cfg, params)
    back = M.args_to_params(cfg, args)
    assert set(back) == set(params)
    for k in params:
        assert back[k] is params[k]


def test_flops_per_token(lm):
    cfg, _ = lm
    assert cfg.flops_per_token() == 2 * cfg.param_count()


# ---------------------------------------------------------------- prefill


def test_prefill_matches_fullseq(lm):
    cfg, params = lm
    p = _problem(3)
    prompt = p.prompt_tokens()
    toks, lens = _pad_prompt(prompt)
    out = M.lm_prefill(cfg, params, toks, lens)
    logits, kvs = out[0], out[1:]
    assert logits.shape == (1, cfg.vocab)
    assert len(kvs) == 2 * cfg.n_layers
    seq = prompt + g.solution_tokens(p)
    full = jnp.array([seq + [g.PAD] * (M.SEQ_TRAIN - len(seq))], jnp.int32)
    flog = M.lm_logits_fullseq(cfg, params, full, jnp.array([len(seq)], jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits[0]), np.asarray(flog[0, len(prompt) - 1]), atol=2e-5
    )


# ------------------------------------------------- incremental == fullseq


def test_incremental_prm_matches_fullseq_with_divergence(prm):
    cfg, params = prm
    p = _problem(5, "math500-s")
    prompt, sol = p.prompt_tokens(), g.solution_tokens(p)
    seq = prompt + sol

    full = jnp.array([seq + [g.PAD] * (M.SEQ_TRAIN - len(seq))], jnp.int32)
    ref_logit = M.prm_logits_fullseq(cfg, params, full, jnp.array([len(seq)], jnp.int32))
    ref_scores = 1 / (1 + np.exp(-np.asarray(ref_logit[0])))[: len(seq)]

    toksP, lensP = _pad_prompt(prompt)
    kvs1 = M.prm_prefill(cfg, params, toksP, lensP)
    B = 2
    kvs = list(M.kv_broadcast(B, *kvs1))
    S = cfg.cache_len
    valid = np.zeros((B, S), np.int32)
    valid[:, : len(prompt)] = 1
    pos_phys, pos_log = g.PROMPT_PAD, np.full((B,), len(prompt), np.int32)
    got = [[], []]
    i = [0, 0]
    rnd = 0
    while i[0] < len(sol):
        T = M.SCORE_BLOCK
        blks, ns = [], []
        for slot in range(B):
            if slot == 1 and rnd == 1:
                blks.append([g.PAD] * T)  # slot 1 idles one round (junk)
                ns.append(0)
            else:
                blk = sol[i[slot] : i[slot] + T]
                ns.append(len(blk))
                blks.append(blk + [g.PAD] * (T - len(blk)))
        out = M.prm_score_block(
            cfg, params,
            jnp.array([pos_phys], jnp.int32), jnp.array(pos_log),
            jnp.array(valid), jnp.array(blks, jnp.int32), *kvs,
        )
        sc, kvs = out[0], list(out[1:])
        for slot in range(B):
            got[slot].extend(np.asarray(sc[slot][: ns[slot]]))
            valid[slot, pos_phys : pos_phys + ns[slot]] = 1
            pos_log[slot] += ns[slot]
            i[slot] += ns[slot]
        pos_phys += T
        rnd += 1

    np.testing.assert_allclose(np.array(got[0]), ref_scores[len(prompt):], atol=2e-5)
    n1 = len(got[1])
    np.testing.assert_allclose(np.array(got[1]), ref_scores[len(prompt):len(prompt) + n1], atol=2e-5)


# ------------------------------------------------------------ decode block


def test_decode_block_shapes_and_determinism(lm):
    cfg, params = lm
    p = _problem(7)
    prompt = p.prompt_tokens()
    toks, lens = _pad_prompt(prompt)
    out = M.lm_prefill(cfg, params, toks, lens)
    kvs1 = out[1:]
    B = 4
    kvs = list(M.kv_broadcast(B, *kvs1))
    S = cfg.cache_len
    valid = np.zeros((B, S), np.int32)
    valid[:, : len(prompt)] = 1
    args = (
        jnp.array([g.PROMPT_PAD], jnp.int32),
        jnp.full((B,), len(prompt), jnp.int32),
        jnp.array(valid),
        jnp.full((B,), g.SEP, jnp.int32),
        jnp.array([0.7], jnp.float32),
        jnp.arange(B * 2, dtype=jnp.uint32).reshape(B, 2),
    )
    o1 = M.lm_decode_block(cfg, params, *args, *kvs)
    o2 = M.lm_decode_block(cfg, params, *args, *kvs)
    assert o1[0].shape == (B, M.DECODE_BLOCK)
    assert o1[0].dtype == jnp.int32
    assert (np.asarray(o1[0]) == np.asarray(o2[0])).all()  # same keys => same sample
    assert (np.asarray(o1[0]) >= 0).all() and (np.asarray(o1[0]) < cfg.vocab).all()
    # different keys => (almost surely) different samples somewhere
    args_k = args[:5] + (args[5] + 1234567,)
    o3 = M.lm_decode_block(cfg, params, *args_k, *kvs)
    assert (np.asarray(o1[0]) != np.asarray(o3[0])).any()


def test_decode_greedy_low_temperature(lm):
    """At temperature -> 0 the in-graph sampler must argmax."""
    cfg, params = lm
    p = _problem(9)
    prompt = p.prompt_tokens()
    toks, lens = _pad_prompt(prompt)
    out = M.lm_prefill(cfg, params, toks, lens)
    logits, kvs1 = out[0], out[1:]
    B = 4
    kvs = list(M.kv_broadcast(B, *kvs1))
    valid = np.zeros((B, cfg.cache_len), np.int32)
    valid[:, : len(prompt)] = 1
    # feed the argmax of the prefill logits as the first decode token
    first = int(np.asarray(logits)[0].argmax())
    o = M.lm_decode_block(
        cfg, params,
        jnp.array([g.PROMPT_PAD], jnp.int32),
        jnp.full((B,), len(prompt), jnp.int32),
        jnp.array(valid),
        jnp.full((B,), first, jnp.int32),
        jnp.array([0.0], jnp.float32),
        jnp.arange(B * 2, dtype=jnp.uint32).reshape(B, 2),
        *kvs,
    )
    sampled = np.asarray(o[0])
    # all slots identical under greedy
    assert (sampled == sampled[0]).all()


# ------------------------------------------------------------------ kv ops


def test_kv_gather_permutes_slots():
    kv = jnp.arange(4 * 2 * 8 * 3, dtype=jnp.float32).reshape(4, 2, 8, 3)
    idx = jnp.array([2, 2, 0, 1], jnp.int32)
    (out,) = M.kv_gather(idx, kv)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(kv[2]))
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(kv[2]))
    np.testing.assert_array_equal(np.asarray(out[2]), np.asarray(kv[0]))
    np.testing.assert_array_equal(np.asarray(out[3]), np.asarray(kv[1]))


def test_kv_broadcast_replicates():
    kv = jnp.arange(1 * 2 * 8 * 3, dtype=jnp.float32).reshape(1, 2, 8, 3)
    (out,) = M.kv_broadcast(5, kv)
    assert out.shape == (5, 2, 8, 3)
    for b in range(5):
        np.testing.assert_array_equal(np.asarray(out[b]), np.asarray(kv[0]))
