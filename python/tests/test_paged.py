"""Paged-KV exporter tests (the device half of rust/src/runtime/blocks.rs).

What the Rust block pool depends on:
  * KV_BLOCK divides every cache_len (block tables tile caches exactly)
  * paged_view / kv_append_block have pure gather/select semantics
  * decode/score bracketed by view/store gathers is *byte-identical* to the
    dense programs — the paged runtime must not perturb outcomes
  * export_paged registers the right manifest programs per model kind, and
    the lowered HLO carries input_output_alias for the donated caches
"""

import os
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import grammar as g
from compile import model as M


@pytest.fixture(scope="module")
def lm():
    cfg = M.LM_CFG
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def prm():
    cfg = M.PRM_SMALL_CFG
    return cfg, M.init_params(cfg, jax.random.PRNGKey(1))


def _problem(seed=0, bench="satmath-s"):
    return g.gen_problem(random.Random(seed), bench)


def _pad_prompt(prompt):
    toks = jnp.array([prompt + [g.PAD] * (g.PROMPT_PAD - len(prompt))], jnp.int32)
    return toks, jnp.array([len(prompt)], jnp.int32)


def _block_perms(batch, nb, seed=0):
    """Per-slot block permutation + inverse, as [B, nb] i32 index arrays."""
    rng = np.random.default_rng(seed)
    t = np.stack([rng.permutation(nb) for _ in range(batch)]).astype(np.int32)
    inv = np.empty_like(t)
    for b in range(batch):
        inv[b, t[b]] = np.arange(nb, dtype=np.int32)
    return jnp.array(t), jnp.array(inv)


# ------------------------------------------------------------- block algebra


@pytest.mark.parametrize("cfg", [M.LM_CFG, M.PRM_LARGE_CFG, M.PRM_SMALL_CFG])
def test_kv_block_divides_every_cache_len(cfg):
    assert cfg.cache_len % M.KV_BLOCK == 0, (cfg.name, cfg.cache_len)


def test_paged_view_permutes_blocks():
    B, H, nb, D = 2, 1, 4, 3
    S = nb * M.KV_BLOCK
    kv = jnp.arange(B * H * S * D, dtype=jnp.float32).reshape(B, H, S, D)
    idx = jnp.array([[2, 0, 3, 1], [1, 1, 0, 2]], jnp.int32)
    out = np.asarray(M.paged_view(idx, kv))
    ref = np.asarray(kv).reshape(B, H, nb, M.KV_BLOCK, D)
    for b in range(B):
        for j in range(nb):
            np.testing.assert_array_equal(
                out.reshape(B, H, nb, M.KV_BLOCK, D)[b, :, j],
                ref[b, :, int(idx[b, j])],
            )


def test_paged_view_roundtrips_through_inverse():
    B, H, nb, D = 3, 2, 8, 2
    S = nb * M.KV_BLOCK
    kv = jnp.arange(B * H * S * D, dtype=jnp.float32).reshape(B, H, S, D)
    t, inv = _block_perms(B, nb, seed=3)
    back = M.paged_view(inv, M.paged_view(t, kv))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(kv))


def test_kv_append_block_writes_one_block_span():
    B, H, nb, D = 2, 1, 4, 2
    S = nb * M.KV_BLOCK
    kv = jnp.zeros((B, H, S, D), jnp.float32)
    span = jnp.ones((B, H, M.KV_BLOCK, D), jnp.float32)
    dst = jnp.array([1, 3], jnp.int32)
    (out,) = M.kv_append_block(dst, span, kv)
    got = np.asarray(out).reshape(B, H, nb, M.KV_BLOCK, D)
    for b in range(B):
        for j in range(nb):
            want = 1.0 if j == int(dst[b]) else 0.0
            assert (got[b, :, j] == want).all(), (b, j)


# ----------------------------------------------- paged == dense, bit for bit


def test_score_paged_matches_dense_bitwise(prm):
    """Scores AND the stored cache must match the dense program exactly —
    this is the exporter half of the runtime's byte-identity contract."""
    cfg, params = prm
    p = _problem(5, "math500-s")
    prompt, sol = p.prompt_tokens(), g.solution_tokens(p)
    toksP, lensP = _pad_prompt(prompt)
    kvs1 = M.prm_prefill(cfg, params, toksP, lensP)
    B = 2
    dense = list(M.kv_broadcast(B, *kvs1))
    S, nb = cfg.cache_len, cfg.cache_len // M.KV_BLOCK
    valid = np.zeros((B, S), np.int32)
    valid[:, : len(prompt)] = 1
    T = M.SCORE_BLOCK
    blk = (sol[:T] + [g.PAD] * T)[:T]
    args = (
        jnp.array([g.PROMPT_PAD], jnp.int32),
        jnp.full((B,), len(prompt), jnp.int32),
        jnp.array(valid),
        jnp.array([blk] * B, jnp.int32),
    )
    out_d = M.prm_score_block(cfg, params, *args, *dense)

    for seed in (0, 4):
        t, inv = _block_perms(B, nb, seed=seed)
        if seed == 0:
            t = inv = jnp.tile(jnp.arange(nb, dtype=jnp.int32), (B, 1))  # identity
        # lay the logical cache out in pool order: physical block p holds
        # logical block inv[p]
        pool = [M.paged_view(inv, kv) for kv in dense]
        out_p = M.prm_score_paged(cfg, params, t, inv, *args, *pool)
        np.testing.assert_array_equal(np.asarray(out_p[0]), np.asarray(out_d[0]))
        for got, want in zip(out_p[1:], out_d[1:]):
            np.testing.assert_array_equal(
                np.asarray(M.paged_view(t, got)), np.asarray(want)
            )


def test_decode_paged_matches_dense_bitwise(lm):
    """Sampled tokens are ints: any perturbation shows up whole, so this
    pins byte-identical solves end to end."""
    cfg, params = lm
    p = _problem(7)
    prompt = p.prompt_tokens()
    toks, lens = _pad_prompt(prompt)
    out = M.lm_prefill(cfg, params, toks, lens)
    B = 4
    dense = list(M.kv_broadcast(B, *out[1:]))
    S, nb = cfg.cache_len, cfg.cache_len // M.KV_BLOCK
    valid = np.zeros((B, S), np.int32)
    valid[:, : len(prompt)] = 1
    args = (
        jnp.array([g.PROMPT_PAD], jnp.int32),
        jnp.full((B,), len(prompt), jnp.int32),
        jnp.array(valid),
        jnp.full((B,), g.SEP, jnp.int32),
        jnp.array([0.7], jnp.float32),
        jnp.arange(B * 2, dtype=jnp.uint32).reshape(B, 2),
    )
    out_d = M.lm_decode_block(cfg, params, *args, *dense)
    t, inv = _block_perms(B, nb, seed=11)
    pool = [M.paged_view(inv, kv) for kv in dense]
    out_p = M.lm_decode_paged(cfg, params, t, inv, *args, *pool)
    np.testing.assert_array_equal(np.asarray(out_p[0]), np.asarray(out_d[0]))
    for got, want in zip(out_p[1:], out_d[1:]):
        np.testing.assert_array_equal(np.asarray(M.paged_view(t, got)), np.asarray(want))


# ----------------------------------------------------------------- programs


def test_paged_program_lowers_with_donated_kv(tmp_path):
    """score_paged_bN takes two [N, S/KV_BLOCK] tables + the dense score
    args + donated caches, and the aliasing survives lowering."""
    os.makedirs(tmp_path / "hlo", exist_ok=True)
    cfg = M.PRM_SMALL_CFG
    b = 4
    nw = len(M.weight_specs(cfg))
    nkv = 2 * cfg.n_layers
    s, nb = cfg.cache_len, cfg.cache_len // M.KV_BLOCK
    kv = [aot.spec(sh) for sh in M.kv_shapes(cfg, b)]

    def fn(*args):
        params = M.args_to_params(cfg, args[:nw])
        return M.prm_score_paged(cfg, params, *args[nw:])

    p = aot.export(
        str(tmp_path), f"toy_score_paged_b{b}", fn,
        [aot.spec(sh) for _, sh in M.weight_specs(cfg)]
        + [aot.spec((b, nb), jnp.int32), aot.spec((b, nb), jnp.int32),
           aot.spec((1,), jnp.int32), aot.spec((b,), jnp.int32),
           aot.spec((b, s), jnp.int32), aot.spec((b, M.SCORE_BLOCK), jnp.int32)]
        + kv,
        donate=range(nw + 6, nw + 6 + nkv),
    )
    txt = open(p).read()
    assert "HloModule" in txt and "ENTRY" in txt
    h, d = cfg.n_heads, cfg.head_dim
    assert f"s32[{b},{nb}]" in txt  # block-table params
    assert f"f32[{b},{h},{s},{d}]" in txt  # cache params/outputs
    assert "input_output_alias" in txt, "KV donation must survive lowering"


@pytest.mark.parametrize(
    "cfg", [M.LM_CFG, M.PRM_LARGE_CFG, M.PRM_SMALL_CFG], ids=lambda c: c.name
)
def test_export_paged_registers_manifest_entries(tmp_path, monkeypatch, cfg):
    """Every model gets block gather/append; the LM gets decode_paged, the
    PRMs score_paged — the program names rust/src/runtime keys on."""
    monkeypatch.setattr(aot, "BATCHES", [4])  # one variant keeps this fast
    os.makedirs(tmp_path / "hlo", exist_ok=True)
    programs = {}
    aot.export_paged(str(tmp_path), cfg, programs)
    assert "gather_blocks_b4" in programs
    assert "append_block_b4" in programs
    if cfg.scored:
        assert "score_paged_b4" in programs
        assert "decode_paged_b4" not in programs
    else:
        assert "decode_paged_b4" in programs
        assert "score_paged_b4" not in programs
    for path in programs.values():
        assert os.path.exists(path)


def test_manifest_carries_kv_block():
    """The Rust manifest parser keys paging on a positive top-level
    kv_block; main() must write it (a full export is too slow to run
    here, so pin the expression in the source)."""
    import inspect

    assert M.KV_BLOCK > 0
    assert '"kv_block": M.KV_BLOCK' in inspect.getsource(aot.main)
