"""Paged-KV exporter tests (the device half of rust/src/runtime/blocks.rs).

What the Rust block pool depends on:
  * KV_BLOCK divides every cache_len (block tables tile caches exactly)
  * paged_view / kv_append_block have pure gather/select semantics
  * decode/score bracketed by view/store gathers is *byte-identical* to the
    dense programs — the paged runtime must not perturb outcomes
  * export_paged registers the right manifest programs per model kind, and
    the lowered HLO carries input_output_alias for the donated caches

Block-native contract (the device half of table-edit merge/split/compact):
  * decode_blocktab/score_blocktab read and write a *shared* pool array
    through (block_table, per-slot frontier) operands and are bitwise-equal
    to both the dense and the gather-bracketed paged programs
  * slot rows are independent: a merged (gang) call's rows equal the solo
    calls' rows bitwise, even at diverged frontiers — which is what makes
    merge/split pure table edits on the Rust side
  * adopt/copy programs have pure gather/scatter semantics over pool rows
  * export_blocktab registers the manifest programs and pool geometry
    (`pool_blocks`) that rust/src/runtime keys block-native mode on
"""

import os
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import grammar as g
from compile import model as M


@pytest.fixture(scope="module")
def lm():
    cfg = M.LM_CFG
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def prm():
    cfg = M.PRM_SMALL_CFG
    return cfg, M.init_params(cfg, jax.random.PRNGKey(1))


def _problem(seed=0, bench="satmath-s"):
    return g.gen_problem(random.Random(seed), bench)


def _pad_prompt(prompt):
    toks = jnp.array([prompt + [g.PAD] * (g.PROMPT_PAD - len(prompt))], jnp.int32)
    return toks, jnp.array([len(prompt)], jnp.int32)


def _block_perms(batch, nb, seed=0):
    """Per-slot block permutation + inverse, as [B, nb] i32 index arrays."""
    rng = np.random.default_rng(seed)
    t = np.stack([rng.permutation(nb) for _ in range(batch)]).astype(np.int32)
    inv = np.empty_like(t)
    for b in range(batch):
        inv[b, t[b]] = np.arange(nb, dtype=np.int32)
    return jnp.array(t), jnp.array(inv)


# ------------------------------------------------------------- block algebra


@pytest.mark.parametrize("cfg", [M.LM_CFG, M.PRM_LARGE_CFG, M.PRM_SMALL_CFG])
def test_kv_block_divides_every_cache_len(cfg):
    assert cfg.cache_len % M.KV_BLOCK == 0, (cfg.name, cfg.cache_len)


def test_paged_view_permutes_blocks():
    B, H, nb, D = 2, 1, 4, 3
    S = nb * M.KV_BLOCK
    kv = jnp.arange(B * H * S * D, dtype=jnp.float32).reshape(B, H, S, D)
    idx = jnp.array([[2, 0, 3, 1], [1, 1, 0, 2]], jnp.int32)
    out = np.asarray(M.paged_view(idx, kv))
    ref = np.asarray(kv).reshape(B, H, nb, M.KV_BLOCK, D)
    for b in range(B):
        for j in range(nb):
            np.testing.assert_array_equal(
                out.reshape(B, H, nb, M.KV_BLOCK, D)[b, :, j],
                ref[b, :, int(idx[b, j])],
            )


def test_paged_view_roundtrips_through_inverse():
    B, H, nb, D = 3, 2, 8, 2
    S = nb * M.KV_BLOCK
    kv = jnp.arange(B * H * S * D, dtype=jnp.float32).reshape(B, H, S, D)
    t, inv = _block_perms(B, nb, seed=3)
    back = M.paged_view(inv, M.paged_view(t, kv))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(kv))


def test_kv_append_block_writes_one_block_span():
    B, H, nb, D = 2, 1, 4, 2
    S = nb * M.KV_BLOCK
    kv = jnp.zeros((B, H, S, D), jnp.float32)
    span = jnp.ones((B, H, M.KV_BLOCK, D), jnp.float32)
    dst = jnp.array([1, 3], jnp.int32)
    (out,) = M.kv_append_block(dst, span, kv)
    got = np.asarray(out).reshape(B, H, nb, M.KV_BLOCK, D)
    for b in range(B):
        for j in range(nb):
            want = 1.0 if j == int(dst[b]) else 0.0
            assert (got[b, :, j] == want).all(), (b, j)


# ----------------------------------------------- paged == dense, bit for bit


def test_score_paged_matches_dense_bitwise(prm):
    """Scores AND the stored cache must match the dense program exactly —
    this is the exporter half of the runtime's byte-identity contract."""
    cfg, params = prm
    p = _problem(5, "math500-s")
    prompt, sol = p.prompt_tokens(), g.solution_tokens(p)
    toksP, lensP = _pad_prompt(prompt)
    kvs1 = M.prm_prefill(cfg, params, toksP, lensP)
    B = 2
    dense = list(M.kv_broadcast(B, *kvs1))
    S, nb = cfg.cache_len, cfg.cache_len // M.KV_BLOCK
    valid = np.zeros((B, S), np.int32)
    valid[:, : len(prompt)] = 1
    T = M.SCORE_BLOCK
    blk = (sol[:T] + [g.PAD] * T)[:T]
    args = (
        jnp.array([g.PROMPT_PAD], jnp.int32),
        jnp.full((B,), len(prompt), jnp.int32),
        jnp.array(valid),
        jnp.array([blk] * B, jnp.int32),
    )
    out_d = M.prm_score_block(cfg, params, *args, *dense)

    for seed in (0, 4):
        t, inv = _block_perms(B, nb, seed=seed)
        if seed == 0:
            t = inv = jnp.tile(jnp.arange(nb, dtype=jnp.int32), (B, 1))  # identity
        # lay the logical cache out in pool order: physical block p holds
        # logical block inv[p]
        pool = [M.paged_view(inv, kv) for kv in dense]
        out_p = M.prm_score_paged(cfg, params, t, inv, *args, *pool)
        np.testing.assert_array_equal(np.asarray(out_p[0]), np.asarray(out_d[0]))
        for got, want in zip(out_p[1:], out_d[1:]):
            np.testing.assert_array_equal(
                np.asarray(M.paged_view(t, got)), np.asarray(want)
            )


def test_decode_paged_matches_dense_bitwise(lm):
    """Sampled tokens are ints: any perturbation shows up whole, so this
    pins byte-identical solves end to end."""
    cfg, params = lm
    p = _problem(7)
    prompt = p.prompt_tokens()
    toks, lens = _pad_prompt(prompt)
    out = M.lm_prefill(cfg, params, toks, lens)
    B = 4
    dense = list(M.kv_broadcast(B, *out[1:]))
    S, nb = cfg.cache_len, cfg.cache_len // M.KV_BLOCK
    valid = np.zeros((B, S), np.int32)
    valid[:, : len(prompt)] = 1
    args = (
        jnp.array([g.PROMPT_PAD], jnp.int32),
        jnp.full((B,), len(prompt), jnp.int32),
        jnp.array(valid),
        jnp.full((B,), g.SEP, jnp.int32),
        jnp.array([0.7], jnp.float32),
        jnp.arange(B * 2, dtype=jnp.uint32).reshape(B, 2),
    )
    out_d = M.lm_decode_block(cfg, params, *args, *dense)
    t, inv = _block_perms(B, nb, seed=11)
    pool = [M.paged_view(inv, kv) for kv in dense]
    out_p = M.lm_decode_paged(cfg, params, t, inv, *args, *pool)
    np.testing.assert_array_equal(np.asarray(out_p[0]), np.asarray(out_d[0]))
    for got, want in zip(out_p[1:], out_d[1:]):
        np.testing.assert_array_equal(np.asarray(M.paged_view(t, got)), np.asarray(want))


# ------------------------------------------------------------- block-native


def _alloc_tables(batch, nb, seed=0):
    """Disjoint per-slot block tables over a pool of batch*nb rows (+1
    trash row at id batch*nb), in a random allocation order."""
    rng = np.random.default_rng(seed)
    t = rng.permutation(batch * nb).reshape(batch, nb).astype(np.int32)
    return jnp.array(t), batch * nb + 1


def _pool_from_dense(table, dense, p1):
    """Lay a dense [B, H, S, D] cache out into pool rows per `table`."""
    bsz, h, s, d = dense.shape
    nb = s // M.KV_BLOCK
    pool = np.zeros((p1, h, M.KV_BLOCK, d), np.float32)
    blocks = np.asarray(dense).reshape(bsz, h, nb, M.KV_BLOCK, d).transpose(0, 2, 1, 3, 4)
    pool[np.asarray(table).reshape(-1)] = blocks.reshape(bsz * nb, h, M.KV_BLOCK, d)
    return jnp.array(pool)


def test_blocktab_attention_matches_gathered_dense():
    """The Pallas block-table kernel (gather per K/V block inside the loop)
    agrees with the dense kernel run on the gathered view — same block
    sizes, same online-softmax accumulation order, so bitwise."""
    from compile.kernels.attention import blocktab_attention, causal_attention

    B, H, S, D = 2, 2, 128, 8
    nb = S // M.KV_BLOCK
    rng = np.random.default_rng(0)
    q = jnp.array(rng.standard_normal((B, H, S, D)), jnp.float32)
    dense_k = jnp.array(rng.standard_normal((B, H, S, D)), jnp.float32)
    dense_v = jnp.array(rng.standard_normal((B, H, S, D)), jnp.float32)
    lengths = jnp.array([S // 2 + 3, S - 7], jnp.int32)
    table, p1 = _alloc_tables(B, nb, seed=2)
    k_pool = _pool_from_dense(table, dense_k, p1)
    v_pool = _pool_from_dense(table, dense_v, p1)

    got = blocktab_attention(
        q, k_pool, v_pool, table, lengths, block_q=M.KV_BLOCK, block_k=M.KV_BLOCK
    )
    want = causal_attention(
        q, dense_k, dense_v, lengths, block_q=M.KV_BLOCK, block_k=M.KV_BLOCK
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_decode_blocktab_matches_dense_and_paged_bitwise(lm):
    """Same tokens and same written cells as the dense program AND the
    gather-bracketed paged program — the pin that lets the Rust runtime
    swap per-request caches for shared-pool tables without perturbing a
    single solve."""
    cfg, params = lm
    p = _problem(7)
    prompt = p.prompt_tokens()
    toks, lens = _pad_prompt(prompt)
    out = M.lm_prefill(cfg, params, toks, lens)
    B = 4
    dense = list(M.kv_broadcast(B, *out[1:]))
    S, nb = cfg.cache_len, cfg.cache_len // M.KV_BLOCK
    valid = np.zeros((B, S), np.int32)
    valid[:, : len(prompt)] = 1
    args = (
        jnp.full((B,), len(prompt), jnp.int32),
        jnp.array(valid),
        jnp.full((B,), g.SEP, jnp.int32),
        jnp.array([0.7], jnp.float32),
        jnp.arange(B * 2, dtype=jnp.uint32).reshape(B, 2),
    )
    out_d = M.lm_decode_block(cfg, params, jnp.array([g.PROMPT_PAD], jnp.int32), *args, *dense)
    t, inv = _block_perms(B, nb, seed=11)
    paged = [M.paged_view(inv, kv) for kv in dense]
    out_p = M.lm_decode_paged(cfg, params, t, inv, jnp.array([g.PROMPT_PAD], jnp.int32), *args, *paged)

    table, p1 = _alloc_tables(B, nb, seed=5)
    pools = [_pool_from_dense(table, kv, p1) for kv in dense]
    frontier = jnp.full((B,), g.PROMPT_PAD, jnp.int32)
    out_b = M.lm_decode_blocktab(cfg, params, table, frontier, *args, *pools)

    np.testing.assert_array_equal(np.asarray(out_b[0]), np.asarray(out_d[0]))
    np.testing.assert_array_equal(np.asarray(out_b[0]), np.asarray(out_p[0]))
    for got_pool, want_dense, got_paged in zip(out_b[1:], out_d[1:], out_p[1:]):
        np.testing.assert_array_equal(
            np.asarray(M.pool_view(table, got_pool)), np.asarray(want_dense)
        )
        np.testing.assert_array_equal(
            np.asarray(M.paged_view(t, got_paged)), np.asarray(want_dense)
        )


def test_score_blocktab_matches_dense_bitwise(prm):
    cfg, params = prm
    p = _problem(5, "math500-s")
    prompt, sol = p.prompt_tokens(), g.solution_tokens(p)
    toksP, lensP = _pad_prompt(prompt)
    kvs1 = M.prm_prefill(cfg, params, toksP, lensP)
    B = 2
    dense = list(M.kv_broadcast(B, *kvs1))
    S, nb = cfg.cache_len, cfg.cache_len // M.KV_BLOCK
    valid = np.zeros((B, S), np.int32)
    valid[:, : len(prompt)] = 1
    T = M.SCORE_BLOCK
    blk = (sol[:T] + [g.PAD] * T)[:T]
    args = (
        jnp.full((B,), len(prompt), jnp.int32),
        jnp.array(valid),
        jnp.array([blk] * B, jnp.int32),
    )
    out_d = M.prm_score_block(cfg, params, jnp.array([g.PROMPT_PAD], jnp.int32), *args, *dense)

    table, p1 = _alloc_tables(B, nb, seed=9)
    pools = [_pool_from_dense(table, kv, p1) for kv in dense]
    frontier = jnp.full((B,), g.PROMPT_PAD, jnp.int32)
    out_b = M.prm_score_blocktab(cfg, params, table, frontier, *args, *pools)
    np.testing.assert_array_equal(np.asarray(out_b[0]), np.asarray(out_d[0]))
    for got, want in zip(out_b[1:], out_d[1:]):
        np.testing.assert_array_equal(
            np.asarray(M.pool_view(table, got)), np.asarray(want)
        )


def test_blocktab_gang_rows_match_solo(lm):
    """Two requests at *diverged* frontiers share one merged call: every
    merged row must equal its solo row bitwise, and each request's pool
    writes must land exactly where its solo call would put them. This is
    the device-side fact that makes gang merge/split pure table edits."""
    cfg, params = lm
    p = _problem(3)
    prompt = p.prompt_tokens()
    toks, lens = _pad_prompt(prompt)
    out = M.lm_prefill(cfg, params, toks, lens)
    S, nb = cfg.cache_len, cfg.cache_len // M.KV_BLOCK

    valid2 = np.zeros((2, S), np.int32)
    valid2[:, : len(prompt)] = 1
    mk_args = lambda keys: (
        jnp.full((2,), len(prompt), jnp.int32),
        jnp.array(valid2),
        jnp.full((2,), g.SEP, jnp.int32),
        jnp.array([0.7], jnp.float32),
        keys,
    )
    keys_x = jnp.arange(4, dtype=jnp.uint32).reshape(2, 2)
    keys_y = jnp.arange(100, 104, dtype=jnp.uint32).reshape(2, 2)
    # request X at the prompt frontier; request Y four junk positions
    # later, as if it had idled a round (positions [16, 20) uncommitted)
    fx, fy = g.PROMPT_PAD, g.PROMPT_PAD + 4

    table4, p1 = _alloc_tables(4, nb, seed=13)
    tab_x, tab_y = table4[:2], table4[2:]
    pools0 = [_pool_from_dense(table4, kv, p1) for kv in M.kv_broadcast(4, *out[1:])]

    solo_x = M.lm_decode_blocktab(
        cfg, params, tab_x, jnp.full((2,), fx, jnp.int32), *mk_args(keys_x), *pools0)
    solo_y = M.lm_decode_blocktab(
        cfg, params, tab_y, jnp.full((2,), fy, jnp.int32), *mk_args(keys_y), *pools0)

    frontier = jnp.array([fx, fx, fy, fy], jnp.int32)
    valid4 = np.concatenate([valid2, valid2])
    merged = M.lm_decode_blocktab(
        cfg, params, table4, frontier,
        jnp.full((4,), len(prompt), jnp.int32), jnp.array(valid4),
        jnp.full((4,), g.SEP, jnp.int32), jnp.array([0.7], jnp.float32),
        jnp.concatenate([keys_x, keys_y]), *pools0)

    np.testing.assert_array_equal(np.asarray(merged[0][:2]), np.asarray(solo_x[0]))
    np.testing.assert_array_equal(np.asarray(merged[0][2:]), np.asarray(solo_y[0]))
    for mp, sx, sy in zip(merged[1:], solo_x[1:], solo_y[1:]):
        np.testing.assert_array_equal(
            np.asarray(M.pool_view(tab_x, mp)), np.asarray(M.pool_view(tab_x, sx)))
        np.testing.assert_array_equal(
            np.asarray(M.pool_view(tab_y, mp)), np.asarray(M.pool_view(tab_y, sy)))


def test_kv_adopt_blocks_installs_prefix_everywhere():
    H, D, nb = 2, 3, 2
    S = nb * M.KV_BLOCK
    rng = np.random.default_rng(4)
    dense = jnp.array(rng.standard_normal((1, H, S, D)), jnp.float32)
    B = 3
    table, p1 = _alloc_tables(B, nb, seed=1)
    pools = [jnp.zeros((p1, H, M.KV_BLOCK, D), jnp.float32)]
    (out,) = M.kv_adopt_blocks(table, dense, *pools)
    view = M.pool_view(table, out)
    for b in range(B):
        np.testing.assert_array_equal(np.asarray(view[b]), np.asarray(dense[0]))


def test_kv_copy_blocks_moves_rows():
    H, D, nb = 1, 2, 2
    B = 2
    p1 = 2 * B * nb + 1
    rng = np.random.default_rng(6)
    pool = jnp.array(rng.standard_normal((p1, H, M.KV_BLOCK, D)), jnp.float32)
    src = jnp.array([[0, 1], [2, 3]], jnp.int32)
    dst = jnp.array([[4, 5], [6, 7]], jnp.int32)
    (out,) = M.kv_copy_blocks(src, dst, pool)
    np.testing.assert_array_equal(
        np.asarray(M.pool_view(dst, out)), np.asarray(M.pool_view(src, pool))
    )
    # untouched rows (including the trash row) are preserved
    np.testing.assert_array_equal(np.asarray(out[:4]), np.asarray(pool[:4]))
    np.testing.assert_array_equal(np.asarray(out[8:]), np.asarray(pool[8:]))


# ----------------------------------------------------------------- programs


def test_paged_program_lowers_with_donated_kv(tmp_path):
    """score_paged_bN takes two [N, S/KV_BLOCK] tables + the dense score
    args + donated caches, and the aliasing survives lowering."""
    os.makedirs(tmp_path / "hlo", exist_ok=True)
    cfg = M.PRM_SMALL_CFG
    b = 4
    nw = len(M.weight_specs(cfg))
    nkv = 2 * cfg.n_layers
    s, nb = cfg.cache_len, cfg.cache_len // M.KV_BLOCK
    kv = [aot.spec(sh) for sh in M.kv_shapes(cfg, b)]

    def fn(*args):
        params = M.args_to_params(cfg, args[:nw])
        return M.prm_score_paged(cfg, params, *args[nw:])

    p = aot.export(
        str(tmp_path), f"toy_score_paged_b{b}", fn,
        [aot.spec(sh) for _, sh in M.weight_specs(cfg)]
        + [aot.spec((b, nb), jnp.int32), aot.spec((b, nb), jnp.int32),
           aot.spec((1,), jnp.int32), aot.spec((b,), jnp.int32),
           aot.spec((b, s), jnp.int32), aot.spec((b, M.SCORE_BLOCK), jnp.int32)]
        + kv,
        donate=range(nw + 6, nw + 6 + nkv),
    )
    txt = open(p).read()
    assert "HloModule" in txt and "ENTRY" in txt
    h, d = cfg.n_heads, cfg.head_dim
    assert f"s32[{b},{nb}]" in txt  # block-table params
    assert f"f32[{b},{h},{s},{d}]" in txt  # cache params/outputs
    assert "input_output_alias" in txt, "KV donation must survive lowering"


@pytest.mark.parametrize(
    "cfg", [M.LM_CFG, M.PRM_LARGE_CFG, M.PRM_SMALL_CFG], ids=lambda c: c.name
)
def test_export_paged_registers_manifest_entries(tmp_path, monkeypatch, cfg):
    """Every model gets block gather/append; the LM gets decode_paged, the
    PRMs score_paged — the program names rust/src/runtime keys on."""
    monkeypatch.setattr(aot, "BATCHES", [4])  # one variant keeps this fast
    os.makedirs(tmp_path / "hlo", exist_ok=True)
    programs = {}
    aot.export_paged(str(tmp_path), cfg, programs)
    assert "gather_blocks_b4" in programs
    assert "append_block_b4" in programs
    if cfg.scored:
        assert "score_paged_b4" in programs
        assert "decode_paged_b4" not in programs
    else:
        assert "decode_paged_b4" in programs
        assert "score_paged_b4" not in programs
    for path in programs.values():
        assert os.path.exists(path)


def test_manifest_carries_kv_block():
    """The Rust manifest parser keys paging on a positive top-level
    kv_block; main() must write it (a full export is too slow to run
    here, so pin the expression in the source)."""
    import inspect

    assert M.KV_BLOCK > 0
    assert '"kv_block": M.KV_BLOCK' in inspect.getsource(aot.main)


def test_blocktab_program_lowers_with_donated_pool(tmp_path, monkeypatch):
    """score_blocktab_bN takes one [N, S/KV_BLOCK] table + a per-slot
    frontier + the dense score args + donated *pool* arrays, and the
    aliasing survives lowering."""
    monkeypatch.setattr(aot, "POOL_BLOCKS", 32)
    os.makedirs(tmp_path / "hlo", exist_ok=True)
    cfg = M.PRM_SMALL_CFG
    b = 4
    nw = len(M.weight_specs(cfg))
    nkv = 2 * cfg.n_layers
    s, nb = cfg.cache_len, cfg.cache_len // M.KV_BLOCK
    pools = [aot.spec(sh) for sh in aot.pool_shapes(cfg)]

    def fn(*args):
        params = M.args_to_params(cfg, args[:nw])
        return M.prm_score_blocktab(cfg, params, *args[nw:])

    p = aot.export(
        str(tmp_path), f"toy_score_blocktab_b{b}", fn,
        [aot.spec(sh) for _, sh in M.weight_specs(cfg)]
        + [aot.spec((b, nb), jnp.int32), aot.spec((b,), jnp.int32),
           aot.spec((b,), jnp.int32), aot.spec((b, s), jnp.int32),
           aot.spec((b, M.SCORE_BLOCK), jnp.int32)]
        + pools,
        donate=range(nw + 5, nw + 5 + nkv),
    )
    txt = open(p).read()
    assert "HloModule" in txt and "ENTRY" in txt
    h, d = cfg.n_heads, cfg.head_dim
    assert f"s32[{b},{nb}]" in txt  # block-table param
    assert f"f32[33,{h},{M.KV_BLOCK},{d}]" in txt  # pool params/outputs (+1 trash row)
    assert "input_output_alias" in txt, "pool donation must survive lowering"


@pytest.mark.parametrize(
    "cfg", [M.LM_CFG, M.PRM_LARGE_CFG, M.PRM_SMALL_CFG], ids=lambda c: c.name
)
def test_export_blocktab_registers_manifest_entries(tmp_path, monkeypatch, cfg):
    """Every model gets adopt/copy; the LM gets decode_blocktab, the PRMs
    score_blocktab — the names rust/src/runtime keys block-native mode on."""
    monkeypatch.setattr(aot, "BATCHES", [4])  # one variant keeps this fast
    monkeypatch.setattr(aot, "POOL_BLOCKS", 32)
    os.makedirs(tmp_path / "hlo", exist_ok=True)
    programs = {}
    aot.export_blocktab(str(tmp_path), cfg, programs)
    assert "adopt_blocktab_b4" in programs
    assert "copy_blocktab_b4" in programs
    if cfg.scored:
        assert "score_blocktab_b4" in programs
        assert "decode_blocktab_b4" not in programs
    else:
        assert "decode_blocktab_b4" in programs
        assert "score_blocktab_b4" not in programs
    for path in programs.values():
        assert os.path.exists(path)


def test_manifest_carries_pool_blocks():
    """Block-native mode keys on a positive top-level pool_blocks whose
    value matches the exported pool shapes; main() must write it."""
    import inspect

    assert aot.POOL_BLOCKS > 0
    assert '"pool_blocks": POOL_BLOCKS' in inspect.getsource(aot.main)


def test_pool_blocks_default_tracks_memory_budget():
    """Geometry-derived sizing: more device memory -> more blocks, floor
    and ceiling respected, and the floor survives an impossible budget."""
    small = aot.pool_blocks_default(budget_bytes=128 * 1024 * 1024)
    big = aot.pool_blocks_default(budget_bytes=1024 * 1024 * 1024)
    assert 64 <= small <= big <= 4096
    assert aot.pool_blocks_default(budget_bytes=0) == 64
