"""Training-path tests: losses decrease, batches are well-formed, Adam works."""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import grammar as g
from compile import model as M
from compile import train as T


def test_lm_batch_shapes():
    rng = random.Random(0)
    toks, lens, mask = T.lm_batch(rng, 4, verbose=False)
    assert toks.shape == (4, T.SEQ) and mask.shape == (4, T.SEQ)
    assert (np.asarray(lens) <= T.SEQ).all()
    # mask covers only solution positions (strictly inside the sequence)
    m = np.asarray(mask)
    for i in range(4):
        assert m[i].sum() > 0
        assert m[i, int(lens[i]) :].sum() == 0


def test_prm_batch_labels_monotone():
    rng = random.Random(1)
    toks, lens, labels, mask = T.prm_batch(rng, 8)
    lab, msk = np.asarray(labels), np.asarray(mask)
    for i in range(8):
        sol = lab[i][msk[i] > 0]
        # once 0, stays 0
        if (sol == 0).any():
            first = int(np.argmax(sol == 0))
            assert (sol[first:] == 0).all()


def test_adam_step_moves_params():
    params = {"w": jnp.ones((3,)), "b": jnp.zeros((2,))}
    grads = {"w": jnp.ones((3,)), "b": jnp.ones((2,))}
    st = T.adam_init(params)
    new, st2 = T.adam_step(params, grads, st, 0.1)
    assert float(st2["t"]) == 1.0
    assert (np.asarray(new["w"]) < 1.0).all()


@pytest.mark.slow
def test_lm_loss_decreases_quickly():
    cfg = M.LM_CFG
    rng = random.Random(2)
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    opt = T.adam_init(params)

    @jax.jit
    def step(params, opt, toks, lens, mask):
        loss, grads = jax.value_and_grad(lambda p: T.lm_loss(cfg, p, toks, lens, mask))(params)
        params, opt = T.adam_step(params, grads, opt, 3e-3)
        return params, opt, loss

    losses = []
    for s in range(12):
        toks, lens, mask = T.lm_batch(rng, 8, verbose=False)
        params, opt, loss = step(params, opt, toks, lens, mask)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses


def test_prm_loss_finite():
    cfg = M.PRM_SMALL_CFG
    rng = random.Random(3)
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    toks, lens, labels, mask = T.prm_batch(rng, 4)
    loss = T.prm_loss(cfg, params, toks, lens, labels, mask)
    assert np.isfinite(float(loss))
    assert 0.2 < float(loss) < 2.0  # near log(2) at init


def test_cosine_lr_schedule():
    assert T._cosine_lr(0, 100, 1.0) == pytest.approx(1.0)
    assert T._cosine_lr(100, 100, 1.0) == pytest.approx(0.0, abs=1e-9)
    assert 0.4 < T._cosine_lr(50, 100, 1.0) < 0.6
