"""L1 Pallas kernels vs pure-jnp oracles — hypothesis sweeps over shapes.

This is the core correctness signal for the kernel layer: the exact same
kernel code is lowered into the AOT artifacts the Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import causal_attention
from compile.kernels.prm_score import prm_prefix_score

SET = dict(max_examples=12, deadline=None)


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


# ------------------------------------------------------------- attention


@settings(**SET)
@given(
    b=st.integers(1, 3),
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([16, 64, 128, 256]),
    d=st.sampled_from([8, 16, 24]),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref_nolen(b, h, s, d, seed):
    q, k, v = (rand(seed + i, (b, h, s, d)) for i in range(3))
    got = causal_attention(q, k, v)
    want = ref.causal_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


@settings(**SET)
@given(
    b=st.integers(1, 3),
    s=st.sampled_from([64, 128, 256]),
    seed=st.integers(0, 2**16),
    data=st.data(),
)
def test_attention_matches_ref_with_lengths(b, s, seed, data):
    h, d = 4, 16
    q, k, v = (rand(seed + i, (b, h, s, d)) for i in range(3))
    lens = jnp.array(
        [data.draw(st.integers(1, s), label=f"len{i}") for i in range(b)], jnp.int32
    )
    got = causal_attention(q, k, v, lens)
    want = ref.causal_attention_ref(q, k, v, lens)
    # padded query rows are garbage in both; compare only valid rows
    for bi in range(b):
        L = int(lens[bi])
        np.testing.assert_allclose(
            np.asarray(got)[bi, :, :L], np.asarray(want)[bi, :, :L], atol=2e-5, rtol=2e-5
        )


@pytest.mark.parametrize("block", [32, 64, 128])
def test_attention_block_size_invariance(block):
    q, k, v = (rand(i, (2, 4, 256, 16)) for i in range(3))
    a = causal_attention(q, k, v, block_q=block, block_k=block)
    b = causal_attention(q, k, v, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


def test_attention_causality():
    """Perturbing a future key must not change earlier outputs."""
    q, k, v = (rand(i, (1, 2, 64, 16)) for i in range(3))
    out1 = np.asarray(causal_attention(q, k, v))
    k2 = k.at[:, :, 50, :].add(100.0)
    v2 = v.at[:, :, 50, :].add(100.0)
    out2 = np.asarray(causal_attention(q, k2, v2))
    np.testing.assert_allclose(out1[:, :, :50], out2[:, :, :50], atol=1e-6)
    assert np.abs(out1[:, :, 50:] - out2[:, :, 50:]).max() > 1e-3


# ------------------------------------------------------------ prm scorer


@settings(**SET)
@given(
    b=st.integers(1, 4),
    s=st.sampled_from([8, 64, 256]),
    dm=st.sampled_from([16, 48, 96]),
    seed=st.integers(0, 2**16),
)
def test_prm_prefix_score_matches_ref(b, s, dm, seed):
    hid = rand(seed, (b, s, dm))
    w = rand(seed + 1, (dm,), 0.3)
    bias = 0.1
    got = prm_prefix_score(hid, w, bias)
    want = ref.prm_prefix_score_ref(hid, w, bias)
    for gname, a, e in zip(("score", "cummin", "cummean"), got, want):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(e), atol=2e-6, rtol=2e-6, err_msg=gname
        )


def test_prm_prefix_score_properties():
    hid = rand(0, (2, 128, 48))
    w = rand(1, (48,), 0.3)
    score, cmin, cmean = (np.asarray(x) for x in prm_prefix_score(hid, w, 0.0))
    # scores are probabilities
    assert (score > 0).all() and (score < 1).all()
    # cummin is monotone nonincreasing and a lower bound of score
    assert (np.diff(cmin, axis=1) <= 1e-7).all()
    assert (cmin <= score + 1e-7).all()
    # cummean at t=0 equals score at t=0
    np.testing.assert_allclose(cmean[:, 0], score[:, 0], atol=1e-6)


def test_prm_prefix_score_is_prefix_consistent():
    """Partial-reward semantics: the aggregate at tau only depends on the
    first tau positions — the property early rejection relies on."""
    hid = rand(3, (1, 64, 48))
    w = rand(4, (48,), 0.3)
    _, cmin_full, cmean_full = (np.asarray(x) for x in prm_prefix_score(hid, w, 0.0))
    tau = 20
    hid2 = hid.at[:, tau:, :].set(99.0)  # wreck the future
    _, cmin2, cmean2 = (np.asarray(x) for x in prm_prefix_score(hid2, w, 0.0))
    np.testing.assert_allclose(cmin_full[:, :tau], cmin2[:, :tau], atol=1e-6)
    np.testing.assert_allclose(cmean_full[:, :tau], cmean2[:, :tau], atol=1e-6)
