"""Grammar / task-substrate tests: tokenization, gold traces, validator."""

import random

import pytest

from compile import grammar as g


def test_vocab_size_and_strings():
    assert g.VOCAB_SIZE == 24
    assert len(g.TOKEN_STRS) == g.VOCAB_SIZE
    assert g.TOKEN_STRS[g.PLUS] == "+"
    assert g.TOKEN_STRS[g.ANS] == "A"
    assert g.TOKEN_STRS[g.DIG0 + 7] == "7"


def test_two_digits_roundtrip():
    for v in range(100):
        t = g.two_digits(v)
        assert len(t) == 2
        assert (t[0] - g.DIG0) * 10 + (t[1] - g.DIG0) == v
    assert g.two_digits(105) == g.two_digits(5)


def test_apply_op_mod():
    assert g.apply_op(99, g.PLUS, 3) == 2
    assert g.apply_op(1, g.MINUS, 4) == 97
    assert g.apply_op(25, g.TIMES, 5) == 25
    with pytest.raises(ValueError):
        g.apply_op(1, g.EQ, 1)


def test_problem_answer_chains():
    p = g.Problem(v0=10, ops=[(g.PLUS, 5), (g.TIMES, 3), (g.MINUS, 9)])
    assert p.answer == ((10 + 5) * 3 - 9) % 100
    toks = p.prompt_tokens()
    assert toks[0] == g.BOS and toks[-1] == g.SEP
    # BOS vv (op d ;)*3 '>'
    assert len(toks) == 2 + 2 + 3 * 3
    assert g.detok(toks) == "<bos>10+5;*3;-9;>"


@pytest.mark.parametrize("bench", list(g.BENCHMARKS))
def test_benchmark_problems_fit(bench):
    rng = random.Random(7)
    for _ in range(300):
        p = g.gen_problem(rng, bench)
        seq = g.full_sequence(p, verbose=True, rng=rng)
        assert len(seq) <= g.MAX_SEQ
        assert len(p.prompt_tokens()) <= g.PROMPT_PAD


@pytest.mark.parametrize("verbose", [False, True])
def test_gold_traces_validate(verbose):
    rng = random.Random(11)
    for _ in range(200):
        p = g.gen_mixed_problem(rng)
        sol = g.solution_tokens(p, verbose=verbose, rng=rng)
        labels = g.label_positions(p, sol)
        assert all(labels), g.detok(sol)
        assert g.extract_answer(sol) == p.answer


@pytest.mark.parametrize("verbose", [False, True])
def test_corrupted_traces_detected(verbose):
    rng = random.Random(13)
    for _ in range(200):
        p = g.gen_mixed_problem(rng)
        bad = g.corrupt_solution(p, rng, verbose=verbose)
        labels = g.label_positions(p, bad)
        assert not all(labels), g.detok(bad)


def test_labels_monotone():
    """'Correct so far' must never recover after the first error."""
    rng = random.Random(17)
    for _ in range(200):
        p = g.gen_mixed_problem(rng)
        bad = g.corrupt_solution(p, rng, verbose=rng.random() < 0.5)
        labels = g.label_positions(p, bad)
        first_bad = labels.index(0)
        assert all(l == 0 for l in labels[first_bad:])


def test_validator_rejects_malformed():
    p = g.Problem(v0=12, ops=[(g.PLUS, 2)])
    st = g.ValidatorState(v=p.v0)
    # wrong head value
    for t in g.two_digits(99):
        st.feed(t)
    assert not st.ok


def test_validator_wrong_answer():
    p = g.Problem(v0=12, ops=[(g.PLUS, 2)])
    sol = g.solution_tokens(p)
    # flip the final answer's units digit: the mismatch is only checkable
    # once the answer group completes (at EOS)
    sol2 = list(sol)
    sol2[-2] = g.DIG0 + ((sol2[-2] - g.DIG0 + 1) % 10)
    labels = g.label_positions(p, sol2)
    assert labels[-2] == 1 and labels[-1] == 0


def test_extract_answer_none():
    assert g.extract_answer([g.BOS, g.DIG0, g.EOS]) is None


def test_wrong_op_step_detected():
    """A step applying the wrong operation (internally consistent) must be
    rejected at the op token — the dominant real LM failure mode."""
    p = g.Problem(v0=12, ops=[(g.TIMES, 6)])
    wrong = g.Problem(v0=12, ops=[(g.PLUS, 6)])
    trace = g.solution_tokens(wrong)
    labels = g.label_positions(p, trace)
    assert labels[0] and labels[1] and not labels[2]


def test_early_answer_detected():
    p = g.Problem(v0=10, ops=[(g.PLUS, 2), (g.PLUS, 3)])
    one = g.Problem(v0=10, ops=[(g.PLUS, 2)])
    labels = g.label_positions(p, g.solution_tokens(one))
    assert not all(labels)


def test_scratch_items():
    assert g.scratch_items(98, g.PLUS, 3) == [99, 0, 1]
    assert g.scratch_items(1, g.MINUS, 2) == [0, 99]
    assert g.scratch_items(25, g.TIMES, 4) == [25, 50, 75, 0]


def test_benchmark_difficulty_ordering():
    """aime-s must have more steps than satmath-s (difficulty gradient)."""
    rng = random.Random(3)
    ks = {b: g.gen_problem(rng, b).ops for b in g.BENCHMARKS}
    assert len(ks["satmath-s"]) < len(ks["math500-s"]) < len(ks["aime-s"])
