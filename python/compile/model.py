"""L2: JAX model definitions — decoder LM and decoder PRM with KV caches.

These are the compute graphs that `aot.py` lowers to HLO text for the Rust
runtime. Weights are *arguments* (not baked constants) so one HLO program
serves every weight set of the same architecture (lm-concise and lm-verbose
share all LM programs); Rust uploads weights.bin once into device buffers
and threads the KV cache through `execute_b` without host copies.

Entry points (all pure, shapes static per export variant):
  lm_prefill      prompt -> KV cache (b=1) + last-token logits
  lm_decode_block sample DECODE_BLOCK tokens with in-graph categorical
                  sampling (temperature + per-slot RNG keys are args)
  prm_prefill     prompt -> PRM KV cache (b=1)
  prm_score_block incremental per-token reward scores for new tokens
  prm_fullseq     whole-sequence scoring via the Pallas prefix kernel
                  (correlation studies, Fig. 2 / Fig. 4)
  kv_gather       beam prune/expand slot permutation, on device
  kv_broadcast    b=1 prompt KV -> N beam slots, on device
  paged kv        KV_BLOCK-granular ops for the Rust block pool:
                  kv_gather_blocks / kv_append_block permute or fill
                  blocks, lm_decode_paged / prm_score_paged wrap the dense
                  block stack in view/store block gathers
  block-native    the cache lives in one shared pool array per shard:
                  lm_decode_blocktab / prm_score_blocktab take
                  (block_table, per-slot frontier) operands and write only
                  the frontier span; kv_adopt_blocks installs prefill
                  output, kv_copy_blocks moves blocks inside the pool

KV cache discipline (the L3 contract; see rust/src/runtime/):
  * The cache is 2*L separate arrays [B, H, S, D] (k and v per layer) —
    separate args alias cleanly under donation.
  * Writes happen at a *lockstep physical frontier*: every call writes its
    whole token block at positions [pos, pos+T) for all slots, via
    dynamic_update_slice with a scalar start (no scatter => XLA can update
    in place). Slots whose logical sequences diverged (step boundaries at
    different offsets) simply have junk at some physical positions.
  * Attendability is an explicit `valid` bitmask [B, S] maintained by the
    Rust coordinator: junk/pad positions are 0 and never attended.
  * RoPE uses *logical* per-slot positions (an i32[B] argument), so relative
    geometry matches training even when physical slots contain gaps.
  * Within a block, fresh tokens attend to the cache (mask = valid) plus the
    block's own earlier tokens held in registers; the cache is written once
    per layer-plane at block end (4x less DUS traffic than per-token).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.attention import causal_attention
from .kernels.prm_score import prm_prefix_score
from .kernels.ref import causal_attention_ref
from . import grammar

SEQ_TRAIN = grammar.MAX_SEQ  # 256: training / full-sequence scoring width
PROMPT_PAD = grammar.PROMPT_PAD
DECODE_BLOCK = 4  # tokens sampled per decode call (amortizes PJRT overhead)
SCORE_BLOCK = 16  # tokens scored per PRM call
NEG_INF = -1e30


@dataclass(frozen=True)
class ModelCfg:
    name: str
    vocab: int = grammar.VOCAB_SIZE
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 4
    ffn: int = 256
    cache_len: int = 384  # serving KV cache length (>= trace + junk margin)
    scored: bool = False  # PRM: per-position reward head instead of LM head

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        p = self.vocab * self.d_model  # embedding
        per_layer = 4 * self.d_model * self.d_model + 2 * self.d_model * self.ffn
        per_layer += 4 * self.d_model  # ln scales/biases
        p += self.n_layers * per_layer + 2 * self.d_model  # final ln
        if self.scored:
            p += self.d_model + 1
        else:
            p += self.d_model * self.vocab
        return p

    def flops_per_token(self) -> int:
        """Analytic forward cost per token (the FLOPs ledger's unit)."""
        return 2 * self.param_count()


LM_CFG = ModelCfg(name="lm")
# PRM caches are longer: SCORE_BLOCK-aligned feeding wastes up to 15
# positions per scoring round (see rust/src/coordinator/scorer.rs).
PRM_LARGE_CFG = ModelCfg(name="prm-large", d_model=96, n_layers=3, ffn=384, scored=True, cache_len=512)
PRM_SMALL_CFG = ModelCfg(name="prm-small", d_model=48, n_layers=2, ffn=192, scored=True, cache_len=512)


# ----------------------------------------------------------------- params


def weight_specs(cfg: ModelCfg) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) list — the weights.bin / HLO arg order."""
    d, f, v = cfg.d_model, cfg.ffn, cfg.vocab
    specs: List[Tuple[str, Tuple[int, ...]]] = [("emb", (v, d))]
    for i in range(cfg.n_layers):
        specs += [
            (f"l{i}.ln1_s", (d,)), (f"l{i}.ln1_b", (d,)),
            (f"l{i}.wq", (d, d)), (f"l{i}.wk", (d, d)),
            (f"l{i}.wv", (d, d)), (f"l{i}.wo", (d, d)),
            (f"l{i}.ln2_s", (d,)), (f"l{i}.ln2_b", (d,)),
            (f"l{i}.w1", (d, f)), (f"l{i}.w2", (f, d)),
        ]
    specs += [("lnf_s", (d,)), ("lnf_b", (d,))]
    if cfg.scored:
        specs += [("head_w", (d,)), ("head_b", (1,))]
    else:
        specs += [("head", (d, v))]
    return specs


def init_params(cfg: ModelCfg, key) -> Dict[str, jnp.ndarray]:
    params = {}
    for name, shape in weight_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("_s"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith("_b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            std = 1.0 / math.sqrt(shape[0])
            params[name] = jax.random.normal(sub, shape, jnp.float32) * std
    return params


def params_to_args(cfg: ModelCfg, params: Dict[str, jnp.ndarray]) -> List[jnp.ndarray]:
    return [params[name] for name, _ in weight_specs(cfg)]


def args_to_params(cfg: ModelCfg, args) -> Dict[str, jnp.ndarray]:
    return {name: a for (name, _), a in zip(weight_specs(cfg), args)}


# ----------------------------------------------------------------- layers


def layer_norm(x, s, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * lax.rsqrt(var + eps) * s + b


def rope(x, positions):
    """Rotary embedding. x: [..., T, H, D]; positions: [..., T] (logical)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., T, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def kv_shapes(cfg: ModelCfg, batch: int) -> List[Tuple[int, ...]]:
    """Shapes of the 2*L cache args, order [l0.k, l0.v, l1.k, l1.v, ...]."""
    return [(batch, cfg.n_heads, cfg.cache_len, cfg.head_dim)] * (2 * cfg.n_layers)


# ----------------------------------------------------------------- prefill


def _stack_fullseq(cfg, params, tokens, lengths, use_kernel=True):
    """Run the stack over a full padded window (training / prefill).

    Returns (hidden [B, T, d], k_list, v_list) with per-layer roped K/V
    [B, H, T, D] so callers can install them into a serving cache.
    `use_kernel=False` selects the differentiable jnp reference attention
    (Pallas kernels have no autodiff rule) — training only; the AOT export
    path always runs the L1 kernel."""
    bsz, t = tokens.shape
    h = params["emb"][tokens]
    pos = jnp.arange(t, dtype=jnp.int32)[None, :].repeat(bsz, 0)
    ks, vs = [], []
    for i in range(cfg.n_layers):
        x = layer_norm(h, params[f"l{i}.ln1_s"], params[f"l{i}.ln1_b"])
        q = (x @ params[f"l{i}.wq"]).reshape(bsz, t, cfg.n_heads, cfg.head_dim)
        k = (x @ params[f"l{i}.wk"]).reshape(bsz, t, cfg.n_heads, cfg.head_dim)
        v = (x @ params[f"l{i}.wv"]).reshape(bsz, t, cfg.n_heads, cfg.head_dim)
        q, k = rope(q, pos), rope(k, pos)
        qh, kh, vh = (a.transpose(0, 2, 1, 3) for a in (q, k, v))
        # L1 Pallas kernel on the prefill path (the big contraction).
        attn = causal_attention if use_kernel else causal_attention_ref
        o = attn(qh, kh, vh, lengths)
        o = o.transpose(0, 2, 1, 3).reshape(bsz, t, cfg.d_model)
        h = h + o @ params[f"l{i}.wo"]
        x = layer_norm(h, params[f"l{i}.ln2_s"], params[f"l{i}.ln2_b"])
        h = h + jax.nn.gelu(x @ params[f"l{i}.w1"]) @ params[f"l{i}.w2"]
        ks.append(kh)
        vs.append(vh)
    h = layer_norm(h, params["lnf_s"], params["lnf_b"])
    return h, ks, vs


def _install_prefix(cfg, ks, vs, bsz):
    """Place prompt K/V at physical positions [0, PROMPT_PAD) of a fresh
    serving cache."""
    out = []
    for i in range(cfg.n_layers):
        for a in (ks[i], vs[i]):
            cache = jnp.zeros((bsz, cfg.n_heads, cfg.cache_len, cfg.head_dim), jnp.float32)
            out.append(lax.dynamic_update_slice(cache, a, (0, 0, 0, 0)))
    return out


def lm_prefill(cfg: ModelCfg, params, tokens, lengths):
    """tokens: [1, PROMPT_PAD] i32; lengths: [1] i32.
    Returns (logits_last [1, V], *kv arrays [1, H, S, D])."""
    h, ks, vs = _stack_fullseq(cfg, params, tokens, lengths)
    last = jnp.take_along_axis(h, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)
    logits = last[:, 0, :] @ params["head"]
    return (logits, *_install_prefix(cfg, ks, vs, tokens.shape[0]))


def prm_prefill(cfg: ModelCfg, params, tokens, lengths):
    """Same as lm_prefill but for the PRM; returns only the cache arrays."""
    _, ks, vs = _stack_fullseq(cfg, params, tokens, lengths)
    return tuple(_install_prefix(cfg, ks, vs, tokens.shape[0]))


# ----------------------------------------------------------------- decode


def _block_stack(cfg, params, kvs, pos_phys, pos_log, valid, n_tokens, mode, tokens=None, temp=None, keys=None, keys_init_tok=None, frontier=None):
    """Shared autoregressive block driver as a `lax.scan`.

    One scan step = one token through the whole stack: embed, per-layer
    (LN -> qkv -> RoPE at *logical* positions -> write K/V into the cache at
    the *physical* frontier via dynamic_update_slice -> masked attention ->
    MLP), final LN, then either sample the next token (mode="decode",
    in-graph categorical with per-slot keys) or emit a reward score
    (mode="score", inputs come from `tokens`).

    scan keeps the compiled HLO one-body-sized: the unrolled variant made
    XLA CPU spend minutes compiling the 16-token x n-layer graph.

    Attention mask per sub-step s: `valid` (committed clean positions)
    OR physical positions [pos_phys, pos_phys+s] (this block's own prefix).

    `frontier` ([B] i32) selects the block-native write discipline: each
    slot writes at its *own* frontier (a where-select at per-slot positions
    instead of the scalar dynamic_update_slice), and the block's own-prefix
    mask window is per-slot too. With a uniform frontier the computed
    values are bitwise-identical to the scalar path — every cell holds the
    same numbers, and the attention contractions are the same ops over
    elementwise-equal arrays — which is what lets gang members keep their
    own pacing (no union gap) without perturbing solo outcomes.
    Returns (outputs [B, T], new kv list).
    """
    bsz = valid.shape[0]
    s = cfg.cache_len
    scale = 1.0 / math.sqrt(cfg.head_dim)
    p0 = pos_phys[0] if frontier is None else None
    idx = lax.broadcasted_iota(jnp.int32, (1, s), 1)  # [1, S]
    vmask = valid > 0  # [B, S]
    t_eff = jnp.maximum(temp[0], 1e-2) if temp is not None else None

    def body(carry, step):
        tok, kvs = carry
        if mode == "score":
            tok = tokens[:, step]
        h = params["emb"][tok]  # [B, d]
        logpos = pos_log + step
        if frontier is None:
            phys = p0 + step
            mask = vmask | ((idx >= p0) & (idx <= phys))  # [B, S]
        else:
            wpos = frontier + step  # [B] per-slot write positions
            mask = vmask | ((idx >= frontier[:, None]) & (idx <= wpos[:, None]))
            hit = (idx == wpos[:, None])[:, None, :, None]  # [B, 1, S, 1]
        new_kvs = list(kvs)
        for i in range(cfg.n_layers):
            x = layer_norm(h, params[f"l{i}.ln1_s"], params[f"l{i}.ln1_b"])
            q = (x @ params[f"l{i}.wq"]).reshape(bsz, cfg.n_heads, cfg.head_dim)
            k = (x @ params[f"l{i}.wk"]).reshape(bsz, cfg.n_heads, cfg.head_dim)
            v = (x @ params[f"l{i}.wv"]).reshape(bsz, cfg.n_heads, cfg.head_dim)
            q = rope(q[:, None], logpos[:, None])[:, 0]
            k = rope(k[:, None], logpos[:, None])[:, 0]
            if frontier is None:
                kk = lax.dynamic_update_slice(new_kvs[2 * i], k[:, :, None, :], (0, 0, phys, 0))
                vv = lax.dynamic_update_slice(new_kvs[2 * i + 1], v[:, :, None, :], (0, 0, phys, 0))
            else:
                kk = jnp.where(hit, k[:, :, None, :], new_kvs[2 * i])
                vv = jnp.where(hit, v[:, :, None, :], new_kvs[2 * i + 1])
            new_kvs[2 * i] = kk
            new_kvs[2 * i + 1] = vv
            sc = jnp.einsum("bhd,bhsd->bhs", q, kk) * scale
            sc = jnp.where(mask[:, None, :], sc, NEG_INF)
            p = jax.nn.softmax(sc, axis=-1)
            o = jnp.einsum("bhs,bhsd->bhd", p, vv)
            h = h + o.reshape(bsz, cfg.d_model) @ params[f"l{i}.wo"]
            x = layer_norm(h, params[f"l{i}.ln2_s"], params[f"l{i}.ln2_b"])
            h = h + jax.nn.gelu(x @ params[f"l{i}.w1"]) @ params[f"l{i}.w2"]
        h = layer_norm(h, params["lnf_s"], params["lnf_b"])
        if mode == "decode":
            logits = h @ params["head"]
            kdata = keys.astype(jnp.uint32)
            folded = jax.vmap(
                lambda kk_: jax.random.fold_in(jax.random.wrap_key_data(kk_), step)
            )(kdata)
            nxt = jax.vmap(jax.random.categorical)(folded, logits / t_eff).astype(jnp.int32)
            return (nxt, tuple(new_kvs)), nxt
        else:
            score = 1.0 / (1.0 + jnp.exp(-(h @ params["head_w"] + params["head_b"][0])))
            return (tok, tuple(new_kvs)), score

    init_tok = tokens[:, 0] if mode == "score" else keys_init_tok
    (_, final_kvs), outs = lax.scan(
        body, (init_tok, tuple(kvs)), jnp.arange(n_tokens, dtype=jnp.int32)
    )
    return jnp.transpose(outs, (1, 0)), list(final_kvs)


def lm_decode_block(cfg: ModelCfg, params, pos_phys, pos_log, valid, tok, temp, keys, *kvs):
    """Sample DECODE_BLOCK tokens per slot with in-graph categorical sampling.

    pos_phys: [1] i32 lockstep write frontier; pos_log: [B] logical positions;
    valid: [B, S] i32 attendability bitmask; tok: [B] previous token;
    temp: [1] f32; keys: [B, 2] u32 per-slot RNG keys.
    Returns (tokens [B, DECODE_BLOCK] i32, *kv').
    """
    outs, new_kvs = _block_stack(
        cfg, params, list(kvs), pos_phys, pos_log, valid, DECODE_BLOCK,
        mode="decode", temp=temp, keys=keys, keys_init_tok=tok,
    )
    return (outs, *new_kvs)


def prm_score_block(cfg: ModelCfg, params, pos_phys, pos_log, valid, tokens, *kvs):
    """Incremental PRM scoring: feed SCORE_BLOCK new tokens per slot.

    tokens: [B, SCORE_BLOCK] i32 (PAD beyond each slot's valid span; the
    host only reads scores it knows are valid).
    Returns (scores [B, SCORE_BLOCK] f32 in (0,1), *kv').
    """
    outs, new_kvs = _block_stack(
        cfg, params, list(kvs), pos_phys, pos_log, valid, SCORE_BLOCK,
        mode="score", tokens=tokens,
    )
    return (outs, *new_kvs)


# ----------------------------------------------------------- full-sequence


def prm_fullseq(cfg: ModelCfg, params, tokens, lengths):
    """Whole-sequence PRM scoring through the Pallas prefix-score kernel.

    tokens: [B, SEQ_TRAIN] i32; lengths: [B] i32.
    Returns (score, cummin, cummean) each [B, SEQ_TRAIN] — the correlation
    studies (Fig. 2 / Fig. 4) read partial rewards at arbitrary tau from one
    call.
    """
    h, _, _ = _stack_fullseq(cfg, params, tokens, lengths)
    return prm_prefix_score(h, params["head_w"], params["head_b"])


def lm_logits_fullseq(cfg: ModelCfg, params, tokens, lengths):
    """Teacher-forcing logits for training. tokens: [B, S]."""
    h, _, _ = _stack_fullseq(cfg, params, tokens, lengths, use_kernel=False)
    return h @ params["head"]


def prm_logits_fullseq(cfg: ModelCfg, params, tokens, lengths):
    """Per-position reward logits for training (BCE applied outside)."""
    h, _, _ = _stack_fullseq(cfg, params, tokens, lengths, use_kernel=False)
    return h @ params["head_w"] + params["head_b"][0]


# ----------------------------------------------------------------- kv ops


def kv_gather(idx, *kvs):
    """Beam slot permutation on device. idx: [B] i32 source slot per dest."""
    return tuple(jnp.take(kv, idx, axis=0) for kv in kvs)


def kv_broadcast(batch: int, *kvs):
    """Replicate b=1 prompt KV into `batch` beam slots."""
    out = []
    for kv in kvs:
        _, h, s, d = kv.shape
        out.append(jnp.broadcast_to(kv, (batch, h, s, d)) + 0.0)
    return tuple(out)


def kv_compact(idx, *kvs):
    """Per-slot re-compaction along the cache axis: `out[b, h, p, d] =
    kv[b, h, idx[b, p], d]` with a host-computed `[B, S]` index matrix that
    packs each slot's valid positions down to a dense prefix (original
    order preserved; dest positions past a slot's dense length replay
    junk the packed validity row masks out). This is the device half of
    frontier re-compaction: ganged requests spend physical positions at
    the fastest member's rate, and this gather reclaims the junk gap so
    the lockstep frontier can drop back to the max dense length. KV args
    are donated at export (same aliasing as decode/score)."""
    out = []
    for kv in kvs:
        out.append(jnp.take_along_axis(kv, idx[:, None, :, None], axis=2))
    return tuple(out)


KV_BLOCK = 32  # tokens per paged-KV block; must divide every cache_len


def paged_view(idx, kv):
    """Reorder one cache array's KV_BLOCK-wide blocks per slot: output
    block j of slot b is input block `idx[b, j]`. idx: [B, S/KV_BLOCK] i32,
    a per-slot block permutation (identity entries for untouched blocks).
    A pure take_along_axis gather — no scatter, so the donated buffer
    still aliases in place."""
    b, h, s, d = kv.shape
    nb = s // KV_BLOCK
    blocks = kv.reshape(b, h, nb, KV_BLOCK, d)
    out = jnp.take_along_axis(blocks, idx[:, None, :, None, None], axis=2)
    return out.reshape(b, h, s, d)


def kv_gather_blocks(idx, *kvs):
    """Block-granular cache permutation — the paged analogue of
    `kv_compact`. The host sends each slot's block table (logical block ->
    physical block) and the device materializes the logical-dense view;
    with the inverse table it stores a dense view back into pool layout."""
    return tuple(paged_view(idx, kv) for kv in kvs)


def kv_append_block(dst, *arrays):
    """Write one fresh KV_BLOCK-wide span per slot into its destination
    block: `out[b, :, dst[b]*KV_BLOCK:(dst[b]+1)*KV_BLOCK, :] = span[b]`.
    `arrays` is 2*L spans [B, H, KV_BLOCK, D] followed by the 2*L caches
    [B, H, S, D] (same layer order, like `kv_merge`). A one-hot select
    over blocks — no scatter."""
    n = len(arrays) // 2
    assert len(arrays) == 2 * n, "kv_append_block wants spans then caches"
    out = []
    for span, kv in zip(arrays[:n], arrays[n:]):
        b, h, s, d = kv.shape
        nb = s // KV_BLOCK
        blocks = kv.reshape(b, h, nb, KV_BLOCK, d)
        hit = lax.broadcasted_iota(jnp.int32, (b, nb), 1) == dst[:, None]
        mixed = jnp.where(hit[:, None, :, None, None], span[:, :, None, :, :], blocks)
        out.append(mixed.reshape(b, h, s, d))
    return tuple(out)


def lm_decode_paged(cfg: ModelCfg, params, view_idx, store_idx, pos_phys, pos_log, valid, tok, temp, keys, *kvs):
    """Paged decode: gather each slot's logical-dense cache view through
    its block table, run the dense block stack (the frontier write lands
    inside the view), then permute blocks back to pool layout through the
    inverse table. `pos_phys`/`valid` are in logical-view coordinates;
    everything between the two gathers is byte-for-byte the dense
    `lm_decode_block` graph, which is what makes paged solves
    byte-identical to dense ones."""
    view = [paged_view(view_idx, kv) for kv in kvs]
    outs, new_kvs = _block_stack(
        cfg, params, view, pos_phys, pos_log, valid, DECODE_BLOCK,
        mode="decode", temp=temp, keys=keys, keys_init_tok=tok,
    )
    return (outs, *(paged_view(store_idx, kv) for kv in new_kvs))


def prm_score_paged(cfg: ModelCfg, params, view_idx, store_idx, pos_phys, pos_log, valid, tokens, *kvs):
    """Paged analogue of `prm_score_block` (see `lm_decode_paged`)."""
    view = [paged_view(view_idx, kv) for kv in kvs]
    outs, new_kvs = _block_stack(
        cfg, params, view, pos_phys, pos_log, valid, SCORE_BLOCK,
        mode="score", tokens=tokens,
    )
    return (outs, *(paged_view(store_idx, kv) for kv in new_kvs))


# ----------------------------------------------------- block-native (tables)
#
# The gather-bracketed paged programs above still materialize the dense
# view on every call and force the runtime to keep one device cache per
# request. Block-native programs instead take the shared per-shard block
# pool itself as an argument — 2*L arrays [P+1, H, KV_BLOCK, D], where row
# P is a trash block that absorbs writes from padded table entries and
# dead slots — plus a per-slot block table and a *per-slot* write
# frontier. Cross-request merge/split then needs no device call at all
# (the Rust side concatenates table rows), and each gang member keeps its
# own frontier, so the union junk gap the compaction machinery existed to
# reclaim is never created.


def pool_view(table, pool):
    """Gather one logical-dense view [B, H, S, D] out of a shared block
    pool [P+1, H, KV_BLOCK, D]: logical block j of slot b is pool row
    `table[b, j]`. A pure `take` — bitwise-exact, like `paged_view`."""
    b, nb = table.shape
    _, h, kb, d = pool.shape
    blocks = jnp.take(pool, table, axis=0)  # [B, nb, H, KB, D]
    return blocks.transpose(0, 2, 1, 3, 4).reshape(b, h, nb * kb, d)


def _pool_store_span(pool, table, frontier, view, n):
    """Scatter view positions [frontier_b, frontier_b + n) of every slot
    back into its pool rows — the only cells a block call writes, so the
    full-cache store gather of the paged path disappears. Slots whose
    table entries point at the trash row scatter harmlessly there."""
    b, h, s, d = view.shape
    for t in range(n):
        p = frontier + t  # [B]
        blk = jnp.take_along_axis(table, (p // KV_BLOCK)[:, None], axis=1)[:, 0]
        off = p % KV_BLOCK
        span = jnp.broadcast_to(p[:, None, None, None], (b, h, 1, d))
        vals = jnp.take_along_axis(view, span, axis=2)[:, :, 0, :]  # [B, H, D]
        pool = pool.at[blk, :, off, :].set(vals)
    return pool


def lm_decode_blocktab(cfg: ModelCfg, params, table, frontier, pos_log, valid, tok, temp, keys, *pools):
    """Block-native decode. table: [B, S/KV_BLOCK] i32 pool row ids
    (trash-padded past each slot's allocation); frontier: [B] i32 per-slot
    write frontier; remaining args as `lm_decode_block`; `pools` are the
    shared 2*L pool arrays (donated). With a uniform frontier the sampled
    tokens and written cells are bitwise-identical to the dense program."""
    views = [pool_view(table, p) for p in pools]
    outs, new_views = _block_stack(
        cfg, params, views, None, pos_log, valid, DECODE_BLOCK,
        mode="decode", temp=temp, keys=keys, keys_init_tok=tok, frontier=frontier,
    )
    new_pools = [
        _pool_store_span(p, table, frontier, v, DECODE_BLOCK)
        for p, v in zip(pools, new_views)
    ]
    return (outs, *new_pools)


def prm_score_blocktab(cfg: ModelCfg, params, table, frontier, pos_log, valid, tokens, *pools):
    """Block-native analogue of `prm_score_block` (see `lm_decode_blocktab`)."""
    views = [pool_view(table, p) for p in pools]
    outs, new_views = _block_stack(
        cfg, params, views, None, pos_log, valid, SCORE_BLOCK,
        mode="score", tokens=tokens, frontier=frontier,
    )
    new_pools = [
        _pool_store_span(p, table, frontier, v, SCORE_BLOCK)
        for p, v in zip(pools, new_views)
    ]
    return (outs, *new_pools)


def kv_adopt_blocks(table, *arrays):
    """Install a dense b=1 cache (the prefill output) into pool rows for
    every slot: `pool[table[s, j]] = dense_block_j` — prefill + broadcast
    in one scatter. `arrays` is 2*L dense caches [1, H, S, D] followed by
    the 2*L pool arrays [P+1, H, KV_BLOCK, D] (donated)."""
    n = len(arrays) // 2
    assert len(arrays) == 2 * n, "kv_adopt_blocks wants dense caches then pools"
    b, nb = table.shape
    out = []
    for kv, pool in zip(arrays[:n], arrays[n:]):
        _, h, s, d = kv.shape
        blocks = kv[0].reshape(h, s // KV_BLOCK, KV_BLOCK, d).transpose(1, 0, 2, 3)
        src = jnp.broadcast_to(blocks[None], (b, nb, h, KV_BLOCK, d))
        out.append(pool.at[table.reshape(-1)].set(src.reshape(b * nb, h, KV_BLOCK, d)))
    return tuple(out)


def kv_copy_blocks(src_table, dst_table, *pools):
    """Physical block copy inside the pool: `pool[dst_table[s, j]] =
    pool[src_table[s, j]]`. One program per batch variant replaces the
    whole gather/resize family in block-native mode — permutation, beam
    expansion, and cross-variant resize are all just host-chosen source
    rows, since the pool is shared across every request on the shard."""
    flat_src, flat_dst = src_table.reshape(-1), dst_table.reshape(-1)
    out = []
    for pool in pools:
        vals = jnp.take(pool, flat_src, axis=0)
        out.append(pool.at[flat_dst].set(vals))
    return tuple(out)


def kv_merge(idx, *kvs):
    """Concat two caches along the batch axis and gather slots from the
    union: `out[slot] = concat(A, B)[idx[slot]]` with `idx` in
    `[0, A_batch + B_batch)`. `kvs` is A's 2*L arrays followed by B's 2*L
    arrays (same layer order). This is the device half of gang batching:
    two requests' beam slots land in one shared batch for a merged
    decode/score call, then split back out with `resize`/`gather`."""
    n = len(kvs) // 2
    assert len(kvs) == 2 * n, "kv_merge wants two equal cache lists"
    out = []
    for a, b in zip(kvs[:n], kvs[n:]):
        cat = jnp.concatenate([a, b], axis=0)
        out.append(jnp.take(cat, idx, axis=0))
    return tuple(out)
