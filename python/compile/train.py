"""Build-time training of the four model checkpoints.

The paper serves pretrained 3B LLMs and 1.5-7B PRMs; those weights are not
available here, so the substitution (DESIGN.md) trains tiny real models on
the synthetic arithmetic-chain task at artifact-build time:

  lm-concise   trained on minimal scratchpad traces   (Llama-3.2-3B analog)
  lm-verbose   trained on filler/redundant traces     (Qwen-2.5-3B analog)
  prm-large    3-layer reward model                   (MathShepherd-7B analog)
  prm-small    2-layer half-width reward model        (Skywork-1.5B analog)

PRMs are trained on a 50/50 mix of gold and corrupted traces with
per-position "correct so far" labels from the grammar validator — this is
what makes their partial scores genuinely predictive of final scores, the
property the paper's hypothesis rests on.

Everything is CPU-friendly: hand-rolled Adam (optax is not installed),
streaming synthetic data (no dataset files), fp32. Checkpoints are cached
as .npz under artifacts/weights/ and training curves logged to
artifacts/train_log_<model>.json; `make artifacts` skips training when the
cache exists.
"""

from __future__ import annotations

import functools
import json
import os
import random
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import grammar as g
from . import model as M

SEQ = M.SEQ_TRAIN


# ----------------------------------------------------------------- batches


def _pad(seq: List[int], width: int = SEQ) -> List[int]:
    return (seq + [g.PAD] * width)[:width]


HEAD_WEIGHT = 8.0  # loss emphasis on step-head tokens (vv op d ':')


def lm_batch(rng: random.Random, bsz: int, verbose: bool):
    """Teacher-forcing batch: predict token t+1 from prefix; loss only on
    solution positions (after '>'), with extra weight on step-head tokens —
    a single wrong op token ruins a whole trace, but contributes 1/70th of
    uniform loss, so the optimizer underweights exactly the tokens that
    matter most for end-task accuracy."""
    toks, lens, masks = [], [], []
    for _ in range(bsz):
        p = g.gen_mixed_problem(rng)
        prompt = p.prompt_tokens()
        sol = g.solution_tokens(p, verbose=verbose, rng=rng)
        seq = prompt + sol
        if len(seq) > SEQ:
            seq = seq[:SEQ]
        # per-target weights: mask[i] weights the prediction of seq[i+1]
        w = [0.0] * len(seq)
        head = True  # after '>' or ';' the next 5 tokens are a step head
        head_left = 5
        for i in range(len(prompt) - 1, len(seq) - 1):
            nxt = seq[i + 1]
            weight = 1.0
            if head and head_left > 0:
                weight = HEAD_WEIGHT
                head_left -= 1
                if head_left == 0:
                    head = False
            if nxt == g.SEMI:
                head = True
                head_left = 5
            if nxt == g.ANS:
                weight = HEAD_WEIGHT  # answer region matters too
            w[i] = weight
        toks.append(_pad(seq))
        lens.append(len(seq))
        masks.append((w + [0.0] * SEQ)[:SEQ])
    return (
        jnp.array(toks, jnp.int32),
        jnp.array(lens, jnp.int32),
        jnp.array(masks, jnp.float32),
    )


def prm_batch(rng: random.Random, bsz: int):
    """Per-position BCE batch: 50% gold, 50% corrupted; labels from the
    incremental validator; loss only on solution positions."""
    toks, lens, labels, masks = [], [], [], []
    for _ in range(bsz):
        p = g.gen_mixed_problem(rng)
        verbose = rng.random() < 0.5
        if rng.random() < 0.4:
            sol = g.solution_tokens(p, verbose=verbose, rng=rng)
        else:
            sol = g.corrupt_solution(p, rng, verbose=verbose)
        lab = g.label_positions(p, sol)
        prompt = p.prompt_tokens()
        seq = prompt + sol
        full_lab = [1] * len(prompt) + lab
        mask = [0.0] * len(prompt) + [1.0] * len(sol)
        if len(seq) > SEQ:
            seq, full_lab, mask = seq[:SEQ], full_lab[:SEQ], mask[:SEQ]
        toks.append(_pad(seq))
        lens.append(len(seq))
        labels.append(_pad(full_lab))
        masks.append(_pad([int(m) for m in mask]))
    return (
        jnp.array(toks, jnp.int32),
        jnp.array(lens, jnp.int32),
        jnp.array(labels, jnp.float32),
        jnp.array(masks, jnp.float32),
    )


# ----------------------------------------------------------------- losses


def lm_loss(cfg, params, toks, lens, mask):
    logits = M.lm_logits_fullseq(cfg, params, toks, lens)
    targets = jnp.roll(toks, -1, axis=1)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def prm_loss(cfg, params, toks, lens, labels, mask):
    logit = M.prm_logits_fullseq(cfg, params, toks, lens)
    bce = jnp.maximum(logit, 0) - logit * labels + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    return (bce * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ------------------------------------------------------------------- adam


def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": jnp.zeros((), jnp.float32)}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1.0
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    mhat = {k: m[k] / (1 - b1**t) for k in params}
    vhat = {k: v[k] / (1 - b2**t) for k in params}
    new = {k: params[k] - lr * mhat[k] / (jnp.sqrt(vhat[k]) + eps) for k in params}
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------- drivers


def _cosine_lr(step, steps, base):
    return base * 0.5 * (1 + np.cos(np.pi * min(step / steps, 1.0)))


def train_lm(name: str, verbose: bool, steps: int, bsz: int, seed: int, log_dir: str):
    cfg = M.LM_CFG
    rng = random.Random(seed)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt, toks, lens, mask, lr):
        loss, grads = jax.value_and_grad(lambda p: lm_loss(cfg, p, toks, lens, mask))(params)
        params, opt = adam_step(params, grads, opt, lr)
        return params, opt, loss

    log = []
    t0 = time.time()
    for s in range(steps):
        toks, lens, mask = lm_batch(rng, bsz, verbose)
        lr = jnp.float32(_cosine_lr(s, steps, 3e-3))
        params, opt, loss = step_fn(params, opt, toks, lens, mask, lr)
        if s % 25 == 0 or s == steps - 1:
            l = float(loss)
            log.append({"step": s, "loss": l, "wall_s": time.time() - t0})
            print(f"[{name}] step {s:4d} loss {l:.4f} ({time.time()-t0:.0f}s)", flush=True)
    with open(os.path.join(log_dir, f"train_log_{name}.json"), "w") as f:
        json.dump(log, f)
    return params


def train_prm(name: str, cfg: M.ModelCfg, steps: int, bsz: int, seed: int, log_dir: str):
    rng = random.Random(seed)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt, toks, lens, labels, mask, lr):
        loss, grads = jax.value_and_grad(lambda p: prm_loss(cfg, p, toks, lens, labels, mask))(params)
        params, opt = adam_step(params, grads, opt, lr)
        return params, opt, loss

    log = []
    t0 = time.time()
    for s in range(steps):
        toks, lens, labels, mask = prm_batch(rng, bsz)
        lr = jnp.float32(_cosine_lr(s, steps, 2e-3))
        params, opt, loss = step_fn(params, opt, toks, lens, labels, mask, lr)
        if s % 25 == 0 or s == steps - 1:
            l = float(loss)
            log.append({"step": s, "loss": l, "wall_s": time.time() - t0})
            print(f"[{name}] step {s:4d} loss {l:.4f} ({time.time()-t0:.0f}s)", flush=True)
    with open(os.path.join(log_dir, f"train_log_{name}.json"), "w") as f:
        json.dump(log, f)
    return params


# ------------------------------------------------------------- checkpoint


def save_params(path: str, params: Dict[str, jnp.ndarray]):
    np.savez(path, **{k: np.asarray(v) for k, v in params.items()})


def load_params(path: str) -> Dict[str, jnp.ndarray]:
    data = np.load(path)
    return {k: jnp.asarray(data[k]) for k in data.files}


CHECKPOINTS: List[Tuple[str, M.ModelCfg]] = [
    ("lm-concise", M.LM_CFG),
    ("lm-verbose", M.LM_CFG),
    ("prm-large", M.PRM_LARGE_CFG),
    ("prm-small", M.PRM_SMALL_CFG),
]


def ensure_checkpoints(weights_dir: str, log_dir: str) -> Dict[str, Dict[str, jnp.ndarray]]:
    """Train (or load cached) all four checkpoints."""
    os.makedirs(weights_dir, exist_ok=True)
    steps_lm = int(os.environ.get("ERPRM_TRAIN_STEPS_LM", "700"))
    steps_prm = int(os.environ.get("ERPRM_TRAIN_STEPS_PRM", "500"))
    out = {}
    for name, cfg in CHECKPOINTS:
        path = os.path.join(weights_dir, f"{name}.npz")
        if os.path.exists(path):
            print(f"[train] cached {name}", flush=True)
            out[name] = load_params(path)
            continue
        print(f"[train] training {name} ({cfg.param_count()} params)", flush=True)
        if cfg.scored:
            bsz = 8 if cfg is M.PRM_LARGE_CFG else 16
            params = train_prm(name, cfg, steps_prm, bsz, seed=hash(name) % 2**31, log_dir=log_dir)
        else:
            params = train_lm(name, name == "lm-verbose", steps_lm, 16, seed=hash(name) % 2**31, log_dir=log_dir)
        save_params(path, params)
        out[name] = params
    return out
