"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness ground truth: `python/tests/test_kernels.py`
(hypothesis) sweeps shapes/dtypes and asserts the Pallas kernels match
these to tight tolerances. They are also used directly by the L2 model in
places where a fused kernel buys nothing (tiny decode-step matvecs).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def causal_attention_ref(q, k, v, lengths=None):
    """Masked causal attention.

    q, k, v: [B, H, S, D]; lengths: optional [B] int32 — positions >= length
    are masked out of the keys (padded prompt tail).
    Returns [B, H, S, D].
    """
    b, h, s, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    qi = lax.broadcasted_iota(jnp.int32, (s, s), 0)
    ki = lax.broadcasted_iota(jnp.int32, (s, s), 1)
    mask = ki <= qi  # causal
    if lengths is not None:
        klen = ki[None, :, :] < lengths[:, None, None]
        full = mask[None] & klen
        logits = jnp.where(full[:, None], logits, NEG_INF)
    else:
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jnp.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def prm_prefix_score_ref(hidden, w, b):
    """Reward head + prefix aggregation (the paper-specific fusion).

    hidden: [B, S, Dm]; w: [Dm]; b: scalar (0-d array or float).
    Returns (score, cummin, cummean), each [B, S]:
      score[b, t]   = sigmoid(hidden[b, t] . w + b)       per-token reward
      cummin[b, t]  = min_{u <= t} score[b, u]            running min
      cummean[b, t] = mean_{u <= t} score[b, u]           running mean
    A single PRM invocation therefore yields the partial reward at *every*
    prefix length tau — the serving layer reads any index for free.
    """
    logit = jnp.einsum("bsd,d->bs", hidden, w) + b
    score = 1.0 / (1.0 + jnp.exp(-logit))
    cummin = lax.associative_scan(jnp.minimum, score, axis=1)
    csum = jnp.cumsum(score, axis=1)
    denom = jnp.arange(1, score.shape[1] + 1, dtype=score.dtype)[None, :]
    cummean = csum / denom
    return score, cummin, cummean
