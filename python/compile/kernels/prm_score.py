"""Pallas fused PRM reward head + prefix aggregation.

This is the paper-specific kernel: "Partial Reward Modeling" means reading
the PRM's score at an intermediate prefix length tau. Done naively that is
one head projection per tau queried, plus an HBM round-trip of the [B, S]
score tensor for every aggregation the policy wants. This kernel fuses

    logit[t]   = hidden[t] . w + b          (head matvec, MXU)
    score[t]   = sigmoid(logit[t])
    cummin[t]  = min(score[0..t])           (running min)
    cummean[t] = mean(score[0..t])          (running mean)

into a single VMEM-resident pass per sequence, so one PRM invocation yields
the partial reward at *every* prefix and every aggregation mode; the Rust
serving layer then indexes any tau for free.

Grid: one step per sequence row; block = the whole [S, Dm] hidden row
(S=256, Dm<=96 -> <=98 KB f32 in VMEM, well under budget; at paper scale
S=1024, Dm=4096 the row tiles by S-blocks with carried scan state — the
structure below is written so the scan carry is explicit).

interpret=True for the same reason as attention.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _prm_kernel(h_ref, w_ref, b_ref, score_ref, cmin_ref, cmean_ref, *, seq_len):
    h = h_ref[...]  # [S, Dm]
    w = w_ref[...]  # [Dm]
    b = b_ref[0]
    logit = jnp.dot(h, w, preferred_element_type=jnp.float32) + b
    score = 1.0 / (1.0 + jnp.exp(-logit))
    cmin = lax.associative_scan(jnp.minimum, score)
    csum = lax.associative_scan(jnp.add, score)
    denom = lax.iota(jnp.float32, seq_len) + 1.0
    score_ref[...] = score.astype(score_ref.dtype)
    cmin_ref[...] = cmin.astype(cmin_ref.dtype)
    cmean_ref[...] = (csum / denom).astype(cmean_ref.dtype)


@jax.jit
def prm_prefix_score(hidden, w, b):
    """hidden: [B, S, Dm]; w: [Dm]; b: scalar array [1].

    Returns (score, cummin, cummean), each [B, S]. Matches
    `ref.prm_prefix_score_ref` (tested via hypothesis sweeps).
    """
    bsz, s, dm = hidden.shape
    b_arr = jnp.reshape(jnp.asarray(b, hidden.dtype), (1,))
    kernel = functools.partial(_prm_kernel, seq_len=s)
    outs = pl.pallas_call(
        kernel,
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((None, s, dm), lambda i: (i, 0, 0)),
            pl.BlockSpec((dm,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((None, s), lambda i: (i, 0)),
            pl.BlockSpec((None, s), lambda i: (i, 0)),
            pl.BlockSpec((None, s), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s), hidden.dtype),
            jax.ShapeDtypeStruct((bsz, s), hidden.dtype),
            jax.ShapeDtypeStruct((bsz, s), hidden.dtype),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(hidden, w, b_arr)
    return tuple(outs)
