"""Pallas fused causal attention (flash-style, TPU-shaped, interpret=True).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's serving
stack runs CUDA attention kernels; here the same insight — never materialize
the [S, S] score matrix in HBM — is expressed TPU-style. The BlockSpec grid
streams one (block_q x D) query tile through VMEM against (block_k x D)
key/value tiles with an online-softmax accumulator, which is the HBM<->VMEM
schedule a GPU kernel would express with threadblocks + shared memory. The
QK^T and PV contractions are the MXU-bound ops.

VMEM footprint per grid step (f32 words):
    q tile        block_q * D
    k, v tiles    2 * block_k * D
    scores        block_q * block_k
    accum + stats block_q * (D + 2)
At the toy dims (S=256, D=16, block=64) this is ~13 KB — far under the
16 MB/core budget; at paper scale (D=128, block=128) it is ~330 KB, still
comfortable, which is what the §Perf VMEM estimate in DESIGN.md records.

`interpret=True` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. Interpret mode
lowers to plain HLO, so the kernel runs inside the AOT artifacts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, seq_len, use_len):
    """One (batch*head, q-block) grid step: online softmax over k blocks."""
    qi = pl.program_id(1)
    q = q_ref[...]  # [block_q, D]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))

    acc = jnp.zeros((block_q, d), jnp.float32)
    m_i = jnp.full((block_q,), NEG_INF, jnp.float32)
    l_i = jnp.zeros((block_q,), jnp.float32)

    q_pos = qi * block_q + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    n_kblocks = seq_len // block_k

    def body(kb, carry):
        acc, m_i, l_i = carry
        k_tile = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        v_tile = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        s = jnp.dot(q, k_tile.T, preferred_element_type=jnp.float32) * scale
        k_pos = kb * block_k + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_pos <= q_pos
        if use_len:
            mask = mask & (k_pos < len_ref[0])
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_i, s.max(axis=-1))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_i * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, v_tile, preferred_element_type=jnp.float32)
        return acc_new, m_new, l_new

    # Causal: only k blocks at or below the current q block contribute.
    acc, m_i, l_i = lax.fori_loop(0, qi + 1, body, (acc, m_i, l_i))
    o_ref[...] = (acc / jnp.maximum(l_i, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def causal_attention(q, k, v, lengths=None, *, block_q=64, block_k=64):
    """Fused causal attention. q, k, v: [B, H, S, D]; lengths: optional [B].

    Matches `ref.causal_attention_ref` (tested via hypothesis sweeps).
    """
    b, h, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    use_len = lengths is not None
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)

    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)
    lens = jnp.repeat(lengths.astype(jnp.int32), h)

    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, seq_len=s, use_len=use_len
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, s // block_q),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),  # lengths, one per bh row
            pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),  # q tile
            pl.BlockSpec((None, s, d), lambda i, j: (i, 0, 0)),  # full k row
            pl.BlockSpec((None, s, d), lambda i, j: (i, 0, 0)),  # full v row
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(lens, qf, kf, vf)
    return out.reshape(b, h, s, d)


def _blocktab_kernel(len_ref, tab_ref, q_ref, k_ref, v_ref, o_ref, *, block_q, block_k):
    """One (batch*head, q-block) grid step over a *block pool*: the k loop
    resolves each logical K/V block to its pool row through the slot's
    block-table row before loading the tile — the paged-attention gather,
    done inside the kernel instead of as a device-wide pre-pass."""
    qi = pl.program_id(1)
    q = q_ref[...]  # [block_q, D]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))

    acc = jnp.zeros((block_q, d), jnp.float32)
    m_i = jnp.full((block_q,), NEG_INF, jnp.float32)
    l_i = jnp.zeros((block_q,), jnp.float32)

    q_pos = qi * block_q + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)

    def body(kb, carry):
        acc, m_i, l_i = carry
        phys = tab_ref[kb]  # logical block kb -> pool row (per-slot table)
        k_tile = pl.load(k_ref, (pl.dslice(phys * block_k, block_k), slice(None)))
        v_tile = pl.load(v_ref, (pl.dslice(phys * block_k, block_k), slice(None)))
        s = jnp.dot(q, k_tile.T, preferred_element_type=jnp.float32) * scale
        k_pos = kb * block_k + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        # Causal within the slot, and nothing at or past the slot's own
        # frontier: gang members share the pool but not a write clock.
        mask = (k_pos <= q_pos) & (k_pos < len_ref[0])
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_i, s.max(axis=-1))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_i * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, v_tile, preferred_element_type=jnp.float32)
        return acc_new, m_new, l_new

    # Only k blocks overlapping [0, (qi+1)*block_q) can contribute.
    n_kb = (qi * block_q + block_q + block_k - 1) // block_k
    acc, m_i, l_i = lax.fori_loop(0, n_kb, body, (acc, m_i, l_i))
    o_ref[...] = (acc / jnp.maximum(l_i, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def blocktab_attention(q, k_pool, v_pool, table, lengths, *, block_q=64, block_k=32):
    """Block-table-indexed causal attention over a shared K/V block pool.

    q: [B, H, S, D] logical-order queries; k_pool, v_pool: [P, H, block_k, D]
    pool arrays shared across slots; table: [B, S/block_k] i32 pool row per
    logical block; lengths: [B] per-slot frontier (attendable prefix).
    Accumulation order matches `causal_attention` at the same block sizes,
    so on a pool laid out from a dense cache the outputs agree bitwise.
    """
    b, h, s, d = q.shape
    p1 = k_pool.shape[0]
    block_q = min(block_q, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    assert k_pool.shape == v_pool.shape == (p1, h, block_k, d)
    assert table.shape == (b, s // block_k), (table.shape, b, s // block_k)

    qf = q.reshape(b * h, s, d)
    # Pool rows flattened per head: row p of head hh lives at
    # [hh, p*block_k : (p+1)*block_k) — the kernel's dslice coordinates.
    kf = k_pool.transpose(1, 0, 2, 3).reshape(h, p1 * block_k, d)
    vf = v_pool.transpose(1, 0, 2, 3).reshape(h, p1 * block_k, d)
    tabs = jnp.repeat(table.astype(jnp.int32), h, axis=0)  # [b*h, S/block_k]
    lens = jnp.repeat(lengths.astype(jnp.int32), h)

    kernel = functools.partial(_blocktab_kernel, block_q=block_q, block_k=block_k)
    nb = s // block_k
    out = pl.pallas_call(
        kernel,
        grid=(b * h, s // block_q),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),  # per-slot frontier
            pl.BlockSpec((None, nb), lambda i, j: (i, 0)),  # block-table row
            pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),  # q tile
            pl.BlockSpec((None, p1 * block_k, d), lambda i, j: (i % h, 0, 0)),  # k pool, head plane
            pl.BlockSpec((None, p1 * block_k, d), lambda i, j: (i % h, 0, 0)),  # v pool, head plane
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(lens, tabs, qf, kf, vf)
    return out.reshape(b, h, s, d)
