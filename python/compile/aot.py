"""AOT export: lower every serving program to HLO text + write weights.bin.

This is the only bridge between the Python build path and the Rust serving
runtime. It emits, under artifacts/:

  hlo/<model>_<program>_b<batch>.hlo.txt   one per (program, batch) variant
  weights/<checkpoint>.bin                 raw little-endian f32, in
                                           model.weight_specs order
  manifest.json                            vocab, model dims, program map,
                                           weight specs, FLOPs-per-token —
                                           everything Rust needs to load and
                                           run without importing Python

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids, so text
round-trips cleanly. See /opt/xla-example/README.md.

KV-cache args in decode/score programs are lowered with donate_argnums so
the HLO carries input_output_alias — the PJRT runtime updates caches in
place instead of copying ~MBs per step.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import grammar as g
from . import model as M
from . import train as T

BATCHES = [int(x) for x in os.environ.get("ERPRM_BATCHES", "4,8,16,32,64").split(",")]
FULLSEQ_BATCH = 8


def pool_blocks_default(budget_bytes=None):
    """Derive the exported block-pool size from a device-memory budget:
    (budget - weights - dense workspace) / per-block bytes, clamped to a
    sane range. The dense workspace term reserves room for the widest
    dense decode/score variant's caches (prefill staging and the dense
    fallback path both still allocate them), so the pool can't starve the
    programs that feed it. `ERPRM_DEVICE_MEM_MB` overrides the budget.
    The result is baked into the blocktab program shapes and written to
    the manifest as `pool_blocks` — the Rust `--kv-pool-blocks` default."""
    if budget_bytes is None:
        budget_bytes = int(os.environ.get("ERPRM_DEVICE_MEM_MB", "512")) * 1024 * 1024
    cfgs = (M.LM_CFG, M.PRM_LARGE_CFG, M.PRM_SMALL_CFG)
    weights = sum(4 * cfg.param_count() for cfg in cfgs)
    widest = max(BATCHES)
    workspace = sum(
        2  # donation double-buffer
        * 4 * widest * cfg.n_heads * cfg.cache_len * cfg.head_dim
        * 2 * cfg.n_layers
        for cfg in cfgs
    )
    per_block = sum(
        4 * cfg.n_heads * M.KV_BLOCK * cfg.head_dim * 2 * cfg.n_layers for cfg in cfgs
    )
    return max(64, min(4096, (budget_bytes - weights - workspace) // per_block))


POOL_BLOCKS = pool_blocks_default()

F32 = jnp.float32
I32 = jnp.int32
U32 = jnp.uint32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(out_dir: str, name: str, fn, arg_specs, donate=()):
    path = os.path.join(out_dir, "hlo", f"{name}.hlo.txt")
    if os.path.exists(path):
        return path
    t0 = time.time()
    # keep_unused: arguments the program happens not to need (e.g. the PRM
    # head weights in prm_prefill) must stay parameters — the Rust runtime
    # passes every weight buffer unconditionally.
    lowered = jax.jit(fn, donate_argnums=tuple(donate), keep_unused=True).lower(*arg_specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"[aot] {name}: {len(text)//1024} KiB in {time.time()-t0:.1f}s", flush=True)
    return path


def weight_arg_specs(cfg: M.ModelCfg):
    return [spec(shape) for _, shape in M.weight_specs(cfg)]


def write_weights_bin(path: str, cfg: M.ModelCfg, params) -> int:
    """Raw little-endian f32 concat in weight_specs order; returns n_floats."""
    bufs = []
    for wname, shape in M.weight_specs(cfg):
        a = np.asarray(params[wname], dtype="<f4")
        assert a.shape == tuple(shape), (wname, a.shape, shape)
        bufs.append(a.ravel())
    flat = np.concatenate(bufs)
    flat.tofile(path)
    return int(flat.size)


def export_resize(out_dir: str, cfg: M.ModelCfg, programs: dict):
    """Cross-batch gather programs: `resize_bS_to_bD` selects/replicates beam
    slots while moving between batch variants — the device-side mechanism of
    the paper's two-tiered batching (prefix phase at b1=N, completion phase
    at b2=N/M) and of beam expansion back up to N."""
    for src in BATCHES:
        kv_src = [spec(sh) for sh in M.kv_shapes(cfg, src)]
        for dst in BATCHES:
            if src == dst:
                continue
            programs[f"resize_b{src}_to_b{dst}"] = export(
                out_dir, f"{cfg.name}_resize_b{src}_to_b{dst}",
                M.kv_gather, [spec((dst,), I32)] + kv_src,
            )


def merge_pairs(batches=None):
    """(src_a, src_b, dst) merge variants worth exporting: every ordered
    pair with src_a >= src_b whose combined real slots still fit an
    exported batch variant (dst is the smallest variant >= a + b). The
    gang planner sorts members largest-first, so the a >= b half of the
    grid is sufficient and halves the program count."""
    bs = sorted(batches or BATCHES)
    out = []
    for a in bs:
        for b in bs:
            if b > a:
                continue
            fits = [c for c in bs if c >= a + b]
            if fits:
                out.append((a, b, min(fits)))
    return out


def export_merge(out_dir: str, cfg: M.ModelCfg, programs: dict):
    """Cross-cache concat programs: `merge_bA_bB_to_bC` gathers `C` slots
    out of the union of two caches (batches A and B) so two concurrent
    requests' beams share one device batch (gang batching). The split back
    to per-request caches reuses the existing `resize`/`gather` programs."""
    for a, b, c in merge_pairs():
        kv_a = [spec(sh) for sh in M.kv_shapes(cfg, a)]
        kv_b = [spec(sh) for sh in M.kv_shapes(cfg, b)]
        programs[f"merge_b{a}_b{b}_to_b{c}"] = export(
            out_dir, f"{cfg.name}_merge_b{a}_b{b}_to_b{c}",
            M.kv_merge, [spec((c,), I32)] + kv_a + kv_b,
        )


def export_compact(out_dir: str, cfg: M.ModelCfg, programs: dict):
    """Per-slot cache re-compaction programs: `compact_bN` gathers every
    slot's valid positions down to a dense prefix along the cache axis,
    taking a host-computed `[N, S]` index matrix. KV args are donated
    (input_output_alias, like decode/score) so the runtime repacks caches
    in place instead of copying ~MBs per compaction."""
    nkv = 2 * cfg.n_layers
    for b in BATCHES:
        kv = [spec(sh) for sh in M.kv_shapes(cfg, b)]
        programs[f"compact_b{b}"] = export(
            out_dir, f"{cfg.name}_compact_b{b}",
            M.kv_compact, [spec((b, cfg.cache_len), I32)] + kv,
            donate=range(1, 1 + nkv),
        )


def export_paged(out_dir: str, cfg: M.ModelCfg, programs: dict):
    """Paged-KV programs, block-granular over KV_BLOCK-token cache blocks
    (the device half of rust/src/runtime/blocks.rs):

      gather_blocks_bN   per-slot block permutation (table -> dense view
                         or its inverse), donated like compact
      append_block_bN    write one fresh block span per slot at a per-slot
                         destination block
      decode_paged_bN /  the dense decode/score stack bracketed by
      score_paged_bN     view/store block gathers, so paged solves stay
                         byte-identical to dense ones

    All cache args are donated (input_output_alias) — pure gathers, no
    scatter, so the pool buffer updates in place."""
    assert cfg.cache_len % M.KV_BLOCK == 0, (cfg.name, cfg.cache_len, M.KV_BLOCK)
    nw = len(M.weight_specs(cfg))
    nkv = 2 * cfg.n_layers
    s = cfg.cache_len
    nb = s // M.KV_BLOCK

    def wrap(core):
        def fn(*args):
            params = M.args_to_params(cfg, args[:nw])
            return core(params, *args[nw:])
        return fn

    for b in BATCHES:
        kv = [spec(sh) for sh in M.kv_shapes(cfg, b)]
        spans = [spec((b, cfg.n_heads, M.KV_BLOCK, cfg.head_dim)) for _ in range(nkv)]
        programs[f"gather_blocks_b{b}"] = export(
            out_dir, f"{cfg.name}_gather_blocks_b{b}",
            M.kv_gather_blocks, [spec((b, nb), I32)] + kv,
            donate=range(1, 1 + nkv),
        )
        programs[f"append_block_b{b}"] = export(
            out_dir, f"{cfg.name}_append_block_b{b}",
            M.kv_append_block, [spec((b,), I32)] + spans + kv,
            donate=range(1 + nkv, 1 + 2 * nkv),
        )
        if cfg.scored:
            programs[f"score_paged_b{b}"] = export(
                out_dir, f"{cfg.name}_score_paged_b{b}",
                wrap(lambda p, *a: M.prm_score_paged(cfg, p, *a)),
                weight_arg_specs(cfg)
                + [spec((b, nb), I32), spec((b, nb), I32),
                   spec((1,), I32), spec((b,), I32), spec((b, s), I32),
                   spec((b, M.SCORE_BLOCK), I32)]
                + kv,
                donate=range(nw + 6, nw + 6 + nkv),
            )
        else:
            programs[f"decode_paged_b{b}"] = export(
                out_dir, f"{cfg.name}_decode_paged_b{b}",
                wrap(lambda p, *a: M.lm_decode_paged(cfg, p, *a)),
                weight_arg_specs(cfg)
                + [spec((b, nb), I32), spec((b, nb), I32),
                   spec((1,), I32), spec((b,), I32), spec((b, s), I32),
                   spec((b,), I32), spec((1,), F32), spec((b, 2), U32)]
                + kv,
                donate=range(nw + 8, nw + 8 + nkv),
            )


def pool_shapes(cfg: M.ModelCfg):
    """Shapes of the 2*L shared pool arrays: POOL_BLOCKS rows plus one
    trash row that absorbs writes from padded table entries and dead
    slots (id POOL_BLOCKS — reads from it are always masked)."""
    nkv = 2 * cfg.n_layers
    return [(POOL_BLOCKS + 1, cfg.n_heads, M.KV_BLOCK, cfg.head_dim)] * nkv


def export_blocktab(out_dir: str, cfg: M.ModelCfg, programs: dict):
    """Block-native programs — the cache is a shared per-shard pool, so
    cross-request merge/split/compact need no device program at all (the
    runtime edits block tables); what remains on device is:

      decode_blocktab_bN /  decode/score against (block_table, per-slot
      score_blocktab_bN     frontier) operands; only the written frontier
                            span is scattered back, no view round-trip
      adopt_blocktab_bN     install a dense b=1 prefill cache into every
                            slot's pool rows (prefill + broadcast in one)
      copy_blocktab_bN      pool row -> pool row block copies; one program
                            per dest width replaces the whole
                            gather/resize/broadcast family

    Pool args are donated (input_output_alias) so the shard's pool updates
    in place."""
    assert cfg.cache_len % M.KV_BLOCK == 0, (cfg.name, cfg.cache_len, M.KV_BLOCK)
    nw = len(M.weight_specs(cfg))
    nkv = 2 * cfg.n_layers
    s = cfg.cache_len
    nb = s // M.KV_BLOCK
    pools = [spec(sh) for sh in pool_shapes(cfg)]

    def wrap(core):
        def fn(*args):
            params = M.args_to_params(cfg, args[:nw])
            return core(params, *args[nw:])
        return fn

    for b in BATCHES:
        tab = spec((b, nb), I32)
        programs[f"adopt_blocktab_b{b}"] = export(
            out_dir, f"{cfg.name}_adopt_blocktab_b{b}",
            M.kv_adopt_blocks,
            [tab] + [spec(sh) for sh in M.kv_shapes(cfg, 1)] + pools,
            donate=range(1 + nkv, 1 + 2 * nkv),
        )
        programs[f"copy_blocktab_b{b}"] = export(
            out_dir, f"{cfg.name}_copy_blocktab_b{b}",
            M.kv_copy_blocks, [tab, tab] + pools,
            donate=range(2, 2 + nkv),
        )
        if cfg.scored:
            programs[f"score_blocktab_b{b}"] = export(
                out_dir, f"{cfg.name}_score_blocktab_b{b}",
                wrap(lambda p, *a: M.prm_score_blocktab(cfg, p, *a)),
                weight_arg_specs(cfg)
                + [tab, spec((b,), I32), spec((b,), I32), spec((b, s), I32),
                   spec((b, M.SCORE_BLOCK), I32)]
                + pools,
                donate=range(nw + 5, nw + 5 + nkv),
            )
        else:
            programs[f"decode_blocktab_b{b}"] = export(
                out_dir, f"{cfg.name}_decode_blocktab_b{b}",
                wrap(lambda p, *a: M.lm_decode_blocktab(cfg, p, *a)),
                weight_arg_specs(cfg)
                + [tab, spec((b,), I32), spec((b,), I32), spec((b, s), I32),
                   spec((b,), I32), spec((1,), F32), spec((b, 2), U32)]
                + pools,
                donate=range(nw + 7, nw + 7 + nkv),
            )


def export_lm(out_dir: str, cfg: M.ModelCfg) -> dict:
    nw = len(M.weight_specs(cfg))
    nkv = 2 * cfg.n_layers
    s = cfg.cache_len
    programs = {}

    def wrap(core, n_state):
        def fn(*args):
            params = M.args_to_params(cfg, args[:nw])
            return core(params, *args[nw:])
        return fn

    programs["prefill_b1"] = export(
        out_dir, f"{cfg.name}_prefill_b1",
        wrap(lambda p, t, l: M.lm_prefill(cfg, p, t, l), 2),
        weight_arg_specs(cfg) + [spec((1, g.PROMPT_PAD), I32), spec((1,), I32)],
    )
    for b in BATCHES:
        kv = [spec(sh) for sh in M.kv_shapes(cfg, b)]
        programs[f"decode_b{b}"] = export(
            out_dir, f"{cfg.name}_decode_b{b}",
            wrap(lambda p, *a: M.lm_decode_block(cfg, p, *a), 6 + nkv),
            weight_arg_specs(cfg)
            + [spec((1,), I32), spec((b,), I32), spec((b, s), I32),
               spec((b,), I32), spec((1,), F32), spec((b, 2), U32)]
            + kv,
            donate=range(nw + 6, nw + 6 + nkv),
        )
        programs[f"gather_b{b}"] = export(
            out_dir, f"{cfg.name}_gather_b{b}",
            M.kv_gather, [spec((b,), I32)] + kv,
        )
        programs[f"broadcast_b{b}"] = export(
            out_dir, f"{cfg.name}_broadcast_b{b}",
            lambda *kv1, b=b: M.kv_broadcast(b, *kv1),
            [spec(sh) for sh in M.kv_shapes(cfg, 1)],
        )
    export_resize(out_dir, cfg, programs)
    export_merge(out_dir, cfg, programs)
    export_compact(out_dir, cfg, programs)
    export_paged(out_dir, cfg, programs)
    export_blocktab(out_dir, cfg, programs)
    return programs


def export_prm(out_dir: str, cfg: M.ModelCfg) -> dict:
    nw = len(M.weight_specs(cfg))
    nkv = 2 * cfg.n_layers
    s = cfg.cache_len
    programs = {}

    def wrap(core):
        def fn(*args):
            params = M.args_to_params(cfg, args[:nw])
            return core(params, *args[nw:])
        return fn

    programs["prefill_b1"] = export(
        out_dir, f"{cfg.name}_prefill_b1",
        wrap(lambda p, t, l: M.prm_prefill(cfg, p, t, l)),
        weight_arg_specs(cfg) + [spec((1, g.PROMPT_PAD), I32), spec((1,), I32)],
    )
    for b in BATCHES:
        kv = [spec(sh) for sh in M.kv_shapes(cfg, b)]
        programs[f"score_b{b}"] = export(
            out_dir, f"{cfg.name}_score_b{b}",
            wrap(lambda p, *a: M.prm_score_block(cfg, p, *a)),
            weight_arg_specs(cfg)
            + [spec((1,), I32), spec((b,), I32), spec((b, s), I32),
               spec((b, M.SCORE_BLOCK), I32)]
            + kv,
            donate=range(nw + 4, nw + 4 + nkv),
        )
        programs[f"gather_b{b}"] = export(
            out_dir, f"{cfg.name}_gather_b{b}",
            M.kv_gather, [spec((b,), I32)] + kv,
        )
        programs[f"broadcast_b{b}"] = export(
            out_dir, f"{cfg.name}_broadcast_b{b}",
            lambda *kv1, b=b: M.kv_broadcast(b, *kv1),
            [spec(sh) for sh in M.kv_shapes(cfg, 1)],
        )
    export_resize(out_dir, cfg, programs)
    export_merge(out_dir, cfg, programs)
    export_compact(out_dir, cfg, programs)
    export_paged(out_dir, cfg, programs)
    export_blocktab(out_dir, cfg, programs)
    programs[f"fullseq_b{FULLSEQ_BATCH}"] = export(
        out_dir, f"{cfg.name}_fullseq_b{FULLSEQ_BATCH}",
        wrap(lambda p, t, l: M.prm_fullseq(cfg, p, t, l)),
        weight_arg_specs(cfg)
        + [spec((FULLSEQ_BATCH, M.SEQ_TRAIN), I32), spec((FULLSEQ_BATCH,), I32)],
    )
    return programs


def model_manifest(cfg: M.ModelCfg, programs: dict, weights: dict, out_dir: str) -> dict:
    rel = lambda p: os.path.relpath(p, out_dir)
    return {
        "kind": "prm" if cfg.scored else "lm",
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "head_dim": cfg.head_dim,
        "ffn": cfg.ffn,
        "vocab": cfg.vocab,
        "cache_len": cfg.cache_len,
        "params": cfg.param_count(),
        "flops_per_token": cfg.flops_per_token(),
        "weight_specs": [[n, list(s)] for n, s in M.weight_specs(cfg)],
        "programs": {k: rel(v) for k, v in programs.items()},
        "weights": weights,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out)
    os.makedirs(os.path.join(out_dir, "hlo"), exist_ok=True)
    os.makedirs(os.path.join(out_dir, "weights"), exist_ok=True)

    ckpts = T.ensure_checkpoints(os.path.join(out_dir, "weights"), out_dir)

    weights_rel = {}
    for name, cfg in T.CHECKPOINTS:
        binp = os.path.join(out_dir, "weights", f"{name}.bin")
        if not os.path.exists(binp):
            n = write_weights_bin(binp, cfg, ckpts[name])
            print(f"[aot] weights {name}: {n} f32", flush=True)
        weights_rel[name] = f"weights/{name}.bin"

    lm_programs = export_lm(out_dir, M.LM_CFG)
    prml_programs = export_prm(out_dir, M.PRM_LARGE_CFG)
    prms_programs = export_prm(out_dir, M.PRM_SMALL_CFG)

    manifest = {
        "vocab": g.TOKEN_STRS,
        "prompt_pad": g.PROMPT_PAD,
        "decode_block": M.DECODE_BLOCK,
        "score_block": M.SCORE_BLOCK,
        "seq_train": M.SEQ_TRAIN,
        "mod": g.MOD,
        "batch_variants": BATCHES,
        "fullseq_batch": FULLSEQ_BATCH,
        # tokens per paged-KV block; runtimes that predate paging ignore
        # it, and a manifest without it makes the Rust pool fall back to
        # dense caches
        "kv_block": M.KV_BLOCK,
        # rows in the exported shared block-pool arrays (excluding the
        # trash row) — geometry-derived (device memory minus weights and
        # workspace) and the Rust --kv-pool-blocks default; absent or 0
        # disables block-native mode
        "pool_blocks": POOL_BLOCKS,
        "models": {
            "lm": model_manifest(
                M.LM_CFG, lm_programs,
                {k: weights_rel[k] for k in ("lm-concise", "lm-verbose")}, out_dir),
            "prm-large": model_manifest(
                M.PRM_LARGE_CFG, prml_programs,
                {"prm-large": weights_rel["prm-large"]}, out_dir),
            "prm-small": model_manifest(
                M.PRM_SMALL_CFG, prms_programs,
                {"prm-small": weights_rel["prm-small"]}, out_dir),
        },
        # Paper-scale parameter counts, used only for narrative comparison in
        # EXPERIMENTS.md (the ledger reports our analytic FLOPs).
        "paper_scale": {"lm": 3.0e9, "prm-large": 7.0e9, "prm-small": 1.5e9},
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("[aot] manifest written", flush=True)


if __name__ == "__main__":
    main()
