"""Synthetic arithmetic-chain reasoning task (the benchmark substrate).

The paper evaluates on MATH-500 / SAT-MATH / AIME with 3B LLMs and 1.5-7B
PRMs; none of those are available here, so this module defines the synthetic
equivalent (see DESIGN.md "Substitutions"): a problem is a start value
v0 in [0,99] and K chained operations (op, d) with values mod 100. The gold
solution writes one *reasoning step* per operation with digit-level scratch
work, which makes steps 15-46 tokens long — long enough for mid-step partial
rewards at tau in {4,8,16,24} to be meaningful, mirroring the paper's
tau in {32,64,128} over ~300-token steps at the same tau/L ratios.

Everything here (vocab, trace format, validator) is mirrored by the Rust
tokenizer/workload modules; the vocab is exported in artifacts/manifest.json
so both sides always agree.

Trace format (token-level):
  prompt:   BOS v0 (op d)*K '>'
  step i:   vv op d ':' [~ filler]* (item ' ')*d [redundancy] '=' ww ';'
  answer:   'A' ww EOS

Scratch items: for '+d' count up v+1..v+d; for '-d' count down; for '*d'
repeated addition v,2v,..,dv (all mod 100, printed as two digits).
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

# ---------------------------------------------------------------- vocabulary

PAD, BOS, EOS = 0, 1, 2
DIG0 = 3  # '0'..'9' -> 3..12
PLUS, MINUS, TIMES, EQ, SEMI, SEP, ANS, COLON, FILL, SPACE, RSV = (
    13, 14, 15, 16, 17, 18, 19, 20, 21, 22, 23,
)
VOCAB_SIZE = 24

TOKEN_STRS = (
    ["<pad>", "<bos>", "<eos>"]
    + [str(i) for i in range(10)]
    + ["+", "-", "*", "=", ";", ">", "A", ":", "~", " ", "#"]
)
assert len(TOKEN_STRS) == VOCAB_SIZE

OPS = {PLUS: "+", MINUS: "-", TIMES: "*"}

MOD = 100
MAX_SEQ = 256
PROMPT_PAD = 24  # prompts are <= 20 tokens; prefill program uses this width


def detok(ids: List[int]) -> str:
    return "".join(TOKEN_STRS[i] for i in ids)


def two_digits(v: int) -> List[int]:
    v %= MOD
    return [DIG0 + v // 10, DIG0 + v % 10]


def apply_op(v: int, op: int, d: int) -> int:
    if op == PLUS:
        return (v + d) % MOD
    if op == MINUS:
        return (v - d) % MOD
    if op == TIMES:
        return (v * d) % MOD
    raise ValueError(f"bad op token {op}")


# ---------------------------------------------------------------- problems


@dataclass
class Problem:
    v0: int
    ops: List[Tuple[int, int]]  # (op_token, operand)

    @property
    def answer(self) -> int:
        v = self.v0
        for op, d in self.ops:
            v = apply_op(v, op, d)
        return v

    def prompt_tokens(self) -> List[int]:
        # ops are ';'-separated: the k-th op follows the (k-1)-th ';' in the
        # prompt, and the model generating step k has emitted k-1 ';' in its
        # solution — aligning the two counts is an attention pattern a
        # 2-layer model learns reliably (plain concatenation was not).
        toks = [BOS] + two_digits(self.v0)
        for op, d in self.ops:
            toks += [op, DIG0 + d, SEMI]
        toks.append(SEP)
        return toks


# Benchmark analogs: (#ops K, operand range, op mix) — a difficulty gradient
# mirroring SAT-MATH < MATH-500 < AIME. Harder = more steps, bigger operands
# (longer scratch), more multiplication.
BENCHMARKS = {
    "satmath-s": dict(k=3, d_lo=2, d_hi=6, p_times=0.2),
    "math500-s": dict(k=4, d_lo=2, d_hi=8, p_times=0.35),
    "aime-s": dict(k=5, d_lo=4, d_hi=9, p_times=0.5),
}


def gen_problem(rng: random.Random, bench: str = "satmath-s") -> Problem:
    cfg = BENCHMARKS[bench]
    ops = []
    for _ in range(cfg["k"]):
        r = rng.random()
        op = TIMES if r < cfg["p_times"] else (PLUS if r < (1 + cfg["p_times"]) / 2 else MINUS)
        ops.append((op, rng.randint(cfg["d_lo"], cfg["d_hi"])))
    return Problem(v0=rng.randint(0, MOD - 1), ops=ops)


def gen_mixed_problem(rng: random.Random, k_lo: int = 2, k_hi: int = 5) -> Problem:
    """Training-distribution problems spanning all benchmark difficulties."""
    k = rng.randint(k_lo, k_hi)
    ops = []
    for _ in range(k):
        op = rng.choice([PLUS, MINUS, TIMES])
        ops.append((op, rng.randint(2, 9)))
    return Problem(v0=rng.randint(0, MOD - 1), ops=ops)


# ---------------------------------------------------------------- gold traces


def scratch_items(v: int, op: int, d: int) -> List[int]:
    """The digit-level working for one step: d intermediate values."""
    if op == PLUS:
        return [(v + i) % MOD for i in range(1, d + 1)]
    if op == MINUS:
        return [(v - i) % MOD for i in range(1, d + 1)]
    if op == TIMES:
        return [(v * i) % MOD for i in range(1, d + 1)]
    raise ValueError(f"bad op token {op}")


def step_tokens(
    v: int,
    op: int,
    d: int,
    verbose: bool,
    rng: Optional[random.Random],
    item_override: Optional[List[int]] = None,
    result_override: Optional[int] = None,
) -> List[int]:
    """One reasoning step. `verbose` adds filler + a redundant re-listing,
    reproducing the paper's "exploratory LLM" trace style (Qwen analog)."""
    items = item_override if item_override is not None else scratch_items(v, op, d)
    result = result_override if result_override is not None else items[-1]
    toks = two_digits(v) + [op, DIG0 + d, COLON]
    if verbose and rng is not None:
        toks += [FILL] * rng.randint(1, 3)
    for it in items:
        toks += two_digits(it) + [SPACE]
    if verbose and rng is not None and rng.random() < 0.4 and len(items) >= 2:
        toks += [FILL]
        for it in items[-2:]:
            toks += two_digits(it) + [SPACE]
    toks += [EQ] + two_digits(result) + [SEMI]
    return toks


def solution_tokens(p: Problem, verbose: bool = False, rng: Optional[random.Random] = None) -> List[int]:
    toks: List[int] = []
    v = p.v0
    for op, d in p.ops:
        toks += step_tokens(v, op, d, verbose, rng)
        v = apply_op(v, op, d)
    toks += [ANS] + two_digits(v) + [EOS]
    return toks


def full_sequence(p: Problem, verbose: bool = False, rng: Optional[random.Random] = None) -> List[int]:
    seq = p.prompt_tokens() + solution_tokens(p, verbose, rng)
    if len(seq) > MAX_SEQ:
        # Regenerate without redundancy bloat: strip filler to fit.
        seq = [t for t in seq if t != FILL]
    return seq[:MAX_SEQ]


# ---------------------------------------------------------------- corruption


def corrupt_solution(p: Problem, rng: random.Random, verbose: bool = False) -> List[int]:
    """A solution with an injected error (for PRM training).

    Error modes (validator-labelled, so compounding effects are exact):
      * wrong-op: a step applies a different operation/operand than the
        problem's k-th — internally consistent arithmetic, wrong problem.
        This is the dominant real LM failure mode, so the PRM must see it.
      * scratch/result: a perturbed intermediate value or step result.
    """
    # wrong-op corruption: substitute the op or operand of one step and
    # compute that step *consistently* with the wrong op.
    if rng.random() < 0.4:
        err_step = rng.randrange(len(p.ops))
        new_ops = list(p.ops)
        op, d = new_ops[err_step]
        if rng.random() < 0.5:
            alt = rng.choice([o for o in (PLUS, MINUS, TIMES) if o != op])
            new_ops[err_step] = (alt, d)
        else:
            alt_d = d + rng.choice([-2, -1, 1, 2])
            alt_d = min(9, max(1, alt_d))
            if alt_d == d:
                alt_d = d - 1 if d > 1 else d + 1
            new_ops[err_step] = (op, alt_d)
        wrong = Problem(v0=p.v0, ops=new_ops)
        return solution_tokens(wrong, verbose=verbose, rng=rng)

    toks: List[int] = []
    v = p.v0
    err_step = rng.randrange(len(p.ops))
    carried = None  # wrong running value once the error propagates
    for i, (op, d) in enumerate(p.ops):
        cur = carried if carried is not None else v
        items = scratch_items(cur, op, d)
        result = items[-1]
        if i == err_step:
            mode = rng.random()
            delta = rng.choice([-3, -2, -1, 1, 2, 3])
            if mode < 0.5 and len(items) > 1:
                j = rng.randrange(len(items) - 1)
                items[j] = (items[j] + delta) % MOD
                # downstream items recomputed from the wrong one for +/-
                if op in (PLUS, MINUS):
                    sign = 1 if op == PLUS else -1
                    for t in range(j + 1, len(items)):
                        items[t] = (items[j] + sign * (t - j)) % MOD
                    result = items[-1]
            else:
                result = (result + delta) % MOD
                items[-1] = result
            carried = result
        elif carried is not None:
            items = scratch_items(cur, op, d)
            result = items[-1]
            carried = result
        toks += step_tokens(cur, op, d, verbose, rng, item_override=items, result_override=result)
        v = apply_op(v, op, d)
    final = carried if carried is not None else v
    toks += [ANS] + two_digits(final) + [EOS]
    return toks


# ---------------------------------------------------------------- validator


@dataclass
class ValidatorState:
    """Incremental token-level validator.

    Feeds one token at a time; `ok` flips to False at the first position
    where the trace is arithmetically or syntactically wrong — including a
    step that uses the wrong operation for its index in the problem — and
    stays False (monotone), which is exactly the "correct so far" semantics
    the PRM is trained to estimate.
    """

    v: int  # running value entering the current step
    ops: Optional[List[Tuple[int, int]]] = None  # expected (op, d) per step
    step_idx: int = 0
    ok: bool = True
    done: bool = False
    answer: Optional[int] = None
    # parser state
    _phase: str = "head"  # head | scratch | result | answer | done
    _buf: List[int] = field(default_factory=list)
    _step_op: int = 0
    _step_d: int = 0
    _items_seen: int = 0
    _expect: List[int] = field(default_factory=list)
    _after_redundant: bool = False

    def _fail(self):
        self.ok = False

    def feed(self, tok: int) -> bool:
        """Consume one token; returns current ok flag."""
        if self.done or not self.ok:
            # once wrong/finished, stay wrong/finished
            if not self.done and tok == EOS:
                self.done = True
            return self.ok

        ph = self._phase
        if ph == "head":
            # expecting: vv op d ':'   (or 'A' vv EOS)
            if tok == ANS and not self._buf:
                if self.ops is not None and self.step_idx != len(self.ops):
                    self._fail()  # answered before finishing all steps
                self._phase = "answer"
                self._buf = []
                return self.ok
            self._buf.append(tok)
            n = len(self._buf)
            if n <= 2:
                if not (DIG0 <= tok <= DIG0 + 9):
                    self._fail()
                elif n == 2:
                    head_v = (self._buf[0] - DIG0) * 10 + (self._buf[1] - DIG0)
                    if head_v != self.v:
                        self._fail()
            elif n == 3:
                if tok not in OPS:
                    self._fail()
                elif self.ops is not None:
                    if self.step_idx >= len(self.ops) or tok != self.ops[self.step_idx][0]:
                        self._fail()  # wrong operation for this step
                self._step_op = tok
            elif n == 4:
                if not (DIG0 <= tok <= DIG0 + 9):
                    self._fail()
                else:
                    self._step_d = tok - DIG0
                    if self._step_d < 1:
                        self._fail()
                    elif self.ops is not None and self._step_d != self.ops[self.step_idx][1]:
                        self._fail()  # wrong operand for this step
            elif n == 5:
                if tok != COLON:
                    self._fail()
                else:
                    self._expect = scratch_items(self.v, self._step_op, self._step_d)
                    self._items_seen = 0
                    self._buf = []
                    self._after_redundant = False
                    self._phase = "scratch"
        elif ph == "scratch":
            if tok == FILL:
                if self._buf:
                    self._fail()
                elif self._items_seen >= 2:
                    self._after_redundant = True
                return self.ok
            if tok == EQ:
                if self._buf or (self._items_seen < len(self._expect) and not self._after_redundant):
                    self._fail()
                else:
                    self._buf = []
                    self._phase = "result"
                return self.ok
            if DIG0 <= tok <= DIG0 + 9:
                self._buf.append(tok)
                if len(self._buf) > 2:
                    self._fail()
                return self.ok
            if tok == SPACE:
                if len(self._buf) != 2:
                    self._fail()
                    return self.ok
                val = (self._buf[0] - DIG0) * 10 + (self._buf[1] - DIG0)
                self._buf = []
                if self._after_redundant:
                    # redundant re-listing: must match one of the last items
                    tail = self._expect[-2:]
                    if val not in tail:
                        self._fail()
                else:
                    if self._items_seen >= len(self._expect) or val != self._expect[self._items_seen]:
                        self._fail()
                    self._items_seen += 1
                return self.ok
            self._fail()
        elif ph == "result":
            self._buf.append(tok)
            n = len(self._buf)
            if n <= 2:
                if not (DIG0 <= tok <= DIG0 + 9):
                    self._fail()
            elif n == 3:
                if tok != SEMI:
                    self._fail()
                else:
                    val = (self._buf[0] - DIG0) * 10 + (self._buf[1] - DIG0)
                    want = apply_op(self.v, self._step_op, self._step_d)
                    if val != want:
                        self._fail()
                    else:
                        self.v = want
                        self.step_idx += 1
                        self._buf = []
                        self._phase = "head"
        elif ph == "answer":
            self._buf.append(tok)
            n = len(self._buf)
            if n <= 2:
                if not (DIG0 <= tok <= DIG0 + 9):
                    self._fail()
            elif n == 3:
                if tok != EOS:
                    self._fail()
                else:
                    val = (self._buf[0] - DIG0) * 10 + (self._buf[1] - DIG0)
                    self.answer = val
                    if val != self.v:
                        self._fail()
                    self.done = True
        return self.ok


def label_positions(p: Problem, sol: List[int]) -> List[int]:
    """Per-token 'correct so far' labels for PRM training."""
    st = ValidatorState(v=p.v0, ops=p.ops)
    labels = []
    for t in sol:
        labels.append(1 if st.feed(t) else 0)
    return labels


def extract_answer(sol: List[int]) -> Optional[int]:
    """Final answer from a generated solution (last 'A dd' group)."""
    for i in range(len(sol) - 2):
        if sol[i] == ANS and DIG0 <= sol[i + 1] <= DIG0 + 9 and DIG0 <= sol[i + 2] <= DIG0 + 9:
            return (sol[i + 1] - DIG0) * 10 + (sol[i + 2] - DIG0)
    return None
