//! **Offline compile stub** of the `xla-rs` PJRT bindings.
//!
//! The build environment for this repo has no XLA/PJRT shared library, so
//! this crate exposes exactly the API surface `erprm::runtime` consumes —
//! same type names, same signatures — with every runtime entry point
//! returning a descriptive error. That keeps the full crate (and its unit
//! test suite) compiling and green offline: every engine-touching test
//! skips when `artifacts/` is absent, and `Engine::load` fails cleanly at
//! `PjRtClient::cpu()` if artifacts *are* present but the real bindings
//! are not.
//!
//! To execute compiled artifacts, replace this path dependency with the
//! real `xla-rs` bindings (the API subset here is drop-in compatible).

use std::fmt;
use std::marker::PhantomData;

/// Error type mirroring `xla::Error` (message-only in the stub).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: offline xla stub — vendor the real xla-rs bindings in \
         rust/third_party/xla-rs to execute artifacts"
    )))
}

/// Element types uploadable to device buffers.
pub trait ArrayElement: Copy {}
impl ArrayElement for i32 {}
impl ArrayElement for u32 {}
impl ArrayElement for i64 {}
impl ArrayElement for u64 {}
impl ArrayElement for f32 {}
impl ArrayElement for f64 {}

/// A PJRT device handle (opaque in the stub).
#[derive(Debug)]
pub struct PjRtDevice {
    _private: PhantomData<()>,
}

/// The PJRT client. `Rc`-based in the real bindings, hence `!Send`; the
/// stub mirrors that so threading bugs surface identically offline.
#[derive(Debug)]
pub struct PjRtClient {
    _not_send: PhantomData<std::rc::Rc<()>>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub_err("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        stub_err("PjRtClient::buffer_from_host_buffer")
    }
}

/// A device-resident buffer (opaque in the stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: PhantomData<()>,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled, loaded executable (opaque in the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: PhantomData<()>,
}

impl PjRtLoadedExecutable {
    /// Untupled buffer-donating execution (`execute_b` + untuple).
    pub fn execute_b_untuple(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err("PjRtLoadedExecutable::execute_b_untuple")
    }
}

/// A host-side literal downloaded from device.
#[derive(Debug)]
pub struct Literal {
    _private: PhantomData<()>,
}

impl Literal {
    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        stub_err("Literal::to_vec")
    }
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: PhantomData<()>,
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        stub_err("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation {
    _private: PhantomData<()>,
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: PhantomData }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_entry_points_error_descriptively() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline xla stub"));
        let e = HloModuleProto::from_text_file("x.hlo").unwrap_err();
        assert!(e.to_string().contains("offline xla stub"));
    }
}
