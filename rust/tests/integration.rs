//! Integration tests over the real artifacts (runtime + coordinator + server).
//!
//! These need `make artifacts` to have run; they skip (pass trivially) when
//! the artifacts directory is missing so that `cargo test` works in a fresh
//! checkout. Run `make artifacts && cargo test` for full coverage.

use std::path::{Path, PathBuf};

use erprm::config::{SearchConfig, SearchMode};
use erprm::coordinator::{solve_early_rejection, solve_vanilla};
use erprm::coordinator::early_reject::solve_early_rejection_with_policy;
use erprm::coordinator::policy::RejectPolicy;
use erprm::fleet::FleetOptions;
use erprm::harness;
use erprm::runtime::Engine;
use erprm::server::{api, error_response, http, metrics::Metrics, route, router::EnginePool};
use erprm::server::PoolOptions;
use erprm::tokenizer as tk;
use erprm::util::error::Error;
use erprm::util::threadpool::ThreadPool;
use erprm::workload::{gen_problem, problem_set, OpStep, Problem, SATMATH};
use erprm::util::rng::Rng;

fn artifacts() -> Option<PathBuf> {
    let candidates = [Path::new("artifacts"), Path::new("../artifacts")];
    for c in candidates {
        if c.join("manifest.json").exists() {
            return Some(c.to_path_buf());
        }
    }
    eprintln!("[integration] artifacts missing; skipping (run `make artifacts`)");
    None
}

fn engine() -> Option<Engine> {
    artifacts().map(|dir| Engine::load(&dir).expect("engine load"))
}

fn cfg(mode: SearchMode, n: usize, tau: usize) -> SearchConfig {
    SearchConfig { mode, n_beams: n, tau, seed: 7, ..SearchConfig::default() }
}

#[test]
fn manifest_loads_and_matches_tokenizer() {
    let Some(e) = engine() else { return };
    assert_eq!(e.manifest.vocab.len(), tk::VOCAB_SIZE);
    assert!(e.manifest.models.contains_key("lm"));
    assert!(e.manifest.models.contains_key("prm-large"));
    assert!(e.manifest.models.contains_key("prm-small"));
}

#[test]
fn prefill_returns_logits_and_cache() {
    let Some(e) = engine() else { return };
    let p = Problem { v0: 12, ops: vec![erprm::workload::OpStep { op: tk::PLUS, d: 3 }] };
    let (logits, kv) = e.lm_prefill("lm-concise", &p.prompt_tokens()).unwrap();
    assert_eq!(logits.len(), tk::VOCAB_SIZE);
    assert!(logits.iter().all(|v| v.is_finite()));
    assert_eq!(kv.batch, 1);
    assert_eq!(kv.pos_log[0] as usize, p.prompt_tokens().len());
    // the model should strongly predict the first solution token: '1' of "12"
    let argmax = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    assert_eq!(argmax as i32, tk::DIG0 + 1, "expected '1' to start '12+3:'");
}

#[test]
fn broadcast_and_gather_preserve_bookkeeping() {
    let Some(e) = engine() else { return };
    let p = Problem { v0: 40, ops: vec![erprm::workload::OpStep { op: tk::MINUS, d: 2 }] };
    let (_, kv1) = e.lm_prefill("lm-concise", &p.prompt_tokens()).unwrap();
    let mut kv = e.kv_broadcast("lm-concise", &kv1, 4).unwrap();
    assert_eq!(kv.batch, 4);
    assert!(kv.pos_log.iter().all(|&l| l as usize == p.prompt_tokens().len()));
    kv.commit(2, kv.pos_phys, 0); // no-op commit is fine
    e.kv_gather("lm-concise", &mut kv, &[3, 2, 1, 0]).unwrap();
    assert_eq!(kv.batch, 4);
}

#[test]
fn decode_block_is_deterministic_per_keys() {
    let Some(e) = engine() else { return };
    let p = Problem { v0: 25, ops: vec![erprm::workload::OpStep { op: tk::PLUS, d: 4 }] };
    let (_, kv1) = e.lm_prefill("lm-concise", &p.prompt_tokens()).unwrap();
    let run = |e: &Engine| {
        let mut kv = e.kv_broadcast("lm-concise", &kv1, 4).unwrap();
        let prev = vec![tk::DIG0 + 2; 4];
        let keys: Vec<u32> = (0..8).collect();
        e.lm_decode_block("lm-concise", &mut kv, &prev, 0.7, &keys).unwrap()
    };
    let a = run(&e);
    let b = run(&e);
    assert_eq!(a, b);
    assert_eq!(a.len(), 4 * e.manifest.decode_block);
    assert!(a.iter().all(|&t| (0..tk::VOCAB_SIZE as i32).contains(&t)));
}

#[test]
fn prm_scores_are_probabilities() {
    let Some(e) = engine() else { return };
    let p = Problem { v0: 33, ops: vec![erprm::workload::OpStep { op: tk::PLUS, d: 2 }] };
    let mut kv = {
        let kv1 = e.prm_prefill("prm-large", &p.prompt_tokens()).unwrap();
        e.kv_broadcast("prm-large", &kv1, 4).unwrap()
    };
    let sol = p.gold_solution();
    let t = e.manifest.score_block;
    let mut tokens = vec![tk::PAD; 4 * t];
    let n = sol.len().min(t);
    for slot in 0..4 {
        tokens[slot * t..slot * t + n].copy_from_slice(&sol[..n]);
    }
    let scores = e.prm_score_block("prm-large", &mut kv, &tokens).unwrap();
    assert_eq!(scores.len(), 4 * t);
    assert!(scores.iter().all(|&s| s > 0.0 && s < 1.0));
    // identical inputs in every slot must give identical scores
    for slot in 1..4 {
        for i in 0..n {
            assert!((scores[i] - scores[slot * t + i]).abs() < 1e-5);
        }
    }
}

#[test]
fn prm_fullseq_matches_incremental() {
    let Some(e) = engine() else { return };
    let p = Problem { v0: 33, ops: vec![erprm::workload::OpStep { op: tk::PLUS, d: 2 }] };
    let sol = p.gold_solution();
    let prompt = p.prompt_tokens();
    let seq: Vec<i32> = prompt.iter().chain(sol.iter()).cloned().collect();
    let fb = e.manifest.fullseq_batch;
    let s = e.manifest.seq_train;
    let mut tokens = vec![tk::PAD; fb * s];
    tokens[..seq.len()].copy_from_slice(&seq);
    let lens: Vec<i32> = (0..fb).map(|i| if i == 0 { seq.len() as i32 } else { 1 }).collect();
    let (score, cummin, _) = e.prm_fullseq("prm-large", &tokens, &lens).unwrap();

    // incremental path on the same trace
    let mut kv = {
        let kv1 = e.prm_prefill("prm-large", &prompt).unwrap();
        e.kv_broadcast("prm-large", &kv1, 4).unwrap()
    };
    let t = e.manifest.score_block;
    let mut got = Vec::new();
    let mut i0 = 0usize;
    while i0 < sol.len() {
        let n = (sol.len() - i0).min(t);
        let mut blk = vec![tk::PAD; 4 * t];
        for slot in 0..4 {
            blk[slot * t..slot * t + n].copy_from_slice(&sol[i0..i0 + n]);
        }
        let frontier = kv.pos_phys;
        let sc = e.prm_score_block("prm-large", &mut kv, &blk).unwrap();
        got.extend_from_slice(&sc[..n]);
        for slot in 0..4 {
            kv.commit(slot, frontier, n);
        }
        i0 += n;
    }
    for (i, g) in got.iter().enumerate() {
        let want = score[prompt.len() + i];
        assert!(
            (g - want).abs() < 1e-4,
            "token {i}: incremental {g} vs fullseq {want}"
        );
    }
    // cummin is monotone nonincreasing over the valid span
    for i in 1..seq.len() {
        assert!(cummin[i] <= cummin[i - 1] + 1e-6);
    }
}

#[test]
fn vanilla_and_er_solve_end_to_end() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(5);
    let p = gen_problem(&mut rng, &SATMATH);
    let van = solve_vanilla(&e, "lm-concise", "prm-large", &p, &cfg(SearchMode::Vanilla, 4, 8), 0.5).unwrap();
    let er = solve_early_rejection(&e, "lm-concise", "prm-large", &p, &cfg(SearchMode::EarlyRejection, 4, 8), 0.5).unwrap();
    for out in [&van, &er] {
        assert!(out.steps_executed >= 1);
        assert!(out.ledger.total_flops() > 0.0);
        assert!(!out.best_trace.is_empty());
    }
    // ER must do no more generation work than vanilla on the same problem
    assert!(
        er.ledger.lm_decode_tokens <= van.ledger.lm_decode_tokens,
        "ER {} vs vanilla {} decode tokens",
        er.ledger.lm_decode_tokens,
        van.ledger.lm_decode_tokens
    );
}

#[test]
fn best_of_n_baseline_runs() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(21);
    let p = gen_problem(&mut rng, &SATMATH);
    let out = erprm::coordinator::solve_best_of_n(
        &e, "lm-concise", "prm-large", &p, &cfg(SearchMode::Vanilla, 4, 8), 0.5,
    )
    .unwrap();
    assert!(out.ledger.total_flops() > 0.0);
    assert!(out.steps_executed >= 1);
}

#[test]
fn er_policies_run() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(9);
    let p = gen_problem(&mut rng, &SATMATH);
    let c = cfg(SearchMode::EarlyRejection, 4, 8);
    for policy in [
        RejectPolicy::TopK { keep: 1 },
        RejectPolicy::Threshold { min_score: 0.5, floor: 1 },
        RejectPolicy::AdaptiveGap { keep: 1, min_gap: 0.05 },
    ] {
        let out = solve_early_rejection_with_policy(
            &e, "lm-concise", "prm-large", &p, &c, 0.5, policy, true,
        )
        .unwrap();
        assert!(out.ledger.total_flops() > 0.0);
    }
}

#[test]
fn harness_cell_runs_and_aggregates() {
    let Some(e) = engine() else { return };
    let cell = harness::Cell {
        bench: SATMATH,
        lm_ckpt: "lm-concise".into(),
        prm_ckpt: "prm-small".into(),
        mode: SearchMode::EarlyRejection,
        n_beams: 4,
        tau: 8,
    };
    let res = harness::run_cell(&e, &cell, 2, 123).unwrap();
    assert_eq!(res.n_problems, 2);
    assert!(res.accuracy >= 0.0 && res.accuracy <= 100.0);
    assert!(res.ledger.total_flops() > 0.0);
}

#[test]
fn correlation_corpus_scores() {
    let Some(e) = engine() else { return };
    let traces =
        erprm::harness::correlation::score_corpus(&e, "prm-small", &SATMATH, 8, 3).unwrap();
    assert_eq!(traces.len(), 8);
    for t in &traces {
        assert!(t.len > 10);
        assert!(t.final_reward() > 0.0 && t.final_reward() < 1.0);
        // cummin monotone
        for i in 1..t.len {
            assert!(t.cummin[i] <= t.cummin[i - 1] + 1e-6);
        }
    }
}

// ---------------------------------------------------------------- serving

fn http_get(addr: std::net::SocketAddr, reqbytes: &[u8]) -> String {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.write_all(reqbytes).unwrap();
    let mut out = String::new();
    let _ = s.read_to_string(&mut out);
    out
}

// The Saturated error must render as HTTP 503 + Retry-After end to end.
// Pure HTTP-layer test: needs no artifacts, always runs.
#[test]
fn saturated_error_maps_to_503_over_http() {
    let pool = ThreadPool::new(2);
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let addr = http::serve(
        "127.0.0.1:0",
        &pool,
        1024,
        std::sync::Arc::clone(&stop),
        std::sync::Arc::new(|_| error_response(&Error::saturated("all shard queues full"))),
    )
    .unwrap();
    let out = http_get(addr, b"POST /solve HTTP/1.1\r\nContent-Length: 0\r\n\r\n");
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    assert!(out.starts_with("HTTP/1.1 503"), "{out}");
    assert!(out.contains("Retry-After"), "{out}");
    assert!(out.contains("saturated"), "{out}");
}

fn solve_body() -> &'static [u8] {
    br#"{"v0": 61, "ops": [["-",5],["*",6],["+",4]], "mode": "er", "n_beams": 8, "tau": 8}"#
}

#[test]
fn pool_saturation_returns_503_and_depth_recovers() {
    let Some(dir) = artifacts() else { return };
    // 1 shard x 1 queue slot: concurrent requests must overflow into 503.
    let epool = EnginePool::spawn(dir, 1, 1, 0).unwrap();
    let metrics = std::sync::Arc::new(Metrics::default());
    let tpool = ThreadPool::new(8);
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let p2 = epool.clone();
    let m2 = std::sync::Arc::clone(&metrics);
    let life2 = erprm::server::Lifecycle::new();
    let addr = http::serve(
        "127.0.0.1:0",
        &tpool,
        1 << 20,
        std::sync::Arc::clone(&stop),
        std::sync::Arc::new(move |req| route(&p2, &m2, &SearchConfig::default(), &life2, req)),
    )
    .unwrap();
    let req = format!(
        "POST /solve HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        solve_body().len(),
        std::str::from_utf8(solve_body()).unwrap()
    );
    let joins: Vec<_> = (0..6)
        .map(|_| {
            let req = req.clone();
            std::thread::spawn(move || http_get(addr, req.as_bytes()))
        })
        .collect();
    let mut ok = 0;
    let mut saturated = 0;
    for j in joins {
        let out = j.join().unwrap();
        if out.starts_with("HTTP/1.1 200") {
            ok += 1;
        } else if out.starts_with("HTTP/1.1 503") {
            assert!(out.contains("Retry-After"), "{out}");
            saturated += 1;
        } else {
            panic!("unexpected response: {out}");
        }
    }
    assert_eq!(ok + saturated, 6);
    assert!(ok >= 1, "at least one request must be served");
    assert!(saturated >= 1, "1-slot pool under 6 concurrent requests must shed load");
    // the depth gauge must fully recover once the queue drains
    assert_eq!(epool.queue_depth(), 0, "depth gauge leaked");
    let metrics_text = http_get(addr, b"GET /metrics HTTP/1.1\r\n\r\n");
    assert!(
        metrics_text.contains("erprm_shard_queue_depth{shard=\"0\"} 0"),
        "{metrics_text}"
    );
    assert!(metrics_text.contains("erprm_errors_5xx_total"), "{metrics_text}");
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    epool.shutdown();
}

#[test]
fn sharding_preserves_seed_determinism() {
    let Some(dir) = artifacts() else { return };
    let epool = EnginePool::spawn(dir, 2, 4, 0).unwrap();
    let cfg = SearchConfig::default();
    let req = api::parse_solve(solve_body(), &cfg).unwrap();
    // Same (problem, seed) on two different shards — two distinct engine
    // instances — must produce byte-identical traces and ledgers.
    let a = epool.solve_on_shard(0, req.clone(), cfg.clone()).unwrap();
    let b = epool.solve_on_shard(1, req.clone(), cfg.clone()).unwrap();
    assert_eq!(a.answer, b.answer);
    assert_eq!(a.best_trace, b.best_trace, "traces diverged across shards");
    assert_eq!(a.ledger, b.ledger, "FLOPs accounting diverged across shards");
    let solves = epool.shard_solves();
    assert_eq!(solves, vec![1, 1], "each shard must have executed exactly once");
    epool.shutdown();
}

#[test]
fn cache_hit_returns_identical_body_and_counts() {
    let Some(dir) = artifacts() else { return };
    let epool = EnginePool::spawn(dir, 1, 4, 16).unwrap();
    let cfg = SearchConfig::default();
    let req = api::parse_solve(solve_body(), &cfg).unwrap();
    let first = epool.solve(req.clone(), cfg.clone()).unwrap();
    let second = epool.solve(req.clone(), cfg.clone()).unwrap();
    assert_eq!(epool.cache_counters(), (1, 1), "second solve must hit the cache");
    assert_eq!(
        api::render_solve(&req, &first, 0.0),
        api::render_solve(&req, &second, 0.0),
        "cache hit must render a byte-identical body"
    );
    assert_eq!(
        epool.shard_solves(),
        vec![1],
        "the engine must only have run once"
    );
    assert!(epool.render_metrics().contains("erprm_cache_hits_total 1"));
    epool.shutdown();
}

// ------------------------------------------------------------------ fleet

fn fleet_pool(dir: PathBuf, shards: usize, max_inflight: usize, cache: usize) -> EnginePool {
    EnginePool::spawn_with(
        dir,
        PoolOptions {
            shards,
            capacity: 64,
            cache_entries: cache,
            default_deadline_ms: 0,
            fleet: Some(FleetOptions { max_inflight, ..FleetOptions::default() }),
            // off so these tests keep exercising the *shard-local*
            // coalescer; the pool-level table has its own tests
            singleflight: false,
            kv_pool_blocks: None,
            trace: erprm::obs::TraceOptions::default(),
            ..PoolOptions::default()
        },
    )
    .expect("fleet pool spawn")
}

// The acceptance gate for the fleet refactor: a solve interleaved with
// other in-flight requests must produce the same outcome, byte for byte
// (modulo wall-clock), as the same (problem, cfg, seed) solved alone.
#[test]
fn fleet_interleaving_preserves_sequential_outcomes() {
    let Some(dir) = artifacts() else { return };
    let e = Engine::load(&dir).expect("engine load");
    let cfg = cfg(SearchMode::EarlyRejection, 8, 8);
    let problems = problem_set(&SATMATH, 4, 99);
    let reference: Vec<_> = problems
        .iter()
        .map(|p| solve_early_rejection(&e, "lm-concise", "prm-large", p, &cfg, 0.5).unwrap())
        .collect();

    // Same problems through a 1-shard fleet pool, all in flight at once,
    // so their tasks interleave on one engine.
    let epool = fleet_pool(dir, 1, 4, 0);
    let joins: Vec<_> = problems
        .iter()
        .cloned()
        .map(|p| {
            let pool = epool.clone();
            let c = cfg.clone();
            std::thread::spawn(move || {
                let req = api::SolveRequest {
                    problem: p,
                    mode: SearchMode::EarlyRejection,
                    n_beams: 8,
                    tau: 8,
                    lm: "lm-concise".into(),
                    prm: "prm-large".into(),
                    deadline_ms: None,
                    priority: 0,
                    request_id: String::new(),
                };
                pool.solve(req, c).unwrap()
            })
        })
        .collect();
    for (i, j) in joins.into_iter().enumerate() {
        let out = j.join().unwrap();
        assert_eq!(out.answer, reference[i].answer, "problem {i}: answer diverged");
        assert_eq!(
            out.best_trace, reference[i].best_trace,
            "problem {i}: trace diverged under interleaving"
        );
        assert_eq!(
            out.ledger, reference[i].ledger,
            "problem {i}: FLOPs accounting diverged under interleaving"
        );
    }
    let t = epool.fleet_totals().expect("fleet totals");
    assert_eq!(
        t.completed + t.coalesced,
        4,
        "every request must have been served by a completed task"
    );
    assert_eq!(t.failed + t.expired, 0);
    epool.shutdown();
}

#[test]
fn fleet_coalesces_duplicate_inflight_requests() {
    let Some(dir) = artifacts() else { return };
    let epool = fleet_pool(dir, 1, 4, 0);
    let cfg = SearchConfig::default();
    let req = api::parse_solve(solve_body(), &cfg).unwrap();
    let joins: Vec<_> = (0..4)
        .map(|_| {
            let pool = epool.clone();
            let c = cfg.clone();
            let r = req.clone();
            std::thread::spawn(move || pool.solve(r, c).unwrap())
        })
        .collect();
    let outs: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    for o in &outs[1..] {
        assert_eq!(o.best_trace, outs[0].best_trace, "duplicates must agree");
        assert_eq!(o.ledger, outs[0].ledger);
    }
    let t = epool.fleet_totals().expect("fleet totals");
    assert_eq!(
        t.admitted + t.coalesced,
        4,
        "every duplicate either ran its own task or rode an in-flight one"
    );
    assert_eq!(t.failed + t.expired, 0);
    epool.shutdown();
}

#[test]
fn fleet_deadline_expires_as_504() {
    let Some(dir) = artifacts() else { return };
    let epool = fleet_pool(dir, 1, 2, 0);
    let cfg = SearchConfig::default();
    let mut req = api::parse_solve(solve_body(), &cfg).unwrap();
    req.deadline_ms = Some(1); // a real solve takes far longer than 1ms
    let err = epool.solve(req, cfg.clone()).unwrap_err();
    assert_eq!(err.http_status(), 504, "{err}");
    let t = epool.fleet_totals().expect("fleet totals");
    assert!(t.expired >= 1, "the abort must be counted: {t:?}");
    // the pool stays healthy for bounded requests afterwards
    let ok = epool.solve(api::parse_solve(solve_body(), &cfg).unwrap(), cfg).unwrap();
    assert!(ok.ledger.total_flops() > 0.0);
    epool.shutdown();
}

#[test]
fn fleet_serves_over_http_with_queue_wait_and_metrics() {
    let Some(dir) = artifacts() else { return };
    let epool = fleet_pool(dir, 1, 4, 0);
    let metrics = std::sync::Arc::new(Metrics::default());
    let tpool = ThreadPool::new(4);
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let p2 = epool.clone();
    let m2 = std::sync::Arc::clone(&metrics);
    let life2 = erprm::server::Lifecycle::new();
    let addr = http::serve(
        "127.0.0.1:0",
        &tpool,
        1 << 20,
        std::sync::Arc::clone(&stop),
        std::sync::Arc::new(move |req| route(&p2, &m2, &SearchConfig::default(), &life2, req)),
    )
    .unwrap();
    let req = format!(
        "POST /solve HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        solve_body().len(),
        std::str::from_utf8(solve_body()).unwrap()
    );
    let out = http_get(addr, req.as_bytes());
    assert!(out.starts_with("HTTP/1.1 200"), "{out}");
    assert!(out.contains("queue_wait_ms"), "response must carry scheduling delay: {out}");
    let metrics_text = http_get(addr, b"GET /metrics HTTP/1.1\r\n\r\n");
    assert!(metrics_text.contains("erprm_fleet_enabled 1"), "{metrics_text}");
    assert!(metrics_text.contains("erprm_fleet_admitted_total 1"), "{metrics_text}");
    assert!(metrics_text.contains("erprm_queue_wait_ms_p95"), "{metrics_text}");
    assert!(metrics_text.contains("erprm_latency_ms_p99"), "{metrics_text}");
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    epool.shutdown();
}

// ------------------------------------------------------------------- gang

// Engine-level core of gang batching: two requests' caches merged into
// one shared batch must decode exactly the tokens each would have sampled
// alone (per-slot math never crosses rows), and split back into caches
// whose bookkeeping matches the sources.
#[test]
fn kv_merge_decode_matches_solo_decode() {
    let Some(e) = engine() else { return };
    if !e.manifest.model("lm").unwrap().has_program("merge_b4_b4_to_b8") {
        eprintln!("[integration] artifacts lack merge programs; skipping gang engine test");
        return;
    }
    let pa = Problem { v0: 25, ops: vec![OpStep { op: tk::PLUS, d: 4 }] };
    let pb = Problem { v0: 61, ops: vec![OpStep { op: tk::MINUS, d: 5 }] };
    let (_, ka1) = e.lm_prefill("lm-concise", &pa.prompt_tokens()).unwrap();
    let (_, kb1) = e.lm_prefill("lm-concise", &pb.prompt_tokens()).unwrap();
    let prev_a = vec![tk::DIG0 + 2; 4];
    let prev_b = vec![tk::DIG0 + 3; 4];
    let keys_a: Vec<u32> = (0..8).collect();
    let keys_b: Vec<u32> = (100..108).collect();
    // solo references
    let mut ka = e.kv_broadcast("lm-concise", &ka1, 4).unwrap();
    let solo_a = e.lm_decode_block("lm-concise", &mut ka, &prev_a, 0.7, &keys_a).unwrap();
    let mut kb = e.kv_broadcast("lm-concise", &kb1, 4).unwrap();
    let solo_b = e.lm_decode_block("lm-concise", &mut kb, &prev_b, 0.7, &keys_b).unwrap();
    // merged: fresh caches, one shared b8 call
    let ka = e.kv_broadcast("lm-concise", &ka1, 4).unwrap();
    let kb = e.kv_broadcast("lm-concise", &kb1, 4).unwrap();
    let idx: Vec<i32> = (0..8).collect();
    let mut merged = e.kv_merge("lm-concise", &ka, &kb, &idx).unwrap();
    assert_eq!(merged.batch, 8);
    assert_eq!(merged.pos_phys, ka.pos_phys.max(kb.pos_phys));
    assert_eq!(&merged.pos_log[..4], &ka.pos_log[..]);
    assert_eq!(&merged.pos_log[4..], &kb.pos_log[..]);
    let mut prev = prev_a.clone();
    prev.extend(&prev_b);
    let mut keys = keys_a.clone();
    keys.extend(&keys_b);
    let sampled = e.lm_decode_block("lm-concise", &mut merged, &prev, 0.7, &keys).unwrap();
    let db = e.manifest.decode_block;
    assert_eq!(&sampled[..4 * db], &solo_a[..], "member A rows diverged in the shared batch");
    assert_eq!(&sampled[4 * db..], &solo_b[..], "member B rows diverged in the shared batch");
    // split back restores per-request caches with the merged frontier
    let sa = e.kv_split("lm-concise", &merged, 0, 4).unwrap();
    let sb = e.kv_split("lm-concise", &merged, 4, 4).unwrap();
    assert_eq!(sa.batch, 4);
    assert_eq!(sa.pos_phys, merged.pos_phys);
    assert_eq!(sa.pos_log, ka.pos_log);
    assert_eq!(sb.pos_log, kb.pos_log);
}

// The gang acceptance gate (extending the fleet interleaving-determinism
// proof one level deeper): a solve whose decode/score calls ran inside
// shared device batches must produce the same SolveOutcome, byte for
// byte (modulo wall-clock), as the same (problem, cfg, seed) solved
// alone.
#[test]
fn gang_batched_solves_are_byte_identical_to_solo() {
    let Some(dir) = artifacts() else { return };
    let e = Engine::load(&dir).expect("engine load");
    let has_merge =
        e.manifest.model("lm").map(|m| m.has_program("merge_b8_b8_to_b16")).unwrap_or(false);
    let c = cfg(SearchMode::EarlyRejection, 8, 8);
    let problems = problem_set(&SATMATH, 4, 99);
    let reference: Vec<_> = problems
        .iter()
        .map(|p| solve_early_rejection(&e, "lm-concise", "prm-large", p, &c, 0.5).unwrap())
        .collect();
    drop(e);

    let epool = EnginePool::spawn_with(
        dir,
        PoolOptions {
            shards: 1,
            capacity: 64,
            cache_entries: 0,
            default_deadline_ms: 0,
            fleet: Some(FleetOptions { max_inflight: 4, gang: true, ..FleetOptions::default() }),
            singleflight: false,
            kv_pool_blocks: None,
            trace: erprm::obs::TraceOptions::default(),
            ..PoolOptions::default()
        },
    )
    .expect("gang pool spawn");
    let joins: Vec<_> = problems
        .iter()
        .cloned()
        .map(|p| {
            let pool = epool.clone();
            let cc = c.clone();
            std::thread::spawn(move || {
                let req = api::SolveRequest {
                    problem: p,
                    mode: SearchMode::EarlyRejection,
                    n_beams: 8,
                    tau: 8,
                    lm: "lm-concise".into(),
                    prm: "prm-large".into(),
                    deadline_ms: None,
                    priority: 0,
                    request_id: String::new(),
                };
                pool.solve(req, cc).unwrap()
            })
        })
        .collect();
    for (i, j) in joins.into_iter().enumerate() {
        let out = j.join().unwrap();
        assert_eq!(out.answer, reference[i].answer, "problem {i}: answer diverged under gang");
        assert_eq!(
            out.best_trace, reference[i].best_trace,
            "problem {i}: trace diverged under gang batching"
        );
        assert_eq!(
            out.ledger, reference[i].ledger,
            "problem {i}: FLOPs accounting diverged under gang batching"
        );
    }
    let t = epool.fleet_totals().expect("fleet totals");
    assert_eq!(t.failed + t.expired, 0, "{t:?}");
    let b = epool.batch_totals().expect("batch totals in gang mode");
    if has_merge {
        assert!(
            b.gangs >= 1,
            "4 concurrent same-shape requests never shared a batch: {b:?}"
        );
        assert!(b.merged_slots >= 16, "{b:?}");
    } else {
        eprintln!("[integration] artifacts lack merge programs; gang degraded to solo: {b:?}");
    }
    epool.shutdown();
}

// Client disconnect cancellation: a request whose every reply channel is
// closed must be dropped (queued) or cancelled (mid-flight) so the slot
// goes back to real work — never run to completion for nobody.
#[test]
fn fleet_cancels_abandoned_requests() {
    let Some(e) = engine() else { return };
    let stats = erprm::fleet::FleetStats::default();
    let bstats = erprm::batch::BatchStats::default();
    let solved = std::sync::atomic::AtomicU64::new(0);
    let estats = std::sync::Mutex::new(erprm::runtime::EngineStats::default());
    let (tx, rx) = erprm::util::oneshot::channel();
    let job = erprm::fleet::FleetJob {
        spec: erprm::fleet::TaskSpec {
            problem: Problem { v0: 61, ops: vec![OpStep { op: tk::MINUS, d: 5 }] },
            mode: SearchMode::EarlyRejection,
            lm: "lm-concise".into(),
            prm: "prm-large".into(),
            cfg: cfg(SearchMode::EarlyRejection, 8, 8),
            temp: 0.5,
            tau_plan: None,
        },
        key: None,
        enqueued: std::time::Instant::now(),
        deadline: None,
        priority: 0,
        reply: tx,
        trace: None,
    };
    let mut pending = vec![job];
    let mut rx_holder = Some(rx);
    let mut calls = 0u64;
    let tracer = erprm::obs::TraceRecorder::new(erprm::obs::TraceOptions::default());
    erprm::fleet::drive(&e, &FleetOptions::default(), &stats, &bstats, &solved, &estats, 0, &tracer, |_| {
        calls += 1;
        if let Some(j) = pending.pop() {
            return erprm::fleet::Poll::Job(Box::new(j));
        }
        if calls > 3 {
            // the client hangs up while the task is mid-flight
            rx_holder.take();
        }
        if calls > 5_000 {
            erprm::fleet::Poll::Closed
        } else {
            erprm::fleet::Poll::Empty
        }
    });
    let t = stats.totals();
    assert_eq!(t.cancelled, 1, "{t:?}");
    assert_eq!(t.completed, 0, "{t:?}");
    assert_eq!(t.failed, 0, "{t:?}");
    assert_eq!(
        solved.load(std::sync::atomic::Ordering::Relaxed),
        0,
        "the abandoned solve must not run to completion"
    );
}

// Deadline-aware admission: once a service-time estimate exists, a
// bounded job whose queue-wait forecast exceeds its budget bounces with
// 504 at the door (distinct counter from queue expiry) instead of
// occupying a slot it cannot finish in.
#[test]
fn fleet_rejects_doomed_deadlines_at_admission() {
    let Some(e) = engine() else { return };
    let stats = erprm::fleet::FleetStats::default();
    let bstats = erprm::batch::BatchStats::default();
    let solved = std::sync::atomic::AtomicU64::new(0);
    let estats = std::sync::Mutex::new(erprm::runtime::EngineStats::default());
    let spec = erprm::fleet::TaskSpec {
        problem: Problem { v0: 61, ops: vec![OpStep { op: tk::MINUS, d: 5 }] },
        mode: SearchMode::EarlyRejection,
        lm: "lm-concise".into(),
        prm: "prm-large".into(),
        cfg: cfg(SearchMode::EarlyRejection, 8, 8),
        temp: 0.5,
        tau_plan: None,
    };
    let mk = |deadline: Option<std::time::Duration>| {
        let (tx, rx) = erprm::util::oneshot::channel();
        (
            erprm::fleet::FleetJob {
                spec: spec.clone(),
                key: None,
                enqueued: std::time::Instant::now(),
                deadline,
                priority: 0,
                reply: tx,
                trace: None,
            },
            rx,
        )
    };
    let (warm, _warm_rx) = mk(None);
    let (long, _long_rx) = mk(None);
    let (doomed, doomed_rx) = mk(Some(std::time::Duration::from_millis(1)));
    let opts = FleetOptions { max_inflight: 1, ..FleetOptions::default() };
    let mut phase = 0u32;
    let mut warm = Some(warm);
    let mut long = Some(long);
    let mut doomed = Some(doomed);
    let tracer = erprm::obs::TraceRecorder::new(erprm::obs::TraceOptions::default());
    erprm::fleet::drive(&e, &opts, &stats, &bstats, &solved, &estats, 0, &tracer, |_| {
        use std::sync::atomic::Ordering;
        match phase {
            // 1. one warm-up solve teaches the loop its mean service time
            0 => {
                phase = 1;
                erprm::fleet::Poll::Job(Box::new(warm.take().unwrap()))
            }
            1 => {
                if stats.completed_total.load(Ordering::Relaxed) >= 1 {
                    phase = 2;
                    erprm::fleet::Poll::Job(Box::new(long.take().unwrap()))
                } else {
                    erprm::fleet::Poll::Empty
                }
            }
            // 2. with `long` ahead of it, the 1ms job's forecast is hopeless
            2 => {
                phase = 3;
                erprm::fleet::Poll::Job(Box::new(doomed.take().unwrap()))
            }
            _ => {
                if stats.completed_total.load(Ordering::Relaxed) >= 2 {
                    erprm::fleet::Poll::Closed
                } else {
                    erprm::fleet::Poll::Empty
                }
            }
        }
    });
    let t = stats.totals();
    assert_eq!(t.forecast_rejected, 1, "{t:?}");
    assert_eq!(t.completed, 2, "{t:?}");
    assert_eq!(t.expired, 0, "rejection must use the forecast path, not queue expiry: {t:?}");
    let err = doomed_rx.recv().expect("a reply").unwrap_err();
    assert_eq!(err.http_status(), 504, "{err}");
}

// ------------------------------------------------------------- compaction

// The compaction acceptance gate: a solve that re-compacts its KV caches
// mid-flight must produce the same SolveOutcome, byte for byte (modulo
// wall-clock), as one that never compacts — equivalently, as a solve
// whose cache was always large enough to never fragment. Compaction only
// moves K/V entries whose junk neighbours the validity mask already
// excludes (contributing exact zeros to attention), and preserves each
// slot's attendable sequence in order, so it is semantically invisible.
#[test]
fn compaction_mid_flight_is_byte_identical_to_uncompacted() {
    let Some(dir) = artifacts() else { return };
    let e = Engine::load(&dir).expect("engine load");
    if !e.manifest.model("lm").map(|m| m.has_program("compact_b8")).unwrap_or(false) {
        eprintln!("[integration] artifacts lack compact programs; skipping compaction test");
        return;
    }
    let base = cfg(SearchMode::EarlyRejection, 8, 8);
    let never = SearchConfig { compact_junk: 1.0, ..base.clone() };
    let eager = SearchConfig { compact_junk: 0.0, ..base };
    let problems = problem_set(&SATMATH, 3, 4242);
    let reference: Vec<_> = problems
        .iter()
        .map(|p| solve_early_rejection(&e, "lm-concise", "prm-large", p, &never, 0.5).unwrap())
        .collect();
    assert_eq!(
        e.stats().compact_calls,
        0,
        "threshold 1.0 must not compact these short workloads (rescue never fires)"
    );
    for (i, p) in problems.iter().enumerate() {
        let out =
            solve_early_rejection(&e, "lm-concise", "prm-large", p, &eager, 0.5).unwrap();
        assert_eq!(out.answer, reference[i].answer, "problem {i}: answer diverged");
        assert_eq!(
            out.best_trace, reference[i].best_trace,
            "problem {i}: trace diverged under mid-flight compaction"
        );
        assert_eq!(
            out.ledger, reference[i].ledger,
            "problem {i}: FLOPs accounting diverged under compaction (compaction must \
             never be charged)"
        );
        assert_eq!(out.steps_executed, reference[i].steps_executed, "problem {i}");
        assert_eq!(out.finished_beams, reference[i].finished_beams, "problem {i}");
    }
    let s = e.stats();
    assert!(
        s.compact_calls >= 1,
        "threshold 0.0 must have compacted mid-flight (reclaimed {}, calls {})",
        s.compact_reclaimed,
        s.compact_calls
    );
    assert!(s.compact_reclaimed > 0, "compactions must reclaim positions: {s:?}");
}

// Engine-level compaction semantics against real device buffers: a
// decode after compaction samples exactly what it would have sampled
// without one, and the cache regains the reclaimed headroom.
#[test]
fn kv_compact_is_invisible_to_decode() {
    let Some(e) = engine() else { return };
    if !e.manifest.model("lm").unwrap().has_program("compact_b4") {
        eprintln!("[integration] artifacts lack compact programs; skipping");
        return;
    }
    let p = Problem { v0: 25, ops: vec![OpStep { op: tk::PLUS, d: 4 }] };
    let (_, kv1) = e.lm_prefill("lm-concise", &p.prompt_tokens()).unwrap();
    let prev = vec![tk::DIG0 + 2; 4];
    let keys: Vec<u32> = (0..8).collect();
    // reference: decode on the fragmented cache (prompt junk up to
    // PROMPT_PAD stays in place)
    let mut plain = e.kv_broadcast("lm-concise", &kv1, 4).unwrap();
    let ref_toks = e.lm_decode_block("lm-concise", &mut plain, &prev, 0.7, &keys).unwrap();
    // compacted: same cache repacked first
    let mut packed = e.kv_broadcast("lm-concise", &kv1, 4).unwrap();
    let frontier_before = packed.pos_phys;
    let changed = e.kv_compact("lm-concise", &mut packed).unwrap();
    assert!(changed, "prompt padding junk must be reclaimable");
    assert!(packed.pos_phys < frontier_before, "frontier must drop");
    assert_eq!(
        packed.pos_phys as i32, packed.pos_log[0],
        "dense frontier equals the prompt length"
    );
    let toks = e.lm_decode_block("lm-concise", &mut packed, &prev, 0.7, &keys).unwrap();
    assert_eq!(toks, ref_toks, "compaction changed sampled tokens");
    // idempotence: a dense cache has nothing to reclaim
    let mut again = e.kv_broadcast("lm-concise", &kv1, 4).unwrap();
    e.kv_compact("lm-concise", &mut again).unwrap();
    assert!(!e.kv_compact("lm-concise", &mut again).unwrap());
}

// ------------------------------------------------- pool single-flight

// Cross-shard coalescing (ROADMAP): identical concurrent requests must
// share one engine run even when least-loaded placement would have
// scattered them across different shards. The accounting identity is
// race-free: every request either ran on a shard or coalesced at the
// pool.
#[test]
fn pool_singleflight_coalesces_across_shards() {
    let Some(dir) = artifacts() else { return };
    let epool = EnginePool::spawn_with(
        dir,
        PoolOptions {
            shards: 2,
            capacity: 8,
            cache_entries: 0,
            default_deadline_ms: 0,
            fleet: None,
            singleflight: true,
            kv_pool_blocks: None,
            trace: erprm::obs::TraceOptions::default(),
            ..PoolOptions::default()
        },
    )
    .expect("pool spawn");
    let cfg = SearchConfig::default();
    let req = api::parse_solve(solve_body(), &cfg).unwrap();
    let joins: Vec<_> = (0..4)
        .map(|_| {
            let pool = epool.clone();
            let c = cfg.clone();
            let r = req.clone();
            std::thread::spawn(move || pool.solve(r, c).unwrap())
        })
        .collect();
    let outs: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    for o in &outs[1..] {
        assert_eq!(o.best_trace, outs[0].best_trace, "followers must agree with the leader");
        assert_eq!(o.ledger, outs[0].ledger);
    }
    let engine_runs: u64 = epool.shard_solves().iter().sum();
    assert_eq!(
        engine_runs + epool.pool_coalesced(),
        4,
        "every request either led an engine run or coalesced at the pool"
    );
    assert!(engine_runs >= 1);
    let text = epool.render_metrics();
    assert!(text.contains("erprm_pool_singleflight_enabled 1"), "{text}");
    assert!(text.contains("erprm_kv_junk_fraction"), "{text}");
    assert!(text.contains("erprm_kv_compact_total"), "{text}");
    epool.shutdown();
}

// --------------------------------------------------------------- paged kv

// The paged-KV acceptance gate: a solve whose caches live in block tables
// over the shared device pool must produce the same SolveOutcome, byte
// for byte (modulo wall-clock), as the same (problem, cfg, seed) solved
// on dense per-slot caches — paging is pure bookkeeping. And when the
// last solve's caches drop, every block must be back in the pool.
#[test]
fn paged_solves_are_byte_identical_to_dense() {
    let Some(dir) = artifacts() else { return };
    let dense = Engine::load(&dir).expect("engine load");
    let paged = Engine::load(&dir).expect("engine load");
    if !paged.enable_paging(4096) {
        eprintln!("[integration] artifacts predate paged export (no kv_block); skipping");
        return;
    }
    let problems = problem_set(&SATMATH, 3, 99);
    for mode in [SearchMode::Vanilla, SearchMode::EarlyRejection] {
        let c = cfg(mode, 8, 8);
        for (i, p) in problems.iter().enumerate() {
            let (a, b) = match mode {
                SearchMode::Vanilla => (
                    solve_vanilla(&dense, "lm-concise", "prm-large", p, &c, 0.5).unwrap(),
                    solve_vanilla(&paged, "lm-concise", "prm-large", p, &c, 0.5).unwrap(),
                ),
                SearchMode::EarlyRejection => (
                    solve_early_rejection(&dense, "lm-concise", "prm-large", p, &c, 0.5)
                        .unwrap(),
                    solve_early_rejection(&paged, "lm-concise", "prm-large", p, &c, 0.5)
                        .unwrap(),
                ),
            };
            assert_eq!(a.answer, b.answer, "problem {i} ({mode:?}): answer diverged");
            assert_eq!(
                a.best_trace, b.best_trace,
                "problem {i} ({mode:?}): trace diverged under paging"
            );
            assert_eq!(
                a.ledger, b.ledger,
                "problem {i} ({mode:?}): FLOPs accounting diverged under paging"
            );
            assert_eq!(a.steps_executed, b.steps_executed, "problem {i} ({mode:?})");
        }
    }
    let ps = paged.pool_stats().expect("pool stats while paging is on");
    assert!(ps.hwm > 0, "solves must actually have drawn from the pool: {ps:?}");
    assert_eq!(
        ps.blocks_free, ps.blocks_total,
        "dropped solves must return every block to the pool: {ps:?}"
    );
}

// The memory half of early rejection: rejected beams' blocks go back to
// the pool mid-flight and get reused by the survivors, without
// perturbing them — and the pool high-water mark stays below what dense
// per-slot caches would have pinned for the same traffic.
#[test]
fn paged_rejection_reuses_blocks_without_perturbing_survivors() {
    let Some(dir) = artifacts() else { return };
    let dense = Engine::load(&dir).expect("engine load");
    let paged = Engine::load(&dir).expect("engine load");
    if !paged.enable_paging(4096) {
        eprintln!("[integration] artifacts predate paged export (no kv_block); skipping");
        return;
    }
    let c = cfg(SearchMode::EarlyRejection, 8, 8);
    for (i, p) in problem_set(&SATMATH, 4, 4242).iter().enumerate() {
        let a = solve_early_rejection(&dense, "lm-concise", "prm-large", p, &c, 0.5).unwrap();
        let b = solve_early_rejection(&paged, "lm-concise", "prm-large", p, &c, 0.5).unwrap();
        assert_eq!(
            a.best_trace, b.best_trace,
            "problem {i}: survivors perturbed by mid-flight block reuse"
        );
        assert_eq!(a.ledger, b.ledger, "problem {i}");
    }
    let ps = paged.pool_stats().unwrap();
    let m = &paged.manifest;
    let bs = ps.block_size;
    // dense-equivalent footprint of ONE solve at its base width: every
    // slot of both caches pinned whole, whether or not it was rejected
    let width = m.batch_variants.iter().copied().filter(|&v| v >= 8).min().unwrap_or(8);
    let lm_blocks = m.model("lm").unwrap().cache_len.div_ceil(bs);
    let prm_blocks = m.model("prm-large").unwrap().cache_len.div_ceil(bs);
    let dense_equiv = width * (lm_blocks + prm_blocks);
    assert!(
        ps.hwm < dense_equiv,
        "paged high-water mark {} must undercut the dense footprint {dense_equiv}",
        ps.hwm
    );
    assert_eq!(ps.blocks_free, ps.blocks_total, "leaked blocks: {ps:?}");
}

// Pool exhaustion must surface as Saturated (HTTP 503 + Retry-After, the
// same backpressure contract as full shard queues) and never corrupt
// engine state: after widening the pool the very same engine solves
// byte-identically to dense.
#[test]
fn paged_pool_exhaustion_saturates_then_recovers() {
    let Some(dir) = artifacts() else { return };
    let e = Engine::load(&dir).expect("engine load");
    if !e.enable_paging(2) {
        eprintln!("[integration] artifacts predate paged export (no kv_block); skipping");
        return;
    }
    let p = Problem { v0: 61, ops: vec![OpStep { op: tk::MINUS, d: 5 }] };
    let c = cfg(SearchMode::EarlyRejection, 8, 8);
    let err = solve_early_rejection(&e, "lm-concise", "prm-large", &p, &c, 0.5)
        .expect_err("a 2-block pool cannot host an 8-beam solve");
    assert_eq!(err.http_status(), 503, "exhaustion must map to Saturated: {err}");
    // all-or-nothing reservation: the failed solve must not leak blocks
    let ps = e.pool_stats().unwrap();
    assert_eq!(ps.blocks_free, ps.blocks_total, "{ps:?}");
    // widen the pool on the same engine — state must be unscathed
    assert!(e.enable_paging(4096));
    let out = solve_early_rejection(&e, "lm-concise", "prm-large", &p, &c, 0.5).unwrap();
    let dense = Engine::load(&dir).expect("engine load");
    let want = solve_early_rejection(&dense, "lm-concise", "prm-large", &p, &c, 0.5).unwrap();
    assert_eq!(out.best_trace, want.best_trace, "post-exhaustion solve corrupted");
    assert_eq!(out.ledger, want.ledger);
}

// Fleet admission under a tight pool: requests that would overdraw the
// pool stay *queued* (degrade to queueing, not failure), admit as blocks
// free up, and still finish byte-identical to dense solves.
#[test]
fn paged_fleet_exhaustion_degrades_to_queueing() {
    let Some(dir) = artifacts() else { return };
    let e = Engine::load(&dir).expect("engine load");
    let Some(bs) = e.manifest.kv_block else {
        eprintln!("[integration] artifacts predate paged export (no kv_block); skipping");
        return;
    };
    // exactly the admission floor: one request admits, then the gate
    // stays shut until its caches drop
    let widest = e.manifest.batch_variants.iter().copied().max().unwrap_or(1);
    let floor = 2 * widest * e.manifest.prompt_pad.div_ceil(bs);
    let c = cfg(SearchMode::EarlyRejection, 8, 8);
    let problems = problem_set(&SATMATH, 3, 7171);
    let reference: Vec<_> = problems
        .iter()
        .map(|p| solve_early_rejection(&e, "lm-concise", "prm-large", p, &c, 0.5).unwrap())
        .collect();
    drop(e);

    let epool = EnginePool::spawn_with(
        dir,
        PoolOptions {
            shards: 1,
            capacity: 64,
            cache_entries: 0,
            default_deadline_ms: 0,
            fleet: Some(FleetOptions { max_inflight: 4, ..FleetOptions::default() }),
            singleflight: false,
            kv_pool_blocks: Some(floor),
            trace: erprm::obs::TraceOptions::default(),
            ..PoolOptions::default()
        },
    )
    .expect("paged fleet pool spawn");
    let joins: Vec<_> = problems
        .iter()
        .cloned()
        .map(|p| {
            let pool = epool.clone();
            let cc = c.clone();
            std::thread::spawn(move || {
                let req = api::SolveRequest {
                    problem: p,
                    mode: SearchMode::EarlyRejection,
                    n_beams: 8,
                    tau: 8,
                    lm: "lm-concise".into(),
                    prm: "prm-large".into(),
                    deadline_ms: None,
                    priority: 0,
                    request_id: String::new(),
                };
                pool.solve(req, cc).unwrap()
            })
        })
        .collect();
    for (i, j) in joins.into_iter().enumerate() {
        let out = j.join().unwrap();
        assert_eq!(
            out.best_trace, reference[i].best_trace,
            "problem {i}: trace diverged under pool-gated admission"
        );
        assert_eq!(out.ledger, reference[i].ledger, "problem {i}");
    }
    let t = epool.fleet_totals().expect("fleet totals");
    assert_eq!(t.completed, 3, "every request must complete, none may fail: {t:?}");
    assert_eq!(t.failed + t.expired, 0, "{t:?}");
    assert!(
        t.pool_deferred >= 1,
        "a floor-sized pool under 3 concurrent requests must have deferred admission: {t:?}"
    );
    let text = epool.render_metrics();
    assert!(text.contains(&format!("erprm_kv_pool_blocks_total {floor}")), "{text}");
    assert!(text.contains("erprm_fleet_pool_deferred_total"), "{text}");
    epool.shutdown();
}

#[test]
fn deterministic_solves_with_same_seed() {
    let Some(e) = engine() else { return };
    let problems = problem_set(&SATMATH, 1, 77);
    let c = cfg(SearchMode::EarlyRejection, 4, 8);
    let a = solve_early_rejection(&e, "lm-concise", "prm-large", &problems[0], &c, 0.5).unwrap();
    let b = solve_early_rejection(&e, "lm-concise", "prm-large", &problems[0], &c, 0.5).unwrap();
    assert_eq!(a.best_trace, b.best_trace);
    assert_eq!(a.ledger, b.ledger);
}

// Tentpole equivalence gate, primitive level: one merged decode must
// sample identical tokens whether the member caches are dense (device
// KV-concat merge programs), gather-paged (same device programs over
// pool-accounted caches), or block-native (host table concatenation +
// table-indexed attention kernel). The block-native leg must do it with
// zero merge/split device calls.
#[test]
fn merged_decode_identical_across_dense_gather_and_block_native() {
    let Some(dir) = artifacts() else { return };
    let dense = Engine::load(&dir).expect("engine load");
    if !dense.manifest.model("lm").unwrap().has_program("merge_b4_b4_to_b8") {
        eprintln!("[integration] artifacts lack merge programs; skipping 3-way merge test");
        return;
    }
    let pa = Problem { v0: 25, ops: vec![OpStep { op: tk::PLUS, d: 4 }] };
    let pb = Problem { v0: 61, ops: vec![OpStep { op: tk::MINUS, d: 5 }] };
    let prev: Vec<i32> = [vec![tk::DIG0 + 2; 4], vec![tk::DIG0 + 3; 4]].concat();
    let keys: Vec<u32> = (0..16).collect();
    let run = |e: &Engine| {
        let (_, ka1) = e.lm_prefill("lm-concise", &pa.prompt_tokens()).unwrap();
        let (_, kb1) = e.lm_prefill("lm-concise", &pb.prompt_tokens()).unwrap();
        let ka = e.kv_broadcast("lm-concise", &ka1, 4).unwrap();
        let kb = e.kv_broadcast("lm-concise", &kb1, 4).unwrap();
        let idx: Vec<i32> = (0..8).collect();
        let mut merged = e.kv_merge("lm-concise", &ka, &kb, &idx).unwrap();
        let sampled = e.lm_decode_block("lm-concise", &mut merged, &prev, 0.7, &keys).unwrap();
        let sa = e.kv_split("lm-concise", &merged, 0, 4).unwrap();
        let sb = e.kv_split("lm-concise", &merged, 4, 4).unwrap();
        (sampled, merged.pos_phys, sa.pos_log.clone(), sb.pos_log.clone())
    };
    let reference = run(&dense);
    drop(dense);

    let gather = Engine::load(&dir).expect("engine load");
    if !gather.enable_paging(4096) {
        eprintln!("[integration] artifacts predate paging; skipping paged legs");
        return;
    }
    gather.disable_block_native();
    assert_eq!(run(&gather), reference, "gather-paged merge/decode/split diverged from dense");
    drop(gather);

    let native = Engine::load(&dir).expect("engine load");
    assert!(native.enable_paging(4096));
    if !native.block_native() {
        eprintln!("[integration] artifacts lack blocktab programs; skipping block-native leg");
        return;
    }
    assert_eq!(run(&native), reference, "block-native merge/decode/split diverged from dense");
    let s = native.stats();
    assert_eq!(s.merge_calls, 0, "block-native gang merge must not touch the device: {s:?}");
    assert!(s.table_merges >= 1, "{s:?}");
    assert!(s.table_splits >= 2, "{s:?}");
}

// Tentpole equivalence gate, end-to-end: ganged fleet traffic must
// produce byte-identical SolveOutcomes whether the shard runs dense
// caches or the manifest-default paged pool (block-native when the
// artifact set exports blocktab programs) — and in the block-native
// case the whole run must finish with zero device merge/compact calls.
#[test]
fn gang_outcomes_identical_between_dense_and_block_native_pools() {
    let Some(dir) = artifacts() else { return };
    let e = Engine::load(&dir).expect("engine load");
    let native_ready = e.manifest.pool_blocks.is_some()
        && e.enable_paging(4096)
        && e.block_native();
    let c = cfg(SearchMode::EarlyRejection, 8, 8);
    let problems = problem_set(&SATMATH, 3, 4242);
    drop(e);

    let run_pool = |kv_pool_blocks: Option<usize>| {
        let epool = EnginePool::spawn_with(
            dir.clone(),
            PoolOptions {
                shards: 1,
                capacity: 64,
                cache_entries: 0,
                default_deadline_ms: 0,
                fleet: Some(FleetOptions {
                    max_inflight: 3,
                    gang: true,
                    ..FleetOptions::default()
                }),
                singleflight: false,
                kv_pool_blocks,
                trace: erprm::obs::TraceOptions::default(),
                ..PoolOptions::default()
            },
        )
        .expect("pool spawn");
        let joins: Vec<_> = problems
            .iter()
            .cloned()
            .map(|p| {
                let pool = epool.clone();
                let cc = c.clone();
                std::thread::spawn(move || {
                    let req = api::SolveRequest {
                        problem: p,
                        mode: SearchMode::EarlyRejection,
                        n_beams: 8,
                        tau: 8,
                        lm: "lm-concise".into(),
                        prm: "prm-large".into(),
                        deadline_ms: None,
                        priority: 0,
                        request_id: String::new(),
                    };
                    pool.solve(req, cc).unwrap()
                })
            })
            .collect();
        let outs: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        let stats = epool.engine_stats();
        epool.shutdown();
        (outs, stats)
    };

    let (dense_outs, _) = run_pool(Some(0));
    let (paged_outs, paged_stats) = run_pool(None);
    for (i, (d, p)) in dense_outs.iter().zip(&paged_outs).enumerate() {
        assert_eq!(p.answer, d.answer, "problem {i}: answer diverged dense vs paged gang");
        assert_eq!(p.best_trace, d.best_trace, "problem {i}: trace diverged dense vs paged gang");
        assert_eq!(p.ledger, d.ledger, "problem {i}: FLOPs diverged dense vs paged gang");
    }
    if native_ready {
        assert_eq!(
            paged_stats.merge_calls, 0,
            "block-native ganged traffic ran a device merge: {paged_stats:?}"
        );
        assert_eq!(
            paged_stats.compact_calls, 0,
            "block-native compaction must be a table edit: {paged_stats:?}"
        );
    }
}

// ---------------------------------------------------------------- tracing

// Tracing must be a pure observer. The same (problem, cfg, seed) solved
// through a recording pool and through a pool with retention disabled
// and success sampling at zero must produce byte-identical outcomes —
// the recorder may only watch the solve, never steer it.
#[test]
fn tracing_on_and_off_solve_byte_identically() {
    let Some(dir) = artifacts() else { return };
    let cfg = SearchConfig::default();
    let solve_with = |trace: erprm::obs::TraceOptions| {
        let epool = EnginePool::spawn_with(
            dir.clone(),
            PoolOptions {
                shards: 1,
                capacity: 8,
                cache_entries: 0,
                default_deadline_ms: 0,
                fleet: None,
                singleflight: false,
                kv_pool_blocks: None,
                trace,
                ..PoolOptions::default()
            },
        )
        .expect("pool spawn");
        let req = api::parse_solve(solve_body(), &cfg).unwrap();
        let out = epool.solve(req, cfg.clone()).unwrap();
        epool.shutdown();
        out
    };
    let on = solve_with(erprm::obs::TraceOptions::default());
    let off = solve_with(erprm::obs::TraceOptions {
        capacity: 0,
        sample: erprm::obs::SamplePolicy {
            success_rate: 0.0,
            ..erprm::obs::SamplePolicy::default()
        },
        ..erprm::obs::TraceOptions::default()
    });
    assert_eq!(on.answer, off.answer, "tracing changed the answer");
    assert_eq!(on.best_trace, off.best_trace, "tracing perturbed the search");
    assert_eq!(on.ledger, off.ledger, "tracing perturbed the FLOPs accounting");
    assert_eq!(on.steps_executed, off.steps_executed);
}

// The trace endpoints close the loop end to end: a /solve response's
// X-Request-Id resolves at GET /trace/<id> to a lifecycle document whose
// per-phase FLOPs sum to the response's own `flops` field, /traces lists
// the id, /traces/chrome renders a parseable Chrome trace_event
// document, and the full /metrics page stays exposition-valid with the
// tracer rollups appended.
#[test]
fn trace_endpoints_serve_lifecycle_and_chrome_export() {
    let Some(dir) = artifacts() else { return };
    let epool = fleet_pool(dir, 1, 2, 0);
    let metrics = std::sync::Arc::new(Metrics::default());
    let tpool = ThreadPool::new(4);
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let p2 = epool.clone();
    let m2 = std::sync::Arc::clone(&metrics);
    let life2 = erprm::server::Lifecycle::new();
    let addr = http::serve(
        "127.0.0.1:0",
        &tpool,
        1 << 20,
        std::sync::Arc::clone(&stop),
        std::sync::Arc::new(move |req| route(&p2, &m2, &SearchConfig::default(), &life2, req)),
    )
    .unwrap();
    let req = format!(
        "POST /solve HTTP/1.1\r\nX-Request-Id: trace-me-1\r\nContent-Length: {}\r\n\r\n{}",
        solve_body().len(),
        std::str::from_utf8(solve_body()).unwrap()
    );
    let out = http_get(addr, req.as_bytes());
    assert!(out.starts_with("HTTP/1.1 200"), "{out}");
    assert!(
        out.to_ascii_lowercase().contains("x-request-id: trace-me-1"),
        "the response must echo the client's id: {out}"
    );
    let body = out.split("\r\n\r\n").nth(1).expect("response body");
    let solve_json = erprm::util::json::Json::parse(body).unwrap();
    assert_eq!(
        solve_json.get("request_id").and_then(erprm::util::json::Json::as_str),
        Some("trace-me-1")
    );
    let solve_flops =
        solve_json.get("flops").and_then(erprm::util::json::Json::as_f64).expect("flops");

    let trace_out = http_get(addr, b"GET /trace/trace-me-1 HTTP/1.1\r\n\r\n");
    assert!(trace_out.starts_with("HTTP/1.1 200"), "{trace_out}");
    let trace_body = trace_out.split("\r\n\r\n").nth(1).expect("trace body");
    let tj = erprm::util::json::Json::parse(trace_body).unwrap();
    assert_eq!(
        tj.get("outcome").and_then(erprm::util::json::Json::as_str),
        Some("ok"),
        "{trace_body}"
    );
    let phase_total = tj
        .get("flops")
        .and_then(|f| f.get("total"))
        .and_then(erprm::util::json::Json::as_f64)
        .expect("trace flops.total");
    // both sides derive from the same token counters; only float
    // association order may differ
    assert!(
        (phase_total - solve_flops).abs() <= 1e-9 * solve_flops.max(1.0),
        "trace phase FLOPs {phase_total} != response flops {solve_flops}"
    );
    let spans = tj.get("spans").map(|s| s.to_string()).unwrap_or_default();
    for name in ["queue", "prefill", "decode"] {
        assert!(spans.contains(name), "lifecycle span '{name}' missing: {spans}");
    }

    let list_out = http_get(addr, b"GET /traces HTTP/1.1\r\n\r\n");
    assert!(list_out.contains("trace-me-1"), "{list_out}");

    let chrome_out = http_get(addr, b"GET /traces/chrome HTTP/1.1\r\n\r\n");
    let chrome_body = chrome_out.split("\r\n\r\n").nth(1).expect("chrome body");
    let cj = erprm::util::json::Json::parse(chrome_body).expect("chrome JSON must parse");
    match cj.get("traceEvents") {
        Some(erprm::util::json::Json::Arr(evs)) => {
            assert!(!evs.is_empty(), "chrome export must carry events")
        }
        other => panic!("traceEvents must be an array, got {other:?}"),
    }

    let metrics_out = http_get(addr, b"GET /metrics HTTP/1.1\r\n\r\n");
    let metrics_body = metrics_out.split("\r\n\r\n").nth(1).expect("metrics body");
    erprm::obs::check_exposition(metrics_body).expect("/metrics must stay exposition-valid");
    assert!(metrics_body.contains("erprm_er_flops_saved_total"), "{metrics_body}");
    assert!(metrics_body.contains("erprm_trace_dropped_total"), "{metrics_body}");

    let miss = http_get(addr, b"GET /trace/never-seen HTTP/1.1\r\n\r\n");
    assert!(miss.starts_with("HTTP/1.1 404"), "{miss}");

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    epool.shutdown();
}

// ------------------------------------------------------- calibration

// The calibration observatory streams partial↔final reward pairs out of
// every traced ER solve: after a couple of requests GET /calibration
// serves a per-(checkpoint, depth-bucket) table with sample counts, and
// the erprm_calib_* family keeps the full /metrics page
// exposition-valid.
#[test]
fn calibration_endpoint_streams_partials_and_metrics_stay_valid() {
    let Some(dir) = artifacts() else { return };
    let epool = fleet_pool(dir, 1, 2, 0);
    let metrics = std::sync::Arc::new(Metrics::default());
    let tpool = ThreadPool::new(4);
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let p2 = epool.clone();
    let m2 = std::sync::Arc::clone(&metrics);
    let life2 = erprm::server::Lifecycle::new();
    let addr = http::serve(
        "127.0.0.1:0",
        &tpool,
        1 << 20,
        std::sync::Arc::clone(&stop),
        std::sync::Arc::new(move |req| route(&p2, &m2, &SearchConfig::default(), &life2, req)),
    )
    .unwrap();
    let bodies: [&[u8]; 2] = [
        solve_body(),
        br#"{"v0": 47, "ops": [["+",9],["*",3],["-",6]], "mode": "er", "n_beams": 8, "tau": 8}"#,
    ];
    for body in bodies {
        let req = format!(
            "POST /solve HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            std::str::from_utf8(body).unwrap()
        );
        let out = http_get(addr, req.as_bytes());
        assert!(out.starts_with("HTTP/1.1 200"), "{out}");
    }

    let cal_out = http_get(addr, b"GET /calibration HTTP/1.1\r\n\r\n");
    assert!(cal_out.starts_with("HTTP/1.1 200"), "{cal_out}");
    let cal_body = cal_out.split("\r\n\r\n").nth(1).expect("calibration body");
    let cj = erprm::util::json::Json::parse(cal_body).expect("calibration JSON must parse");
    let num = |v: &erprm::util::json::Json, k: &str| {
        v.get(k).and_then(erprm::util::json::Json::as_f64).unwrap_or_else(|| panic!("{k} missing: {cal_body}"))
    };
    assert!(num(&cj, "epoch") >= 1.0, "two finished ER solves must bump the epoch: {cal_body}");
    assert!(num(&cj, "samples_total") >= 1.0, "no partial↔final pairs streamed: {cal_body}");
    match cj.get("buckets") {
        Some(erprm::util::json::Json::Arr(buckets)) => {
            assert!(!buckets.is_empty(), "table has samples but no buckets: {cal_body}");
            let b = &buckets[0];
            assert_eq!(
                b.get("ckpt").and_then(erprm::util::json::Json::as_str),
                Some("prm-large")
            );
            assert!(num(b, "samples") >= 1.0, "{cal_body}");
            for k in ["depth_bucket", "pearson", "conf_low"] {
                assert!(b.get(k).is_some(), "bucket field '{k}' missing: {cal_body}");
            }
        }
        other => panic!("buckets must be an array, got {other:?}"),
    }

    let metrics_out = http_get(addr, b"GET /metrics HTTP/1.1\r\n\r\n");
    let metrics_body = metrics_out.split("\r\n\r\n").nth(1).expect("metrics body");
    erprm::obs::check_exposition(metrics_body)
        .expect("/metrics with calib gauges must stay exposition-valid");
    for fam in ["erprm_calib_epoch", "erprm_calib_samples", "erprm_calib_corr"] {
        assert!(metrics_body.contains(fam), "metric family '{fam}' missing");
    }

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    epool.shutdown();
}

// Closing the loop must not perturb anything until the table holds
// evidence: on a thin (empty) table the adaptive controller's plan
// degenerates to the static cfg.tau, so an adaptive-on pool and a
// controller-off pool must solve byte-identically — and adaptive runs
// must repeat byte-identically (the per-request plan is frozen against
// the table epoch, never mid-flight state).
#[test]
fn adaptive_tau_on_a_thin_table_matches_static_byte_for_byte() {
    let Some(dir) = artifacts() else { return };
    let cfg = SearchConfig::default();
    let solve_with = |calib: erprm::obs::CalibOptions| {
        let epool = EnginePool::spawn_with(
            dir.clone(),
            PoolOptions {
                shards: 1,
                capacity: 8,
                cache_entries: 0,
                default_deadline_ms: 0,
                fleet: None,
                singleflight: false,
                kv_pool_blocks: None,
                trace: erprm::obs::TraceOptions {
                    calib,
                    ..erprm::obs::TraceOptions::default()
                },
                ..PoolOptions::default()
            },
        )
        .expect("pool spawn");
        let req = api::parse_solve(solve_body(), &cfg).unwrap();
        let out = epool.solve(req, cfg.clone()).unwrap();
        epool.shutdown();
        out
    };
    let adaptive = erprm::obs::CalibOptions {
        adaptive: true,
        shadow_rate: 0.0,
        ..erprm::obs::CalibOptions::default()
    };
    let a1 = solve_with(adaptive);
    let a2 = solve_with(adaptive);
    let s = solve_with(erprm::obs::CalibOptions::default());
    assert_eq!(a1.answer, s.answer, "an evidence-free controller changed the answer");
    assert_eq!(a1.best_trace, s.best_trace, "an evidence-free controller steered the search");
    assert_eq!(a1.ledger, s.ledger, "an evidence-free controller perturbed FLOPs accounting");
    assert_eq!(a1.steps_executed, s.steps_executed);
    assert_eq!(a1.best_trace, a2.best_trace, "adaptive repeats must be byte-identical");
    assert_eq!(a1.ledger, a2.ledger, "adaptive repeats must be byte-identical");
    assert_eq!(a1.answer, a2.answer);
}

// ------------------------------------------------------- fault tolerance

// The acceptance gate for the supervision/retry stack: a workload run
// under seeded shard panics must complete with zero client-visible
// failures and answers byte-identical to the chaos-off run, with the
// supervisor having actually respawned shards along the way.
#[test]
fn chaos_shard_panics_preserve_byte_identical_answers() {
    let Some(dir) = artifacts() else { return };
    let opts = |chaos: erprm::fleet::ChaosOptions| PoolOptions {
        shards: 2,
        capacity: 16,
        supervise: erprm::server::SuperviseOptions {
            interval_ms: 5,
            restart_backoff_ms: 1,
            ..erprm::server::SuperviseOptions::default()
        },
        retry: erprm::server::RetryOptions {
            max_attempts: 6,
            base_ms: 5,
            cap_ms: 40,
            ..erprm::server::RetryOptions::default()
        },
        chaos,
        ..PoolOptions::default()
    };
    // p=1.0 with a cap of 2: the first two chaos draws (one per shard's
    // first dequeue, or two ticks on one shard) panic deterministically,
    // then the schedule is spent.
    let faulty = EnginePool::spawn_with(
        dir.clone(),
        opts(erprm::fleet::ChaosOptions {
            seed: 13,
            panic_per_tick: 1.0,
            max_panics: 2,
            ..erprm::fleet::ChaosOptions::default()
        }),
    )
    .unwrap();
    let clean = EnginePool::spawn_with(dir, opts(erprm::fleet::ChaosOptions::default())).unwrap();
    let cfg = SearchConfig::default();
    let reqs: Vec<_> = (0..6)
        .map(|i| {
            let mut r = api::parse_solve(solve_body(), &cfg).unwrap();
            r.problem.v0 = 40 + i;
            r
        })
        .collect();
    let joins: Vec<_> = reqs
        .iter()
        .map(|r| {
            let p = faulty.clone();
            let (r, c) = (r.clone(), cfg.clone());
            std::thread::spawn(move || p.solve(r, c))
        })
        .collect();
    let with_faults: Vec<_> = joins
        .into_iter()
        .map(|j| j.join().unwrap().expect("zero client-visible failures under chaos"))
        .collect();
    for (r, a) in reqs.iter().zip(&with_faults) {
        let b = clean.solve(r.clone(), cfg.clone()).expect("fault-free run");
        assert_eq!(a.answer, b.answer, "v0={}: answer diverged under recovery", r.problem.v0);
        assert_eq!(a.best_trace, b.best_trace, "v0={}: trace diverged", r.problem.v0);
        assert_eq!(a.ledger, b.ledger, "v0={}: FLOPs accounting diverged", r.problem.v0);
    }
    assert_eq!(faulty.chaos_injected(), Some((2, 0)), "the cap bounds the schedule");
    assert!(faulty.restarts_total() >= 1, "the supervisor respawned panicked shards");
    assert_eq!(clean.restarts_total(), 0);
    let m = faulty.render_metrics();
    assert!(m.contains("erprm_chaos_panics_injected_total 2"), "{m}");
    faulty.shutdown();
    clean.shutdown();
}

// Graceful drain over live HTTP: work admitted before the drain
// completes with 200, new work is refused with 503 + Retry-After, and
// /readyz leaves rotation while /healthz keeps answering.
#[test]
fn drain_finishes_in_flight_work_and_refuses_new() {
    let Some(dir) = artifacts() else { return };
    let epool = EnginePool::spawn(dir, 1, 4, 0).unwrap();
    let metrics = std::sync::Arc::new(Metrics::default());
    let tpool = ThreadPool::new(4);
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let life = erprm::server::Lifecycle::new();
    let p2 = epool.clone();
    let m2 = std::sync::Arc::clone(&metrics);
    let life2 = life.clone();
    let addr = http::serve(
        "127.0.0.1:0",
        &tpool,
        1 << 20,
        std::sync::Arc::clone(&stop),
        std::sync::Arc::new(move |req| route(&p2, &m2, &SearchConfig::default(), &life2, req)),
    )
    .unwrap();
    let req = format!(
        "POST /solve HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        solve_body().len(),
        std::str::from_utf8(solve_body()).unwrap()
    );
    let inflight = {
        let req = req.clone();
        std::thread::spawn(move || http_get(addr, req.as_bytes()))
    };
    // wait until the solve is admitted (holds a queue slot) so the
    // drain provably lands while it is in flight
    let t0 = std::time::Instant::now();
    while epool.queue_depth() == 0 && t0.elapsed() < std::time::Duration::from_secs(5) {
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert!(epool.queue_depth() > 0, "solve admitted before the drain");
    let d = http_get(addr, b"POST /admin/drain HTTP/1.1\r\nContent-Length: 0\r\n\r\n");
    assert!(d.starts_with("HTTP/1.1 200"), "{d}");
    let ready = http_get(addr, b"GET /readyz HTTP/1.1\r\n\r\n");
    assert!(ready.starts_with("HTTP/1.1 503"), "draining leaves rotation: {ready}");
    assert!(ready.contains("Retry-After"), "{ready}");
    let refused = http_get(addr, req.as_bytes());
    assert!(refused.starts_with("HTTP/1.1 503"), "{refused}");
    assert!(refused.contains("draining"), "{refused}");
    let health = http_get(addr, b"GET /healthz HTTP/1.1\r\n\r\n");
    assert!(health.starts_with("HTTP/1.1 200"), "liveness answers during drain: {health}");
    assert!(health.contains("\"draining\":true"), "{health}");
    let out = inflight.join().unwrap();
    assert!(out.starts_with("HTTP/1.1 200"), "in-flight work finishes under drain: {out}");
    assert_eq!(epool.queue_depth(), 0, "drained clean");
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    epool.shutdown();
}
