//! Typed configuration system (JSON-backed, env-overridable).
//!
//! Three config groups cover the stack: `EngineConfig` (artifacts, models),
//! `SearchConfig` (beam search / early rejection parameters — the paper's
//! experiment axes), and `ServerConfig` (HTTP front end). `load_file`
//! reads a JSON config; every field has a sensible default so `erprm serve`
//! works with no config at all.

use std::path::{Path, PathBuf};

use crate::obs::calibration::CalibOptions;
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Which search pipeline to run — the paper's two decoders.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchMode {
    /// Paper Algorithm 2: score only fully completed steps.
    Vanilla,
    /// Paper Algorithm 3: partial reward at tau tokens, prune, complete.
    EarlyRejection,
}

impl SearchMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "vanilla" => Ok(SearchMode::Vanilla),
            "er" | "early-rejection" | "early_rejection" => Ok(SearchMode::EarlyRejection),
            other => Err(Error::parse(format!("unknown search mode '{other}'"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SearchMode::Vanilla => "vanilla",
            SearchMode::EarlyRejection => "er",
        }
    }
}

/// How per-token PRM scores aggregate into a step/beam reward.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Aggregation {
    /// min over step scores ("let's verify step by step" convention).
    Min,
    /// mean over step scores.
    Mean,
    /// score at the last token of the step.
    Last,
}

impl Aggregation {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "min" => Ok(Aggregation::Min),
            "mean" => Ok(Aggregation::Mean),
            "last" => Ok(Aggregation::Last),
            other => Err(Error::parse(format!("unknown aggregation '{other}'"))),
        }
    }
}

/// Engine-level config: where artifacts live, which checkpoints serve.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub artifacts_dir: PathBuf,
    pub lm_checkpoint: String,  // "lm-concise" | "lm-verbose"
    pub prm_model: String,      // "prm-large" | "prm-small"
    pub temperature: f32,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            lm_checkpoint: "lm-concise".into(),
            prm_model: "prm-large".into(),
            temperature: 0.7,
        }
    }
}

impl EngineConfig {
    /// The serving temperature the experiments use per LM behaviour class:
    /// concise (Llama-analog) decodes colder than verbose (Qwen-analog).
    pub fn default_temperature(lm_checkpoint: &str) -> f32 {
        if lm_checkpoint.contains("verbose") {
            0.9
        } else {
            0.5
        }
    }
}

/// Search config — the paper's experiment axes (Sec. 5).
#[derive(Debug, Clone)]
pub struct SearchConfig {
    pub mode: SearchMode,
    /// Beam count N.
    pub n_beams: usize,
    /// Expansion factor M (keep top N/M, expand each by M). Paper: M=4.
    pub m_expand: usize,
    /// Early-rejection prefix length tau (tokens into the current step).
    pub tau: usize,
    /// Two-tier batching: batch size for the prefix phase (b1) and the
    /// completion phase (b2); b1 >= b2 per the paper's Sec. 3.2.
    pub b1: usize,
    pub b2: usize,
    /// Aggregation of per-token PRM scores into step rewards.
    pub agg: Aggregation,
    /// Hard cap on generated tokens per beam per step (runaway guard).
    pub max_step_tokens: usize,
    /// Hard cap on reasoning steps (search depth).
    pub max_steps: usize,
    /// RNG seed for sampling.
    pub seed: u64,
    /// Proactive KV re-compaction threshold: when the junk share of a
    /// cache's spent positions reaches this fraction (and the reclaimable
    /// gap is worth a device call), the solve yields a compaction intent.
    /// 1.0 disables the proactive trigger; the exhaustion-rescue trigger
    /// (compact instead of truncating when the cache cannot fit the next
    /// block) is always on. Compaction is semantically invisible — it
    /// never changes a solve's outcome, only extends effective cache
    /// length — so this is a pure perf knob.
    pub compact_junk: f32,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            mode: SearchMode::EarlyRejection,
            n_beams: 16,
            m_expand: 4,
            tau: 8,
            b1: 64,
            b2: 16,
            agg: Aggregation::Mean,
            max_step_tokens: 64,
            max_steps: 8,
            seed: 0,
            compact_junk: 0.6,
        }
    }
}

impl SearchConfig {
    pub fn keep(&self) -> usize {
        (self.n_beams / self.m_expand).max(1)
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_beams == 0 || self.m_expand == 0 {
            return Err(Error::invalid("n_beams and m_expand must be positive"));
        }
        if self.n_beams % self.m_expand != 0 {
            return Err(Error::invalid(format!(
                "n_beams ({}) must be divisible by m_expand ({})",
                self.n_beams, self.m_expand
            )));
        }
        if self.b2 > self.b1 {
            return Err(Error::invalid(format!(
                "two-tier batching requires b1 >= b2 (got b1={} b2={})",
                self.b1, self.b2
            )));
        }
        if self.tau == 0 || self.tau > self.max_step_tokens {
            return Err(Error::invalid(format!(
                "tau ({}) must be in 1..=max_step_tokens ({})",
                self.tau, self.max_step_tokens
            )));
        }
        if !(0.0..=1.0).contains(&self.compact_junk) || self.compact_junk.is_nan() {
            return Err(Error::invalid(format!(
                "compact_junk ({}) must be a fraction in 0.0..=1.0",
                self.compact_junk
            )));
        }
        Ok(())
    }
}

/// HTTP server config.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub addr: String,
    pub workers: usize,
    pub max_body_bytes: usize,
    /// Engine shards in the pool; 0 means auto (available cores - 1).
    pub shards: usize,
    /// Queue slots per shard; the pool 503s when every queue is full.
    pub capacity: usize,
    /// LRU solve-cache entries; 0 disables the cache.
    pub cache_entries: usize,
    /// Run shards under the fleet scheduler (continuous cross-request
    /// batching) instead of sequential one-request-at-a-time dispatch.
    pub fleet: bool,
    /// Fleet slot-table size per shard: how many requests interleave.
    pub max_inflight: usize,
    /// Gang batching (fleet mode only): merge compatible in-flight
    /// requests' decode/score calls into shared device batches.
    pub gang: bool,
    /// Default per-request deadline in ms, honored in both dispatch
    /// modes; 0 = unbounded.
    pub deadline_ms: u64,
    /// Pool-level single-flight: identical requests that would land on
    /// different shards coalesce onto one engine run (the shard-local
    /// fleet coalescer only sees duplicates placed on its own shard).
    pub singleflight: bool,
    /// Paged-KV block pool size per shard, in blocks of the manifest's
    /// `kv_block` tokens; 0 = defer to the manifest's exported
    /// `pool_blocks` sizing, falling back to dense per-slot caches when
    /// the artifact set predates paged export. An explicit
    /// `--kv-pool-blocks 0` on the CLI still forces dense.
    pub kv_pool_blocks: usize,
    /// Request traces retained in the in-memory ring served by
    /// `GET /trace/<id>`; 0 disables retention (rollup counters still
    /// accumulate on `/metrics`).
    pub trace_capacity: usize,
    /// Fraction of *successful* requests whose trace is retained
    /// (failures are always kept). 1.0 keeps everything.
    pub trace_sample: f64,
    /// Calibration observatory knobs: the partial↔final correlation
    /// table always streams; `calib.adaptive` additionally lets the
    /// router shave per-depth taus where the table has proven itself.
    pub calib: CalibOptions,
    /// Graceful-drain budget: after SIGTERM or `POST /admin/drain`, how
    /// long the serve loop waits for in-flight work before shutting the
    /// pool down anyway.
    pub drain_deadline_ms: u64,
    /// Transparent retry attempts per request for retryable failures
    /// (shard death mid-solve); 1 disables retry.
    pub retry_max_attempts: u32,
    /// First retry backoff in ms (doubles per attempt, jittered).
    pub retry_base_ms: u64,
    /// Backoff growth ceiling in ms.
    pub retry_cap_ms: u64,
    /// Also retry `Saturated` admissions inside the request's own
    /// deadline budget (off by default: 503 + Retry-After pushes the
    /// wait to the client, which is usually the right backpressure).
    pub retry_saturated: bool,
    /// Supervisor wedge threshold: a shard with queued work whose
    /// heartbeat is older than this is declared lost and respawned.
    pub supervise_stale_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:8377".into(),
            workers: 2,
            max_body_bytes: 1 << 20,
            shards: 0,
            capacity: 64,
            cache_entries: 128,
            fleet: false,
            max_inflight: 8,
            gang: false,
            deadline_ms: 0,
            singleflight: true,
            kv_pool_blocks: 0,
            trace_capacity: 256,
            trace_sample: 1.0,
            calib: CalibOptions::default(),
            drain_deadline_ms: 10_000,
            retry_max_attempts: 3,
            retry_base_ms: 25,
            retry_cap_ms: 1000,
            retry_saturated: false,
            supervise_stale_ms: 10_000,
        }
    }
}

impl ServerConfig {
    /// Auto shard count: one engine thread per core, minus one core left
    /// for the HTTP front end; never below 1.
    pub fn default_shards() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get().saturating_sub(1))
            .unwrap_or(1)
            .max(1)
    }

    /// The shard count to actually spawn (resolves the 0 = auto case).
    pub fn effective_shards(&self) -> usize {
        if self.shards == 0 {
            ServerConfig::default_shards()
        } else {
            self.shards
        }
    }
}

/// Whole-stack config file.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub engine: EngineConfig,
    pub search: SearchConfig,
    pub server: ServerConfig,
}

impl Config {
    pub fn from_json(v: &Json) -> Result<Config> {
        let mut cfg = Config::default();
        if let Some(e) = v.get("engine") {
            if let Some(s) = e.get("artifacts_dir").and_then(Json::as_str) {
                cfg.engine.artifacts_dir = PathBuf::from(s);
            }
            if let Some(s) = e.get("lm_checkpoint").and_then(Json::as_str) {
                cfg.engine.lm_checkpoint = s.to_string();
            }
            if let Some(s) = e.get("prm_model").and_then(Json::as_str) {
                cfg.engine.prm_model = s.to_string();
            }
            if let Some(t) = e.get("temperature").and_then(Json::as_f64) {
                cfg.engine.temperature = t as f32;
            }
        }
        if let Some(s) = v.get("search") {
            if let Some(m) = s.get("mode").and_then(Json::as_str) {
                cfg.search.mode = SearchMode::parse(m)?;
            }
            if let Some(n) = s.get("n_beams").and_then(Json::as_usize) {
                cfg.search.n_beams = n;
            }
            if let Some(n) = s.get("m_expand").and_then(Json::as_usize) {
                cfg.search.m_expand = n;
            }
            if let Some(n) = s.get("tau").and_then(Json::as_usize) {
                cfg.search.tau = n;
            }
            if let Some(n) = s.get("b1").and_then(Json::as_usize) {
                cfg.search.b1 = n;
            }
            if let Some(n) = s.get("b2").and_then(Json::as_usize) {
                cfg.search.b2 = n;
            }
            if let Some(a) = s.get("agg").and_then(Json::as_str) {
                cfg.search.agg = Aggregation::parse(a)?;
            }
            if let Some(n) = s.get("seed").and_then(Json::as_i64) {
                cfg.search.seed = n as u64;
            }
            if let Some(n) = s.get("max_steps").and_then(Json::as_usize) {
                cfg.search.max_steps = n;
            }
            if let Some(n) = s.get("max_step_tokens").and_then(Json::as_usize) {
                cfg.search.max_step_tokens = n;
            }
            if let Some(f) = s.get("compact_junk").and_then(Json::as_f64) {
                cfg.search.compact_junk = f as f32;
            }
        }
        if let Some(s) = v.get("server") {
            if let Some(a) = s.get("addr").and_then(Json::as_str) {
                cfg.server.addr = a.to_string();
            }
            if let Some(w) = s.get("workers").and_then(Json::as_usize) {
                cfg.server.workers = w;
            }
            if let Some(n) = s.get("shards").and_then(Json::as_usize) {
                cfg.server.shards = n;
            }
            if let Some(n) = s.get("capacity").and_then(Json::as_usize) {
                cfg.server.capacity = n;
            }
            if let Some(n) = s.get("cache_entries").and_then(Json::as_usize) {
                cfg.server.cache_entries = n;
            }
            if let Some(b) = s.get("fleet").and_then(Json::as_bool) {
                cfg.server.fleet = b;
            }
            if let Some(n) = s.get("max_inflight").and_then(Json::as_usize) {
                cfg.server.max_inflight = n;
            }
            if let Some(b) = s.get("gang").and_then(Json::as_bool) {
                cfg.server.gang = b;
            }
            if let Some(n) = s.get("deadline_ms").and_then(Json::as_i64) {
                cfg.server.deadline_ms = n.max(0) as u64;
            }
            if let Some(b) = s.get("singleflight").and_then(Json::as_bool) {
                cfg.server.singleflight = b;
            }
            if let Some(n) = s.get("kv_pool_blocks").and_then(Json::as_usize) {
                cfg.server.kv_pool_blocks = n;
            }
            if let Some(n) = s.get("trace_capacity").and_then(Json::as_usize) {
                cfg.server.trace_capacity = n;
            }
            if let Some(f) = s.get("trace_sample").and_then(Json::as_f64) {
                cfg.server.trace_sample = f.clamp(0.0, 1.0);
            }
            if let Some(b) = s.get("adaptive_tau").and_then(Json::as_bool) {
                cfg.server.calib.adaptive = b;
            }
            if let Some(n) = s.get("calib_min_samples").and_then(Json::as_i64) {
                cfg.server.calib.min_samples = n.max(1) as u64;
            }
            if let Some(f) = s.get("calib_conf_floor").and_then(Json::as_f64) {
                cfg.server.calib.conf_floor = f.clamp(-1.0, 1.0);
            }
            if let Some(f) = s.get("calib_aggressiveness").and_then(Json::as_f64) {
                cfg.server.calib.aggressiveness = f.clamp(0.0, 1.0);
            }
            if let Some(n) = s.get("calib_min_tau").and_then(Json::as_usize) {
                cfg.server.calib.min_tau = n.max(1);
            }
            if let Some(f) = s.get("calib_shadow_rate").and_then(Json::as_f64) {
                cfg.server.calib.shadow_rate = f.clamp(0.0, 1.0);
            }
            if let Some(n) = s.get("calib_depth_buckets").and_then(Json::as_usize) {
                cfg.server.calib.depth_buckets = n.max(1);
            }
            if let Some(n) = s.get("drain_deadline_ms").and_then(Json::as_i64) {
                cfg.server.drain_deadline_ms = n.max(0) as u64;
            }
            if let Some(n) = s.get("retry_max_attempts").and_then(Json::as_i64) {
                cfg.server.retry_max_attempts = n.max(1) as u32;
            }
            if let Some(n) = s.get("retry_base_ms").and_then(Json::as_i64) {
                cfg.server.retry_base_ms = n.max(1) as u64;
            }
            if let Some(n) = s.get("retry_cap_ms").and_then(Json::as_i64) {
                cfg.server.retry_cap_ms = n.max(1) as u64;
            }
            if let Some(b) = s.get("retry_saturated").and_then(Json::as_bool) {
                cfg.server.retry_saturated = b;
            }
            if let Some(n) = s.get("supervise_stale_ms").and_then(Json::as_i64) {
                cfg.server.supervise_stale_ms = n.max(1) as u64;
            }
        }
        cfg.search.validate()?;
        Ok(cfg)
    }

    pub fn load_file(path: &Path) -> Result<Config> {
        let src = std::fs::read_to_string(path)?;
        Config::from_json(&Json::parse(&src)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        SearchConfig::default().validate().unwrap();
    }

    #[test]
    fn from_json_overrides() {
        let j = Json::parse(
            r#"{"engine": {"lm_checkpoint": "lm-verbose", "temperature": 0.9},
                "search": {"mode": "vanilla", "n_beams": 32, "tau": 16},
                "server": {"addr": "0.0.0.0:9000"}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.engine.lm_checkpoint, "lm-verbose");
        assert_eq!(c.search.mode, SearchMode::Vanilla);
        assert_eq!(c.search.n_beams, 32);
        assert_eq!(c.search.tau, 16);
        assert_eq!(c.server.addr, "0.0.0.0:9000");
    }

    #[test]
    fn fault_tolerance_knobs_default_and_parse() {
        let d = ServerConfig::default();
        assert_eq!(d.drain_deadline_ms, 10_000);
        assert_eq!(d.retry_max_attempts, 3);
        assert_eq!(d.retry_base_ms, 25);
        assert_eq!(d.retry_cap_ms, 1000);
        assert!(!d.retry_saturated);
        assert_eq!(d.supervise_stale_ms, 10_000);

        let j = Json::parse(
            r#"{"server": {"drain_deadline_ms": 2500, "retry_max_attempts": 5,
                "retry_base_ms": 10, "retry_cap_ms": 200, "retry_saturated": true,
                "supervise_stale_ms": 3000}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.server.drain_deadline_ms, 2500);
        assert_eq!(c.server.retry_max_attempts, 5);
        assert_eq!(c.server.retry_base_ms, 10);
        assert_eq!(c.server.retry_cap_ms, 200);
        assert!(c.server.retry_saturated);
        assert_eq!(c.server.supervise_stale_ms, 3000);

        // floors: zero attempts/backoffs are configuration mistakes
        let j = Json::parse(
            r#"{"server": {"retry_max_attempts": 0, "retry_base_ms": 0,
                "supervise_stale_ms": 0}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.server.retry_max_attempts, 1, "clamped to at least one attempt");
        assert_eq!(c.server.retry_base_ms, 1);
        assert_eq!(c.server.supervise_stale_ms, 1);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut s = SearchConfig::default();
        s.n_beams = 10;
        s.m_expand = 4;
        assert!(s.validate().is_err()); // not divisible
        let mut s = SearchConfig::default();
        s.b1 = 4;
        s.b2 = 16;
        assert!(s.validate().is_err()); // b2 > b1
        let mut s = SearchConfig::default();
        s.tau = 0;
        assert!(s.validate().is_err());
        let mut s = SearchConfig::default();
        s.compact_junk = 1.5;
        assert!(s.validate().is_err()); // not a fraction
        let mut s = SearchConfig::default();
        s.compact_junk = -0.1;
        assert!(s.validate().is_err());
        let mut s = SearchConfig::default();
        s.compact_junk = f32::NAN;
        assert!(s.validate().is_err());
    }

    #[test]
    fn compact_and_singleflight_knobs_parse_and_default() {
        let d = SearchConfig::default();
        assert!(d.compact_junk > 0.0 && d.compact_junk < 1.0, "proactive compaction on");
        assert!(ServerConfig::default().singleflight, "pool single-flight on by default");
        let j = Json::parse(
            r#"{"search": {"compact_junk": 1.0},
                "server": {"singleflight": false}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.search.compact_junk, 1.0, "1.0 disables proactive compaction");
        assert!(!c.server.singleflight);
    }

    #[test]
    fn server_pool_knobs_parse_and_default() {
        let d = ServerConfig::default();
        assert_eq!(d.shards, 0, "default is auto");
        assert!(d.effective_shards() >= 1);
        assert!(ServerConfig::default_shards() >= 1);
        let j = Json::parse(
            r#"{"server": {"shards": 4, "capacity": 8, "cache_entries": 0}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.server.shards, 4);
        assert_eq!(c.server.effective_shards(), 4);
        assert_eq!(c.server.capacity, 8);
        assert_eq!(c.server.cache_entries, 0);
    }

    #[test]
    fn trace_knobs_parse_default_and_clamp() {
        let d = ServerConfig::default();
        assert_eq!(d.trace_capacity, 256);
        assert_eq!(d.trace_sample, 1.0, "keep every trace unless told otherwise");
        let j = Json::parse(
            r#"{"server": {"trace_capacity": 16, "trace_sample": 2.5}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.server.trace_capacity, 16);
        assert_eq!(c.server.trace_sample, 1.0, "sample rate clamps to [0,1]");
    }

    #[test]
    fn calib_knobs_parse_default_and_clamp() {
        let d = ServerConfig::default();
        assert!(!d.calib.adaptive, "the controller is observe-only until opted in");
        assert_eq!(d.calib.min_samples, 64);
        assert_eq!(d.calib.min_tau, 2);
        let j = Json::parse(
            r#"{"server": {"adaptive_tau": true, "calib_min_samples": 8,
                "calib_conf_floor": 0.2, "calib_aggressiveness": 3.0,
                "calib_min_tau": 0, "calib_shadow_rate": 0.5,
                "calib_depth_buckets": 6}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert!(c.server.calib.adaptive);
        assert_eq!(c.server.calib.min_samples, 8);
        assert_eq!(c.server.calib.conf_floor, 0.2);
        assert_eq!(c.server.calib.aggressiveness, 1.0, "aggressiveness clamps to [0,1]");
        assert_eq!(c.server.calib.min_tau, 1, "a zero floor would reject on no evidence");
        assert_eq!(c.server.calib.shadow_rate, 0.5);
        assert_eq!(c.server.calib.depth_buckets, 6);
    }

    #[test]
    fn fleet_knobs_parse_and_default() {
        let d = ServerConfig::default();
        assert!(!d.fleet, "fleet is opt-in; the sequential path is the fallback");
        assert_eq!(d.max_inflight, 8);
        assert!(!d.gang, "gang batching is opt-in on top of the fleet");
        assert_eq!(d.deadline_ms, 0, "no deadline unless configured");
        assert_eq!(d.kv_pool_blocks, 0, "0 = defer to the manifest's pool sizing");
        let j = Json::parse(
            r#"{"server": {"fleet": true, "max_inflight": 16, "gang": true, "deadline_ms": 2000, "kv_pool_blocks": 512}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert!(c.server.fleet);
        assert_eq!(c.server.max_inflight, 16);
        assert!(c.server.gang);
        assert_eq!(c.server.deadline_ms, 2000);
        assert_eq!(c.server.kv_pool_blocks, 512);
    }

    #[test]
    fn mode_parsing() {
        assert_eq!(SearchMode::parse("er").unwrap(), SearchMode::EarlyRejection);
        assert_eq!(SearchMode::parse("vanilla").unwrap(), SearchMode::Vanilla);
        assert!(SearchMode::parse("x").is_err());
        assert_eq!(SearchMode::EarlyRejection.name(), "er");
    }

    #[test]
    fn keep_rounds_up_to_one() {
        let mut s = SearchConfig::default();
        s.n_beams = 4;
        s.m_expand = 4;
        assert_eq!(s.keep(), 1);
    }

    #[test]
    fn default_temperature_by_behaviour() {
        assert!(EngineConfig::default_temperature("lm-verbose") > EngineConfig::default_temperature("lm-concise"));
    }
}
