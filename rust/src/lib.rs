//! # erprm — Early Rejection with Partial Reward Modeling
//!
//! A PRM-guided beam-search **serving stack** reproducing
//! *"Accelerating LLM Reasoning via Early Rejection with Partial Reward
//! Modeling"* (EMNLP 2025 Findings).
//!
//! Architecture (see `DESIGN.md`):
//! * **L3 (this crate)** — engine shard pool + request router, the fleet
//!   scheduler (continuous cross-request batching with rejection-freed
//!   slot backfill), dynamic two-tier batcher, KV-cache slot manager,
//!   prefill/decode scheduler, vanilla PRM beam search (paper Alg. 2) and
//!   the early-rejection search (paper Alg. 3) — both compiled to a
//!   resumable `SolveTask` state machine — analytic FLOPs ledger, HTTP
//!   serving front end. Python is never on the request path.
//! * **L2/L1 (build-time Python)** — JAX transformer LM + PRM lowered to
//!   HLO text with Pallas kernels inside; loaded here via the PJRT C API
//!   (`runtime` module).
//!
//! The `util` modules are hand-rolled substrates (JSON, CLI, RNG, stats,
//! thread pool, property testing, bench harness): the offline build
//! environment provides no serde/clap/tokio/criterion/proptest.

pub mod batch;
pub mod config;
pub mod coordinator;
pub mod fleet;
pub mod harness;
pub mod obs;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod tokenizer;
pub mod util;
pub mod workload;

pub use util::error::{Error, Result};
