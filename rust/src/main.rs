//! `erprm` — leader binary: serve / solve / sweep / correlate / theory / info.
//!
//! Examples:
//!   erprm info  --artifacts artifacts
//!   erprm solve --artifacts artifacts --v0 61 --ops -5,*6,+4 --mode er --n 16 --tau 8
//!   erprm serve --artifacts artifacts --addr 127.0.0.1:8377 --shards 4 --cache 128
//!   erprm serve --artifacts artifacts --fleet --max-inflight 8 --deadline-ms 5000
//!   erprm serve --artifacts artifacts --gang --max-inflight 8
//!   erprm serve --artifacts artifacts --fleet --kv-pool-blocks 512
//!   erprm sweep --artifacts artifacts --bench satmath-s --n-list 4,8 --problems 10
//!   erprm theory
//!
//! See README.md for the full walkthrough.

use std::path::PathBuf;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use erprm::config::{SearchConfig, SearchMode, ServerConfig};
use erprm::coordinator::{solve_early_rejection, solve_vanilla};
use erprm::fleet::{ChaosOptions, FleetOptions};
use erprm::obs::{SamplePolicy, TraceOptions};
use erprm::harness::{self, Cell};
use erprm::runtime::Engine;
use erprm::server::{
    http, lifecycle, metrics::Metrics, route, router::EnginePool, Lifecycle, PoolOptions,
    RetryOptions, SuperviseOptions,
};
use erprm::sim;
use erprm::tokenizer as tk;
use erprm::util::benchkit::{fmt_flops, Table};
use erprm::util::cli::Args;
use erprm::util::error::{Error, Result};
use erprm::util::logging;
use erprm::util::threadpool::ThreadPool;
use erprm::workload::{bench_by_name, OpStep, Problem};

fn main() {
    logging::init_from_env();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let r = match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("solve") => cmd_solve(&args),
        Some("serve") => cmd_serve(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("theory") => cmd_theory(&args),
        _ => {
            eprintln!(
                "usage: erprm <info|solve|serve|sweep|theory> [--artifacts DIR] [options]\n\
                 run `erprm <cmd> --help` conventions in README.md"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn cmd_info(args: &Args) -> Result<()> {
    let engine = Engine::load(&artifacts_dir(args))?;
    let m = &engine.manifest;
    println!("artifacts: {}", m.dir.display());
    println!("vocab: {} tokens; prompt_pad {}; decode_block {}; score_block {}",
        m.vocab.len(), m.prompt_pad, m.decode_block, m.score_block);
    println!("batch variants: {:?}", m.batch_variants);
    for (name, arch) in &m.models {
        println!(
            "  {name}: kind={} d={} L={} H={} params={} flops/token={} cache={} ckpts={:?}",
            arch.kind, arch.d_model, arch.n_layers, arch.n_heads, arch.params,
            arch.flops_per_token, arch.cache_len,
            arch.weights.keys().collect::<Vec<_>>()
        );
    }
    Ok(())
}

fn parse_ops(spec: &str) -> Result<Vec<OpStep>> {
    spec.split(',')
        .map(|s| {
            let s = s.trim();
            // Guard before split_at: an empty segment ("-5,,+4" or a
            // trailing comma) must be a parse error, not a panic.
            if s.len() < 2 || !s.is_char_boundary(1) {
                return Err(Error::parse(format!(
                    "bad op segment '{s}' in '{spec}' (expected e.g. '-5,*6,+4')"
                )));
            }
            let (op, d) = s.split_at(1);
            let op = match op {
                "+" => tk::PLUS,
                "-" => tk::MINUS,
                "*" => tk::TIMES,
                _ => return Err(Error::parse(format!("bad op '{s}'"))),
            };
            let d: i64 = d.parse().map_err(|_| Error::parse(format!("bad operand '{s}'")))?;
            Ok(OpStep { op, d })
        })
        .collect()
}

fn cmd_solve(args: &Args) -> Result<()> {
    let engine = Engine::load(&artifacts_dir(args))?;
    let v0 = args.get_u64("v0", 61)? as i64;
    let ops = parse_ops(args.get_or("ops", "-5,*6,+4"))?;
    let problem = Problem { v0, ops };
    let mode = SearchMode::parse(args.get_or("mode", "er"))?;
    let mut cfg = SearchConfig {
        mode,
        n_beams: args.get_usize("n", 16)?,
        tau: args.get_usize("tau", 8)?,
        seed: args.get_u64("seed", 0)?,
        ..SearchConfig::default()
    };
    cfg.m_expand = args.get_usize("m", 4)?;
    let lm = args.get_or("lm", "lm-concise");
    let prm = args.get_or("prm", "prm-large");
    let temp = harness::temp_for(lm);
    let out = match mode {
        SearchMode::Vanilla => solve_vanilla(&engine, lm, prm, &problem, &cfg, temp)?,
        SearchMode::EarlyRejection => {
            solve_early_rejection(&engine, lm, prm, &problem, &cfg, temp)?
        }
    };
    println!("problem: {}", tk::detok(&problem.prompt_tokens()));
    println!("trace:   {}", tk::detok(&out.best_trace));
    println!(
        "answer {:?} (expected {}) correct={} reward={:.3}",
        out.answer, problem.answer(), out.correct, out.best_reward
    );
    let r = out.ledger.report();
    println!(
        "flops: total {} (LM {} / PRM {}), steps {}, wall {:.0}ms",
        fmt_flops(r.total_flops), fmt_flops(r.lm_flops), fmt_flops(r.prm_flops),
        out.steps_executed, out.wall_s * 1000.0
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let scfg = ServerConfig::default();
    let addr = args.get_or("addr", &scfg.addr).to_string();
    let capacity = args.get_usize_min("capacity", scfg.capacity, 1)?;
    // --shards 0 (the default) means auto: available cores minus one.
    let shards = match args.get_usize("shards", scfg.shards)? {
        0 => ServerConfig::default_shards(),
        n => n,
    };
    // HTTP workers gate request concurrency. Fleet shards interleave
    // max_inflight requests each, so the worker default scales with
    // whichever concurrency the pool can actually absorb.
    let fleet = args.flag("fleet") || scfg.fleet;
    let max_inflight = args.get_usize_min("max-inflight", scfg.max_inflight, 1)?;
    // --gang: merge compatible in-flight requests' decode/score calls
    // into shared device batches (implies --fleet).
    let gang = args.flag("gang") || scfg.gang;
    let fleet = fleet || gang;
    let gang_max_wait = args.get_u64("gang-max-wait", FleetOptions::default().gang_max_wait)?;
    let deadline_ms = args.get_u64("deadline-ms", scfg.deadline_ms)?;
    // pool-level single-flight (cross-shard duplicate coalescing) is on
    // by default; `--no-singleflight` or the config file disable it
    let singleflight = scfg.singleflight && !args.flag("no-singleflight");
    // --kv-pool-blocks N: paged KV over a shared per-shard block pool.
    // An explicit 0 forces dense per-slot caches; with the flag absent
    // the config value applies, and when that is 0 too each shard
    // defaults to the manifest's exported `pool_blocks` sizing (if any —
    // artifact sets predating paged export stay dense).
    let kv_pool_blocks = match args.get("kv-pool-blocks") {
        Some(_) => Some(args.get_usize("kv-pool-blocks", 0)?),
        None if scfg.kv_pool_blocks > 0 => Some(scfg.kv_pool_blocks),
        None => None,
    };
    // --trace-capacity N: request traces retained for GET /trace/<id>
    // (0 disables retention; rollups still hit /metrics).
    // --trace-sample F: fraction of successful requests traced
    // (failures are always kept).
    let trace_capacity = args.get_usize("trace-capacity", scfg.trace_capacity)?;
    let trace_sample =
        args.get_f64("trace-sample", scfg.trace_sample)?.clamp(0.0, 1.0);
    // Calibration observatory: the partial↔final table streams on every
    // ER request regardless; --adaptive-tau additionally closes the
    // loop and lets the router shave taus where the table has proven
    // itself (GET /calibration shows the live table either way).
    let mut calib = scfg.calib;
    calib.adaptive = calib.adaptive || args.flag("adaptive-tau");
    calib.min_samples = args.get_u64("calib-min-samples", calib.min_samples)?.max(1);
    calib.conf_floor =
        args.get_f64("calib-conf-floor", calib.conf_floor)?.clamp(-1.0, 1.0);
    calib.aggressiveness =
        args.get_f64("calib-aggressiveness", calib.aggressiveness)?.clamp(0.0, 1.0);
    calib.min_tau = args.get_usize_min("calib-min-tau", calib.min_tau, 1)?;
    calib.shadow_rate =
        args.get_f64("calib-shadow-rate", calib.shadow_rate)?.clamp(0.0, 1.0);
    let worker_default = if fleet { shards * max_inflight + 2 } else { shards + 2 };
    let workers = args.get_usize_min("workers", worker_default, 1)?;
    // --cache N sets the LRU solve-cache size; --cache 0 disables it.
    let cache = args.get_usize("cache", scfg.cache_entries)?;
    // Fault tolerance: transparent retry of retryable failures within
    // the request's deadline budget, supervisor respawn of dead/wedged
    // shards, and a bounded graceful drain on SIGTERM / POST
    // /admin/drain.
    let retry = RetryOptions {
        max_attempts: args.get_u64("retry-max", scfg.retry_max_attempts as u64)?.max(1) as u32,
        base_ms: args.get_u64("retry-base-ms", scfg.retry_base_ms)?.max(1),
        cap_ms: args.get_u64("retry-cap-ms", scfg.retry_cap_ms)?.max(1),
        retry_saturated: args.flag("retry-saturated") || scfg.retry_saturated,
    };
    let supervise = SuperviseOptions {
        enabled: !args.flag("no-supervise"),
        stale_ms: args.get_u64("supervise-stale-ms", scfg.supervise_stale_ms)?.max(1),
        ..SuperviseOptions::default()
    };
    let drain_deadline_ms = args.get_u64("drain-deadline-ms", scfg.drain_deadline_ms)?;
    // --chaos-*: deterministic fault injection for resilience testing.
    // Off unless a probability/slow-shard knob is set; never enable in
    // production.
    let chaos = ChaosOptions {
        seed: args.get_u64("chaos-seed", 0)?,
        panic_per_tick: args.get_f64("chaos-panic", 0.0)?.clamp(0.0, 1.0),
        max_panics: args.get_u64("chaos-max-panics", 0)?,
        stall_per_tick: args.get_f64("chaos-stall", 0.0)?.clamp(0.0, 1.0),
        stall_ms: args.get_u64("chaos-stall-ms", 0)?,
        max_stalls: args.get_u64("chaos-max-stalls", 0)?,
        slow_shard: args
            .get("chaos-slow-shard")
            .map(|_| args.get_usize("chaos-slow-shard", 0))
            .transpose()?,
        slow_ms: args.get_u64("chaos-slow-ms", 0)?,
    };
    if chaos.enabled() {
        eprintln!(
            "warning: chaos injection enabled (seed {}, panic {}, stall {}) — testing only",
            chaos.seed, chaos.panic_per_tick, chaos.stall_per_tick
        );
    }
    let defaults = SearchConfig::default();
    let pool = EnginePool::spawn_with(
        dir,
        PoolOptions {
            shards,
            capacity,
            cache_entries: cache,
            default_deadline_ms: deadline_ms,
            fleet: fleet.then(|| FleetOptions {
                max_inflight,
                gang,
                gang_max_wait,
                ..FleetOptions::default()
            }),
            singleflight,
            kv_pool_blocks,
            trace: TraceOptions {
                capacity: trace_capacity,
                sample: SamplePolicy { success_rate: trace_sample, ..SamplePolicy::default() },
                calib,
            },
            retry,
            supervise,
            chaos,
        },
    )?;
    let metrics = Arc::new(Metrics::default());
    let tpool = ThreadPool::new(workers);
    let stop = Arc::new(AtomicBool::new(false));
    let life = Lifecycle::new();
    lifecycle::install_sigterm();

    let p2 = pool.clone();
    let m2 = Arc::clone(&metrics);
    let d2 = defaults.clone();
    let l2 = life.clone();
    let local = http::serve(
        &addr,
        &tpool,
        scfg.max_body_bytes,
        Arc::clone(&stop),
        Arc::new(move |req| route(&p2, &m2, &d2, &l2, req)),
    )?;
    let mode = if fleet {
        let g = if gang {
            format!(", gang batching (max wait {gang_max_wait})")
        } else {
            String::new()
        };
        format!("fleet: {max_inflight} in-flight/shard{g}, default deadline {deadline_ms}ms")
    } else {
        format!("sequential dispatch, default deadline {deadline_ms}ms")
    };
    let tau_mode = if calib.adaptive {
        format!(
            ", adaptive tau (min {} samples, conf floor {:.2}, shadow {:.0}%)",
            calib.min_samples,
            calib.conf_floor,
            calib.shadow_rate * 100.0
        )
    } else {
        String::new()
    };
    println!(
        "erprm serving on http://{local}  ({} engine shards, {capacity} queue slots/shard, \
         cache {cache}, {mode}{tau_mode})  (POST /solve, GET /metrics, GET /healthz, \
         GET /readyz, POST /admin/drain, GET /calibration, GET /trace/<id>, GET /traces, \
         GET /traces/chrome)",
        pool.n_shards()
    );
    // run until a drain is requested (SIGTERM or POST /admin/drain),
    // then finish in-flight work — bounded by --drain-deadline-ms —
    // stop admitting connections, and shut the pool down.
    while !life.draining() {
        if lifecycle::term_requested() {
            life.drain();
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    eprintln!("draining: refusing new work, finishing in-flight requests");
    let t0 = std::time::Instant::now();
    let budget = std::time::Duration::from_millis(drain_deadline_ms);
    while pool.queue_depth() > 0 && t0.elapsed() < budget {
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    // one extra beat so responses for just-finished solves flush
    std::thread::sleep(std::time::Duration::from_millis(100));
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let abandoned = pool.queue_depth();
    pool.shutdown();
    if abandoned > 0 {
        eprintln!("drain deadline elapsed with {abandoned} requests still queued");
    } else {
        eprintln!("drain complete in {:.1}s", t0.elapsed().as_secs_f64());
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let engine = Engine::load(&artifacts_dir(args))?;
    let bench = bench_by_name(args.get_or("bench", "satmath-s"))
        .ok_or_else(|| Error::invalid("unknown bench (satmath-s|math500-s|aime-s)"))?;
    let n_list = args.get_usize_list("n-list", &[4, 8, 16])?;
    let taus = args.get_usize_list("taus", &[8, 16])?;
    let problems = args.get_usize("problems", 10)?;
    let lm = args.get_or("lm", "lm-concise").to_string();
    let prm = args.get_or("prm", "prm-large").to_string();
    let seed = args.get_u64("seed", 42)?;

    let mut table = Table::new(
        &format!("{} / {} / {}", bench.name, lm, prm),
        &["setting", "N", "accuracy %", "total FLOPs", "LM FLOPs", "PRM FLOPs", "wall s"],
    );
    for &n in &n_list {
        let mut cells = vec![Cell {
            bench,
            lm_ckpt: lm.clone(),
            prm_ckpt: prm.clone(),
            mode: SearchMode::Vanilla,
            n_beams: n,
            tau: 1,
        }];
        for &tau in &taus {
            cells.push(Cell {
                bench,
                lm_ckpt: lm.clone(),
                prm_ckpt: prm.clone(),
                mode: SearchMode::EarlyRejection,
                n_beams: n,
                tau,
            });
        }
        for cell in cells {
            let res = harness::run_cell(&engine, &cell, problems, seed)?;
            let r = res.ledger.report();
            let setting = match cell.mode {
                SearchMode::Vanilla => "vanilla".into(),
                SearchMode::EarlyRejection => format!("ER(tau={})", cell.tau),
            };
            table.row(vec![
                setting,
                n.to_string(),
                format!("{:.1}", res.accuracy),
                fmt_flops(r.total_flops),
                fmt_flops(r.lm_flops),
                fmt_flops(r.prm_flops),
                format!("{:.1}", res.wall_s),
            ]);
        }
    }
    table.emit("sweep");
    Ok(())
}

fn cmd_theory(args: &Args) -> Result<()> {
    let l = args.get_usize("len", 64)?;
    let trials = args.get_usize("trials", 4000)?;
    let mut t1 = Table::new(
        "rho(P,F) vs tau — toy model (paper Sec. 4, Fig. 4 trend)",
        &["tau", "pearson (MC)", "kendall (MC)", "sqrt(tau/L)"],
    );
    for tau in [4usize, 8, 16, 24, 32, 48, 64] {
        let tau = tau.min(l);
        let (p, k) = sim::toy_correlation(tau, l, trials, 7);
        t1.row(vec![
            tau.to_string(),
            format!("{p:.3}"),
            format!("{k:.3}"),
            format!("{:.3}", sim::toy_correlation_exact(tau, l)),
        ]);
    }
    t1.emit("theory_correlation");

    let mut t2 = Table::new(
        "Pr[prune optimal] vs bound (N-1)exp(-Delta^2/4sigma^2)",
        &["tau", "delta/token", "empirical", "bound"],
    );
    for &(tau, d) in &[(4usize, 0.25f64), (8, 0.25), (16, 0.25), (32, 0.25), (16, 0.5), (16, 0.1)] {
        let (emp, bound) = sim::prune_probability(16, 4, tau, d, 1.0, trials, 11);
        t2.row(vec![
            tau.to_string(),
            format!("{d:.2}"),
            format!("{emp:.4}"),
            format!("{bound:.4}"),
        ]);
    }
    t2.emit("theory_bound");
    println!(
        "min tau for rho*=0.8 at L=100: {} (paper: 64)",
        sim::min_tau_for_rho(0.8, 100)
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_ops_happy_path() {
        let ops = parse_ops("-5,*6,+4").unwrap();
        assert_eq!(ops.len(), 3);
        assert_eq!(ops[0].op, tk::MINUS);
        assert_eq!(ops[0].d, 5);
        assert_eq!(ops[1].op, tk::TIMES);
        assert_eq!(ops[2].op, tk::PLUS);
        assert_eq!(ops[2].d, 4);
    }

    #[test]
    fn parse_ops_rejects_empty_segments_without_panicking() {
        // These used to panic via split_at(1) on an empty segment.
        assert!(parse_ops("-5,,+4").is_err());
        assert!(parse_ops("-5,*6,").is_err());
        assert!(parse_ops("").is_err());
        assert!(parse_ops(",").is_err());
        assert!(parse_ops("  ").is_err());
    }

    #[test]
    fn parse_ops_rejects_bad_ops_and_operands() {
        assert!(parse_ops("%5").is_err());
        assert!(parse_ops("+x").is_err());
        assert!(parse_ops("5").is_err());
        // multi-byte first char must be a parse error, not a panic
        assert!(parse_ops("é5").is_err());
    }
}
