//! `erprm` — leader binary: serve / solve / sweep / correlate / theory / info.
//!
//! Examples:
//!   erprm info  --artifacts artifacts
//!   erprm solve --artifacts artifacts --v0 61 --ops -5,*6,+4 --mode er --n 16 --tau 8
//!   erprm serve --artifacts artifacts --addr 127.0.0.1:8377
//!   erprm sweep --artifacts artifacts --bench satmath-s --n-list 4,8 --problems 10
//!   erprm theory
//!
//! See README.md for the full walkthrough.

use std::path::PathBuf;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use erprm::config::{SearchConfig, SearchMode};
use erprm::coordinator::{solve_early_rejection, solve_vanilla};
use erprm::harness::{self, Cell};
use erprm::runtime::Engine;
use erprm::server::{api, http, metrics::Metrics, router::EngineHandle};
use erprm::sim;
use erprm::tokenizer as tk;
use erprm::util::benchkit::{fmt_flops, Table};
use erprm::util::cli::Args;
use erprm::util::error::{Error, Result};
use erprm::util::logging;
use erprm::util::threadpool::ThreadPool;
use erprm::workload::{bench_by_name, OpStep, Problem};

fn main() {
    logging::init_from_env();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let r = match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("solve") => cmd_solve(&args),
        Some("serve") => cmd_serve(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("theory") => cmd_theory(&args),
        _ => {
            eprintln!(
                "usage: erprm <info|solve|serve|sweep|theory> [--artifacts DIR] [options]\n\
                 run `erprm <cmd> --help` conventions in README.md"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn cmd_info(args: &Args) -> Result<()> {
    let engine = Engine::load(&artifacts_dir(args))?;
    let m = &engine.manifest;
    println!("artifacts: {}", m.dir.display());
    println!("vocab: {} tokens; prompt_pad {}; decode_block {}; score_block {}",
        m.vocab.len(), m.prompt_pad, m.decode_block, m.score_block);
    println!("batch variants: {:?}", m.batch_variants);
    for (name, arch) in &m.models {
        println!(
            "  {name}: kind={} d={} L={} H={} params={} flops/token={} cache={} ckpts={:?}",
            arch.kind, arch.d_model, arch.n_layers, arch.n_heads, arch.params,
            arch.flops_per_token, arch.cache_len,
            arch.weights.keys().collect::<Vec<_>>()
        );
    }
    Ok(())
}

fn parse_ops(spec: &str) -> Result<Vec<OpStep>> {
    spec.split(',')
        .map(|s| {
            let s = s.trim();
            let (op, d) = s.split_at(1);
            let op = match op {
                "+" => tk::PLUS,
                "-" => tk::MINUS,
                "*" => tk::TIMES,
                _ => return Err(Error::parse(format!("bad op '{s}'"))),
            };
            let d: i64 = d.parse().map_err(|_| Error::parse(format!("bad operand '{s}'")))?;
            Ok(OpStep { op, d })
        })
        .collect()
}

fn cmd_solve(args: &Args) -> Result<()> {
    let engine = Engine::load(&artifacts_dir(args))?;
    let v0 = args.get_u64("v0", 61)? as i64;
    let ops = parse_ops(args.get_or("ops", "-5,*6,+4"))?;
    let problem = Problem { v0, ops };
    let mode = SearchMode::parse(args.get_or("mode", "er"))?;
    let mut cfg = SearchConfig {
        mode,
        n_beams: args.get_usize("n", 16)?,
        tau: args.get_usize("tau", 8)?,
        seed: args.get_u64("seed", 0)?,
        ..SearchConfig::default()
    };
    cfg.m_expand = args.get_usize("m", 4)?;
    let lm = args.get_or("lm", "lm-concise");
    let prm = args.get_or("prm", "prm-large");
    let temp = harness::temp_for(lm);
    let out = match mode {
        SearchMode::Vanilla => solve_vanilla(&engine, lm, prm, &problem, &cfg, temp)?,
        SearchMode::EarlyRejection => {
            solve_early_rejection(&engine, lm, prm, &problem, &cfg, temp)?
        }
    };
    println!("problem: {}", tk::detok(&problem.prompt_tokens()));
    println!("trace:   {}", tk::detok(&out.best_trace));
    println!(
        "answer {:?} (expected {}) correct={} reward={:.3}",
        out.answer, problem.answer(), out.correct, out.best_reward
    );
    let r = out.ledger.report();
    println!(
        "flops: total {} (LM {} / PRM {}), steps {}, wall {:.0}ms",
        fmt_flops(r.total_flops), fmt_flops(r.lm_flops), fmt_flops(r.prm_flops),
        out.steps_executed, out.wall_s * 1000.0
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let addr = args.get_or("addr", "127.0.0.1:8377").to_string();
    let workers = args.get_usize("workers", 2)?;
    let capacity = args.get_usize("capacity", 64)?;
    let defaults = SearchConfig::default();
    let handle = EngineHandle::spawn(dir, defaults.clone(), capacity)?;
    let metrics = Arc::new(Metrics::default());
    let pool = ThreadPool::new(workers);
    let stop = Arc::new(AtomicBool::new(false));

    let h2 = handle.clone();
    let m2 = Arc::clone(&metrics);
    let d2 = defaults.clone();
    let local = http::serve(
        &addr,
        &pool,
        1 << 20,
        Arc::clone(&stop),
        Arc::new(move |req| route(&h2, &m2, &d2, req)),
    )?;
    println!("erprm serving on http://{local}  (POST /solve, GET /metrics, GET /healthz)");
    // run until killed
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Route one HTTP request (shared with `examples/serve_benchmark.rs`).
pub fn route(
    handle: &EngineHandle,
    metrics: &Metrics,
    defaults: &SearchConfig,
    req: http::Request,
) -> http::Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => http::Response::json(200, "{\"ok\":true}".into()),
        ("GET", "/metrics") => http::Response::text(200, &metrics.render()),
        ("POST", "/solve") => {
            let t0 = std::time::Instant::now();
            let parsed = match api::parse_solve(&req.body, defaults) {
                Ok(p) => p,
                Err(e) => {
                    metrics.record_error();
                    return http::Response::json(400, format!("{{\"error\":\"{e}\"}}"));
                }
            };
            match handle.solve(parsed.clone(), defaults.clone()) {
                Ok(out) => {
                    metrics.record_ok(
                        t0.elapsed().as_secs_f64() * 1000.0,
                        out.ledger.total_flops(),
                        out.correct,
                    );
                    http::Response::json(200, api::render_solve(&parsed, &out))
                }
                Err(e) => {
                    metrics.record_error();
                    let code = if e.to_string().contains("queue full") { 503 } else { 500 };
                    http::Response::json(code, format!("{{\"error\":\"{e}\"}}"))
                }
            }
        }
        _ => http::Response::json(404, "{\"error\":\"not found\"}".into()),
    }
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let engine = Engine::load(&artifacts_dir(args))?;
    let bench = bench_by_name(args.get_or("bench", "satmath-s"))
        .ok_or_else(|| Error::invalid("unknown bench (satmath-s|math500-s|aime-s)"))?;
    let n_list = args.get_usize_list("n-list", &[4, 8, 16])?;
    let taus = args.get_usize_list("taus", &[8, 16])?;
    let problems = args.get_usize("problems", 10)?;
    let lm = args.get_or("lm", "lm-concise").to_string();
    let prm = args.get_or("prm", "prm-large").to_string();
    let seed = args.get_u64("seed", 42)?;

    let mut table = Table::new(
        &format!("{} / {} / {}", bench.name, lm, prm),
        &["setting", "N", "accuracy %", "total FLOPs", "LM FLOPs", "PRM FLOPs", "wall s"],
    );
    for &n in &n_list {
        let mut cells = vec![Cell {
            bench,
            lm_ckpt: lm.clone(),
            prm_ckpt: prm.clone(),
            mode: SearchMode::Vanilla,
            n_beams: n,
            tau: 1,
        }];
        for &tau in &taus {
            cells.push(Cell {
                bench,
                lm_ckpt: lm.clone(),
                prm_ckpt: prm.clone(),
                mode: SearchMode::EarlyRejection,
                n_beams: n,
                tau,
            });
        }
        for cell in cells {
            let res = harness::run_cell(&engine, &cell, problems, seed)?;
            let r = res.ledger.report();
            let setting = match cell.mode {
                SearchMode::Vanilla => "vanilla".into(),
                SearchMode::EarlyRejection => format!("ER(tau={})", cell.tau),
            };
            table.row(vec![
                setting,
                n.to_string(),
                format!("{:.1}", res.accuracy),
                fmt_flops(r.total_flops),
                fmt_flops(r.lm_flops),
                fmt_flops(r.prm_flops),
                format!("{:.1}", res.wall_s),
            ]);
        }
    }
    table.emit("sweep");
    Ok(())
}

fn cmd_theory(args: &Args) -> Result<()> {
    let l = args.get_usize("len", 64)?;
    let trials = args.get_usize("trials", 4000)?;
    let mut t1 = Table::new(
        "rho(P,F) vs tau — toy model (paper Sec. 4, Fig. 4 trend)",
        &["tau", "pearson (MC)", "kendall (MC)", "sqrt(tau/L)"],
    );
    for tau in [4usize, 8, 16, 24, 32, 48, 64] {
        let tau = tau.min(l);
        let (p, k) = sim::toy_correlation(tau, l, trials, 7);
        t1.row(vec![
            tau.to_string(),
            format!("{p:.3}"),
            format!("{k:.3}"),
            format!("{:.3}", sim::toy_correlation_exact(tau, l)),
        ]);
    }
    t1.emit("theory_correlation");

    let mut t2 = Table::new(
        "Pr[prune optimal] vs bound (N-1)exp(-Delta^2/4sigma^2)",
        &["tau", "delta/token", "empirical", "bound"],
    );
    for &(tau, d) in &[(4usize, 0.25f64), (8, 0.25), (16, 0.25), (32, 0.25), (16, 0.5), (16, 0.1)] {
        let (emp, bound) = sim::prune_probability(16, 4, tau, d, 1.0, trials, 11);
        t2.row(vec![
            tau.to_string(),
            format!("{d:.2}"),
            format!("{emp:.4}"),
            format!("{bound:.4}"),
        ]);
    }
    t2.emit("theory_bound");
    println!(
        "min tau for rho*=0.8 at L=100: {} (paper: 64)",
        sim::min_tau_for_rho(0.8, 100)
    );
    Ok(())
}
