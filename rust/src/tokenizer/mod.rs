//! Tokenizer for the arithmetic-chain grammar.
//!
//! The vocabulary is defined once in `python/compile/grammar.py` and shipped
//! in `artifacts/manifest.json`; this module hard-codes the same ids (they
//! are part of the artifact ABI) and verifies them against the manifest at
//! engine startup (`runtime::artifacts`), so Python and Rust can never
//! disagree silently.

/// Token ids (ABI shared with python/compile/grammar.py).
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const DIG0: i32 = 3; // '0'..'9' -> 3..12
pub const PLUS: i32 = 13;
pub const MINUS: i32 = 14;
pub const TIMES: i32 = 15;
pub const EQ: i32 = 16;
pub const SEMI: i32 = 17; // step separator
pub const SEP: i32 = 18; // problem/solution separator '>'
pub const ANS: i32 = 19;
pub const COLON: i32 = 20;
pub const FILL: i32 = 21; // '~' filler (verbose traces)
pub const SPACE: i32 = 22;
pub const RSV: i32 = 23;
pub const VOCAB_SIZE: usize = 24;

/// Value modulus of the task (two-digit arithmetic).
pub const MOD: i64 = 100;

/// The canonical token strings, index == id.
pub fn token_strs() -> Vec<&'static str> {
    let mut v = vec!["<pad>", "<bos>", "<eos>"];
    v.extend(["0", "1", "2", "3", "4", "5", "6", "7", "8", "9"]);
    v.extend(["+", "-", "*", "=", ";", ">", "A", ":", "~", " ", "#"]);
    v
}

/// Render token ids as a human-readable string.
pub fn detok(ids: &[i32]) -> String {
    let strs = token_strs();
    ids.iter()
        .map(|&i| strs.get(i as usize).copied().unwrap_or("?"))
        .collect()
}

/// Two zero-padded digit tokens for a value mod 100.
pub fn two_digits(v: i64) -> [i32; 2] {
    let v = v.rem_euclid(MOD);
    [DIG0 + (v / 10) as i32, DIG0 + (v % 10) as i32]
}

/// Parse two consecutive digit tokens; None if either is not a digit.
pub fn parse_two_digits(a: i32, b: i32) -> Option<i64> {
    if (DIG0..DIG0 + 10).contains(&a) && (DIG0..DIG0 + 10).contains(&b) {
        Some(((a - DIG0) * 10 + (b - DIG0)) as i64)
    } else {
        None
    }
}

pub fn is_digit(t: i32) -> bool {
    (DIG0..DIG0 + 10).contains(&t)
}

pub fn is_op(t: i32) -> bool {
    matches!(t, PLUS | MINUS | TIMES)
}

/// Apply an operation token to a running value (mod 100).
pub fn apply_op(v: i64, op: i32, d: i64) -> i64 {
    match op {
        PLUS => (v + d).rem_euclid(MOD),
        MINUS => (v - d).rem_euclid(MOD),
        TIMES => (v * d).rem_euclid(MOD),
        _ => panic!("bad op token {op}"),
    }
}

/// Scratch items for one reasoning step (mirrors grammar.scratch_items).
pub fn scratch_items(v: i64, op: i32, d: i64) -> Vec<i64> {
    (1..=d)
        .map(|i| match op {
            PLUS => (v + i).rem_euclid(MOD),
            MINUS => (v - i).rem_euclid(MOD),
            TIMES => (v * i).rem_euclid(MOD),
            _ => panic!("bad op token {op}"),
        })
        .collect()
}

/// Extract the final answer from a generated solution: last `A dd` group.
pub fn extract_answer(sol: &[i32]) -> Option<i64> {
    for i in (0..sol.len().saturating_sub(2)).rev() {
        if sol[i] == ANS {
            if let Some(v) = parse_two_digits(sol[i + 1], sol[i + 2]) {
                return Some(v);
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_matches_size() {
        assert_eq!(token_strs().len(), VOCAB_SIZE);
        assert_eq!(token_strs()[PLUS as usize], "+");
        assert_eq!(token_strs()[ANS as usize], "A");
    }

    #[test]
    fn two_digit_roundtrip() {
        for v in 0..100 {
            let [a, b] = two_digits(v);
            assert_eq!(parse_two_digits(a, b), Some(v));
        }
        assert_eq!(two_digits(105), two_digits(5));
        assert_eq!(two_digits(-1), two_digits(99));
    }

    #[test]
    fn parse_rejects_non_digits() {
        assert_eq!(parse_two_digits(PLUS, DIG0), None);
        assert_eq!(parse_two_digits(DIG0, EOS), None);
    }

    #[test]
    fn ops() {
        assert_eq!(apply_op(99, PLUS, 3), 2);
        assert_eq!(apply_op(1, MINUS, 4), 97);
        assert_eq!(apply_op(25, TIMES, 5), 25);
    }

    #[test]
    fn scratch_matches_python() {
        assert_eq!(scratch_items(98, PLUS, 3), vec![99, 0, 1]);
        assert_eq!(scratch_items(1, MINUS, 2), vec![0, 99]);
        assert_eq!(scratch_items(25, TIMES, 4), vec![25, 50, 75, 0]);
    }

    #[test]
    fn answer_extraction() {
        let mut sol = vec![DIG0 + 1, SEMI];
        sol.push(ANS);
        sol.extend(two_digits(42));
        sol.push(EOS);
        assert_eq!(extract_answer(&sol), Some(42));
        assert_eq!(extract_answer(&[BOS, EOS]), None);
    }

    #[test]
    fn detok_readable() {
        let mut toks = vec![BOS];
        toks.extend(two_digits(61));
        toks.extend([MINUS, DIG0 + 5, SEP]);
        assert_eq!(detok(&toks), "<bos>61-5>");
    }
}
