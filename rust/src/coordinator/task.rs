//! Resumable solve tasks — the state machine the fleet scheduler drives.
//!
//! A [`SolveTask`] is one request's beam search reified as an explicit
//! state machine: `init → phase-A decode → reject → phase-B decode →
//! finalize/expand → done` for early rejection, `init → decode → select →
//! done` for the vanilla baseline. Each [`SolveTask::advance`] call does a
//! *bounded* amount of engine work (one lockstep decode block, one scoring
//! catch-up, one reject/expand transition) and returns, so a shard thread
//! can interleave many in-flight tasks on one engine instead of running
//! each request to completion back to back.
//!
//! Determinism contract: a task performs exactly the same engine-call
//! sequence, in the same order, as the blocking `solve_*` functions did —
//! all of its state (KV caches, RNG streams, FLOPs ledger) is private to
//! the task, so the resulting [`SolveOutcome`] is byte-identical (modulo
//! wall-clock) no matter how many other tasks are interleaved between its
//! `advance` calls. The integration suite pins this down.

use std::sync::Arc;
use std::time::Instant;

use crate::config::{Aggregation, SearchConfig};
use crate::coordinator::beam::BeamSet;
use crate::coordinator::policy::{RejectPolicy, TauPlan};
use crate::coordinator::scheduler::TwoTierPlan;
use crate::coordinator::scorer::ScoreRound;
use crate::coordinator::search::{
    CompactTarget, DecodePrep, DecodeStage, PhaseTarget, SearchCtx, SolveOutcome,
};
use crate::obs::{ErEvent, TraceBuilder};
use crate::runtime::{Engine, KvSet};
use crate::util::error::{Error, Result};
use crate::workload::Problem;

/// What one `advance` call produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Progress {
    /// More engine work remains; call `advance` again.
    Working,
    /// The task is finished; collect the result with `take_outcome`.
    Done,
}

/// What one cooperative `poll` call produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Step {
    /// The task parked a prepared engine call (see [`SolveTask::intent`]).
    /// The caller must run it — alone via [`SolveTask::execute_intent`],
    /// or merged with other tasks' compatible intents by the gang batcher
    /// (`crate::batch`) — before the next `poll`.
    Yielded,
    /// A host-side transition (or a terminal event) happened.
    Progressed(Progress),
}

/// Which engine program class a yielded intent targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IntentKind {
    /// `decode_bN` on the LM cache.
    Decode,
    /// `score_bN` on the PRM cache.
    Score,
    /// `compact_bN` on one of this task's caches (frontier
    /// re-compaction). Never ganged — a compaction is a per-cache repack
    /// with nothing to share — so the dispatcher executes these solo
    /// immediately instead of parking them for partners.
    Compact,
}

/// A prepared engine call a [`SolveTask`] has yielded to its scheduler
/// instead of executing itself — the unit the gang batcher packs into
/// shared device batches. Carries only host data; the device cache it
/// targets stays inside the task (see `gang_kv`/`gang_absorb`).
#[derive(Debug)]
pub struct DecodeIntent {
    pub kind: IntentKind,
    /// Checkpoint the call runs against (LM for decode, PRM for score).
    pub ckpt: String,
    /// Device batch of this task's own cache.
    pub batch: usize,
    /// Sampling temperature. Part of the gang key because the decode
    /// program takes one scalar for the whole (possibly shared) batch.
    pub temp: f32,
    payload: Payload,
}

#[derive(Debug)]
enum Payload {
    Decode(DecodePrep),
    Score(ScoreRound),
    Compact(CompactTarget),
}

impl DecodeIntent {
    /// Grouping key: only intents agreeing on all of these may share one
    /// device call. (Compact intents carry a key too, but the dispatcher
    /// never gangs them — see [`IntentKind::Compact`].)
    pub fn gang_key(&self) -> (IntentKind, &str, u32) {
        (self.kind, &self.ckpt, self.temp.to_bits())
    }

    /// Decode inputs `(prev_tok, keys)`, if this is a decode intent.
    pub(crate) fn decode_inputs(&self) -> Option<(&[i32], &[u32])> {
        match &self.payload {
            Payload::Decode(p) => Some((&p.prev, &p.keys)),
            _ => None,
        }
    }

    /// Score token matrix `[batch * score_block]`, if a score intent.
    pub(crate) fn score_tokens(&self) -> Option<&[i32]> {
        match &self.payload {
            Payload::Score(r) => Some(&r.tokens),
            _ => None,
        }
    }
}

/// One member's slice of a (possibly merged) call's outputs, routed back
/// by the gang executor.
pub(crate) enum GangOut<'a> {
    Tokens(&'a [i32]),
    Scores(&'a [f32]),
}

/// Which decoder drives the task.
#[derive(Debug, Clone, Copy)]
enum Mode {
    Vanilla,
    Er { policy: RejectPolicy, two_tier: bool },
}

/// The resumable-solve state. Decode states tick one block per advance;
/// host-side transitions (reject, finalize, expand) are one advance each.
/// `score_ok` is the PRM KV-budget verdict, taken once at the decode →
/// score transition (the same point the blocking path checked it) so the
/// round-at-a-time cooperative scoring keeps the blocking semantics.
#[derive(Debug, Clone, Copy)]
enum State {
    Init,
    // vanilla: decode to boundary, score, select + expand
    VDecode,
    VScore { decode_ok: bool, score_ok: bool },
    VSelect,
    // early rejection: prefix decode, score, reject (+shrink),
    // completion decode, score, finalize (+expand)
    ADecode,
    AScore { decode_ok: bool, score_ok: bool },
    Reject,
    BDecode { plan: TwoTierPlan },
    BScore { plan: TwoTierPlan, decode_ok: bool, score_ok: bool },
    Finalize { plan: TwoTierPlan },
    Done,
}

/// One in-flight solve, parked between engine calls.
///
/// Invariant: `lm_ckpt`/`prm_ckpt`/`cfg`/`temp` are construction inputs
/// and never mutate; after `Init`, `ctx` holds equal copies (a `SearchCtx`
/// must be self-contained for its own methods). Reads through either are
/// interchangeable — do not add mutation to one side only.
pub struct SolveTask {
    problem: Problem,
    lm_ckpt: String,
    prm_ckpt: String,
    cfg: SearchConfig,
    temp: f32,
    mode: Mode,
    state: State,
    ctx: Option<SearchCtx>,
    /// Engine call parked by the last `poll` (see [`Step::Yielded`]).
    pending: Option<DecodeIntent>,
    t0: Instant,
    /// Steps counted the same way the blocking solvers counted them.
    steps: usize,
    /// Completed select/expand rounds (the blocking `for` loop index).
    iters: usize,
    outcome: Option<SolveOutcome>,
    /// Request trace riding the task (owned, lock-free). `None` leaves
    /// every record site a no-op; the determinism contract above extends
    /// to tracing — recording never touches RNG, beams, or engine-call
    /// order, so a traced solve is byte-identical to an untraced one.
    pub trace: Option<Box<TraceBuilder>>,
    /// Frozen per-request rejection schedule from the adaptive-tau
    /// controller. `None` (and any plan whose per-bucket taus equal the
    /// base) reproduces the static `cfg.tau` behaviour bit-for-bit.
    pub tau_plan: Option<Arc<TauPlan>>,
    /// Survivors' partial rewards from the last `Reject`, keyed by the
    /// slot each survivor occupies *after* any two-tier shrink, waiting
    /// to be paired with their finalized step rewards in `Finalize`.
    calib_pending: Vec<(usize, f32)>,
}

impl SolveTask {
    /// Vanilla beam search (paper Algorithm 2) as a resumable task.
    pub fn vanilla(
        problem: Problem,
        lm_ckpt: &str,
        prm_ckpt: &str,
        cfg: &SearchConfig,
        temp: f32,
    ) -> Result<SolveTask> {
        cfg.validate()?;
        Ok(SolveTask::new(problem, lm_ckpt, prm_ckpt, cfg, temp, Mode::Vanilla))
    }

    /// Early rejection (paper Algorithm 3) with the default top-N/M policy.
    pub fn early_rejection(
        problem: Problem,
        lm_ckpt: &str,
        prm_ckpt: &str,
        cfg: &SearchConfig,
        temp: f32,
    ) -> Result<SolveTask> {
        let policy = RejectPolicy::TopK { keep: cfg.keep() };
        SolveTask::early_rejection_with_policy(problem, lm_ckpt, prm_ckpt, cfg, temp, policy, true)
    }

    /// Early rejection with a custom policy / two-tier toggle (ablations).
    pub fn early_rejection_with_policy(
        problem: Problem,
        lm_ckpt: &str,
        prm_ckpt: &str,
        cfg: &SearchConfig,
        temp: f32,
        policy: RejectPolicy,
        two_tier: bool,
    ) -> Result<SolveTask> {
        cfg.validate()?;
        Ok(SolveTask::new(problem, lm_ckpt, prm_ckpt, cfg, temp, Mode::Er { policy, two_tier }))
    }

    fn new(
        problem: Problem,
        lm_ckpt: &str,
        prm_ckpt: &str,
        cfg: &SearchConfig,
        temp: f32,
        mode: Mode,
    ) -> SolveTask {
        SolveTask {
            problem,
            lm_ckpt: lm_ckpt.to_string(),
            prm_ckpt: prm_ckpt.to_string(),
            cfg: cfg.clone(),
            temp,
            mode,
            state: State::Init,
            ctx: None,
            pending: None,
            t0: Instant::now(),
            steps: 0,
            iters: 0,
            outcome: None,
            trace: None,
            tau_plan: None,
            calib_pending: Vec::new(),
        }
    }

    pub fn is_done(&self) -> bool {
        matches!(self.state, State::Done)
    }

    /// The finished outcome; `None` until `advance` returned `Done` (or
    /// after it has already been taken).
    pub fn take_outcome(&mut self) -> Option<SolveOutcome> {
        self.outcome.take()
    }

    /// Short state label for logs/metrics.
    pub fn state_name(&self) -> &'static str {
        match self.state {
            State::Init => "init",
            State::VDecode => "decode",
            State::VScore { .. } => "score",
            State::VSelect => "select",
            State::ADecode => "phase_a",
            State::AScore { .. } => "score_a",
            State::Reject => "reject",
            State::BDecode { .. } => "phase_b",
            State::BScore { .. } => "score_b",
            State::Finalize { .. } => "finalize",
            State::Done => "done",
        }
    }

    /// Drive the task to completion on one engine (the blocking path).
    pub fn run_to_completion(mut self, engine: &Engine) -> Result<SolveOutcome> {
        loop {
            if let Progress::Done = self.advance(engine)? {
                return self
                    .take_outcome()
                    .ok_or_else(|| Error::internal("finished task lost its outcome"));
            }
        }
    }

    fn ctx_mut(&mut self) -> &mut SearchCtx {
        self.ctx.as_mut().expect("SolveTask advanced past Init without a SearchCtx")
    }

    /// Seal the outcome from the current search state.
    fn complete(&mut self) -> Result<Progress> {
        let ctx = self
            .ctx
            .take()
            .ok_or_else(|| Error::internal("SolveTask completed without a SearchCtx"))?;
        self.outcome = Some(ctx.finish(&self.problem, self.t0, self.steps));
        self.state = State::Done;
        Ok(Progress::Done)
    }

    /// Perform one bounded unit of work, executing any yielded engine call
    /// immediately on `engine` (the sequential path — byte-identical to
    /// the pre-gang dispatch). Errors are terminal: the caller should drop
    /// the task and surface the error.
    pub fn advance(&mut self, engine: &Engine) -> Result<Progress> {
        match self.poll(engine)? {
            Step::Progressed(p) => Ok(p),
            Step::Yielded => {
                self.execute_intent(engine)?;
                Ok(Progress::Working)
            }
        }
    }

    /// The engine call parked by the last `poll`, if any.
    pub fn intent(&self) -> Option<&DecodeIntent> {
        self.pending.as_ref()
    }

    /// Execute the parked engine call on this task's own cache — the solo
    /// path. Performs exactly the call `decode_tick`/`score_catch_up`
    /// would have made.
    pub fn execute_intent(&mut self, engine: &Engine) -> Result<()> {
        let intent = self
            .pending
            .take()
            .ok_or_else(|| Error::internal("execute_intent without a pending intent"))?;
        if let Some(tb) = self.trace.as_mut() {
            let name = match intent.kind {
                IntentKind::Decode => "decode",
                IntentKind::Score => "score",
                IntentKind::Compact => "compact",
            };
            tb.begin_detail(name, format!("batch={}", intent.batch));
        }
        let ctx = self
            .ctx
            .as_mut()
            .ok_or_else(|| Error::internal("pending intent without a SearchCtx"))?;
        match intent.payload {
            Payload::Decode(prep) => {
                let sampled = engine.lm_decode_block(
                    &ctx.lm_ckpt,
                    &mut ctx.lm_kv,
                    &prep.prev,
                    intent.temp,
                    &prep.keys,
                )?;
                ctx.decode_absorb(&prep, &sampled);
            }
            Payload::Score(round) => {
                let scores = engine.prm_score_block(&ctx.prm_ckpt, &mut ctx.prm_kv, &round.tokens)?;
                ctx.score_absorb(&round, &scores);
            }
            Payload::Compact(target) => {
                let changed = match target {
                    CompactTarget::Lm => engine.kv_compact(&ctx.lm_ckpt, &mut ctx.lm_kv)?,
                    CompactTarget::Prm => engine.kv_compact(&ctx.prm_ckpt, &mut ctx.prm_kv)?,
                };
                ctx.note_compact(target, changed);
            }
        }
        if let Some(tb) = self.trace.as_mut() {
            tb.end();
        }
        Ok(())
    }

    /// The device cache the parked intent targets (gang-merge input).
    pub(crate) fn gang_kv(&self) -> Result<&KvSet> {
        let intent = self
            .pending
            .as_ref()
            .ok_or_else(|| Error::internal("gang_kv without a pending intent"))?;
        let ctx = self
            .ctx
            .as_ref()
            .ok_or_else(|| Error::internal("pending intent without a SearchCtx"))?;
        Ok(match &intent.payload {
            Payload::Decode(_) | Payload::Compact(CompactTarget::Lm) => &ctx.lm_kv,
            Payload::Score(_) | Payload::Compact(CompactTarget::Prm) => &ctx.prm_kv,
        })
    }

    /// Re-compact the cache the parked decode/score intent targets when
    /// its junk share crossed `threshold` — the gang executor calls this
    /// on every member before chain-merging, so aligned (dense) frontiers
    /// shrink the max-frontier union gap and the padding waste merged
    /// batches carry. Returns whether the cache actually changed.
    pub(crate) fn gang_precompact(&mut self, engine: &Engine, threshold: f64) -> Result<bool> {
        let intent = self
            .pending
            .as_ref()
            .ok_or_else(|| Error::internal("gang_precompact without a pending intent"))?;
        let target = match intent.kind {
            IntentKind::Decode => CompactTarget::Lm,
            IntentKind::Score => CompactTarget::Prm,
            IntentKind::Compact => return Ok(false), // executes solo anyway
        };
        let ctx = self
            .ctx
            .as_mut()
            .ok_or_else(|| Error::internal("pending intent without a SearchCtx"))?;
        let (enabled, kv) = match target {
            CompactTarget::Lm => (ctx.lm_compact, &ctx.lm_kv),
            CompactTarget::Prm => (ctx.prm_compact, &ctx.prm_kv),
        };
        // compact_junk = 1.0 is the documented proactive-compaction off
        // switch; pre-merge alignment is proactive, so it obeys it too
        if !enabled || ctx.cfg.compact_junk >= 1.0 {
            return Ok(false);
        }
        // mode-aware reclaim: on a block-native cache the dense-repack
        // figure would propose truncations that reclaim nothing and get
        // compaction permanently disabled by `note_compact`
        if kv.reclaimable() == 0 || kv.junk_fraction() < threshold {
            return Ok(false);
        }
        let changed = match target {
            CompactTarget::Lm => engine.kv_compact(&ctx.lm_ckpt, &mut ctx.lm_kv)?,
            CompactTarget::Prm => engine.kv_compact(&ctx.prm_ckpt, &mut ctx.prm_kv)?,
        };
        ctx.note_compact(target, changed);
        Ok(changed)
    }

    /// Complete the parked intent after a gang-merged call: install the
    /// split-back cache (carrying the merged post-call frontier) and fold
    /// this member's output slice into the beams.
    pub(crate) fn gang_absorb(&mut self, kv: KvSet, out: GangOut) -> Result<()> {
        let intent = self
            .pending
            .take()
            .ok_or_else(|| Error::internal("gang_absorb without a pending intent"))?;
        let ctx = self
            .ctx
            .as_mut()
            .ok_or_else(|| Error::internal("pending intent without a SearchCtx"))?;
        match (intent.payload, out) {
            (Payload::Decode(prep), GangOut::Tokens(toks)) => {
                ctx.lm_kv = kv;
                ctx.decode_absorb(&prep, toks);
                Ok(())
            }
            (Payload::Score(round), GangOut::Scores(scores)) => {
                ctx.prm_kv = kv;
                ctx.score_absorb(&round, scores);
                Ok(())
            }
            _ => Err(Error::internal("gang output kind mismatched the intent")),
        }
    }

    /// Run a proposed compaction inline — the block-native path, where a
    /// re-compaction is a pure host table truncation (tail blocks release
    /// by refcount, no device call) and parking it as a schedulable
    /// intent would only add a scheduler round-trip around free work.
    fn compact_now(&mut self, engine: &Engine, target: CompactTarget) -> Result<()> {
        let ctx = self.ctx_mut();
        let changed = match target {
            CompactTarget::Lm => engine.kv_compact(&ctx.lm_ckpt, &mut ctx.lm_kv)?,
            CompactTarget::Prm => engine.kv_compact(&ctx.prm_ckpt, &mut ctx.prm_kv)?,
        };
        ctx.note_compact(target, changed);
        Ok(())
    }

    /// Park a compaction of `target`'s cache as the pending intent.
    fn yield_compact(&mut self, target: CompactTarget) -> Step {
        let ctx = self.ctx.as_ref().expect("compaction proposed without a SearchCtx");
        let (ckpt, batch) = match target {
            CompactTarget::Lm => (ctx.lm_ckpt.clone(), ctx.lm_kv.batch),
            CompactTarget::Prm => (ctx.prm_ckpt.clone(), ctx.prm_kv.batch),
        };
        self.pending = Some(DecodeIntent {
            kind: IntentKind::Compact,
            ckpt,
            batch,
            temp: 0.0,
            payload: Payload::Compact(target),
        });
        Step::Yielded
    }

    /// Shared decode-state driver: yield the prepared call (or the cache
    /// compaction that must precede it), or take the decode → score
    /// transition (fixing the PRM budget verdict at the same point the
    /// blocking path checked it).
    fn poll_decode(
        &mut self,
        engine: &Engine,
        target: PhaseTarget,
        next: impl FnOnce(bool, bool) -> State,
    ) -> Result<Step> {
        match self.ctx_mut().decode_prepare(target) {
            DecodeStage::Compact => {
                if engine.block_native() {
                    self.compact_now(engine, CompactTarget::Lm)?;
                    return Ok(Step::Progressed(Progress::Working));
                }
                Ok(self.yield_compact(CompactTarget::Lm))
            }
            DecodeStage::Call(prep) => {
                let ctx = self.ctx.as_ref().expect("decode_prepare ran on a ctx");
                self.pending = Some(DecodeIntent {
                    kind: IntentKind::Decode,
                    ckpt: ctx.lm_ckpt.clone(),
                    batch: ctx.lm_kv.batch,
                    temp: self.temp,
                    payload: Payload::Decode(prep),
                });
                Ok(Step::Yielded)
            }
            DecodeStage::Done => {
                let score_ok = self.ctx_mut().score_budget_ok();
                self.state = next(true, score_ok);
                Ok(Step::Progressed(Progress::Working))
            }
            DecodeStage::Exhausted => {
                let score_ok = self.ctx_mut().score_budget_ok();
                self.state = next(false, score_ok);
                Ok(Step::Progressed(Progress::Working))
            }
        }
    }

    /// Shared score-state driver: yield the PRM compaction the next round
    /// needs (exhaustion rescue / proactive junk threshold) — or run it
    /// inline when the engine is block-native, since a table truncation
    /// has no device call worth scheduling around — yield the next
    /// scoring round, or report the phase drained (after harvesting
    /// finished beams, like the blocking path did right after
    /// `score_catch_up`).
    fn poll_score(&mut self, engine: &Engine, score_ok: bool) -> Result<Option<Step>> {
        if score_ok {
            if self.ctx_mut().prm_wants_compact() {
                if engine.block_native() {
                    self.compact_now(engine, CompactTarget::Prm)?;
                    return Ok(Some(Step::Progressed(Progress::Working)));
                }
                return Ok(Some(self.yield_compact(CompactTarget::Prm)));
            }
            if self.ctx_mut().score_round_fits() {
                if let Some(round) = self.ctx_mut().score_prepare() {
                    let ctx = self.ctx.as_ref().expect("score_prepare ran on a ctx");
                    self.pending = Some(DecodeIntent {
                        kind: IntentKind::Score,
                        ckpt: ctx.prm_ckpt.clone(),
                        batch: ctx.prm_kv.batch,
                        temp: 0.0,
                        payload: Payload::Score(round),
                    });
                    return Ok(Some(Step::Yielded));
                }
            }
        }
        self.ctx_mut().harvest_finished();
        Ok(None)
    }

    /// One cooperative unit of work: either a host transition happened
    /// ([`Step::Progressed`]) or an engine call was prepared and parked
    /// ([`Step::Yielded`]) for the caller to execute solo or gang-merged.
    /// Engine-call order is identical to the blocking path in both cases.
    pub fn poll(&mut self, engine: &Engine) -> Result<Step> {
        if self.pending.is_some() {
            return Err(Error::internal("poll while an intent is still pending"));
        }
        match self.state {
            State::Done => Ok(Step::Progressed(Progress::Done)),
            State::Init => {
                if let Some(tb) = self.trace.as_mut() {
                    tb.begin_detail("prefill", format!("beams={}", self.cfg.n_beams));
                }
                let ctx = SearchCtx::init(
                    engine,
                    &self.lm_ckpt,
                    &self.prm_ckpt,
                    &self.problem,
                    &self.cfg,
                    self.temp,
                )?;
                if let Some(tb) = self.trace.as_mut() {
                    tb.end();
                }
                self.ctx = Some(ctx);
                if let Some(plan) = self.tau_plan.as_deref() {
                    if let Some(tb) = self.trace.as_mut() {
                        tb.calib_control(true, plan.shadow);
                    }
                }
                if self.cfg.max_steps == 0 {
                    // parity with the blocking `for _ in 0..max_steps`
                    // loops: zero iterations, finish on the sampled beams
                    return self.complete().map(Step::Progressed);
                }
                self.state = match self.mode {
                    Mode::Vanilla => State::VDecode,
                    Mode::Er { .. } => State::ADecode,
                };
                Ok(Step::Progressed(Progress::Working))
            }

            // ---------------------------------------------------- vanilla
            State::VDecode => self.poll_decode(engine, PhaseTarget::Boundary, |decode_ok, score_ok| {
                State::VScore { decode_ok, score_ok }
            }),
            State::VScore { decode_ok, score_ok } => {
                if let Some(step) = self.poll_score(engine, score_ok)? {
                    return Ok(step);
                }
                // gang merges (and budget verdicts that counted
                // reclaimable junk) can leave a round that doesn't fit
                // even after the compaction attempts above: truncate,
                // exactly like the blocking path
                let score_ok = score_ok && self.ctx_mut().score_round_fits();
                if !decode_ok || !score_ok {
                    return self.complete().map(Step::Progressed);
                }
                self.steps += 1;
                self.state = State::VSelect;
                Ok(Step::Progressed(Progress::Working))
            }
            State::VSelect => {
                let agg = self.cfg.agg;
                let keep = self.cfg.keep();
                let ctx = self.ctx_mut();
                let mut scored: Vec<(usize, f32)> = Vec::new();
                for (slot, beam) in ctx.beams.beams.iter_mut().enumerate() {
                    if beam.active() && beam.awaiting_finalize {
                        let r = beam.finalize_step(agg);
                        scored.push((slot, r));
                    }
                }
                if scored.is_empty() {
                    return self.complete().map(Step::Progressed); // every beam finished or died
                }
                scored.sort_by(crate::coordinator::policy::rank_desc);
                let survivors: Vec<usize> = scored.iter().take(keep).map(|&(s, _)| s).collect();
                self.ctx_mut().expand(engine, &survivors)?;
                self.iters += 1;
                if self.iters >= self.cfg.max_steps {
                    return self.complete().map(Step::Progressed);
                }
                self.state = State::VDecode;
                Ok(Step::Progressed(Progress::Working))
            }

            // -------------------------------------------- early rejection
            State::ADecode => {
                let base = self.cfg.tau;
                let eff = self.tau_plan.as_deref().map_or(base, |p| p.tau_for(self.iters));
                // Shadow-sampled requests decode phase A out to the base
                // checkpoint even when the effective tau is shorter, so
                // the base-tau counterfactual partials exist for the
                // regret check in `Reject`. Rejection still happens at
                // the effective tau.
                let shadow = self.tau_plan.as_deref().map_or(false, |p| p.shadow);
                let tau = if shadow && eff < base { base } else { eff };
                self.poll_decode(engine, PhaseTarget::Prefix { tau }, |decode_ok, score_ok| {
                    State::AScore { decode_ok, score_ok }
                })
            }
            State::AScore { decode_ok, score_ok } => {
                if let Some(step) = self.poll_score(engine, score_ok)? {
                    return Ok(step);
                }
                let score_ok = score_ok && self.ctx_mut().score_round_fits();
                if !decode_ok || !score_ok {
                    return self.complete().map(Step::Progressed);
                }
                self.steps += 1;
                self.state = State::Reject;
                Ok(Step::Progressed(Progress::Working))
            }
            State::Reject => {
                let Mode::Er { policy, two_tier } = self.mode else {
                    return Err(Error::internal("vanilla task reached an ER state"));
                };
                let (base, agg) = (self.cfg.tau, self.cfg.agg);
                let (eff, shadow, reason) = match self.tau_plan.as_deref() {
                    None => (base, false, "static"),
                    Some(p) => {
                        let bt = p.bucket_for(self.iters);
                        (bt.tau.min(base), p.shadow, if bt.confident { "confident" } else { "fallback" })
                    }
                };
                let scored = partial_scores(&self.ctx_mut().beams, eff, agg);
                if scored.is_empty() {
                    // pool exhausted (all finished or dead)
                    return self.complete().map(Step::Progressed);
                }
                let survivors = policy.select(&scored);
                if let Some(tb) = self.trace.as_mut() {
                    tb.event(
                        "tau",
                        format!("depth={} tau={eff} base={base} reason={reason}", self.iters),
                    );
                }
                // Shadow counterfactual: score the same slate at the base
                // checkpoint and count how many beams the effective tau
                // rejects that the base tau would have kept — the regret
                // half of the FLOPs-saved-vs-regret ledger.
                if shadow && eff < base {
                    let base_scored = partial_scores(&self.ctx_mut().beams, base, agg);
                    let base_survivors = policy.select(&base_scored);
                    let checked =
                        scored.iter().filter(|&&(s, _)| !survivors.contains(&s)).count();
                    let regret =
                        base_survivors.iter().filter(|s| !survivors.contains(s)).count();
                    if let Some(tb) = self.trace.as_mut() {
                        tb.calib_regret(checked as u64, regret as u64);
                    }
                }
                let ctx = self.ctx_mut();
                let mut rejected: Vec<usize> = Vec::new();
                for (slot, beam) in ctx.beams.beams.iter_mut().enumerate() {
                    if beam.active() && !survivors.contains(&slot) {
                        beam.dead = true; // << the early rejection
                        rejected.push(slot);
                    }
                }
                // paged KV: a rejected beam's blocks return to the shard
                // pool *in this same tick* — the memory half of early
                // rejection. No-op on dense caches.
                for &slot in &rejected {
                    ctx.lm_kv.free_slot(slot);
                    ctx.prm_kv.free_slot(slot);
                }
                let (lm_rate, prm_rate) =
                    (ctx.ledger.lm_flops_per_token, ctx.ledger.prm_flops_per_token);
                if !rejected.is_empty() {
                    if let Some(tb) = self.trace.as_mut() {
                        // Estimated compute the rejection avoided: each
                        // dead beam skips this round's completion tokens
                        // (max_step_tokens - tau) plus every remaining
                        // round, decoded by the LM and scored by the PRM.
                        // An upper bound — a beam might have finished
                        // early (same accounting as ErEvent docs).
                        let this_round =
                            self.cfg.max_step_tokens.saturating_sub(eff) as f64;
                        let future = self.cfg.max_steps.saturating_sub(self.iters + 1) as f64
                            * self.cfg.max_step_tokens as f64;
                        let per_beam =
                            (this_round + future) * (lm_rate as f64 + prm_rate as f64);
                        let scores: Vec<f32> = rejected
                            .iter()
                            .map(|&slot| {
                                scored
                                    .iter()
                                    .find(|&&(s, _)| s == slot)
                                    .map(|&(_, r)| r)
                                    .unwrap_or(0.0)
                            })
                            .collect();
                        tb.reject(ErEvent {
                            depth: self.iters,
                            tau: eff,
                            rejected: rejected.clone(),
                            scores,
                            flops_saved: per_beam * rejected.len() as f64,
                        });
                    }
                }
                let plan = TwoTierPlan::plan(
                    self.cfg.n_beams,
                    survivors.len(),
                    &engine.manifest.batch_variants,
                    two_tier,
                )?;
                // Calibration pairing, half one: remember each survivor's
                // partial reward keyed by the slot it will occupy after
                // any two-tier shrink (`shrink_to_b2` moves survivor j
                // into slot j). `Finalize` pairs these with the same
                // beams' full-step rewards.
                self.calib_pending.clear();
                if self.trace.is_some() {
                    for (j, &slot) in survivors.iter().enumerate() {
                        if let Some(&(_, p)) = scored.iter().find(|&&(s, _)| s == slot) {
                            let dst = if plan.shrink { j } else { slot };
                            self.calib_pending.push((dst, p));
                        }
                    }
                }
                if plan.shrink {
                    self.ctx_mut().shrink_to_b2(engine, &survivors, plan)?;
                }
                self.state = State::BDecode { plan };
                Ok(Step::Progressed(Progress::Working))
            }
            State::BDecode { plan } => {
                self.poll_decode(engine, PhaseTarget::Boundary, |decode_ok, score_ok| State::BScore {
                    plan,
                    decode_ok,
                    score_ok,
                })
            }
            State::BScore { plan, decode_ok, score_ok } => {
                if let Some(step) = self.poll_score(engine, score_ok)? {
                    return Ok(step);
                }
                let score_ok = score_ok && self.ctx_mut().score_round_fits();
                if !decode_ok || !score_ok {
                    return self.complete().map(Step::Progressed);
                }
                self.state = State::Finalize { plan };
                Ok(Step::Progressed(Progress::Working))
            }
            State::Finalize { plan } => {
                let agg = self.cfg.agg;
                let ctx = self.ctx_mut();
                let mut final_survivors: Vec<(usize, f32)> = Vec::new();
                for (slot, beam) in ctx.beams.beams.iter_mut().enumerate() {
                    if beam.active() && beam.awaiting_finalize {
                        let r = beam.finalize_step(agg);
                        final_survivors.push((slot, r));
                    }
                }
                // Calibration pairing, half two: each (partial, final)
                // pair at this depth becomes one observatory sample.
                if !self.calib_pending.is_empty() {
                    let pending = std::mem::take(&mut self.calib_pending);
                    let depth = self.iters as u32;
                    if let Some(tb) = self.trace.as_mut() {
                        for (slot, partial) in pending {
                            if let Some(&(_, r)) =
                                final_survivors.iter().find(|&&(s, _)| s == slot)
                            {
                                tb.calib_sample(&self.prm_ckpt, depth, partial, r);
                            }
                        }
                    }
                }
                if final_survivors.is_empty() {
                    return self.complete().map(Step::Progressed);
                }
                final_survivors.sort_by(crate::coordinator::policy::rank_desc);
                let order: Vec<usize> = final_survivors.iter().map(|&(s, _)| s).collect();
                if plan.shrink && self.ctx_mut().lm_kv.batch != plan.b1 {
                    self.ctx_mut().expand_from_b2(engine, &order, plan)?;
                } else {
                    self.ctx_mut().expand(engine, &order)?;
                }
                self.iters += 1;
                if self.iters >= self.cfg.max_steps {
                    return self.complete().map(Step::Progressed);
                }
                self.state = State::ADecode;
                Ok(Step::Progressed(Progress::Working))
            }
        }
    }
}

/// Partial rewards of every live candidate after the prefix phase —
/// the rejection decision's input. Empty when no beam is both active and
/// fully scored, which is the zero-survivor guard that ends the search.
pub fn partial_scores(beams: &BeamSet, tau: usize, agg: Aggregation) -> Vec<(usize, f32)> {
    let mut scored = Vec::new();
    for (slot, beam) in beams.beams.iter().enumerate() {
        if beam.active() {
            if let Some(p) = beam.partial_reward(tau, agg) {
                scored.push((slot, p));
            }
        }
    }
    scored
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer as tk;

    fn beamset(n: usize) -> BeamSet {
        BeamSet::new(n, tk::DIG0, 7)
    }

    #[test]
    fn partial_scores_skips_dead_and_finished() {
        let mut set = beamset(4);
        for b in set.beams.iter_mut() {
            b.scores = vec![0.9];
        }
        set.beams[1].dead = true;
        set.beams[2].finished = true;
        let scored = partial_scores(&set, 1, Aggregation::Min);
        let slots: Vec<usize> = scored.iter().map(|&(s, _)| s).collect();
        assert_eq!(slots, vec![0, 3]);
    }

    #[test]
    fn partial_scores_empty_is_the_zero_survivor_guard() {
        // every beam dead or finished -> no rejection input -> the search
        // must complete instead of calling the policy on an empty slate
        let mut set = beamset(3);
        set.beams[0].dead = true;
        set.beams[1].finished = true;
        set.beams[2].dead = true;
        assert!(partial_scores(&set, 4, Aggregation::Mean).is_empty());
        // active beams whose scorer hasn't caught up are also excluded
        let set2 = beamset(2); // fresh beams: 1 gen token, 0 scores
        assert!(partial_scores(&set2, 4, Aggregation::Mean).is_empty());
    }

    #[test]
    fn cooperative_surface_guards() {
        let p = Problem { v0: 5, ops: vec![crate::workload::OpStep { op: tk::PLUS, d: 3 }] };
        let mut task =
            SolveTask::early_rejection(p, "lm", "prm", &SearchConfig::default(), 0.5).unwrap();
        // nothing parked before the first poll reaches a decode/score state
        assert!(task.intent().is_none());
        assert!(task.gang_kv().is_err(), "gang access without a pending intent");
        let e = task.gang_absorb(
            crate::runtime::KvSet::new(Vec::new(), 1, 4),
            super::GangOut::Tokens(&[]),
        );
        assert!(e.is_err());
    }

    #[test]
    fn task_construction_validates_config() {
        let p = Problem { v0: 5, ops: vec![crate::workload::OpStep { op: tk::PLUS, d: 3 }] };
        // n_beams not divisible by m_expand -> construction must fail
        let cfg = SearchConfig { n_beams: 10, m_expand: 4, ..SearchConfig::default() };
        assert!(SolveTask::vanilla(p.clone(), "lm", "prm", &cfg, 0.5).is_err());
        let task =
            SolveTask::early_rejection(p, "lm", "prm", &SearchConfig::default(), 0.5).unwrap();
        assert!(!task.is_done());
        assert_eq!(task.state_name(), "init");
    }
}
