//! Two-tier batch planning (paper Sec. 3.2).
//!
//! Early rejection changes the shape of the work: the prefix phase touches
//! all N beams for only tau tokens (wide, shallow), the completion phase
//! touches N/M survivors to the end of the step (narrow, deep). The paper
//! exploits this by running the prefix phase at a large batch b1 and the
//! completion phase at a smaller b2. Here that maps to picking the batch
//! *variant* for each phase and planning the KV resize between them; the
//! `ablation_two_tier` bench measures the wallclock effect of disabling it.

use crate::util::error::Result;

/// Phase plan for one reasoning step of ER search.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TwoTierPlan {
    /// Batch variant for the prefix phase (all N beams).
    pub b1: usize,
    /// Batch variant for the completion phase (N/M survivors).
    pub b2: usize,
    /// Whether the completion phase shrinks to b2 (false = stay at b1,
    /// wasting lockstep compute on dead slots — the ablation baseline).
    pub shrink: bool,
}

impl TwoTierPlan {
    /// Plan from the beam parameters and the exported batch variants.
    pub fn plan(
        n_beams: usize,
        keep: usize,
        variants: &[usize],
        enable_two_tier: bool,
    ) -> Result<TwoTierPlan> {
        let b1 = smallest_variant(variants, n_beams)?;
        let b2 = smallest_variant(variants, keep)?;
        Ok(TwoTierPlan { b1, b2, shrink: enable_two_tier && b2 < b1 })
    }

    /// Batch the completion phase actually runs at.
    pub fn completion_batch(&self) -> usize {
        if self.shrink {
            self.b2
        } else {
            self.b1
        }
    }
}

fn smallest_variant(variants: &[usize], n: usize) -> Result<usize> {
    variants
        .iter()
        .copied()
        .filter(|&b| b >= n)
        .min()
        .ok_or_else(|| {
            crate::util::error::Error::invalid(format!(
                "no batch variant >= {n} in {variants:?}"
            ))
        })
}

/// Expansion index plan: map `keep` survivors (in compact order) onto `b1`
/// slots, `m` children each; leftover slots replicate survivor 0 but are
/// marked inactive by the caller. Returns (indices, active_count).
pub fn expansion_indices(keep: usize, m: usize, b1: usize) -> (Vec<i32>, usize) {
    assert!(keep >= 1);
    let active = (keep * m).min(b1);
    let mut idx = Vec::with_capacity(b1);
    for slot in 0..b1 {
        if slot < active {
            idx.push((slot / m).min(keep - 1) as i32);
        } else {
            idx.push(0);
        }
    }
    (idx, active)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check_simple;

    const VARIANTS: [usize; 5] = [4, 8, 16, 32, 64];

    #[test]
    fn plan_picks_variants() {
        let p = TwoTierPlan::plan(16, 4, &VARIANTS, true).unwrap();
        assert_eq!((p.b1, p.b2, p.shrink), (16, 4, true));
        assert_eq!(p.completion_batch(), 4);
    }

    #[test]
    fn plan_no_shrink_when_same_variant() {
        let p = TwoTierPlan::plan(4, 1, &VARIANTS, true).unwrap();
        assert_eq!((p.b1, p.b2), (4, 4));
        assert!(!p.shrink);
    }

    #[test]
    fn plan_ablation_disables_shrink() {
        let p = TwoTierPlan::plan(64, 16, &VARIANTS, false).unwrap();
        assert!(!p.shrink);
        assert_eq!(p.completion_batch(), 64);
    }

    #[test]
    fn plan_errors_beyond_largest() {
        assert!(TwoTierPlan::plan(128, 4, &VARIANTS, true).is_err());
    }

    #[test]
    fn expansion_fills_slots() {
        let (idx, active) = expansion_indices(4, 4, 16);
        assert_eq!(active, 16);
        assert_eq!(idx[0..4], [0, 0, 0, 0]);
        assert_eq!(idx[4..8], [1, 1, 1, 1]);
        assert_eq!(idx[15], 3);
    }

    #[test]
    fn expansion_partial_fill() {
        let (idx, active) = expansion_indices(1, 4, 16);
        assert_eq!(active, 4);
        assert!(idx.iter().all(|&i| i == 0));
    }

    #[test]
    fn expansion_overflow_clamps_to_b1() {
        // survivors * m_expand > b1: the batch can't hold a full brood, so
        // active clamps to b1 and the best-ranked survivors (lowest compact
        // index) keep their children; trailing survivors may get none.
        let (idx, active) = expansion_indices(8, 4, 16);
        assert_eq!(active, 16);
        assert_eq!(idx.len(), 16);
        assert!(idx.iter().all(|&i| (i as usize) < 8), "index beyond survivors");
        assert_eq!(idx[0..4], [0, 0, 0, 0]);
        assert_eq!(idx[12..16], [3, 3, 3, 3]);
        // survivors 4..8 lost out — every slot went to the top ranks
        assert!(idx.iter().all(|&i| i < 4));
        // extreme overflow: more surviving children than slots — the best
        // survivor's brood fills the batch, indices never go out of bounds
        let (idx, active) = expansion_indices(6, 4, 4);
        assert_eq!(active, 4);
        assert_eq!(idx, vec![0, 0, 0, 0]);
    }

    #[test]
    fn plan_with_single_batch_variant() {
        // only one exported variant: both phases run at it, never shrink
        let p = TwoTierPlan::plan(8, 2, &[8], true).unwrap();
        assert_eq!((p.b1, p.b2, p.shrink), (8, 8, false));
        assert_eq!(p.completion_batch(), 8);
        // a single variant smaller than N is a planning error, not a panic
        assert!(TwoTierPlan::plan(16, 2, &[8], true).is_err());
        // keep larger than any variant errors too (guards kv_resize)
        assert!(TwoTierPlan::plan(8, 9, &[8], true).is_err());
    }

    #[test]
    fn prop_expansion_indices_valid() {
        check_simple(
            "expansion-valid",
            |rng| {
                let keep = rng.below(8) + 1;
                let m = rng.below(6) + 1;
                let b1 = [4usize, 8, 16, 32, 64][rng.below(5)];
                (keep, m, b1)
            },
            |&(keep, m, b1)| {
                let (idx, active) = expansion_indices(keep, m, b1);
                if idx.len() != b1 {
                    return Err("wrong arity".into());
                }
                if active > b1 || active == 0 {
                    return Err(format!("active {active} out of range"));
                }
                if idx.iter().any(|&i| (i as usize) >= keep) {
                    return Err("index beyond survivors".into());
                }
                // each survivor gets at least one child if room allows
                if keep * m <= b1 {
                    for s in 0..keep {
                        if !idx[..active].contains(&(s as i32)) {
                            return Err(format!("survivor {s} lost"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
