//! The L3 coordination layer — the paper's system contribution.
//!
//! * [`beam`] — beam bookkeeping: clean token sequences, per-token PRM
//!   scores, step segmentation, pending-token / KV-frontier discipline.
//! * [`flops`] — the analytic FLOPs ledger (the paper's headline metric),
//!   split LLM vs PRM as in Table 3.
//! * [`sampler`] — host-side sampling (first token after prefill) and the
//!   per-slot RNG key streams fed to the in-graph sampler.
//! * [`scorer`] — incremental PRM scoring over beam slots (score-block
//!   batching, backlog tracking, partial/step reward aggregation).
//! * [`policy`] — rejection policies: the paper's top-N/M rule plus
//!   threshold and adaptive-tau extensions (paper's future work).
//! * [`scheduler`] — two-tier batch planning (paper Sec. 3.2): prefix phase
//!   at b1 >= completion phase at b2.
//! * [`search`] — Algorithm 2, vanilla PRM-guided beam search (baseline).
//! * [`early_reject`] — Algorithm 3, beam search with early rejection.
//! * [`task`] — the resumable [`task::SolveTask`] state machine both
//!   algorithms compile down to; the unit the fleet scheduler interleaves.

pub mod beam;
pub mod bon;
pub mod early_reject;
pub mod flops;
pub mod policy;
pub mod sampler;
pub mod scheduler;
pub mod scorer;
pub mod search;
pub mod task;

pub use beam::{Beam, BeamSet};
pub use bon::solve_best_of_n;
pub use early_reject::solve_early_rejection;
pub use flops::{FlopsLedger, FlopsReport};
pub use search::{solve_vanilla, SolveOutcome};
pub use task::{DecodeIntent, IntentKind, Progress, SolveTask, Step};
