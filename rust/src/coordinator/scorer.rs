//! Incremental PRM scoring over beam slots.
//!
//! The PRM is a causal decoder with its own KV cache mirroring the beam
//! slots. Each beam accumulates a backlog of clean generated tokens not
//! yet scored; `SearchCtx::score_catch_up` drains all backlogs with as few
//! `score_block` calls as necessary (each call scores up to `score_block`
//! tokens per slot, lockstep). This is the serving optimization that replaces the
//! naive "re-run the PRM on the whole prefix at every decision point" —
//! per decision the PRM pays only for new tokens.

use crate::coordinator::beam::BeamSet;
use crate::coordinator::flops::FlopsLedger;
use crate::runtime::KvSet;
use crate::tokenizer as tk;

/// One prepared PRM scoring round: the lockstep `[batch, score_block]`
/// token matrix plus how many tokens each slot contributes. Built by
/// [`prepare_round`], consumed by [`absorb_round`] after the engine call —
/// which the caller may run alone or merged into a gang batch.
#[derive(Debug, Clone)]
pub struct ScoreRound {
    /// Row-major `[batch * score_block]`, PAD beyond each slot's span.
    pub tokens: Vec<i32>,
    /// Tokens contributed per slot (0 = idle slot this round).
    pub counts: Vec<usize>,
}

/// Build the next scoring round, or `None` when every backlog is drained.
/// Includes finished beams (their final step still needs scores) but not
/// dead ones.
pub fn prepare_round(beams: &BeamSet, batch: usize, score_block: usize) -> Option<ScoreRound> {
    let t = score_block;
    let mut any = false;
    for beam in &beams.beams {
        if !beam.dead && beam.prm_fed < beam.gen.len() {
            any = true;
            break;
        }
    }
    if !any {
        return None;
    }
    let mut tokens = vec![tk::PAD; batch * t];
    let mut counts = vec![0usize; batch];
    for (slot, beam) in beams.beams.iter().enumerate().take(batch) {
        if beam.dead {
            continue;
        }
        let backlog = &beam.gen[beam.prm_fed..];
        let n = backlog.len().min(t);
        tokens[slot * t..slot * t + n].copy_from_slice(&backlog[..n]);
        counts[slot] = n;
    }
    Some(ScoreRound { tokens, counts })
}

/// Fold one round's scores back into the beams and the cache bookkeeping.
/// `prm_kv` must already hold the post-call frontier (the engine call
/// advanced it by `score_block`), which is also what makes this correct
/// for gang-merged calls: the write frontier is wherever the shared batch
/// actually wrote, not where this request's solo cache stood.
pub fn absorb_round(
    round: &ScoreRound,
    scores: &[f32],
    score_block: usize,
    prm_kv: &mut KvSet,
    beams: &mut BeamSet,
    ledger: &mut FlopsLedger,
) {
    let t = score_block;
    let frontier = prm_kv.pos_phys - t;
    ledger.call();
    for (slot, beam) in beams.beams.iter_mut().enumerate().take(round.counts.len()) {
        let n = round.counts[slot];
        if n == 0 {
            continue;
        }
        // paged: every position this slot just scored must be reachable
        // through its block table (reserve_frontier grew it before the
        // call); a freed slot can never appear here because prepare_round
        // skips dead beams
        debug_assert!(prm_kv
            .pages
            .as_ref()
            .is_none_or(|p| !p.is_dead(slot) && p.table(slot).len_tokens() >= frontier + n));
        for i in 0..n {
            beam.scores.push(scores[slot * t + i]);
        }
        beam.prm_fed += n;
        ledger.prm_score(n);
        prm_kv.commit(slot, frontier, n);
    }
}

// The blocking drain loop lives in `SearchCtx::score_catch_up`, which
// interleaves rounds with KV re-compaction when a round would not fit —
// a plain prepare/call/absorb loop here would error on caches compaction
// could have rescued.

#[cfg(test)]
mod tests {
    // The scorer's device interaction is covered by the integration tests
    // (rust/tests/integration.rs) which run against real artifacts. Here we
    // verify the backlog arithmetic via a pure model of the loop.

    #[test]
    fn prepare_and_absorb_round_trip() {
        use crate::coordinator::beam::BeamSet;
        use crate::coordinator::flops::FlopsLedger;
        use crate::runtime::KvSet;
        use crate::tokenizer as tk;
        let mut beams = BeamSet::new(2, tk::DIG0, 1);
        beams.beams[0].gen = vec![tk::DIG0; 5];
        beams.beams[1].gen = vec![tk::DIG0; 1];
        let t = 4usize;
        let round = super::prepare_round(&beams, 2, t).unwrap();
        assert_eq!(round.counts, vec![4, 1]);
        let mut kv = KvSet::new(Vec::new(), 2, 16);
        kv.pos_phys = t; // as if the engine call already advanced the frontier
        let scores: Vec<f32> = (0..2 * t).map(|i| i as f32 / 10.0).collect();
        let mut ledger = FlopsLedger::new(1, 1);
        super::absorb_round(&round, &scores, t, &mut kv, &mut beams, &mut ledger);
        assert_eq!(beams.beams[0].prm_fed, 4);
        assert_eq!(beams.beams[1].prm_fed, 1);
        assert_eq!(beams.beams[0].scores, vec![0.0, 0.1, 0.2, 0.3]);
        assert_eq!(beams.beams[1].scores, vec![0.4]);
        assert_eq!(ledger.prm_score_tokens, 5);
        assert_eq!(&kv.valid[0..4], &[1, 1, 1, 1], "slot 0 committed at the old frontier");
        assert_eq!(&kv.valid[16..20], &[1, 0, 0, 0]);
        // the second round drains the remainder; nothing pends after it
        let round2 = super::prepare_round(&beams, 2, t).unwrap();
        assert_eq!(round2.counts, vec![1, 0]);
        kv.pos_phys += t;
        super::absorb_round(&round2, &scores, t, &mut kv, &mut beams, &mut ledger);
        assert!(super::prepare_round(&beams, 2, t).is_none());
    }

    #[test]
    fn paged_absorb_reads_through_the_block_table() {
        use crate::coordinator::beam::BeamSet;
        use crate::coordinator::flops::FlopsLedger;
        use crate::runtime::{shared_pool, KvSet};
        use crate::tokenizer as tk;
        let t = 4usize;
        let mut beams = BeamSet::new(2, tk::DIG0, 1);
        beams.beams[0].gen = vec![tk::DIG0; 3];
        beams.beams[1].gen = vec![tk::DIG0; 2];
        let pool = shared_pool(8, 2);
        let mut kv = KvSet::new(Vec::new(), 2, 16);
        kv.attach_pages(pool.clone()).unwrap();
        // the engine path: reserve the block write, run, advance
        kv.reserve_frontier(t).unwrap();
        kv.advance_frontier(t);
        let round = super::prepare_round(&beams, 2, t).unwrap();
        let scores = vec![0.5f32; 2 * t];
        let mut ledger = FlopsLedger::new(1, 1);
        super::absorb_round(&round, &scores, t, &mut kv, &mut beams, &mut ledger);
        // every committed position resolves through the slot's table
        let p = kv.pages.as_ref().unwrap();
        for slot in 0..2 {
            for pos in 0..beams.beams[slot].prm_fed {
                assert!(p.table(slot).translate(pos, 2).is_some(), "slot {slot} pos {pos}");
            }
        }
        assert_eq!(pool.borrow().allocated(), 4, "2 slots x 2 blocks of 2");
    }

    #[test]
    fn backlog_draining_model() {
        // model: backlogs drain min(backlog, block) per round, all slots in
        // lockstep, until empty.
        let block = 16usize;
        let mut backlogs = vec![0usize, 5, 16, 37];
        let mut rounds = 0;
        while backlogs.iter().any(|&b| b > 0) {
            for b in backlogs.iter_mut() {
                *b -= (*b).min(block);
            }
            rounds += 1;
            assert!(rounds < 10);
        }
        assert_eq!(rounds, 3); // ceil(37/16)
    }
}
