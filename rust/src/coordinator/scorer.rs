//! Incremental PRM scoring over beam slots.
//!
//! The PRM is a causal decoder with its own KV cache mirroring the beam
//! slots. Each beam accumulates a backlog of clean generated tokens not
//! yet scored; `catch_up` drains all backlogs with as few `score_block`
//! calls as necessary (each call scores up to `score_block` tokens per
//! slot, lockstep). This is the serving optimization that replaces the
//! naive "re-run the PRM on the whole prefix at every decision point" —
//! per decision the PRM pays only for new tokens.

use crate::coordinator::beam::BeamSet;
use crate::coordinator::flops::FlopsLedger;
use crate::runtime::{Engine, KvSet};
use crate::tokenizer as tk;
use crate::util::error::Result;

/// Drain every active beam's unscored-token backlog through the PRM.
/// Appends scores to `beam.scores` (aligned with `beam.gen`).
pub fn catch_up(
    engine: &Engine,
    prm_ckpt: &str,
    prm_kv: &mut KvSet,
    beams: &mut BeamSet,
    ledger: &mut FlopsLedger,
) -> Result<()> {
    let t = engine.manifest.score_block;
    let b = prm_kv.batch;
    loop {
        // find slots with backlog; include finished beams (their final step
        // still needs scores) but not dead ones.
        let mut any = false;
        for beam in &beams.beams {
            if !beam.dead && beam.prm_fed < beam.gen.len() {
                any = true;
                break;
            }
        }
        if !any {
            return Ok(());
        }
        let mut tokens = vec![tk::PAD; b * t];
        let mut counts = vec![0usize; b];
        for (slot, beam) in beams.beams.iter().enumerate().take(b) {
            if beam.dead {
                continue;
            }
            let backlog = &beam.gen[beam.prm_fed..];
            let n = backlog.len().min(t);
            tokens[slot * t..slot * t + n].copy_from_slice(&backlog[..n]);
            counts[slot] = n;
        }
        let frontier = prm_kv.pos_phys;
        let scores = engine.prm_score_block(prm_ckpt, prm_kv, &tokens)?;
        ledger.call();
        for (slot, beam) in beams.beams.iter_mut().enumerate().take(b) {
            let n = counts[slot];
            if n == 0 {
                continue;
            }
            for i in 0..n {
                beam.scores.push(scores[slot * t + i]);
            }
            beam.prm_fed += n;
            ledger.prm_score(n);
            prm_kv.commit(slot, frontier, n);
        }
    }
}

#[cfg(test)]
mod tests {
    // The scorer's device interaction is covered by the integration tests
    // (rust/tests/integration.rs) which run against real artifacts. Here we
    // verify the backlog arithmetic via a pure model of the loop.

    #[test]
    fn backlog_draining_model() {
        // model: backlogs drain min(backlog, block) per round, all slots in
        // lockstep, until empty.
        let block = 16usize;
        let mut backlogs = vec![0usize, 5, 16, 37];
        let mut rounds = 0;
        while backlogs.iter().any(|&b| b > 0) {
            for b in backlogs.iter_mut() {
                *b -= (*b).min(block);
            }
            rounds += 1;
            assert!(rounds < 10);
        }
        assert_eq!(rounds, 3); // ceil(37/16)
    }
}
