//! Rejection policies: which beams survive a partial-reward checkpoint.
//!
//! The paper's rule is top-N/M by partial reward (Alg. 3 line 8). Two
//! extensions the paper lists as future work are also provided: an absolute
//! score threshold, and an adaptive-tau gate that defers rejection when the
//! partial scores are too close to call (the gap-vs-noise condition of
//! Sec. 4's sub-Gaussian bound).

/// Decision input: (slot, partial_reward) for every live candidate.
pub type Scored = (usize, f32);

/// A reward normalized for ranking: NaN maps to `-inf` so `total_cmp`
/// never panics *and* a poisoned PRM score always loses — rewards live in
/// (0, 1), so demoting NaN below every real score is unambiguous.
pub fn rankable(x: f32) -> f32 {
    if x.is_nan() {
        f32::NEG_INFINITY
    } else {
        x
    }
}

/// Total descending order on `(slot, score)`: best score first, NaN last,
/// ties broken by the lower slot id (deterministic across runs).
pub fn rank_desc(a: &Scored, b: &Scored) -> std::cmp::Ordering {
    rankable(b.1).total_cmp(&rankable(a.1)).then(a.0.cmp(&b.0))
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RejectPolicy {
    /// Keep the top `keep` candidates (paper's rule).
    TopK { keep: usize },
    /// Keep candidates above `min_score`, but at least `floor` of them.
    Threshold { min_score: f32, floor: usize },
    /// Keep top `keep` only if the standardized gap between the kept and
    /// rejected groups exceeds `min_gap`; otherwise keep everyone (defer
    /// the decision to a longer prefix — adaptive tau).
    AdaptiveGap { keep: usize, min_gap: f32 },
}

impl RejectPolicy {
    /// Returns the surviving slots, best-first. [`rank_desc`] keeps the
    /// ranking total even if a PRM score comes back NaN (it sorts last,
    /// i.e. is rejected first) — a poisoned reward must degrade the beam,
    /// not panic the shard thread mid-request.
    pub fn select(&self, scored: &[Scored]) -> Vec<usize> {
        let mut ranked: Vec<Scored> = scored.to_vec();
        ranked.sort_by(rank_desc);
        match *self {
            RejectPolicy::TopK { keep } => {
                ranked.iter().take(keep.max(1)).map(|&(s, _)| s).collect()
            }
            RejectPolicy::Threshold { min_score, floor } => {
                let above: Vec<usize> =
                    ranked.iter().filter(|&&(_, v)| v >= min_score).map(|&(s, _)| s).collect();
                if above.len() >= floor.max(1) {
                    above
                } else {
                    ranked.iter().take(floor.max(1)).map(|&(s, _)| s).collect()
                }
            }
            RejectPolicy::AdaptiveGap { keep, min_gap } => {
                let keep = keep.max(1);
                if ranked.len() <= keep {
                    return ranked.iter().map(|&(s, _)| s).collect();
                }
                let kept_mean: f32 =
                    ranked[..keep].iter().map(|&(_, v)| v).sum::<f32>() / keep as f32;
                let rest = &ranked[keep..];
                let rest_mean: f32 =
                    rest.iter().map(|&(_, v)| v).sum::<f32>() / rest.len() as f32;
                if kept_mean - rest_mean >= min_gap {
                    ranked.iter().take(keep).map(|&(s, _)| s).collect()
                } else {
                    ranked.iter().map(|&(s, _)| s).collect() // defer: keep all
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check_simple};

    fn scored(vals: &[f32]) -> Vec<Scored> {
        vals.iter().cloned().enumerate().collect()
    }

    #[test]
    fn topk_keeps_best() {
        let s = scored(&[0.1, 0.9, 0.5, 0.7]);
        let kept = RejectPolicy::TopK { keep: 2 }.select(&s);
        assert_eq!(kept, vec![1, 3]);
    }

    #[test]
    fn topk_at_least_one() {
        let s = scored(&[0.3]);
        assert_eq!(RejectPolicy::TopK { keep: 0 }.select(&s), vec![0]);
    }

    #[test]
    fn threshold_with_floor() {
        let s = scored(&[0.1, 0.2, 0.95]);
        let kept = RejectPolicy::Threshold { min_score: 0.9, floor: 2 }.select(&s);
        assert_eq!(kept.len(), 2); // floor kicks in
        assert_eq!(kept[0], 2);
        let kept2 = RejectPolicy::Threshold { min_score: 0.05, floor: 1 }.select(&s);
        assert_eq!(kept2.len(), 3);
    }

    #[test]
    fn adaptive_gap_defers_when_close() {
        let close = scored(&[0.80, 0.81, 0.79, 0.805]);
        let kept = RejectPolicy::AdaptiveGap { keep: 2, min_gap: 0.2 }.select(&close);
        assert_eq!(kept.len(), 4); // too close: keep all
        let wide = scored(&[0.95, 0.9, 0.2, 0.1]);
        let kept = RejectPolicy::AdaptiveGap { keep: 2, min_gap: 0.2 }.select(&wide);
        assert_eq!(kept, vec![0, 1]);
    }

    #[test]
    fn ties_break_deterministically() {
        let s = scored(&[0.5, 0.5, 0.5]);
        assert_eq!(RejectPolicy::TopK { keep: 2 }.select(&s), vec![0, 1]);
    }

    #[test]
    fn nan_reward_loses_instead_of_panicking() {
        // a poisoned PRM score must be rejected first, not crash the sort
        let s = scored(&[0.4, f32::NAN, 0.6, f32::NAN]);
        assert_eq!(RejectPolicy::TopK { keep: 2 }.select(&s), vec![2, 0]);
        // all-NaN still returns a deterministic (slot-ordered) survivor
        let all_nan = scored(&[f32::NAN, f32::NAN]);
        assert_eq!(RejectPolicy::TopK { keep: 1 }.select(&all_nan), vec![0]);
        assert_eq!(rank_desc(&(0, f32::NAN), &(1, 0.0)), std::cmp::Ordering::Greater);
        assert_eq!(rankable(0.7), 0.7);
        assert_eq!(rankable(f32::NAN), f32::NEG_INFINITY);
    }

    #[test]
    fn prop_topk_selects_maximal_subset() {
        check_simple(
            "topk-maximal",
            |rng| {
                let n = rng.below(12) + 1;
                let keep = rng.below(n) + 1;
                let vals: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
                (keep, vals)
            },
            |(keep, vals)| {
                let kept = RejectPolicy::TopK { keep: *keep }.select(&scored(vals));
                if kept.len() != (*keep).min(vals.len()).max(1) {
                    return Err(format!("kept {} of {}", kept.len(), vals.len()));
                }
                let min_kept = kept.iter().map(|&s| vals[s]).fold(f32::INFINITY, f32::min);
                for (i, &v) in vals.iter().enumerate() {
                    if !kept.contains(&i) && v > min_kept {
                        return Err(format!("rejected {i} ({v}) > kept min {min_kept}"));
                    }
                }
                Ok(())
            },
        );
    }
}
