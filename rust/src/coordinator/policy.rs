//! Rejection policies: which beams survive a partial-reward checkpoint.
//!
//! The paper's rule is top-N/M by partial reward (Alg. 3 line 8). Two
//! extensions the paper lists as future work are also provided: an absolute
//! score threshold, and an adaptive-tau gate that defers rejection when the
//! partial scores are too close to call (the gap-vs-noise condition of
//! Sec. 4's sub-Gaussian bound).

/// Decision input: (slot, partial_reward) for every live candidate.
pub type Scored = (usize, f32);

/// A reward normalized for ranking: NaN maps to `-inf` so `total_cmp`
/// never panics *and* a poisoned PRM score always loses — rewards live in
/// (0, 1), so demoting NaN below every real score is unambiguous.
pub fn rankable(x: f32) -> f32 {
    if x.is_nan() {
        f32::NEG_INFINITY
    } else {
        x
    }
}

/// Total descending order on `(slot, score)`: best score first, NaN last,
/// ties broken by the lower slot id (deterministic across runs).
pub fn rank_desc(a: &Scored, b: &Scored) -> std::cmp::Ordering {
    rankable(b.1).total_cmp(&rankable(a.1)).then(a.0.cmp(&b.0))
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RejectPolicy {
    /// Keep the top `keep` candidates (paper's rule).
    TopK { keep: usize },
    /// Keep candidates above `min_score`, but at least `floor` of them.
    Threshold { min_score: f32, floor: usize },
    /// Keep top `keep` only if the standardized gap between the kept and
    /// rejected groups exceeds `min_gap`; otherwise keep everyone (defer
    /// the decision to a longer prefix — adaptive tau).
    AdaptiveGap { keep: usize, min_gap: f32 },
}

impl RejectPolicy {
    /// Returns the surviving slots, best-first. [`rank_desc`] keeps the
    /// ranking total even if a PRM score comes back NaN (it sorts last,
    /// i.e. is rejected first) — a poisoned reward must degrade the beam,
    /// not panic the shard thread mid-request.
    pub fn select(&self, scored: &[Scored]) -> Vec<usize> {
        let mut ranked: Vec<Scored> = scored.to_vec();
        ranked.sort_by(rank_desc);
        match *self {
            RejectPolicy::TopK { keep } => {
                ranked.iter().take(keep.max(1)).map(|&(s, _)| s).collect()
            }
            RejectPolicy::Threshold { min_score, floor } => {
                let above: Vec<usize> =
                    ranked.iter().filter(|&&(_, v)| v >= min_score).map(|&(s, _)| s).collect();
                if above.len() >= floor.max(1) {
                    above
                } else {
                    ranked.iter().take(floor.max(1)).map(|&(s, _)| s).collect()
                }
            }
            RejectPolicy::AdaptiveGap { keep, min_gap } => {
                let keep = keep.max(1);
                if ranked.len() <= keep {
                    return ranked.iter().map(|&(s, _)| s).collect();
                }
                let kept_mean: f32 =
                    ranked[..keep].iter().map(|&(_, v)| v).sum::<f32>() / keep as f32;
                let rest = &ranked[keep..];
                let rest_mean: f32 =
                    rest.iter().map(|&(_, v)| v).sum::<f32>() / rest.len() as f32;
                if kept_mean - rest_mean >= min_gap {
                    ranked.iter().take(keep).map(|&(s, _)| s).collect()
                } else {
                    ranked.iter().map(|&(s, _)| s).collect() // defer: keep all
                }
            }
        }
    }
}

/// One depth bucket's resolved rejection checkpoint.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BucketTau {
    /// Effective tau for rounds falling in this bucket.
    pub tau: usize,
    /// Whether the calibration evidence cleared the confidence gate
    /// (false ⇒ `tau == base`, the static fallback).
    pub confident: bool,
    /// The Fisher-z lower confidence bound the decision was made on
    /// (-1 = no evidence).
    pub conf_low: f64,
}

/// A frozen per-request rejection schedule.
///
/// Resolved once at admission from a calibration snapshot and never
/// mutated mid-request — two requests that resolved against the same
/// table epoch carry byte-identical plans, which is what keeps the solve
/// cache and single-flight coalescing sound (their keys embed `epoch`).
/// `None` plan on a task ⇒ the static `cfg.tau` everywhere, bit-for-bit
/// the pre-controller behavior.
#[derive(Debug, Clone, PartialEq)]
pub struct TauPlan {
    /// The request's static `cfg.tau` (fallback and shadow checkpoint).
    pub base: usize,
    /// Effective tau per depth bucket; the last bucket absorbs all
    /// deeper rounds.
    pub by_bucket: Vec<BucketTau>,
    /// Run the shadow regret check: decode phase A to `base`, reject at
    /// the effective tau, and count rejections the base-tau
    /// counterfactual would have kept.
    pub shadow: bool,
    /// Calibration table epoch the plan was frozen against.
    pub epoch: u64,
}

impl TauPlan {
    /// An all-static plan (controller on but no proven bucket).
    pub fn static_plan(base: usize, buckets: usize, epoch: u64) -> TauPlan {
        TauPlan {
            base,
            by_bucket: vec![BucketTau { tau: base, confident: false, conf_low: -1.0 }; buckets.max(1)],
            shadow: false,
            epoch,
        }
    }

    /// Effective tau for a select/expand round at `depth`.
    pub fn tau_for(&self, depth: usize) -> usize {
        match self.by_bucket.get(depth.min(self.by_bucket.len().saturating_sub(1))) {
            Some(b) => b.tau,
            None => self.base,
        }
    }

    /// The bucket entry a round at `depth` resolves through.
    pub fn bucket_for(&self, depth: usize) -> BucketTau {
        let i = depth.min(self.by_bucket.len().saturating_sub(1));
        self.by_bucket
            .get(i)
            .copied()
            .unwrap_or(BucketTau { tau: self.base, confident: false, conf_low: -1.0 })
    }

    /// True when every bucket fell back to the static tau.
    pub fn is_static(&self) -> bool {
        self.by_bucket.iter().all(|b| b.tau == self.base)
    }
}

/// The adaptive-tau controller: maps per-bucket calibration evidence to
/// a rejection schedule.
///
/// A bucket is *proven* when it holds at least `min_samples` pairs and
/// the Fisher-z lower bound of its partial↔final Pearson clears
/// `conf_floor`. Proven buckets shave the checkpoint toward `min_tau`
/// proportionally to how far the bound exceeds the floor (scaled by
/// `aggressiveness`); everything else keeps the static base — the
/// paper's exponential-risk intuition that aggressiveness must be earned
/// by demonstrated predictiveness, not assumed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptiveTau {
    pub min_samples: u64,
    pub conf_floor: f64,
    pub aggressiveness: f64,
    pub min_tau: usize,
}

impl AdaptiveTau {
    /// Resolve a frozen plan. `stats[b] = (samples, conf_low)` per depth
    /// bucket. Pure: same inputs ⇒ same plan, byte-for-byte.
    pub fn plan(&self, base: usize, stats: &[(u64, f64)], shadow: bool, epoch: u64) -> TauPlan {
        let floor = self.min_tau.max(1).min(base);
        let span = (1.0 - self.conf_floor).max(1e-9);
        let by_bucket = stats
            .iter()
            .map(|&(n, conf_low)| {
                let confident = n >= self.min_samples && conf_low >= self.conf_floor;
                if !confident {
                    return BucketTau { tau: base, confident: false, conf_low };
                }
                let excess = ((conf_low - self.conf_floor) / span).clamp(0.0, 1.0);
                let shave = (self.aggressiveness.clamp(0.0, 1.0)
                    * excess
                    * (base - floor) as f64)
                    .round() as usize;
                BucketTau { tau: base.saturating_sub(shave).max(floor), confident: true, conf_low }
            })
            .collect();
        TauPlan { base, by_bucket, shadow, epoch }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check_simple};

    fn scored(vals: &[f32]) -> Vec<Scored> {
        vals.iter().cloned().enumerate().collect()
    }

    #[test]
    fn topk_keeps_best() {
        let s = scored(&[0.1, 0.9, 0.5, 0.7]);
        let kept = RejectPolicy::TopK { keep: 2 }.select(&s);
        assert_eq!(kept, vec![1, 3]);
    }

    #[test]
    fn topk_at_least_one() {
        let s = scored(&[0.3]);
        assert_eq!(RejectPolicy::TopK { keep: 0 }.select(&s), vec![0]);
    }

    #[test]
    fn threshold_with_floor() {
        let s = scored(&[0.1, 0.2, 0.95]);
        let kept = RejectPolicy::Threshold { min_score: 0.9, floor: 2 }.select(&s);
        assert_eq!(kept.len(), 2); // floor kicks in
        assert_eq!(kept[0], 2);
        let kept2 = RejectPolicy::Threshold { min_score: 0.05, floor: 1 }.select(&s);
        assert_eq!(kept2.len(), 3);
    }

    #[test]
    fn adaptive_gap_defers_when_close() {
        let close = scored(&[0.80, 0.81, 0.79, 0.805]);
        let kept = RejectPolicy::AdaptiveGap { keep: 2, min_gap: 0.2 }.select(&close);
        assert_eq!(kept.len(), 4); // too close: keep all
        let wide = scored(&[0.95, 0.9, 0.2, 0.1]);
        let kept = RejectPolicy::AdaptiveGap { keep: 2, min_gap: 0.2 }.select(&wide);
        assert_eq!(kept, vec![0, 1]);
    }

    #[test]
    fn ties_break_deterministically() {
        let s = scored(&[0.5, 0.5, 0.5]);
        assert_eq!(RejectPolicy::TopK { keep: 2 }.select(&s), vec![0, 1]);
    }

    #[test]
    fn nan_reward_loses_instead_of_panicking() {
        // a poisoned PRM score must be rejected first, not crash the sort
        let s = scored(&[0.4, f32::NAN, 0.6, f32::NAN]);
        assert_eq!(RejectPolicy::TopK { keep: 2 }.select(&s), vec![2, 0]);
        // all-NaN still returns a deterministic (slot-ordered) survivor
        let all_nan = scored(&[f32::NAN, f32::NAN]);
        assert_eq!(RejectPolicy::TopK { keep: 1 }.select(&all_nan), vec![0]);
        assert_eq!(rank_desc(&(0, f32::NAN), &(1, 0.0)), std::cmp::Ordering::Greater);
        assert_eq!(rankable(0.7), 0.7);
        assert_eq!(rankable(f32::NAN), f32::NEG_INFINITY);
    }

    #[test]
    fn adaptive_tau_falls_back_to_base_when_thin() {
        let ctl = AdaptiveTau { min_samples: 64, conf_floor: 0.35, aggressiveness: 1.0, min_tau: 2 };
        // thin samples, strong-but-unproven corr, and proven-but-weak corr
        let plan = ctl.plan(8, &[(10, 0.9), (64, 0.2), (0, -1.0)], false, 3);
        assert!(plan.is_static());
        assert_eq!(plan.tau_for(0), 8);
        assert_eq!(plan.tau_for(99), 8, "deep rounds clamp into the last bucket");
        assert!(plan.by_bucket.iter().all(|b| !b.confident));
        assert_eq!(plan.epoch, 3);
    }

    #[test]
    fn adaptive_tau_shaves_proportionally_and_clamps() {
        let ctl = AdaptiveTau { min_samples: 16, conf_floor: 0.35, aggressiveness: 1.0, min_tau: 2 };
        let plan = ctl.plan(8, &[(100, 0.35), (100, 0.675), (100, 1.0), (100, 0.999)], false, 0);
        assert_eq!(plan.tau_for(0), 8, "exactly at the floor shaves nothing");
        assert_eq!(plan.tau_for(1), 5, "halfway excess shaves half the span");
        assert_eq!(plan.tau_for(2), 2, "full confidence hits min_tau");
        assert_eq!(plan.tau_for(3), 2, "clamped at min_tau");
        assert!(plan.by_bucket[2].confident);
        assert!(!plan.is_static());
        // aggressiveness scales the shave; min_tau >= base degenerates to static
        let timid = AdaptiveTau { aggressiveness: 0.5, ..ctl };
        assert_eq!(timid.plan(8, &[(100, 1.0)], false, 0).tau_for(0), 5);
        let pinned = AdaptiveTau { min_tau: 8, ..ctl };
        assert!(pinned.plan(8, &[(100, 1.0)], false, 0).is_static());
    }

    #[test]
    fn plans_are_pure_functions_of_their_inputs() {
        let ctl = AdaptiveTau { min_samples: 8, conf_floor: 0.3, aggressiveness: 0.7, min_tau: 3 };
        let stats = [(32, 0.55), (9, 0.8), (0, -1.0)];
        let a = ctl.plan(12, &stats, true, 17);
        let b = ctl.plan(12, &stats, true, 17);
        assert_eq!(a, b, "frozen table => frozen plan");
        assert!(a.shadow);
        // static_plan matches what thin evidence resolves to
        let s = TauPlan::static_plan(12, 3, 17);
        assert_eq!(s.tau_for(1), 12);
        assert!(s.is_static());
        assert_eq!(s.bucket_for(5).conf_low, -1.0);
    }

    #[test]
    fn prop_topk_selects_maximal_subset() {
        check_simple(
            "topk-maximal",
            |rng| {
                let n = rng.below(12) + 1;
                let keep = rng.below(n) + 1;
                let vals: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
                (keep, vals)
            },
            |(keep, vals)| {
                let kept = RejectPolicy::TopK { keep: *keep }.select(&scored(vals));
                if kept.len() != (*keep).min(vals.len()).max(1) {
                    return Err(format!("kept {} of {}", kept.len(), vals.len()));
                }
                let min_kept = kept.iter().map(|&s| vals[s]).fold(f32::INFINITY, f32::min);
                for (i, &v) in vals.iter().enumerate() {
                    if !kept.contains(&i) && v > min_kept {
                        return Err(format!("rejected {i} ({v}) > kept min {min_kept}"));
                    }
                }
                Ok(())
            },
        );
    }
}
