//! Rejection policies: which beams survive a partial-reward checkpoint.
//!
//! The paper's rule is top-N/M by partial reward (Alg. 3 line 8). Two
//! extensions the paper lists as future work are also provided: an absolute
//! score threshold, and an adaptive-tau gate that defers rejection when the
//! partial scores are too close to call (the gap-vs-noise condition of
//! Sec. 4's sub-Gaussian bound).

/// Decision input: (slot, partial_reward) for every live candidate.
pub type Scored = (usize, f32);

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RejectPolicy {
    /// Keep the top `keep` candidates (paper's rule).
    TopK { keep: usize },
    /// Keep candidates above `min_score`, but at least `floor` of them.
    Threshold { min_score: f32, floor: usize },
    /// Keep top `keep` only if the standardized gap between the kept and
    /// rejected groups exceeds `min_gap`; otherwise keep everyone (defer
    /// the decision to a longer prefix — adaptive tau).
    AdaptiveGap { keep: usize, min_gap: f32 },
}

impl RejectPolicy {
    /// Returns the surviving slots, best-first.
    pub fn select(&self, scored: &[Scored]) -> Vec<usize> {
        let mut ranked: Vec<Scored> = scored.to_vec();
        ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        match *self {
            RejectPolicy::TopK { keep } => {
                ranked.iter().take(keep.max(1)).map(|&(s, _)| s).collect()
            }
            RejectPolicy::Threshold { min_score, floor } => {
                let above: Vec<usize> =
                    ranked.iter().filter(|&&(_, v)| v >= min_score).map(|&(s, _)| s).collect();
                if above.len() >= floor.max(1) {
                    above
                } else {
                    ranked.iter().take(floor.max(1)).map(|&(s, _)| s).collect()
                }
            }
            RejectPolicy::AdaptiveGap { keep, min_gap } => {
                let keep = keep.max(1);
                if ranked.len() <= keep {
                    return ranked.iter().map(|&(s, _)| s).collect();
                }
                let kept_mean: f32 =
                    ranked[..keep].iter().map(|&(_, v)| v).sum::<f32>() / keep as f32;
                let rest = &ranked[keep..];
                let rest_mean: f32 =
                    rest.iter().map(|&(_, v)| v).sum::<f32>() / rest.len() as f32;
                if kept_mean - rest_mean >= min_gap {
                    ranked.iter().take(keep).map(|&(s, _)| s).collect()
                } else {
                    ranked.iter().map(|&(s, _)| s).collect() // defer: keep all
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check_simple};

    fn scored(vals: &[f32]) -> Vec<Scored> {
        vals.iter().cloned().enumerate().collect()
    }

    #[test]
    fn topk_keeps_best() {
        let s = scored(&[0.1, 0.9, 0.5, 0.7]);
        let kept = RejectPolicy::TopK { keep: 2 }.select(&s);
        assert_eq!(kept, vec![1, 3]);
    }

    #[test]
    fn topk_at_least_one() {
        let s = scored(&[0.3]);
        assert_eq!(RejectPolicy::TopK { keep: 0 }.select(&s), vec![0]);
    }

    #[test]
    fn threshold_with_floor() {
        let s = scored(&[0.1, 0.2, 0.95]);
        let kept = RejectPolicy::Threshold { min_score: 0.9, floor: 2 }.select(&s);
        assert_eq!(kept.len(), 2); // floor kicks in
        assert_eq!(kept[0], 2);
        let kept2 = RejectPolicy::Threshold { min_score: 0.05, floor: 1 }.select(&s);
        assert_eq!(kept2.len(), 3);
    }

    #[test]
    fn adaptive_gap_defers_when_close() {
        let close = scored(&[0.80, 0.81, 0.79, 0.805]);
        let kept = RejectPolicy::AdaptiveGap { keep: 2, min_gap: 0.2 }.select(&close);
        assert_eq!(kept.len(), 4); // too close: keep all
        let wide = scored(&[0.95, 0.9, 0.2, 0.1]);
        let kept = RejectPolicy::AdaptiveGap { keep: 2, min_gap: 0.2 }.select(&wide);
        assert_eq!(kept, vec![0, 1]);
    }

    #[test]
    fn ties_break_deterministically() {
        let s = scored(&[0.5, 0.5, 0.5]);
        assert_eq!(RejectPolicy::TopK { keep: 2 }.select(&s), vec![0, 1]);
    }

    #[test]
    fn prop_topk_selects_maximal_subset() {
        check_simple(
            "topk-maximal",
            |rng| {
                let n = rng.below(12) + 1;
                let keep = rng.below(n) + 1;
                let vals: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
                (keep, vals)
            },
            |(keep, vals)| {
                let kept = RejectPolicy::TopK { keep: *keep }.select(&scored(vals));
                if kept.len() != (*keep).min(vals.len()).max(1) {
                    return Err(format!("kept {} of {}", kept.len(), vals.len()));
                }
                let min_kept = kept.iter().map(|&s| vals[s]).fold(f32::INFINITY, f32::min);
                for (i, &v) in vals.iter().enumerate() {
                    if !kept.contains(&i) && v > min_kept {
                        return Err(format!("rejected {i} ({v}) > kept min {min_kept}"));
                    }
                }
                Ok(())
            },
        );
    }
}
