//! Host-side sampling utilities.
//!
//! The in-graph sampler (temperature categorical, per-slot threefry keys)
//! handles steady-state decoding; the host side only samples the *first*
//! generated token per beam from the prefill logits (the first point of
//! beam diversity) and derives the per-call key material.

use crate::util::rng::Rng;

/// Sample `n` first tokens from prefill logits (one independent draw per
/// beam) at the given temperature.
pub fn sample_first_tokens(logits: &[f32], n: usize, temp: f32, rng: &mut Rng) -> Vec<i32> {
    (0..n).map(|_| rng.sample_logits(logits, temp) as i32).collect()
}

/// Per-slot u32x2 key material for one decode call: derived from each
/// beam's stream id and a per-call counter so repeated calls never reuse
/// keys, and sibling beams (same parent, different slot) diverge.
pub fn decode_keys(beam_keys: &[u64], call_counter: u64) -> Vec<u32> {
    let mut out = Vec::with_capacity(beam_keys.len() * 2);
    for (slot, &k) in beam_keys.iter().enumerate() {
        let mixed = splitmix(k ^ call_counter.wrapping_mul(0xA24BAED4963EE407) ^ (slot as u64) << 17);
        out.push((mixed >> 32) as u32);
        out.push(mixed as u32);
    }
    out
}

fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_tokens_in_vocab_and_diverse() {
        let mut rng = Rng::new(1);
        let logits = vec![0.0f32; 24];
        let toks = sample_first_tokens(&logits, 16, 1.0, &mut rng);
        assert_eq!(toks.len(), 16);
        assert!(toks.iter().all(|&t| (0..24).contains(&t)));
        let distinct: std::collections::BTreeSet<_> = toks.iter().collect();
        assert!(distinct.len() > 1);
    }

    #[test]
    fn greedy_at_zero_temperature() {
        let mut rng = Rng::new(2);
        let mut logits = vec![0.0f32; 10];
        logits[7] = 5.0;
        let toks = sample_first_tokens(&logits, 8, 0.0, &mut rng);
        assert!(toks.iter().all(|&t| t == 7));
    }

    #[test]
    fn keys_unique_across_slots_and_calls() {
        let beam_keys = vec![42u64; 8]; // identical streams (fresh siblings)
        let a = decode_keys(&beam_keys, 0);
        let b = decode_keys(&beam_keys, 1);
        assert_eq!(a.len(), 16);
        assert_ne!(a, b); // new call, new keys
        // identical beam keys but different slots must differ
        assert_ne!(&a[0..2], &a[2..4]);
    }
}
