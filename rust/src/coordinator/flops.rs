//! Analytic FLOPs ledger — the paper's headline metric.
//!
//! The paper reports total inference FLOPs split by generator (LLM) and
//! evaluator (PRM) (Table 3) and the reduction factors (1.4x-9x). FLOPs are
//! counted analytically — 2 * params per forward token — exactly as the
//! paper's accounting does; what early rejection changes is *how many
//! tokens* each component processes. Only logically-required tokens are
//! charged (the lockstep implementation's on-device junk positions are an
//! artifact of this backend, not of the algorithm).

/// Token-level accounting for one run (one problem or an aggregate).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FlopsLedger {
    /// 2*P cost units per token for each component.
    pub lm_flops_per_token: u64,
    pub prm_flops_per_token: u64,
    /// Token counters.
    pub lm_prefill_tokens: u64,
    pub lm_decode_tokens: u64,
    pub prm_prefill_tokens: u64,
    pub prm_score_tokens: u64,
    /// Runtime counters (wallclock diagnostics, not FLOPs).
    pub pjrt_calls: u64,
}

impl FlopsLedger {
    pub fn new(lm_flops_per_token: u64, prm_flops_per_token: u64) -> Self {
        FlopsLedger { lm_flops_per_token, prm_flops_per_token, ..Default::default() }
    }

    pub fn lm_prefill(&mut self, tokens: usize) {
        self.lm_prefill_tokens += tokens as u64;
        self.pjrt_calls += 1;
    }

    pub fn lm_decode(&mut self, tokens: usize) {
        self.lm_decode_tokens += tokens as u64;
    }

    pub fn prm_prefill(&mut self, tokens: usize) {
        self.prm_prefill_tokens += tokens as u64;
        self.pjrt_calls += 1;
    }

    pub fn prm_score(&mut self, tokens: usize) {
        self.prm_score_tokens += tokens as u64;
    }

    pub fn call(&mut self) {
        self.pjrt_calls += 1;
    }

    pub fn lm_flops(&self) -> f64 {
        (self.lm_prefill_tokens + self.lm_decode_tokens) as f64 * self.lm_flops_per_token as f64
    }

    pub fn prm_flops(&self) -> f64 {
        (self.prm_prefill_tokens + self.prm_score_tokens) as f64
            * self.prm_flops_per_token as f64
    }

    pub fn total_flops(&self) -> f64 {
        self.lm_flops() + self.prm_flops()
    }

    /// Merge another ledger (aggregating a problem set).
    pub fn merge(&mut self, other: &FlopsLedger) {
        debug_assert_eq!(self.lm_flops_per_token, other.lm_flops_per_token);
        debug_assert_eq!(self.prm_flops_per_token, other.prm_flops_per_token);
        self.lm_prefill_tokens += other.lm_prefill_tokens;
        self.lm_decode_tokens += other.lm_decode_tokens;
        self.prm_prefill_tokens += other.prm_prefill_tokens;
        self.prm_score_tokens += other.prm_score_tokens;
        self.pjrt_calls += other.pjrt_calls;
    }

    pub fn report(&self) -> FlopsReport {
        FlopsReport {
            lm_flops: self.lm_flops(),
            prm_flops: self.prm_flops(),
            total_flops: self.total_flops(),
            lm_tokens: self.lm_prefill_tokens + self.lm_decode_tokens,
            prm_tokens: self.prm_prefill_tokens + self.prm_score_tokens,
        }
    }
}

/// Summary in the paper's reporting units.
#[derive(Debug, Clone, Copy, Default)]
pub struct FlopsReport {
    pub lm_flops: f64,
    pub prm_flops: f64,
    pub total_flops: f64,
    pub lm_tokens: u64,
    pub prm_tokens: u64,
}

impl FlopsReport {
    /// Reduction factor of `self` relative to a baseline (paper's "Nx").
    pub fn reduction_vs(&self, baseline: &FlopsReport) -> f64 {
        if self.total_flops <= 0.0 {
            return f64::INFINITY;
        }
        baseline.total_flops / self.total_flops
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_splits() {
        let mut l = FlopsLedger::new(200, 700);
        l.lm_prefill(10);
        l.lm_decode(90);
        l.prm_prefill(10);
        l.prm_score(40);
        assert_eq!(l.lm_flops(), 100.0 * 200.0);
        assert_eq!(l.prm_flops(), 50.0 * 700.0);
        assert_eq!(l.total_flops(), 100.0 * 200.0 + 50.0 * 700.0);
        let r = l.report();
        assert_eq!(r.lm_tokens, 100);
        assert_eq!(r.prm_tokens, 50);
    }

    #[test]
    fn merge_adds() {
        let mut a = FlopsLedger::new(2, 3);
        a.lm_decode(5);
        let mut b = FlopsLedger::new(2, 3);
        b.lm_decode(7);
        b.prm_score(1);
        a.merge(&b);
        assert_eq!(a.lm_decode_tokens, 12);
        assert_eq!(a.prm_score_tokens, 1);
    }

    #[test]
    fn reduction_factor() {
        let mut base = FlopsLedger::new(2, 2);
        base.lm_decode(100);
        let mut er = FlopsLedger::new(2, 2);
        er.lm_decode(25);
        let f = er.report().reduction_vs(&base.report());
        assert!((f - 4.0).abs() < 1e-12);
    }

    #[test]
    fn empty_ledger_zero() {
        let l = FlopsLedger::new(10, 10);
        assert_eq!(l.total_flops(), 0.0);
    }
}
