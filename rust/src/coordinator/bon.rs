//! Best-of-N baseline (paper Sec. 2, "Early Rejection" related work).
//!
//! BoN generates N *complete* solutions with no intermediate pruning and
//! picks the best by PRM score — the decoding regime Speculative Rejection
//! (Sun et al., 2024) accelerates with an ORM. Here it serves as the
//! no-search baseline the PRM-guided decoders are measured against: same
//! engines, same ledger, no step-level selection.

use std::time::Instant;

use crate::config::SearchConfig;
use crate::coordinator::search::{PhaseTarget, SearchCtx, SolveOutcome};
use crate::runtime::Engine;
use crate::util::error::Result;
use crate::workload::Problem;

/// Generate N full solutions, score them with the PRM, return the best.
pub fn solve_best_of_n(
    engine: &Engine,
    lm_ckpt: &str,
    prm_ckpt: &str,
    problem: &Problem,
    cfg: &SearchConfig,
    temp: f32,
) -> Result<SolveOutcome> {
    cfg.validate()?;
    let t0 = Instant::now();
    let mut ctx = SearchCtx::init(engine, lm_ckpt, prm_ckpt, problem, cfg, temp)?;
    let mut steps = 0;
    // drive every beam to EOS (or run-away death), finalizing steps as they
    // close but never pruning or expanding.
    for _ in 0..cfg.max_steps {
        let ok = ctx.decode_phase(engine, PhaseTarget::Boundary)?;
        let ok2 = ctx.score_catch_up(engine)?;
        ctx.harvest_finished();
        if !ok || !ok2 {
            break;
        }
        steps += 1;
        let mut any = false;
        for beam in ctx.beams.beams.iter_mut() {
            if beam.active() && beam.awaiting_finalize {
                beam.finalize_step(cfg.agg);
                any = true;
            }
        }
        if !any {
            break; // all finished or dead
        }
    }
    Ok(ctx.finish(problem, t0, steps))
}

// Covered end-to-end in rust/tests/integration.rs (needs artifacts). By
// construction this module has no pruning or expansion code path: BoN's
// generation FLOPs at width N upper-bound every searched decoder's.
