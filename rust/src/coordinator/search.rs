//! Vanilla PRM-guided beam search — paper Algorithm 2 (the baseline) —
//! plus the shared per-problem search machinery both decoders use.
//!
//! Pipeline per reasoning step: every beam samples a full step (to `;` or
//! EOS), the PRM scores the completed step, the top N/M survive and are
//! expanded into M children each. The only difference in Algorithm 3
//! (`early_reject`) is the mid-step partial-reward checkpoint and the
//! two-tier batch shrink for the completion phase.
//!
//! Since the fleet scheduler landed, the blocking `solve_*` entry points
//! are thin drivers over [`crate::coordinator::task::SolveTask`], the
//! resumable state machine that yields between engine calls so many
//! in-flight solves can share one shard's engine loop. `SearchCtx` owns
//! all per-problem state (no engine borrow) and every engine-touching
//! method takes `&Engine` explicitly, which is what makes a parked task
//! storable in a fleet slot table.

use std::time::Instant;

use crate::config::SearchConfig;
use crate::coordinator::beam::{Beam, BeamSet};
use crate::coordinator::flops::FlopsLedger;
use crate::coordinator::sampler;
use crate::coordinator::scheduler::{self, TwoTierPlan};
use crate::coordinator::scorer;
use crate::coordinator::task::SolveTask;
use crate::log_debug;
use crate::runtime::{Engine, KvSet};
use crate::util::error::Result;
use crate::workload::Problem;

/// Result of solving one problem.
#[derive(Debug, Clone)]
pub struct SolveOutcome {
    pub answer: Option<i64>,
    pub correct: bool,
    pub best_reward: f32,
    pub steps_executed: usize,
    pub wall_s: f64,
    pub ledger: FlopsLedger,
    pub best_trace: Vec<i32>,
    pub finished_beams: usize,
}

/// Which model's cache a compaction intent targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub(crate) enum CompactTarget {
    Lm,
    Prm,
}

/// A proactive compaction must reclaim at least this many future blocks'
/// worth of positions to be worth its device call (a repack is one
/// gather — cheap next to a decode/score — but not free); the
/// exhaustion-rescue trigger only needs one block. Also the anti-thrash
/// guard: right after a compaction `reclaimable()` is 0, so the trigger
/// cannot re-fire until at least this much junk has re-accrued.
const COMPACT_MIN_GAIN_BLOCKS: usize = 2;

/// Per-problem search state shared by both algorithms. Owns its config
/// and checkpoint names so a parked [`SolveTask`] carries everything it
/// needs between `advance` calls.
pub(crate) struct SearchCtx {
    pub lm_ckpt: String,
    pub prm_ckpt: String,
    pub cfg: SearchConfig,
    pub temp: f32,
    pub lm_kv: KvSet,
    pub prm_kv: KvSet,
    pub beams: BeamSet,
    pub done: Vec<Beam>,
    pub ledger: FlopsLedger,
    pub call_counter: u64,
    pub decode_block: usize,
    pub score_block: usize,
    /// Whether the artifact set exported `compact_bN` programs for each
    /// model (false on pre-compaction artifacts, or after a compaction
    /// call reported itself unavailable — both degrade gracefully to the
    /// old truncate-on-exhaustion behavior).
    pub lm_compact: bool,
    pub prm_compact: bool,
}

/// What a decode phase is driving each beam toward.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum PhaseTarget {
    /// Stop each beam at `tau` step-tokens or its step boundary (phase A).
    Prefix { tau: usize },
    /// Run each beam to its step boundary (phase B / vanilla step).
    Boundary,
}

/// Outcome of one lockstep decode block within a phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum DecodeTick {
    /// Every beam satisfies the phase target; the phase is complete.
    Done,
    /// The KV cache cannot fit another block; caller finalizes early.
    Exhausted,
    /// One block was decoded; more ticks needed.
    Progress,
}

/// The host half of one decode tick, prepared before the engine call so
/// the call itself can be executed solo or merged into a gang batch. The
/// inputs are exactly what `Engine::lm_decode_block` takes for this
/// cache's batch.
#[derive(Debug, Clone)]
pub(crate) struct DecodePrep {
    /// Slots the current phase is driving (others idle through the block).
    pub pending: Vec<usize>,
    /// Previous token per slot, `[batch]`.
    pub prev: Vec<i32>,
    /// Per-slot RNG key material, `[batch * 2]`.
    pub keys: Vec<u32>,
    pub target: PhaseTarget,
}

/// What `decode_prepare` decided for this tick.
pub(crate) enum DecodeStage {
    Done,
    Exhausted,
    /// The LM cache should be re-compacted before the next block: either
    /// it cannot fit one more block but the junk gap can (exhaustion
    /// rescue), or the junk share crossed the proactive threshold.
    Compact,
    Call(DecodePrep),
}

/// Shared compaction trigger: rescue when the cache cannot fit the next
/// `block` but post-compaction capacity (`remaining + reclaimable`) can
/// — the same headroom arithmetic `score_budget_ok` promises — and
/// proactive when the junk share crossed `threshold` and the reclaimable
/// gap pays for the compaction. Reclaim is [`KvSet::reclaimable`], the
/// mode-aware figure: the dense-repack number on gather-paged/dense
/// caches, the junk-tail number on block-native ones — promising repack
/// reclaim that a table truncation cannot deliver would livelock the
/// rescue trigger. Runs per scheduler tick, so it early-outs before
/// touching the bitmask whenever neither trigger could possibly fire.
fn wants_compact(kv: &KvSet, block: usize, enabled: bool, threshold: f32) -> bool {
    if !enabled {
        return false;
    }
    // rescue needs remaining < block; proactive needs at least
    // COMPACT_MIN_GAIN_BLOCKS * block reclaimable, impossible while the
    // frontier itself is below that — both checkable without a scan
    if kv.remaining() >= block && kv.pos_phys < COMPACT_MIN_GAIN_BLOCKS * block {
        return false;
    }
    let reclaimable = kv.reclaimable();
    let rescue = kv.remaining() < block && kv.remaining() + reclaimable >= block;
    let junk = kv.junk_fraction();
    let proactive =
        junk >= threshold as f64 && reclaimable >= COMPACT_MIN_GAIN_BLOCKS * block;
    rescue || proactive
}

impl SearchCtx {
    /// Prefill both models, broadcast to the b1 variant, sample first tokens.
    pub fn init(
        engine: &Engine,
        lm_ckpt: &str,
        prm_ckpt: &str,
        problem: &Problem,
        cfg: &SearchConfig,
        temp: f32,
    ) -> Result<Self> {
        let lm_arch = engine.manifest.arch_for_checkpoint(lm_ckpt)?;
        let prm_arch = engine.manifest.arch_for_checkpoint(prm_ckpt)?;
        let mut ledger = FlopsLedger::new(lm_arch.flops_per_token, prm_arch.flops_per_token);

        let prompt = problem.prompt_tokens();
        let (logits, lm_kv1) = engine.lm_prefill(lm_ckpt, &prompt)?;
        ledger.lm_prefill(prompt.len());
        let prm_kv1 = engine.prm_prefill(prm_ckpt, &prompt)?;
        ledger.prm_prefill(prompt.len());

        let b1 = engine.manifest.batch_variant(cfg.n_beams)?;
        let lm_kv = engine.kv_broadcast(lm_ckpt, &lm_kv1, b1)?;
        let prm_kv = engine.kv_broadcast(prm_ckpt, &prm_kv1, b1)?;
        ledger.call();
        ledger.call();
        // compaction availability probe; the exporter emits compact_bN for
        // every batch variant, so one probe per model covers b1 and the
        // two-tier b2 alike (pre-compaction artifacts: both false)
        let lm_compact = lm_arch.has_program(&format!("compact_b{b1}"));
        let prm_compact = prm_arch.has_program(&format!("compact_b{b1}"));

        let mut rng = crate::util::rng::Rng::new(cfg.seed ^ hash_problem(problem));
        let first = sampler::sample_first_tokens(&logits, b1, temp, &mut rng);
        let beams: Vec<Beam> = first
            .iter()
            .enumerate()
            .map(|(i, &t)| {
                let mut b = Beam::new(t, rng.fork(i as u64).next_u64());
                if i >= cfg.n_beams {
                    b.dead = true; // padding slots of the batch variant
                }
                b
            })
            .collect();

        Ok(SearchCtx {
            lm_ckpt: lm_ckpt.to_string(),
            prm_ckpt: prm_ckpt.to_string(),
            cfg: cfg.clone(),
            temp,
            lm_kv,
            prm_kv,
            beams: BeamSet::from_beams(beams),
            done: Vec::new(),
            ledger,
            call_counter: 0,
            decode_block: engine.manifest.decode_block,
            score_block: engine.manifest.score_block,
            lm_compact,
            prm_compact,
        })
    }

    /// Is this beam still being driven by the current phase?
    fn phase_pending(&self, beam: &Beam, target: PhaseTarget) -> bool {
        if !beam.active() || beam.awaiting_finalize {
            return false;
        }
        match target {
            PhaseTarget::Prefix { tau } => {
                beam.current_step_len() < tau && beam.current_step_len() < self.cfg.max_step_tokens
            }
            PhaseTarget::Boundary => beam.current_step_len() < self.cfg.max_step_tokens,
        }
    }

    /// Host side of one decode tick: decide whether the phase is complete
    /// or out of cache, otherwise assemble the engine inputs (and burn one
    /// key-stream counter — prepare/absorb must pair one-to-one).
    pub fn decode_prepare(&mut self, target: PhaseTarget) -> DecodeStage {
        let pending: Vec<usize> = (0..self.beams.beams.len())
            .filter(|&i| self.phase_pending(&self.beams.beams[i], target))
            .collect();
        if pending.is_empty() {
            return DecodeStage::Done;
        }
        if wants_compact(&self.lm_kv, self.decode_block, self.lm_compact, self.cfg.compact_junk) {
            return DecodeStage::Compact;
        }
        if self.lm_kv.remaining() < self.decode_block {
            log_debug!("LM KV cache exhausted; stopping decode phase");
            return DecodeStage::Exhausted;
        }
        let prev: Vec<i32> = self.beams.beams.iter().map(|bm| bm.pending).collect();
        let keys: Vec<u64> = self.beams.beams.iter().map(|bm| bm.key).collect();
        let key_mat = sampler::decode_keys(&keys, self.call_counter);
        self.call_counter += 1;
        DecodeStage::Call(DecodePrep { pending, prev, keys: key_mat, target })
    }

    /// Fold one decode call's sampled tokens back into the beams and the
    /// cache bookkeeping. `lm_kv` must already hold the post-call frontier
    /// (the engine call advanced it), which also makes this correct after
    /// a gang-merged call where the shared batch wrote at the merged
    /// frontier. Beams that exceed `max_step_tokens` without a boundary
    /// are killed (runaway guard).
    pub fn decode_absorb(&mut self, prep: &DecodePrep, sampled: &[i32]) {
        self.ledger.call();
        debug_assert_eq!(sampled.len(), self.lm_kv.batch * self.decode_block);
        let old_frontier = self.lm_kv.pos_phys - self.decode_block;
        for &slot in &prep.pending {
            let blk = &sampled[slot * self.decode_block..(slot + 1) * self.decode_block];
            let beam = &mut self.beams.beams[slot];
            let (fed, boundary) = beam.accept_block(blk);
            self.lm_kv.commit(slot, old_frontier, fed);
            self.ledger.lm_decode(fed);
            if boundary.is_none()
                && beam.current_step_len() >= self.cfg.max_step_tokens
                && matches!(prep.target, PhaseTarget::Boundary)
            {
                beam.dead = true; // runaway: never closed the step
            }
        }
    }

    /// Run one lockstep decode block toward `target` — the resumable unit
    /// the fleet scheduler interleaves across requests. Blocking
    /// composition of [`SearchCtx::decode_prepare`] +
    /// [`SearchCtx::decode_absorb`].
    pub fn decode_tick(&mut self, engine: &Engine, target: PhaseTarget) -> Result<DecodeTick> {
        match self.decode_prepare(target) {
            DecodeStage::Done => Ok(DecodeTick::Done),
            DecodeStage::Exhausted => Ok(DecodeTick::Exhausted),
            DecodeStage::Compact => {
                let changed = engine.kv_compact(&self.lm_ckpt, &mut self.lm_kv)?;
                self.note_compact(CompactTarget::Lm, changed);
                Ok(DecodeTick::Progress)
            }
            DecodeStage::Call(prep) => {
                let sampled = engine.lm_decode_block(
                    &self.lm_ckpt,
                    &mut self.lm_kv,
                    &prep.prev,
                    self.temp,
                    &prep.keys,
                )?;
                self.decode_absorb(&prep, &sampled);
                Ok(DecodeTick::Progress)
            }
        }
    }

    /// Run lockstep decode blocks until every beam satisfies `target`.
    /// Returns false if the KV cache ran out (caller finalizes with what
    /// it has). Blocking form of [`SearchCtx::decode_tick`].
    pub fn decode_phase(&mut self, engine: &Engine, target: PhaseTarget) -> Result<bool> {
        loop {
            match self.decode_tick(engine, target)? {
                DecodeTick::Done => return Ok(true),
                DecodeTick::Exhausted => return Ok(false),
                DecodeTick::Progress => {}
            }
        }
    }

    /// The upfront KV-budget check applied before draining PRM backlogs:
    /// false when the cache cannot hold every round the worst backlog
    /// needs (each round advances the lockstep frontier by `score_block`).
    /// When the artifact set can re-compact, the junk gap counts as
    /// headroom — what used to be a hard capacity wall becomes reclaimable
    /// (the mid-drain compaction happens in [`SearchCtx::score_catch_up`]
    /// or via a yielded compact intent on the cooperative path).
    pub fn score_budget_ok(&self) -> bool {
        let max_backlog = self
            .beams
            .beams
            .iter()
            .filter(|b| !b.dead)
            .map(|b| b.gen.len() - b.prm_fed)
            .max()
            .unwrap_or(0);
        let rounds = max_backlog.div_ceil(self.score_block);
        let headroom = self.prm_kv.remaining()
            + if self.prm_compact { self.prm_kv.reclaimable() } else { 0 };
        if headroom < rounds * self.score_block {
            return false;
        }
        // paged: the physical budget is the shard's shared pool, not this
        // cache's fixed length — every live slot's table must be able to
        // grow to the post-drain frontier out of the free list. (With
        // ample blocks this changes nothing; under pool pressure scoring
        // truncates exactly like the dense capacity wall.)
        if let Some(ps) = self.prm_kv.pool_stats() {
            let live = self.prm_kv.pages.as_ref().map_or(self.prm_kv.batch, |p| {
                (0..self.prm_kv.batch).filter(|&s| !p.is_dead(s)).count()
            });
            let target = self.prm_kv.pos_phys + rounds * self.score_block;
            let held = self.prm_kv.pos_phys.div_ceil(ps.block_size);
            let need = target.div_ceil(ps.block_size).saturating_sub(held) * live;
            if need > ps.blocks_free {
                return false;
            }
        }
        true
    }

    /// Whether the PRM cache should be re-compacted before the next
    /// scoring round (gated on an actual backlog so phase tails never
    /// spend a device call on a cache nothing will read).
    pub fn prm_wants_compact(&self) -> bool {
        let backlog = self.beams.beams.iter().any(|b| !b.dead && b.prm_fed < b.gen.len());
        backlog
            && wants_compact(
                &self.prm_kv,
                self.score_block,
                self.prm_compact,
                self.cfg.compact_junk,
            )
    }

    /// Record a compaction attempt's outcome: an unavailable program
    /// (`changed == false` with junk still present) disables further
    /// proposals for that model, so old artifact sets can never loop.
    pub fn note_compact(&mut self, target: CompactTarget, changed: bool) {
        if !changed {
            match target {
                CompactTarget::Lm => self.lm_compact = false,
                CompactTarget::Prm => self.prm_compact = false,
            }
        }
    }

    /// Mid-phase recheck of the per-round budget. A gang-merged call can
    /// advance the PRM frontier faster than this task's own pacing
    /// (merged writes land at the max of the members' frontiers), and the
    /// upfront [`SearchCtx::score_budget_ok`] verdict may have counted
    /// reclaimable junk that a compaction has yet to return, so the
    /// verdict can go stale between rounds. True when no round is pending
    /// or the next one still fits physically; a false here is what
    /// triggers the mid-drain compaction.
    pub fn score_round_fits(&self) -> bool {
        let backlog = self.beams.beams.iter().any(|b| !b.dead && b.prm_fed < b.gen.len());
        !backlog || self.prm_kv.remaining() >= self.score_block
    }

    /// Next PRM scoring round, or `None` once every backlog is drained.
    pub fn score_prepare(&self) -> Option<scorer::ScoreRound> {
        scorer::prepare_round(&self.beams, self.prm_kv.batch, self.score_block)
    }

    /// Fold one scoring round's results back (post-call frontier already
    /// in `prm_kv`, as with [`SearchCtx::decode_absorb`]).
    pub fn score_absorb(&mut self, round: &scorer::ScoreRound, scores: &[f32]) {
        scorer::absorb_round(
            round,
            scores,
            self.score_block,
            &mut self.prm_kv,
            &mut self.beams,
            &mut self.ledger,
        );
    }

    /// Drain PRM backlogs (scores for all clean tokens), re-compacting the
    /// cache between rounds when a round would not fit otherwise (the
    /// blocking mirror of the cooperative path's yielded compact intents).
    pub fn score_catch_up(&mut self, engine: &Engine) -> Result<bool> {
        if !self.score_budget_ok() {
            log_debug!("PRM KV cache exhausted; stopping scoring");
            return Ok(false);
        }
        while let Some(round) = self.score_prepare() {
            if !self.score_round_fits() {
                let changed = engine.kv_compact(&self.prm_ckpt, &mut self.prm_kv)?;
                self.note_compact(CompactTarget::Prm, changed);
                if !self.score_round_fits() {
                    log_debug!("PRM KV cache exhausted mid-drain; stopping scoring");
                    return Ok(false);
                }
            }
            let scores = engine.prm_score_block(&self.prm_ckpt, &mut self.prm_kv, &round.tokens)?;
            self.score_absorb(&round, &scores);
        }
        Ok(true)
    }

    /// Move finished beams out of the pool into `done`.
    pub fn harvest_finished(&mut self) {
        for beam in self.beams.beams.iter_mut() {
            if beam.finished && !beam.dead {
                self.done.push(beam.clone());
                beam.dead = true;
            }
        }
    }

    /// Expand `survivors` (slot ids, best-first) into M children each,
    /// refilling all b1 slots. Device gather + host permute, both models.
    pub fn expand(&mut self, engine: &Engine, survivors: &[usize]) -> Result<()> {
        let b1 = self.lm_kv.batch;
        let keep = survivors.len();
        // compact order: survivors first (children map onto them)
        let (rel_idx, active) = scheduler::expansion_indices(keep, self.cfg.m_expand, b1);
        let idx: Vec<i32> = rel_idx.iter().map(|&r| survivors[r as usize] as i32).collect();
        engine.kv_gather(&self.lm_ckpt, &mut self.lm_kv, &idx)?;
        engine.kv_gather(&self.prm_ckpt, &mut self.prm_kv, &idx)?;
        self.ledger.call();
        self.ledger.call();
        let key_base = self.call_counter.wrapping_mul(0x2545F4914F6CDD1D) ^ self.cfg.seed;
        self.beams.permute(&idx, key_base);
        for (slot, beam) in self.beams.beams.iter_mut().enumerate() {
            beam.dead = slot >= active;
            beam.finished = false; // children of unfinished survivors
        }
        Ok(())
    }

    /// Compact `survivors` into the b2 variant for the ER completion
    /// phase (two-tier shrink): resize both model caches, permute beams,
    /// and mark padding slots dead.
    pub fn shrink_to_b2(
        &mut self,
        engine: &Engine,
        survivors: &[usize],
        plan: TwoTierPlan,
    ) -> Result<()> {
        let mut idx: Vec<i32> = survivors.iter().map(|&s| s as i32).collect();
        idx.resize(plan.b2, *idx.first().unwrap_or(&0));
        self.lm_kv = engine.kv_resize(&self.lm_ckpt, &self.lm_kv, &idx, plan.b2)?;
        self.prm_kv = engine.kv_resize(&self.prm_ckpt, &self.prm_kv, &idx, plan.b2)?;
        self.ledger.call();
        self.ledger.call();
        let key_base = self.call_counter.wrapping_mul(0x9E3779B97F4A7C15) ^ self.cfg.seed;
        self.beams.permute(&idx, key_base);
        for (slot, beam) in self.beams.beams.iter_mut().enumerate() {
            if slot >= survivors.len() {
                beam.dead = true; // padding slots
            }
        }
        Ok(())
    }

    /// Grow b2 back to b1 with the expansion mapping folded into one
    /// resize (ER expansion after a shrunk completion phase). `order` is
    /// the surviving slots best-first.
    pub fn expand_from_b2(
        &mut self,
        engine: &Engine,
        order: &[usize],
        plan: TwoTierPlan,
    ) -> Result<()> {
        let (rel, active) = scheduler::expansion_indices(order.len(), self.cfg.m_expand, plan.b1);
        let idx: Vec<i32> = rel.iter().map(|&r| order[r as usize] as i32).collect();
        self.lm_kv = engine.kv_resize(&self.lm_ckpt, &self.lm_kv, &idx, plan.b1)?;
        self.prm_kv = engine.kv_resize(&self.prm_ckpt, &self.prm_kv, &idx, plan.b1)?;
        self.ledger.call();
        self.ledger.call();
        let key_base = self.call_counter.wrapping_mul(0x2545F4914F6CDD1D) ^ self.cfg.seed;
        self.beams.permute(&idx, key_base);
        for (slot, beam) in self.beams.beams.iter_mut().enumerate() {
            beam.dead = slot >= active;
            beam.finished = false;
        }
        Ok(())
    }

    /// Wrap up: pick the best candidate among done + pool.
    pub fn finish(mut self, problem: &Problem, t0: Instant, steps: usize) -> SolveOutcome {
        self.harvest_finished();
        let best_done = self.done.iter().max_by(|a, b| {
            crate::coordinator::policy::rankable(a.beam_reward())
                .total_cmp(&crate::coordinator::policy::rankable(b.beam_reward()))
        });
        let best = match best_done {
            Some(b) => Some(b),
            None => self.beams.best(),
        };
        let (answer, best_reward, trace) = match best {
            Some(b) => (b.answer(), b.beam_reward(), b.gen.clone()),
            None => (None, 0.0, Vec::new()),
        };
        SolveOutcome {
            answer,
            correct: answer == Some(problem.answer()),
            best_reward,
            steps_executed: steps,
            wall_s: t0.elapsed().as_secs_f64(),
            ledger: self.ledger,
            best_trace: trace,
            finished_beams: self.done.len(),
        }
    }
}

/// Stable FNV-style digest of a problem — seeds the per-request RNG and
/// (xored with config/seed state) the deterministic shadow-sampling draw
/// the adaptive-tau controller makes at admission.
pub fn hash_problem(p: &Problem) -> u64 {
    let mut h = p.v0 as u64;
    for s in &p.ops {
        h = h
            .wrapping_mul(0x100000001B3)
            .wrapping_add((s.op as u64) << 8 | s.d as u64);
    }
    h
}

/// Paper Algorithm 2: PRM-guided beam search scoring only completed steps.
pub fn solve_vanilla(
    engine: &Engine,
    lm_ckpt: &str,
    prm_ckpt: &str,
    problem: &Problem,
    cfg: &SearchConfig,
    temp: f32,
) -> Result<SolveOutcome> {
    let task = SolveTask::vanilla(problem.clone(), lm_ckpt, prm_ckpt, cfg, temp)?;
    task.run_to_completion(engine)
}
