//! Vanilla PRM-guided beam search — paper Algorithm 2 (the baseline) —
//! plus the shared per-problem search machinery both decoders use.
//!
//! Pipeline per reasoning step: every beam samples a full step (to `;` or
//! EOS), the PRM scores the completed step, the top N/M survive and are
//! expanded into M children each. The only difference in Algorithm 3
//! (`early_reject`) is the mid-step partial-reward checkpoint and the
//! two-tier batch shrink for the completion phase.

use std::time::Instant;

use crate::config::SearchConfig;
use crate::coordinator::beam::{Beam, BeamSet};
use crate::coordinator::flops::FlopsLedger;
use crate::coordinator::sampler;
use crate::coordinator::scheduler;
use crate::coordinator::scorer;
use crate::log_debug;
use crate::runtime::{Engine, KvSet};
use crate::util::error::Result;
use crate::util::rng::Rng;
use crate::workload::Problem;

/// Result of solving one problem.
#[derive(Debug, Clone)]
pub struct SolveOutcome {
    pub answer: Option<i64>,
    pub correct: bool,
    pub best_reward: f32,
    pub steps_executed: usize,
    pub wall_s: f64,
    pub ledger: FlopsLedger,
    pub best_trace: Vec<i32>,
    pub finished_beams: usize,
}

/// Per-problem search state shared by both algorithms.
pub(crate) struct SearchCtx<'a> {
    pub engine: &'a Engine,
    pub lm_ckpt: &'a str,
    pub prm_ckpt: &'a str,
    pub cfg: &'a SearchConfig,
    pub temp: f32,
    pub lm_kv: KvSet,
    pub prm_kv: KvSet,
    pub beams: BeamSet,
    pub done: Vec<Beam>,
    pub ledger: FlopsLedger,
    pub call_counter: u64,
    pub decode_block: usize,
}

/// What a decode phase is driving each beam toward.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum PhaseTarget {
    /// Stop each beam at `tau` step-tokens or its step boundary (phase A).
    Prefix { tau: usize },
    /// Run each beam to its step boundary (phase B / vanilla step).
    Boundary,
}

impl<'a> SearchCtx<'a> {
    /// Prefill both models, broadcast to the b1 variant, sample first tokens.
    pub fn init(
        engine: &'a Engine,
        lm_ckpt: &'a str,
        prm_ckpt: &'a str,
        problem: &Problem,
        cfg: &'a SearchConfig,
        temp: f32,
    ) -> Result<Self> {
        let lm_arch = engine.manifest.arch_for_checkpoint(lm_ckpt)?;
        let prm_arch = engine.manifest.arch_for_checkpoint(prm_ckpt)?;
        let mut ledger = FlopsLedger::new(lm_arch.flops_per_token, prm_arch.flops_per_token);

        let prompt = problem.prompt_tokens();
        let (logits, lm_kv1) = engine.lm_prefill(lm_ckpt, &prompt)?;
        ledger.lm_prefill(prompt.len());
        let prm_kv1 = engine.prm_prefill(prm_ckpt, &prompt)?;
        ledger.prm_prefill(prompt.len());

        let b1 = engine.manifest.batch_variant(cfg.n_beams)?;
        let lm_kv = engine.kv_broadcast(lm_ckpt, &lm_kv1, b1)?;
        let prm_kv = engine.kv_broadcast(prm_ckpt, &prm_kv1, b1)?;
        ledger.call();
        ledger.call();

        let mut rng = Rng::new(cfg.seed ^ hash_problem(problem));
        let first = sampler::sample_first_tokens(&logits, b1, temp, &mut rng);
        let beams: Vec<Beam> = first
            .iter()
            .enumerate()
            .map(|(i, &t)| {
                let mut b = Beam::new(t, rng.fork(i as u64).next_u64());
                if i >= cfg.n_beams {
                    b.dead = true; // padding slots of the batch variant
                }
                b
            })
            .collect();

        Ok(SearchCtx {
            engine,
            lm_ckpt,
            prm_ckpt,
            cfg,
            temp,
            lm_kv,
            prm_kv,
            beams: BeamSet::from_beams(beams),
            done: Vec::new(),
            ledger,
            call_counter: 0,
            decode_block: engine.manifest.decode_block,
        })
    }

    /// Is this beam still being driven by the current phase?
    fn phase_pending(&self, beam: &Beam, target: PhaseTarget) -> bool {
        if !beam.active() || beam.awaiting_finalize {
            return false;
        }
        match target {
            PhaseTarget::Prefix { tau } => {
                beam.current_step_len() < tau && beam.current_step_len() < self.cfg.max_step_tokens
            }
            PhaseTarget::Boundary => beam.current_step_len() < self.cfg.max_step_tokens,
        }
    }

    /// Run lockstep decode blocks until every beam satisfies `target`.
    /// Beams that exceed `max_step_tokens` without a boundary are killed
    /// (runaway guard). Returns false if the KV cache ran out (caller
    /// finalizes with what it has).
    pub fn decode_phase(&mut self, target: PhaseTarget) -> Result<bool> {
        loop {
            let pending: Vec<usize> = (0..self.beams.beams.len())
                .filter(|&i| self.phase_pending(&self.beams.beams[i], target))
                .collect();
            if pending.is_empty() {
                return Ok(true);
            }
            if self.lm_kv.remaining() < self.decode_block {
                log_debug!("LM KV cache exhausted; stopping decode phase");
                return Ok(false);
            }
            let b = self.lm_kv.batch;
            let prev: Vec<i32> = self.beams.beams.iter().map(|bm| bm.pending).collect();
            let keys: Vec<u64> = self.beams.beams.iter().map(|bm| bm.key).collect();
            let key_mat = sampler::decode_keys(&keys, self.call_counter);
            self.call_counter += 1;
            let old_frontier = self.lm_kv.pos_phys;
            let sampled =
                self.engine
                    .lm_decode_block(self.lm_ckpt, &mut self.lm_kv, &prev, self.temp, &key_mat)?;
            self.ledger.call();
            debug_assert_eq!(sampled.len(), b * self.decode_block);
            for &slot in &pending {
                let blk = &sampled[slot * self.decode_block..(slot + 1) * self.decode_block];
                let beam = &mut self.beams.beams[slot];
                let (fed, boundary) = beam.accept_block(blk);
                self.lm_kv.commit(slot, old_frontier, fed);
                self.ledger.lm_decode(fed);
                if boundary.is_none()
                    && beam.current_step_len() >= self.cfg.max_step_tokens
                    && matches!(target, PhaseTarget::Boundary)
                {
                    beam.dead = true; // runaway: never closed the step
                }
            }
        }
    }

    /// Drain PRM backlogs (scores for all clean tokens).
    pub fn score_catch_up(&mut self) -> Result<bool> {
        // bound: each round advances the PRM frontier by score_block
        let max_backlog = self
            .beams
            .beams
            .iter()
            .filter(|b| !b.dead)
            .map(|b| b.gen.len() - b.prm_fed)
            .max()
            .unwrap_or(0);
        let rounds = max_backlog.div_ceil(self.engine.manifest.score_block);
        if self.prm_kv.remaining() < rounds * self.engine.manifest.score_block {
            log_debug!("PRM KV cache exhausted; stopping scoring");
            return Ok(false);
        }
        scorer::catch_up(
            self.engine,
            self.prm_ckpt,
            &mut self.prm_kv,
            &mut self.beams,
            &mut self.ledger,
        )?;
        Ok(true)
    }

    /// Move finished beams out of the pool into `done`.
    pub fn harvest_finished(&mut self) {
        for beam in self.beams.beams.iter_mut() {
            if beam.finished && !beam.dead {
                self.done.push(beam.clone());
                beam.dead = true;
            }
        }
    }

    /// Expand `survivors` (slot ids, best-first) into M children each,
    /// refilling all b1 slots. Device gather + host permute, both models.
    pub fn expand(&mut self, survivors: &[usize]) -> Result<()> {
        let b1 = self.lm_kv.batch;
        let keep = survivors.len();
        // compact order: survivors first (children map onto them)
        let (rel_idx, active) = scheduler::expansion_indices(keep, self.cfg.m_expand, b1);
        let idx: Vec<i32> = rel_idx.iter().map(|&r| survivors[r as usize] as i32).collect();
        self.engine.kv_gather(self.lm_ckpt, &mut self.lm_kv, &idx)?;
        self.engine.kv_gather(self.prm_ckpt, &mut self.prm_kv, &idx)?;
        self.ledger.call();
        self.ledger.call();
        let key_base = self.call_counter.wrapping_mul(0x2545F4914F6CDD1D) ^ self.cfg.seed;
        self.beams.permute(&idx, key_base);
        for (slot, beam) in self.beams.beams.iter_mut().enumerate() {
            beam.dead = slot >= active;
            beam.finished = false; // children of unfinished survivors
        }
        Ok(())
    }

    /// Wrap up: pick the best candidate among done + pool.
    pub fn finish(mut self, problem: &Problem, t0: Instant, steps: usize) -> SolveOutcome {
        self.harvest_finished();
        let best_done = self
            .done
            .iter()
            .max_by(|a, b| a.beam_reward().partial_cmp(&b.beam_reward()).unwrap());
        let best = match best_done {
            Some(b) => Some(b),
            None => self.beams.best(),
        };
        let (answer, best_reward, trace) = match best {
            Some(b) => (b.answer(), b.beam_reward(), b.gen.clone()),
            None => (None, 0.0, Vec::new()),
        };
        SolveOutcome {
            answer,
            correct: answer == Some(problem.answer()),
            best_reward,
            steps_executed: steps,
            wall_s: t0.elapsed().as_secs_f64(),
            ledger: self.ledger,
            best_trace: trace,
            finished_beams: self.done.len(),
        }
    }
}

fn hash_problem(p: &Problem) -> u64 {
    let mut h = p.v0 as u64;
    for s in &p.ops {
        h = h
            .wrapping_mul(0x100000001B3)
            .wrapping_add((s.op as u64) << 8 | s.d as u64);
    }
    h
}

/// Paper Algorithm 2: PRM-guided beam search scoring only completed steps.
pub fn solve_vanilla(
    engine: &Engine,
    lm_ckpt: &str,
    prm_ckpt: &str,
    problem: &Problem,
    cfg: &SearchConfig,
    temp: f32,
) -> Result<SolveOutcome> {
    cfg.validate()?;
    let t0 = Instant::now();
    let mut ctx = SearchCtx::init(engine, lm_ckpt, prm_ckpt, problem, cfg, temp)?;
    let mut steps = 0;
    for _ in 0..cfg.max_steps {
        // 1. every beam samples a full step
        let ok = ctx.decode_phase(PhaseTarget::Boundary)?;
        // 2. PRM scores the completed steps
        let ok2 = ctx.score_catch_up()?;
        ctx.harvest_finished();
        if !ok || !ok2 {
            break;
        }
        steps += 1;
        // 3. rank by the new step's reward, keep top N/M
        let mut scored: Vec<(usize, f32)> = Vec::new();
        for (slot, beam) in ctx.beams.beams.iter_mut().enumerate() {
            if beam.active() && beam.awaiting_finalize {
                let r = beam.finalize_step(cfg.agg);
                scored.push((slot, r));
            }
        }
        if scored.is_empty() {
            break; // every beam finished or died
        }
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        let survivors: Vec<usize> =
            scored.iter().take(cfg.keep()).map(|&(s, _)| s).collect();
        // 4. expand survivors x M
        ctx.expand(&survivors)?;
    }
    Ok(ctx.finish(problem, t0, steps))
}
