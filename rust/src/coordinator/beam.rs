//! Beam bookkeeping: the host-side state of every candidate trajectory.
//!
//! A `Beam` owns the *clean* token sequence it has committed (junk from
//! lockstep block overshoot never enters `gen`), its per-token PRM scores,
//! step segmentation, and the pending-token discipline that keeps the
//! host view consistent with the device KV cache (see
//! `python/compile/model.py` docstring for the cache contract).

use crate::config::Aggregation;
use crate::tokenizer as tk;

/// One candidate trajectory bound to a KV slot of the same index.
#[derive(Debug, Clone)]
pub struct Beam {
    /// Clean generated tokens (solution region only, prompt excluded).
    pub gen: Vec<i32>,
    /// PRM score per `gen` token; filled as the scorer catches up.
    pub scores: Vec<f32>,
    /// Index into `gen` where the current (incomplete) step starts.
    pub step_start: usize,
    /// Aggregated reward of each completed step.
    pub step_rewards: Vec<f32>,
    /// Next token to feed the LM (sampled+accepted, KV not yet written).
    pub pending: i32,
    /// Number of `gen` tokens already fed to the PRM.
    pub prm_fed: usize,
    /// Beam finished (EOS committed).
    pub finished: bool,
    /// Beam rejected by the policy (slot reusable).
    pub dead: bool,
    /// Step boundary (`;`) committed but reward not yet aggregated; the
    /// beam must not decode until `finalize_step` closes the step.
    pub awaiting_finalize: bool,
    /// Per-beam RNG stream id (feeds the in-graph sampler keys).
    pub key: u64,
}

impl Beam {
    /// `first_token` is the first *generated* token (sampled host-side from
    /// the prefill logits): it enters `gen` immediately and is also the
    /// pending token (its KV is written by the first decode call).
    pub fn new(first_token: i32, key: u64) -> Self {
        let mut gen = Vec::with_capacity(256);
        gen.push(first_token);
        Beam {
            gen,
            scores: Vec::with_capacity(256),
            step_start: 0,
            step_rewards: Vec::new(),
            pending: first_token,
            prm_fed: 0,
            finished: false,
            dead: false,
            awaiting_finalize: false,
            key,
        }
    }

    pub fn active(&self) -> bool {
        !self.finished && !self.dead
    }

    /// Tokens of the current (possibly incomplete) step.
    pub fn current_step(&self) -> &[i32] {
        &self.gen[self.step_start..]
    }

    /// Scores of the current step's tokens that the PRM has produced so far.
    pub fn current_step_scores(&self) -> &[f32] {
        let hi = self.scores.len();
        &self.scores[self.step_start.min(hi)..hi]
    }

    /// Accept sampled tokens from a decode block: commits tokens up to and
    /// including the first boundary (`;` or EOS). Returns
    /// `(n_committed_fed, boundary)` where `n_committed_fed` is how many of
    /// the block's KV writes are clean for this slot (prev token + fed
    /// accepted samples) — the amount the caller passes to `KvSet::commit`.
    pub fn accept_block(&mut self, sampled: &[i32]) -> (usize, Option<i32>) {
        debug_assert!(self.active());
        let block = sampled.len();
        let mut boundary = None;
        let mut accepted = 0;
        for (i, &t) in sampled.iter().enumerate() {
            self.gen.push(t);
            accepted = i + 1;
            if t == tk::SEMI || t == tk::EOS {
                boundary = Some(t);
                break;
            }
        }
        // fed tokens this block: prev at +0, sampled[0..block-1] at +1..;
        // the last accepted sample's KV is unwritten iff it sits at index
        // block-1 (never fed) — it becomes the pending token.
        let last_idx = accepted - 1;
        let fed_accepted = last_idx.min(block - 1);
        match boundary {
            Some(tk::EOS) => {
                self.finished = true;
                // pending irrelevant once finished
            }
            Some(b) => {
                self.pending = b;
                self.awaiting_finalize = true;
            }
            None => {
                self.pending = *sampled.last().unwrap();
            }
        }
        (1 + fed_accepted, boundary)
    }

    /// Length of the current step in tokens.
    pub fn current_step_len(&self) -> usize {
        self.gen.len() - self.step_start
    }

    /// Partial reward of the current step after `tau` tokens: aggregation
    /// over the first `min(tau, len)` scored tokens of the step. Returns
    /// None if the scorer hasn't produced them yet.
    pub fn partial_reward(&self, tau: usize, agg: Aggregation) -> Option<f32> {
        let want = tau.min(self.current_step_len());
        if want == 0 {
            return None;
        }
        let have = self.scores.len().saturating_sub(self.step_start);
        if have < want {
            return None;
        }
        Some(aggregate(&self.scores[self.step_start..self.step_start + want], agg))
    }

    /// Close the current step: aggregate its reward from the (complete)
    /// scores and advance `step_start`. Panics if scores are missing.
    pub fn finalize_step(&mut self, agg: Aggregation) -> f32 {
        let end = self.gen.len();
        assert!(self.scores.len() >= end, "finalize_step before scorer caught up");
        let r = aggregate(&self.scores[self.step_start..end], agg);
        self.step_rewards.push(r);
        self.step_start = end;
        self.awaiting_finalize = false;
        r
    }

    /// Whole-beam quality: min over completed step rewards (the standard
    /// "verify step by step" convention), or the running aggregate if no
    /// step completed yet.
    pub fn beam_reward(&self) -> f32 {
        if self.step_rewards.is_empty() {
            if self.scores.is_empty() {
                0.5
            } else {
                aggregate(&self.scores, Aggregation::Min)
            }
        } else {
            self.step_rewards.iter().cloned().fold(f32::INFINITY, f32::min)
        }
    }

    /// The extracted final answer, if finished and well-formed.
    pub fn answer(&self) -> Option<i64> {
        tk::extract_answer(&self.gen)
    }
}

fn aggregate(scores: &[f32], agg: Aggregation) -> f32 {
    assert!(!scores.is_empty());
    match agg {
        Aggregation::Min => scores.iter().cloned().fold(f32::INFINITY, f32::min),
        Aggregation::Mean => scores.iter().sum::<f32>() / scores.len() as f32,
        Aggregation::Last => *scores.last().unwrap(),
    }
}

/// The pool of beams bound to KV slots `0..batch`.
#[derive(Debug, Clone)]
pub struct BeamSet {
    pub beams: Vec<Beam>,
}

impl BeamSet {
    pub fn from_beams(beams: Vec<Beam>) -> Self {
        BeamSet { beams }
    }

    /// Uniform first token for every slot (tests / degenerate cases).
    pub fn new(batch: usize, first_token: i32, key_base: u64) -> Self {
        BeamSet {
            beams: (0..batch)
                .map(|i| Beam::new(first_token, key_base.wrapping_add(i as u64)))
                .collect(),
        }
    }

    pub fn active_slots(&self) -> Vec<usize> {
        (0..self.beams.len()).filter(|&i| self.beams[i].active()).collect()
    }

    pub fn finished_beams(&self) -> Vec<&Beam> {
        self.beams.iter().filter(|b| b.finished && !b.dead).collect()
    }

    /// Best finished beam by reward; falls back to best unfinished.
    /// NaN rewards rank worst (and can't panic the comparator) via
    /// [`crate::coordinator::policy::rankable`].
    pub fn best(&self) -> Option<&Beam> {
        use crate::coordinator::policy::rankable;
        let fin = self
            .beams
            .iter()
            .filter(|b| b.finished && !b.dead)
            .max_by(|a, b| rankable(a.beam_reward()).total_cmp(&rankable(b.beam_reward())));
        fin.or_else(|| {
            self.beams
                .iter()
                .filter(|b| !b.dead)
                .max_by(|a, b| rankable(a.beam_reward()).total_cmp(&rankable(b.beam_reward())))
        })
    }

    /// Permute beams to match a KV gather/resize: `new[i] = old[idx[i]]`.
    pub fn permute(&mut self, idx: &[i32], key_base: u64) {
        let old = self.beams.clone();
        self.beams = idx
            .iter()
            .enumerate()
            .map(|(i, &src)| {
                let mut b = old[src as usize].clone();
                // fresh stream per slot so expanded siblings diverge
                b.key = key_base.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
                b
            })
            .collect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_beam_contains_first_token() {
        let b = Beam::new(tk::DIG0 + 5, 0);
        assert_eq!(b.gen, vec![tk::DIG0 + 5]);
        assert_eq!(b.pending, tk::DIG0 + 5);
        assert!(b.active());
    }

    #[test]
    fn accept_block_no_boundary() {
        let mut b = Beam::new(tk::DIG0, 0);
        let (fed, bd) = b.accept_block(&[tk::DIG0 + 1, tk::DIG0 + 2, tk::DIG0 + 3, tk::DIG0 + 4]);
        assert_eq!(bd, None);
        assert_eq!(b.gen.len(), 5);
        // prev + first 3 samples fed; 4th pending
        assert_eq!(fed, 4);
        assert_eq!(b.pending, tk::DIG0 + 4);
        assert!(b.active());
    }

    #[test]
    fn accept_block_semi_mid_block() {
        let mut b = Beam::new(tk::DIG0, 0);
        let (fed, bd) = b.accept_block(&[tk::DIG0 + 1, tk::SEMI, tk::DIG0 + 9, tk::DIG0 + 9]);
        assert_eq!(bd, Some(tk::SEMI));
        assert_eq!(b.gen, vec![tk::DIG0, tk::DIG0 + 1, tk::SEMI]);
        // prev + s0 written clean; ';' was fed but stays pending (re-fed)
        assert_eq!(fed, 2);
        assert_eq!(b.pending, tk::SEMI);
    }

    #[test]
    fn accept_block_semi_last_position() {
        let mut b = Beam::new(tk::DIG0, 0);
        let (fed, bd) = b.accept_block(&[tk::DIG0 + 1, tk::DIG0 + 2, tk::DIG0 + 3, tk::SEMI]);
        assert_eq!(bd, Some(tk::SEMI));
        assert_eq!(fed, 4); // prev + 3 fed samples; ';' was never fed
        assert_eq!(b.pending, tk::SEMI);
    }

    #[test]
    fn accept_block_eos_finishes() {
        let mut b = Beam::new(tk::SEMI, 0);
        let (_, bd) = b.accept_block(&[tk::ANS, tk::DIG0 + 4, tk::DIG0, tk::EOS]);
        assert_eq!(bd, Some(tk::EOS));
        assert!(b.finished);
        assert_eq!(b.answer(), Some(40));
    }

    #[test]
    fn partial_reward_waits_for_scores() {
        let mut b = Beam::new(tk::DIG0, 0);
        b.accept_block(&[tk::DIG0, tk::DIG0, tk::DIG0, tk::DIG0]);
        assert_eq!(b.partial_reward(5, Aggregation::Min), None);
        b.scores.extend([0.9, 0.8, 0.7, 0.95, 0.99]);
        assert_eq!(b.partial_reward(3, Aggregation::Min), Some(0.7));
        assert_eq!(b.partial_reward(2, Aggregation::Mean), Some(0.85));
        assert_eq!(b.partial_reward(2, Aggregation::Last), Some(0.8));
    }

    #[test]
    fn finalize_step_and_beam_reward() {
        let mut b = Beam::new(tk::DIG0, 0);
        b.accept_block(&[tk::DIG0, tk::SEMI, tk::DIG0, tk::DIG0]);
        // gen = [d, d, ';'] -> 3 tokens
        b.scores.extend([0.9, 0.8, 0.85]);
        let r = b.finalize_step(Aggregation::Min);
        assert!((r - 0.8).abs() < 1e-6);
        assert_eq!(b.step_start, 3);
        assert_eq!(b.current_step_len(), 0);
        b.accept_block(&[tk::DIG0, tk::SEMI, tk::PAD, tk::PAD]);
        b.scores.extend([0.5, 0.6]);
        b.finalize_step(Aggregation::Min);
        assert!((b.beam_reward() - 0.5).abs() < 1e-6); // min over steps
    }

    #[test]
    fn beamset_permute_copies_state() {
        let mut set = BeamSet::new(4, tk::SEP, 7);
        set.beams[2].gen = vec![tk::DIG0];
        set.beams[2].scores = vec![0.9];
        // slots 0/1 keep their fresh state: gen == [SEP]
        set.permute(&[2, 2, 0, 1], 99);
        assert_eq!(set.beams[0].gen, vec![tk::DIG0]);
        assert_eq!(set.beams[1].gen, vec![tk::DIG0]);
        assert_ne!(set.beams[0].key, set.beams[1].key); // siblings diverge
        assert_eq!(set.beams[2].gen, vec![tk::SEP]); // old slot 0's first token
    }

    #[test]
    fn best_prefers_finished() {
        let mut set = BeamSet::new(2, tk::SEP, 0);
        set.beams[0].scores = vec![0.99];
        set.beams[0].gen = vec![tk::DIG0];
        set.beams[1].finished = true;
        set.beams[1].step_rewards = vec![0.4];
        assert!(set.best().unwrap().finished);
    }
}
