//! Early Rejection beam search — paper Algorithm 3, the contribution.
//!
//! Per reasoning step:
//!   Phase A (prefix, batch b1=N): every beam decodes its first `tau`
//!     tokens; one incremental PRM pass yields the *partial rewards*.
//!   Rejection: the policy (top-N/M by partial reward, paper default)
//!     kills unpromising beams *before their steps are completed* —
//!     the compute the vanilla baseline wastes on them never happens.
//!   Phase B (completion, batch b2=N/M): survivors shrink to the smaller
//!     batch variant (two-tier batching, Sec. 3.2) and decode to their
//!     step boundary; the PRM scores the remainder and the step reward is
//!     finalized.
//!   Expansion: each survivor spawns M children, restoring width N at b1.
//!
//! Beams that complete their step before `tau` skip phase B; beams that
//! emit EOS exit the pool as finished candidates.

use std::time::Instant;

use crate::config::SearchConfig;
use crate::coordinator::policy::RejectPolicy;
use crate::coordinator::scheduler::TwoTierPlan;
use crate::coordinator::search::{PhaseTarget, SearchCtx, SolveOutcome};
use crate::runtime::Engine;
use crate::util::error::Result;
use crate::workload::Problem;

/// Paper Algorithm 3 with the top-N/M policy.
pub fn solve_early_rejection(
    engine: &Engine,
    lm_ckpt: &str,
    prm_ckpt: &str,
    problem: &Problem,
    cfg: &SearchConfig,
    temp: f32,
) -> Result<SolveOutcome> {
    let policy = RejectPolicy::TopK { keep: cfg.keep() };
    solve_early_rejection_with_policy(engine, lm_ckpt, prm_ckpt, problem, cfg, temp, policy, true)
}

/// Full-control variant: custom policy and two-tier toggle (ablations).
#[allow(clippy::too_many_arguments)]
pub fn solve_early_rejection_with_policy(
    engine: &Engine,
    lm_ckpt: &str,
    prm_ckpt: &str,
    problem: &Problem,
    cfg: &SearchConfig,
    temp: f32,
    policy: RejectPolicy,
    two_tier: bool,
) -> Result<SolveOutcome> {
    cfg.validate()?;
    let t0 = Instant::now();
    let mut ctx = SearchCtx::init(engine, lm_ckpt, prm_ckpt, problem, cfg, temp)?;
    let variants = engine.manifest.batch_variants.clone();
    let mut steps = 0;

    for _ in 0..cfg.max_steps {
        // ---- Phase A: decode tau prefix tokens for every beam
        let ok = ctx.decode_phase(PhaseTarget::Prefix { tau: cfg.tau })?;
        let ok2 = ctx.score_catch_up()?;
        ctx.harvest_finished();
        if !ok || !ok2 {
            break;
        }
        steps += 1;

        // ---- Partial rewards + early rejection
        let mut scored: Vec<(usize, f32)> = Vec::new();
        for (slot, beam) in ctx.beams.beams.iter().enumerate() {
            if beam.active() {
                if let Some(p) = beam.partial_reward(cfg.tau, cfg.agg) {
                    scored.push((slot, p));
                }
            }
        }
        if scored.is_empty() {
            break; // pool exhausted (all finished or dead)
        }
        let survivors = policy.select(&scored);
        for (slot, beam) in ctx.beams.beams.iter_mut().enumerate() {
            if beam.active() && !survivors.contains(&slot) {
                beam.dead = true; // << the early rejection
            }
        }

        // ---- Phase B: survivors complete the step (two-tier shrink)
        let plan = TwoTierPlan::plan(cfg.n_beams, survivors.len(), &variants, two_tier)?;
        if plan.shrink {
            // compact survivors into the b2 variant (both model caches)
            let mut idx: Vec<i32> = survivors.iter().map(|&s| s as i32).collect();
            idx.resize(plan.b2, *idx.first().unwrap_or(&0));
            ctx.lm_kv = engine.kv_resize(lm_ckpt, &ctx.lm_kv, &idx, plan.b2)?;
            ctx.prm_kv = engine.kv_resize(prm_ckpt, &ctx.prm_kv, &idx, plan.b2)?;
            ctx.ledger.call();
            ctx.ledger.call();
            let key_base = ctx.call_counter.wrapping_mul(0x9E3779B97F4A7C15) ^ cfg.seed;
            ctx.beams.permute(&idx, key_base);
            for (slot, beam) in ctx.beams.beams.iter_mut().enumerate() {
                if slot >= survivors.len() {
                    beam.dead = true; // padding slots
                }
            }
        }
        let ok = ctx.decode_phase(PhaseTarget::Boundary)?;
        let ok2 = ctx.score_catch_up()?;
        ctx.harvest_finished();
        if !ok || !ok2 {
            break;
        }

        // ---- Finalize step rewards for survivors
        let mut final_survivors: Vec<(usize, f32)> = Vec::new();
        for (slot, beam) in ctx.beams.beams.iter_mut().enumerate() {
            if beam.active() && beam.awaiting_finalize {
                let r = beam.finalize_step(cfg.agg);
                final_survivors.push((slot, r));
            }
        }
        if final_survivors.is_empty() {
            break;
        }
        final_survivors.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        let order: Vec<usize> = final_survivors.iter().map(|&(s, _)| s).collect();

        // ---- Expansion back to width N at b1
        if plan.shrink && ctx.lm_kv.batch != plan.b1 {
            // grow b2 -> b1 with expansion mapping in one resize
            let (rel, active) =
                crate::coordinator::scheduler::expansion_indices(order.len(), cfg.m_expand, plan.b1);
            let idx: Vec<i32> = rel.iter().map(|&r| order[r as usize] as i32).collect();
            ctx.lm_kv = engine.kv_resize(lm_ckpt, &ctx.lm_kv, &idx, plan.b1)?;
            ctx.prm_kv = engine.kv_resize(prm_ckpt, &ctx.prm_kv, &idx, plan.b1)?;
            ctx.ledger.call();
            ctx.ledger.call();
            let key_base = ctx.call_counter.wrapping_mul(0x2545F4914F6CDD1D) ^ cfg.seed;
            ctx.beams.permute(&idx, key_base);
            for (slot, beam) in ctx.beams.beams.iter_mut().enumerate() {
                beam.dead = slot >= active;
                beam.finished = false;
            }
        } else {
            ctx.expand(&order)?;
        }
    }
    Ok(ctx.finish(problem, t0, steps))
}
