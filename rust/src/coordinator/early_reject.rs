//! Early Rejection beam search — paper Algorithm 3, the contribution.
//!
//! Per reasoning step:
//!   Phase A (prefix, batch b1=N): every beam decodes its first `tau`
//!     tokens; one incremental PRM pass yields the *partial rewards*.
//!   Rejection: the policy (top-N/M by partial reward, paper default)
//!     kills unpromising beams *before their steps are completed* —
//!     the compute the vanilla baseline wastes on them never happens.
//!   Phase B (completion, batch b2=N/M): survivors shrink to the smaller
//!     batch variant (two-tier batching, Sec. 3.2) and decode to their
//!     step boundary; the PRM scores the remainder and the step reward is
//!     finalized.
//!   Expansion: each survivor spawns M children, restoring width N at b1.
//!
//! Beams that complete their step before `tau` skip phase B; beams that
//! emit EOS exit the pool as finished candidates.
//!
//! The step-by-step mechanics live in the resumable state machine
//! ([`crate::coordinator::task::SolveTask`]); these blocking entry points
//! simply drive a task to completion on one engine, which is also what
//! guarantees the fleet scheduler's interleaved path computes the exact
//! same outcome.

use crate::config::SearchConfig;
use crate::coordinator::policy::RejectPolicy;
use crate::coordinator::search::SolveOutcome;
use crate::coordinator::task::SolveTask;
use crate::runtime::Engine;
use crate::util::error::Result;
use crate::workload::Problem;

/// Paper Algorithm 3 with the top-N/M policy.
pub fn solve_early_rejection(
    engine: &Engine,
    lm_ckpt: &str,
    prm_ckpt: &str,
    problem: &Problem,
    cfg: &SearchConfig,
    temp: f32,
) -> Result<SolveOutcome> {
    let policy = RejectPolicy::TopK { keep: cfg.keep() };
    solve_early_rejection_with_policy(engine, lm_ckpt, prm_ckpt, problem, cfg, temp, policy, true)
}

/// Full-control variant: custom policy and two-tier toggle (ablations).
#[allow(clippy::too_many_arguments)]
pub fn solve_early_rejection_with_policy(
    engine: &Engine,
    lm_ckpt: &str,
    prm_ckpt: &str,
    problem: &Problem,
    cfg: &SearchConfig,
    temp: f32,
    policy: RejectPolicy,
    two_tier: bool,
) -> Result<SolveOutcome> {
    let task = SolveTask::early_rejection_with_policy(
        problem.clone(),
        lm_ckpt,
        prm_ckpt,
        cfg,
        temp,
        policy,
        two_tier,
    )?;
    task.run_to_completion(engine)
}
