//! HTTP serving front end.
//!
//! * [`http`] — minimal HTTP/1.1 server on `std::net` + the thread pool
//!   (tokio is unavailable offline); keep-alive is off (`Connection:
//!   close`) so connection handling stays one-shot per request.
//! * [`metrics`] — request counters (4xx/5xx split), latency/FLOPs
//!   histograms (`/metrics`).
//! * [`router`] — the engine shard pool: the PJRT engine is `!Send`, so
//!   each of N shard threads owns its own engine; a least-loaded
//!   dispatcher places requests onto per-shard bounded queues, rejecting
//!   with `Error::Saturated` (HTTP 503) when all are full, and a
//!   seed-stable LRU solve cache short-circuits repeated requests. With
//!   `--fleet`, shard threads run the continuous scheduler in
//!   [`crate::fleet`] instead of one-request-at-a-time dispatch:
//!   `max_inflight` resumable solves interleave per shard, freed slots
//!   backfill from the queue, duplicates coalesce, deadlines abort.
//! * [`supervisor`] — the pool's fault-tolerance primitives: per-shard
//!   slot state (generation counter, heartbeat, health byte, swappable
//!   mailbox), the custody word that lets the dispatcher follow a job
//!   across a shard respawn, and the deadline-aware retry backoff math.
//! * [`lifecycle`] — process drain state shared by the serve loop and
//!   the handler: SIGTERM or `POST /admin/drain` flips it, admission
//!   returns 503 + Retry-After, and the serve loop exits once in-flight
//!   work finishes (or the drain deadline expires).
//! * [`handler`] — the shared `/solve` / `/healthz` / `/readyz` /
//!   `/metrics` / `/admin/drain` routing and error→status mapping used
//!   by `erprm serve` and the examples.
//! * [`api`] — request/response JSON schema for `/solve`, including the
//!   `deadline_ms`/`priority` scheduling envelope and the
//!   `queue_wait_ms` telemetry field.

pub mod api;
pub mod handler;
pub mod http;
pub mod lifecycle;
pub mod metrics;
pub mod router;
pub mod supervisor;

pub use handler::{error_response, route};
pub use lifecycle::Lifecycle;
pub use router::{EnginePool, PoolOptions};
pub use supervisor::{RetryOptions, SuperviseOptions};
