//! HTTP serving front end.
//!
//! * [`http`] — minimal HTTP/1.1 server on `std::net` + the thread pool
//!   (tokio is unavailable offline); keep-alive is off (`Connection:
//!   close`) so connection handling stays one-shot per request.
//! * [`metrics`] — request counters (4xx/5xx split), latency/FLOPs
//!   histograms (`/metrics`).
//! * [`router`] — the engine shard pool: the PJRT engine is `!Send`, so
//!   each of N shard threads owns its own engine; a least-loaded
//!   dispatcher places requests onto per-shard bounded queues, rejecting
//!   with `Error::Saturated` (HTTP 503) when all are full, and a
//!   seed-stable LRU solve cache short-circuits repeated requests.
//! * [`handler`] — the shared `/solve` / `/healthz` / `/metrics` routing
//!   and error→status mapping used by `erprm serve` and the examples.
//! * [`api`] — request/response JSON schema for `/solve`.

pub mod api;
pub mod handler;
pub mod http;
pub mod metrics;
pub mod router;

pub use handler::{error_response, route};
pub use router::EnginePool;
