//! HTTP serving front end.
//!
//! * [`http`] — minimal HTTP/1.1 server on `std::net` + the thread pool
//!   (tokio is unavailable offline).
//! * [`metrics`] — request counters and latency histograms (`/metrics`).
//! * [`router`] — the engine actor: the PJRT engine is `!Send`, so one
//!   dedicated thread owns it and serves solve requests from a channel;
//!   the router also implements per-model-combo queues and batching of
//!   queued requests into the engine thread.
//! * [`api`] — request/response JSON schema for `/solve`, `/healthz`,
//!   `/metrics`.

pub mod api;
pub mod http;
pub mod metrics;
pub mod router;
