//! Shard supervision primitives: slot state, health, custody, backoff.
//!
//! The router's shard threads are now *supervised*: each thread's drive
//! loop heartbeats into a [`ShardSlot`], panics are caught and flagged,
//! and a pool supervisor thread (in [`crate::server::router`]) respawns
//! failed shards with a fresh `Engine`. This module holds the pieces
//! that are generic over the message type so they can be unit-tested
//! without a pool:
//!
//! * [`ShardSlot`] — the supervisor-visible state of one shard:
//!   generation counter, heartbeat, health, panic flag, restart count,
//!   the persistent chaos tick counter, and the swappable [`Mailbox`].
//! * **Custody** — a packed `(shard, generation)` word each dispatched
//!   job carries in an `Arc<AtomicU64>`. The dispatcher polls it while
//!   waiting: if the owning generation retired and the supervisor did
//!   *not* move the job elsewhere (requeue updates custody first, so a
//!   double read disambiguates), the job is lost and the dispatcher
//!   returns the retryable `Error::ShardLost`.
//! * [`RetryOptions`] / [`backoff_delay`] — the router's transparent
//!   retry policy: capped exponential backoff with seeded jitter that
//!   never sleeps past the request's remaining deadline budget.
//! * [`SuperviseOptions`] — detection cadence and staleness thresholds.
//!
//! Generations are the linchpin: a wedged thread cannot be killed, so
//! the supervisor *retires* it by bumping the slot generation and
//! spawning a replacement. The zombie's heartbeats are generation-gated
//! no-ops, its drive loop exits at its next retirement check, and its
//! late replies bounce off abandoned channels — determinism is never at
//! risk because a retried solve is a fresh deterministic solve.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::sync::{lock_unpoisoned, Mailbox};

/// Shard health states (stored in an `AtomicU8` on the slot).
pub(crate) const HEALTH_STARTING: u8 = 0;
pub(crate) const HEALTH_HEALTHY: u8 = 1;
pub(crate) const HEALTH_DEAD: u8 = 2;

/// Human-readable health name for `/healthz`.
pub(crate) fn health_name(h: u8) -> &'static str {
    match h {
        HEALTH_HEALTHY => "healthy",
        HEALTH_DEAD => "dead",
        _ => "starting",
    }
}

const GEN_BITS: u32 = 48;
const GEN_MASK: u64 = (1 << GEN_BITS) - 1;

/// Pack a job's custody word: shard index in the high 16 bits,
/// generation in the low 48.
pub(crate) fn pack_custody(idx: usize, generation: u64) -> u64 {
    ((idx as u64) << GEN_BITS) | (generation & GEN_MASK)
}

/// Unpack a custody word into `(shard index, generation)`.
pub(crate) fn unpack_custody(c: u64) -> (usize, u64) {
    ((c >> GEN_BITS) as usize, c & GEN_MASK)
}

/// Supervisor-visible state of one shard, shared (via `Arc`) between the
/// shard thread, the dispatcher, the supervisor, and `/metrics`. The
/// mailbox is behind a mutex because recovery *swaps* it: the zombie
/// keeps draining the old (closed) one while new traffic lands on the
/// replacement.
pub(crate) struct ShardSlot<M> {
    pub idx: usize,
    epoch: Instant,
    generation: AtomicU64,
    /// Epoch-relative ms of the last heartbeat from the current
    /// generation's thread.
    beat_ms: AtomicU64,
    health: AtomicU8,
    /// Set by the thread wrapper when `catch_unwind` catches a panic
    /// from the current generation; consumed by the supervisor.
    panicked: AtomicBool,
    restarts: AtomicU64,
    /// Persistent chaos tick counter: survives respawns so the
    /// deterministic injection schedule continues instead of replaying
    /// tick 0 (which would crash-loop a `panic_per_tick=1` shard
    /// forever).
    ticks: AtomicU64,
    mailbox: Mutex<Arc<Mailbox<M>>>,
}

impl<M> ShardSlot<M> {
    pub fn new(idx: usize) -> Self {
        ShardSlot {
            idx,
            epoch: Instant::now(),
            generation: AtomicU64::new(0),
            beat_ms: AtomicU64::new(0),
            health: AtomicU8::new(HEALTH_STARTING),
            panicked: AtomicBool::new(false),
            restarts: AtomicU64::new(0),
            ticks: AtomicU64::new(0),
            mailbox: Mutex::new(Arc::new(Mailbox::new())),
        }
    }

    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::SeqCst)
    }

    /// Retire the current generation (recovery). Returns the new one.
    pub fn bump_generation(&self) -> u64 {
        self.generation.fetch_add(1, Ordering::SeqCst) + 1
    }

    /// Record a heartbeat — a no-op unless `generation` is still
    /// current, so a retired zombie cannot make its replacement look
    /// alive (or mask the replacement's own wedge).
    pub fn beat(&self, generation: u64) {
        if self.generation() == generation {
            self.beat_ms.store(self.now_ms(), Ordering::Relaxed);
        }
    }

    /// Milliseconds since the last heartbeat (the wedge signal).
    pub fn beat_age_ms(&self) -> u64 {
        self.now_ms().saturating_sub(self.beat_ms.load(Ordering::Relaxed))
    }

    pub fn health(&self) -> u8 {
        self.health.load(Ordering::SeqCst)
    }

    pub fn set_health(&self, h: u8) {
        self.health.store(h, Ordering::SeqCst);
    }

    /// The shard body finished loading its engine: mark serving (and
    /// fresh) if this generation is still current.
    pub fn mark_ready(&self, generation: u64) {
        if self.generation() == generation {
            self.beat_ms.store(self.now_ms(), Ordering::Relaxed);
            self.health.store(HEALTH_HEALTHY, Ordering::SeqCst);
        }
    }

    /// A respawned body failed to load its engine: the shard is
    /// permanently dead (generation-gated like every zombie write).
    pub fn mark_dead(&self, generation: u64) {
        if self.generation() == generation {
            self.health.store(HEALTH_DEAD, Ordering::SeqCst);
        }
    }

    /// Flag a caught panic from `generation`'s thread.
    pub fn note_panic(&self, generation: u64) {
        if self.generation() == generation {
            self.panicked.store(true, Ordering::SeqCst);
        }
    }

    /// Consume the panic flag (supervisor detection).
    pub fn take_panicked(&self) -> bool {
        self.panicked.swap(false, Ordering::SeqCst)
    }

    pub fn restarts(&self) -> u64 {
        self.restarts.load(Ordering::Relaxed)
    }

    pub fn record_restart(&self) {
        self.restarts.fetch_add(1, Ordering::Relaxed);
    }

    /// Next chaos tick (monotonic across respawns).
    pub fn next_tick(&self) -> u64 {
        self.ticks.fetch_add(1, Ordering::Relaxed)
    }

    /// The mailbox new work should land on.
    pub fn mailbox(&self) -> Arc<Mailbox<M>> {
        Arc::clone(&lock_unpoisoned(&self.mailbox))
    }

    /// Swap in a fresh mailbox (recovery), returning the old one for
    /// draining. The old one should be closed first thing so a dispatch
    /// that cloned it just before the swap fails its push (and retries
    /// on another shard) instead of stranding a job.
    pub fn replace_mailbox(&self, fresh: Arc<Mailbox<M>>) -> Arc<Mailbox<M>> {
        std::mem::replace(&mut *lock_unpoisoned(&self.mailbox), fresh)
    }
}

/// Router-level transparent retry policy (`--retry-*` knobs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RetryOptions {
    /// Total dispatch attempts per request (1 = no retries).
    pub max_attempts: u32,
    /// Base backoff before the first retry, in ms; doubles per attempt.
    pub base_ms: u64,
    /// Backoff ceiling in ms.
    pub cap_ms: u64,
    /// Also retry `Error::Saturated` bounces (off by default: saturation
    /// is load, and blind retries feed the spiral; shard loss is a
    /// transient hole the supervisor is already filling).
    pub retry_saturated: bool,
}

impl Default for RetryOptions {
    fn default() -> Self {
        RetryOptions { max_attempts: 3, base_ms: 25, cap_ms: 1000, retry_saturated: false }
    }
}

/// Supervisor knobs (`--supervise-*`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SuperviseOptions {
    /// Run the supervisor thread at all.
    pub enabled: bool,
    /// Detection poll cadence in ms.
    pub interval_ms: u64,
    /// A shard with reserved work whose heartbeat is older than this is
    /// declared wedged and retired. Generous by default: a heavily
    /// loaded scheduler round must never look like a wedge.
    pub stale_ms: u64,
    /// Base delay between consecutive restarts of the same shard
    /// (doubles per consecutive failure, capped at ~30x) so a shard
    /// that dies on arrival cannot hot-loop respawns.
    pub restart_backoff_ms: u64,
}

impl Default for SuperviseOptions {
    fn default() -> Self {
        SuperviseOptions {
            enabled: true,
            interval_ms: 50,
            stale_ms: 10_000,
            restart_backoff_ms: 100,
        }
    }
}

impl SuperviseOptions {
    /// Delay before the next permitted restart after `consecutive`
    /// back-to-back failures.
    pub fn restart_delay(&self, consecutive: u32) -> Duration {
        let factor = 1u64 << consecutive.min(5);
        Duration::from_millis(self.restart_backoff_ms.saturating_mul(factor).min(30_000))
    }
}

/// Backoff before retry number `attempt` (1-based: the delay after the
/// `attempt`-th failed dispatch). Returns `None` when the request must
/// not retry: attempts exhausted, or the delay would not fit inside
/// `remaining` (the deadline budget left) — sleeping past the deadline
/// only converts a retryable 503 into a guaranteed 504.
///
/// The delay is `base * 2^(attempt-1)` capped at `cap`, then jittered
/// into `[delay/2, delay]` by `draw` (a seed-stable hash of the request
/// identity and attempt, so coalesced duplicates don't thundering-herd
/// the recovering pool in lockstep — yet reruns of the same workload
/// back off identically, preserving the chaos suite's determinism).
pub fn backoff_delay(
    opts: &RetryOptions,
    attempt: u32,
    draw: u64,
    remaining: Option<Duration>,
) -> Option<Duration> {
    if attempt >= opts.max_attempts {
        return None;
    }
    let exp = opts
        .base_ms
        .saturating_mul(1u64 << (attempt.saturating_sub(1)).min(16))
        .min(opts.cap_ms.max(opts.base_ms));
    let half = exp / 2;
    let delay = Duration::from_millis(half + if half > 0 { draw % (half + 1) } else { 0 });
    match remaining {
        Some(rem) if delay >= rem => None,
        _ => Some(delay),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn custody_round_trips() {
        for (idx, generation) in [(0usize, 0u64), (3, 17), (65_535, GEN_MASK)] {
            let c = pack_custody(idx, generation);
            assert_eq!(unpack_custody(c), (idx, generation));
        }
    }

    #[test]
    fn heartbeats_are_generation_gated() {
        let slot: ShardSlot<u8> = ShardSlot::new(0);
        let g0 = slot.generation();
        std::thread::sleep(Duration::from_millis(15));
        assert!(slot.beat_age_ms() >= 15);
        slot.beat(g0);
        assert!(slot.beat_age_ms() < 15, "current-generation beat lands");
        let g1 = slot.bump_generation();
        std::thread::sleep(Duration::from_millis(15));
        slot.beat(g0); // zombie beat: must not mask the replacement
        assert!(slot.beat_age_ms() >= 15, "retired-generation beat is a no-op");
        slot.beat(g1);
        assert!(slot.beat_age_ms() < 15);
    }

    #[test]
    fn zombie_writes_are_gated_but_current_ones_land() {
        let slot: ShardSlot<u8> = ShardSlot::new(0);
        let g0 = slot.generation();
        let g1 = slot.bump_generation();
        slot.note_panic(g0);
        assert!(!slot.take_panicked(), "zombie panic flag is a no-op");
        slot.mark_ready(g0);
        assert_eq!(slot.health(), HEALTH_STARTING, "zombie ready is a no-op");
        slot.mark_dead(g0);
        assert_eq!(slot.health(), HEALTH_STARTING, "zombie death is a no-op");
        slot.mark_ready(g1);
        assert_eq!(slot.health(), HEALTH_HEALTHY);
        slot.note_panic(g1);
        assert!(slot.take_panicked());
        assert!(!slot.take_panicked(), "flag consumed once");
    }

    #[test]
    fn mailbox_swap_closes_over_to_the_fresh_one() {
        let slot: ShardSlot<u32> = ShardSlot::new(0);
        let old = slot.mailbox();
        old.push(1).unwrap();
        let fresh = Arc::new(Mailbox::new());
        let swapped = slot.replace_mailbox(Arc::clone(&fresh));
        swapped.close();
        assert_eq!(swapped.drain(), vec![1], "queued work recoverable from the old mailbox");
        assert!(swapped.push(2).is_err(), "stale handle pushes fail after close");
        slot.mailbox().push(3).unwrap();
        assert_eq!(fresh.len(), 1, "new work lands on the replacement");
    }

    #[test]
    fn backoff_grows_doubles_and_caps() {
        let opts =
            RetryOptions { max_attempts: 10, base_ms: 100, cap_ms: 400, retry_saturated: false };
        // draw=0 pins jitter to the low edge (delay/2), making growth visible
        let d = |attempt| backoff_delay(&opts, attempt, 0, None).unwrap().as_millis() as u64;
        assert_eq!(d(1), 50);
        assert_eq!(d(2), 100);
        assert_eq!(d(3), 200);
        assert_eq!(d(4), 200, "capped at cap_ms/2 on the low edge");
        // jitter stays within [delay/2, delay]
        for draw in [1u64, 7, 99, u64::MAX] {
            let ms = backoff_delay(&opts, 1, draw, None).unwrap().as_millis() as u64;
            assert!((50..=100).contains(&ms), "{ms}");
        }
    }

    #[test]
    fn backoff_is_deterministic_in_the_draw() {
        let opts = RetryOptions::default();
        for attempt in 1..3 {
            for draw in [0u64, 42, 1 << 60] {
                assert_eq!(
                    backoff_delay(&opts, attempt, draw, None),
                    backoff_delay(&opts, attempt, draw, None),
                );
            }
        }
    }

    #[test]
    fn backoff_never_retries_past_the_deadline_budget() {
        let opts =
            RetryOptions { max_attempts: 5, base_ms: 100, cap_ms: 1000, retry_saturated: false };
        // plenty of budget: retry allowed
        assert!(backoff_delay(&opts, 1, 0, Some(Duration::from_secs(10))).is_some());
        // the minimum possible delay (draw=0 -> 50ms) exceeds what's left
        assert_eq!(backoff_delay(&opts, 1, 0, Some(Duration::from_millis(50))), None);
        assert_eq!(backoff_delay(&opts, 1, 0, Some(Duration::ZERO)), None, "budget spent");
        // whatever fits must leave the sleep strictly inside the budget
        for draw in [0u64, 3, 1 << 40, u64::MAX] {
            for rem_ms in [1u64, 60, 75, 101, 500] {
                let rem = Duration::from_millis(rem_ms);
                if let Some(d) = backoff_delay(&opts, 1, draw, Some(rem)) {
                    assert!(d < rem, "sleep {d:?} must fit inside {rem:?}");
                }
            }
        }
        // unbounded requests always may retry (within attempts)
        assert!(backoff_delay(&opts, 4, 9, None).is_some());
    }

    #[test]
    fn backoff_exhausts_attempts() {
        let opts = RetryOptions::default(); // max_attempts 3
        assert!(backoff_delay(&opts, 1, 0, None).is_some());
        assert!(backoff_delay(&opts, 2, 0, None).is_some());
        assert_eq!(backoff_delay(&opts, 3, 0, None), None, "third failure is final");
        let once = RetryOptions { max_attempts: 1, ..RetryOptions::default() };
        assert_eq!(backoff_delay(&once, 1, 0, None), None, "max_attempts=1 never retries");
    }

    #[test]
    fn restart_delay_backs_off_and_saturates() {
        let opts = SuperviseOptions::default();
        assert_eq!(opts.restart_delay(0), Duration::from_millis(100));
        assert_eq!(opts.restart_delay(1), Duration::from_millis(200));
        assert_eq!(opts.restart_delay(5), Duration::from_millis(3200));
        assert_eq!(opts.restart_delay(50), Duration::from_millis(3200), "factor saturates");
    }
}
