//! Graceful drain state for the serving process.
//!
//! Two events start a drain: SIGTERM (orchestrators' stop signal) and
//! `POST /admin/drain` (operator-initiated). Once draining, the handler
//! refuses new `/solve` admissions with 503 + `Retry-After` (load
//! balancers route around the instance), `/readyz` flips to 503 so the
//! instance falls out of rotation, and the serve loop in `erprm serve`
//! waits for in-flight work to finish — bounded by
//! `--drain-deadline-ms` — before shutting the pool down and exiting.
//!
//! Separation of concerns: the SIGTERM latch is a process-global
//! `AtomicBool` because a signal handler may only do async-signal-safe
//! work (a relaxed store qualifies; taking locks or allocating does
//! not). [`Lifecycle`] itself is plain shared state with no global
//! reach — the serve loop bridges the latch into it by polling
//! [`term_requested`] and calling [`Lifecycle::drain`], which keeps
//! every other consumer (handlers, tests) free of hidden global
//! coupling.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Process-global SIGTERM latch; written only by the signal handler.
static TERM: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn on_term(_signum: i32) {
    TERM.store(true, Ordering::Relaxed);
}

/// Install the SIGTERM handler (idempotent). Returns `false` if the
/// registration was rejected by the OS.
///
/// The crate builds without libc, so the one symbol needed is declared
/// directly; `signal(2)` returns the previous disposition, or `SIG_ERR`
/// (`-1` as a pointer) on failure.
#[cfg(unix)]
pub fn install_sigterm() -> bool {
    const SIGTERM: i32 = 15;
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    unsafe { signal(SIGTERM, on_term) != usize::MAX }
}

#[cfg(not(unix))]
pub fn install_sigterm() -> bool {
    false
}

/// True once SIGTERM has been delivered (after [`install_sigterm`]).
pub fn term_requested() -> bool {
    TERM.load(Ordering::Relaxed)
}

#[cfg(test)]
pub(crate) fn reset_term_for_tests() {
    TERM.store(false, Ordering::Relaxed);
}

/// Shared drain flag for one serving process: cheap to clone into the
/// handler closure, polled by the serve loop.
#[derive(Debug, Clone, Default)]
pub struct Lifecycle {
    draining: Arc<AtomicBool>,
}

impl Lifecycle {
    pub fn new() -> Lifecycle {
        Lifecycle::default()
    }

    /// Enter the draining state (one-way; there is no un-drain).
    pub fn drain(&self) {
        self.draining.store(true, Ordering::Relaxed);
    }

    /// True once a drain has been requested via [`Lifecycle::drain`] —
    /// the serve loop calls that for SIGTERM too, so handlers only ever
    /// consult this flag.
    pub fn draining(&self) -> bool {
        self.draining.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drain_is_one_way_and_shared_across_clones() {
        let life = Lifecycle::new();
        let seen_by_handler = life.clone();
        assert!(!life.draining());
        assert!(!seen_by_handler.draining());
        seen_by_handler.drain();
        assert!(life.draining(), "clones share the flag");
        assert!(seen_by_handler.draining());
    }

    #[test]
    #[cfg(unix)]
    fn sigterm_latch_round_trips() {
        assert!(install_sigterm(), "registration must succeed on unix");
        assert!(!term_requested());
        // invoke the handler directly: same code path as delivery,
        // without racing other tests via a real raise(2)
        on_term(15);
        assert!(term_requested());
        reset_term_for_tests();
        assert!(!term_requested());
    }
}
