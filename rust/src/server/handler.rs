//! Shared request routing for the serving endpoints.
//!
//! One `route` function drives both `erprm serve` and the serving
//! examples, so the status-code contract is tested in one place:
//!
//! * parse/validation failures -> **400** (client mistake, don't retry)
//! * pool saturation ([`crate::util::error::Error::Saturated`]) -> **503**
//!   with `Retry-After` (server transient, retry later)
//! * deadline expiry ([`crate::util::error::Error::Deadline`]) -> **504**
//!   (the request's own budget elapsed; retrying with the same budget
//!   will likely 504 again, so no `Retry-After` hint)
//! * runtime faults (I/O, XLA) -> **500**

use std::time::Instant;

use crate::config::SearchConfig;
use crate::server::api;
use crate::server::http;
use crate::server::metrics::Metrics;
use crate::server::router::EnginePool;
use crate::util::error::Error;

/// Render an error with the status from [`Error::http_status`]; 503s
/// carry a `Retry-After` hint so well-behaved clients back off.
pub fn error_response(e: &Error) -> http::Response {
    let status = e.http_status();
    let resp = http::Response::json(status, format!("{{\"error\":\"{e}\"}}"));
    if status == 503 {
        resp.with_header("Retry-After", "1")
    } else {
        resp
    }
}

/// Route one HTTP request against the shard pool.
pub fn route(
    pool: &EnginePool,
    metrics: &Metrics,
    defaults: &SearchConfig,
    req: http::Request,
) -> http::Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => http::Response::json(200, "{\"ok\":true}".into()),
        ("GET", "/metrics") => {
            let mut text = metrics.render();
            text.push_str(&pool.render_metrics());
            http::Response::text(200, &text)
        }
        ("POST", "/solve") => {
            let t0 = Instant::now();
            let parsed = match api::parse_solve(&req.body, defaults) {
                Ok(p) => p,
                Err(e) => {
                    metrics.record_error(e.http_status());
                    return error_response(&e);
                }
            };
            match pool.solve_timed(parsed.clone(), defaults.clone()) {
                Ok(s) => {
                    metrics.record_ok(
                        t0.elapsed().as_secs_f64() * 1000.0,
                        s.queue_wait_ms,
                        s.outcome.ledger.total_flops(),
                        s.outcome.correct,
                    );
                    http::Response::json(
                        200,
                        api::render_solve(&parsed, &s.outcome, s.queue_wait_ms),
                    )
                }
                Err(e) => {
                    metrics.record_error(e.http_status());
                    error_response(&e)
                }
            }
        }
        _ => http::Response::json(404, "{\"error\":\"not found\"}".into()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saturated_renders_503_with_retry_after() {
        let r = error_response(&Error::saturated("all queues full"));
        assert_eq!(r.status, 503);
        assert!(r.headers.iter().any(|(k, _)| *k == "Retry-After"));
        assert!(String::from_utf8(r.body).unwrap().contains("saturated"));
    }

    #[test]
    fn parse_errors_render_400_without_retry_after() {
        let r = error_response(&Error::parse("bad json"));
        assert_eq!(r.status, 400);
        assert!(r.headers.is_empty());
    }

    #[test]
    fn deadline_renders_504_without_retry_after() {
        let r = error_response(&Error::deadline("budget was 100ms"));
        assert_eq!(r.status, 504);
        assert!(r.headers.is_empty(), "504 is not a back-off-and-retry signal");
        assert!(String::from_utf8(r.body).unwrap().contains("deadline"));
    }
}
