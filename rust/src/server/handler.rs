//! Shared request routing for the serving endpoints.
//!
//! One `route` function drives both `erprm serve` and the serving
//! examples, so the status-code contract is tested in one place:
//!
//! * parse/validation failures -> **400** (client mistake, don't retry)
//! * pool saturation ([`crate::util::error::Error::Saturated`]) -> **503**
//!   with `Retry-After` (server transient, retry later)
//! * deadline expiry ([`crate::util::error::Error::Deadline`]) -> **504**
//!   (the request's own budget elapsed; retrying with the same budget
//!   will likely 504 again, so no `Retry-After` hint)
//! * runtime faults (I/O, XLA) -> **500**
//!
//! Every `/solve` request is keyed by a request id — the client's
//! `X-Request-Id` header or `request_id` body field when usable, a
//! minted id otherwise — echoed back as an `X-Request-Id` response
//! header and in the response body, and usable against the trace
//! endpoints: `GET /trace/<id>` (full lifecycle document),
//! `GET /traces` (recent summaries), `GET /traces/chrome` (Chrome
//! `trace_event` timeline for Perfetto).

use std::time::Instant;

use crate::config::SearchConfig;
use crate::obs::{self, PhaseFlops, TraceBuilder};
use crate::server::api;
use crate::server::http;
use crate::server::metrics::Metrics;
use crate::server::router::EnginePool;
use crate::util::error::Error;
use crate::util::json::Json;

/// Render an error with the status from [`Error::http_status`]; 503s
/// carry a `Retry-After` hint so well-behaved clients back off.
pub fn error_response(e: &Error) -> http::Response {
    let status = e.http_status();
    let resp = http::Response::json(status, format!("{{\"error\":\"{e}\"}}"));
    if status == 503 {
        resp.with_header("Retry-After", "1")
    } else {
        resp
    }
}

/// Route one HTTP request against the shard pool.
pub fn route(
    pool: &EnginePool,
    metrics: &Metrics,
    defaults: &SearchConfig,
    req: http::Request,
) -> http::Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => http::Response::json(200, "{\"ok\":true}".into()),
        ("GET", "/metrics") => {
            let mut text = metrics.render();
            text.push_str(&pool.render_metrics());
            http::Response::text(200, &text)
        }
        ("GET", "/traces") => {
            let items: Vec<Json> =
                pool.tracer().recent(100).iter().map(|t| t.summary()).collect();
            http::Response::json(200, Json::obj(vec![("traces", Json::Arr(items))]).to_string())
        }
        ("GET", "/traces/chrome") => {
            http::Response::json(200, obs::chrome_trace(&pool.tracer().all()).to_string())
        }
        ("GET", "/calibration") => http::Response::json(200, pool.calibration_json()),
        ("GET", p) if p.starts_with("/trace/") => {
            let id = &p["/trace/".len()..];
            match pool.tracer().get(id) {
                Some(t) => http::Response::json(200, t.to_json().to_string()),
                None => http::Response::json(
                    404,
                    "{\"error\":\"no trace retained for that id\"}".into(),
                ),
            }
        }
        ("POST", "/solve") => {
            let t0 = Instant::now();
            // id precedence: X-Request-Id header > body request_id field
            // > minted at the door
            let header_rid =
                req.request_id.as_deref().and_then(obs::sanitize_request_id);
            let mut parsed = match api::parse_solve(&req.body, defaults) {
                Ok(p) => p,
                Err(e) => {
                    // even a parse reject leaves a (failure, hence
                    // always-retained) trace under the client's id
                    let rid = header_rid.unwrap_or_else(obs::mint_request_id);
                    let tb = TraceBuilder::start(rid);
                    pool.tracer()
                        .submit(tb.finish("error", e.http_status(), PhaseFlops::default()));
                    metrics.record_error(e.http_status());
                    return error_response(&e);
                }
            };
            if let Some(rid) = header_rid {
                parsed.request_id = rid;
            } else if parsed.request_id.is_empty() {
                parsed.request_id = obs::mint_request_id();
            }
            let rid = parsed.request_id.clone();
            match pool.solve_timed(parsed.clone(), defaults.clone()) {
                Ok(s) => {
                    metrics.record_ok(
                        t0.elapsed().as_secs_f64() * 1000.0,
                        s.queue_wait_ms,
                        s.outcome.ledger.total_flops(),
                        s.outcome.correct,
                    );
                    http::Response::json(
                        200,
                        api::render_solve(&parsed, &s.outcome, s.queue_wait_ms),
                    )
                    .with_header("X-Request-Id", rid)
                }
                Err(e) => {
                    metrics.record_error(e.http_status());
                    error_response(&e).with_header("X-Request-Id", rid)
                }
            }
        }
        _ => http::Response::json(404, "{\"error\":\"not found\"}".into()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saturated_renders_503_with_retry_after() {
        let r = error_response(&Error::saturated("all queues full"));
        assert_eq!(r.status, 503);
        assert!(r.headers.iter().any(|(k, _)| *k == "Retry-After"));
        assert!(String::from_utf8(r.body).unwrap().contains("saturated"));
    }

    #[test]
    fn parse_errors_render_400_without_retry_after() {
        let r = error_response(&Error::parse("bad json"));
        assert_eq!(r.status, 400);
        assert!(r.headers.is_empty());
    }

    #[test]
    fn deadline_renders_504_without_retry_after() {
        let r = error_response(&Error::deadline("budget was 100ms"));
        assert_eq!(r.status, 504);
        assert!(r.headers.is_empty(), "504 is not a back-off-and-retry signal");
        assert!(String::from_utf8(r.body).unwrap().contains("deadline"));
    }
}
