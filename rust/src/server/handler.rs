//! Shared request routing for the serving endpoints.
//!
//! One `route` function drives both `erprm serve` and the serving
//! examples, so the status-code contract is tested in one place:
//!
//! * parse/validation failures -> **400** (client mistake, don't retry)
//! * pool saturation ([`crate::util::error::Error::Saturated`]) and
//!   unrecovered shard loss
//!   ([`crate::util::error::Error::ShardLost`]) -> **503** with
//!   `Retry-After` (server transient, retry later)
//! * client gone mid-solve ([`crate::util::error::Error::Hangup`]) ->
//!   **499** (nobody is listening; logged and counted, never retried)
//! * deadline expiry ([`crate::util::error::Error::Deadline`]) -> **504**
//!   (the request's own budget elapsed; retrying with the same budget
//!   will likely 504 again, so no `Retry-After` hint)
//! * runtime faults (I/O, XLA) -> **500**
//!
//! Lifecycle endpoints: `GET /healthz` is process liveness plus
//! per-shard supervisor state (health string and restart count per
//! shard); `GET /readyz` is rotation readiness — 503 while draining or
//! while no shard is serving; `POST /admin/drain` starts a graceful
//! drain (new `/solve` work is refused with 503 + `Retry-After`, the
//! serve loop finishes in-flight work and exits).
//!
//! Every `/solve` request is keyed by a request id — the client's
//! `X-Request-Id` header or `request_id` body field when usable, a
//! minted id otherwise — echoed back as an `X-Request-Id` response
//! header and in the response body, and usable against the trace
//! endpoints: `GET /trace/<id>` (full lifecycle document),
//! `GET /traces` (recent summaries), `GET /traces/chrome` (Chrome
//! `trace_event` timeline for Perfetto).

use std::time::Instant;

use crate::config::SearchConfig;
use crate::obs::{self, PhaseFlops, TraceBuilder};
use crate::server::api;
use crate::server::http;
use crate::server::lifecycle::Lifecycle;
use crate::server::metrics::Metrics;
use crate::server::router::EnginePool;
use crate::util::error::Error;
use crate::util::json::Json;

/// Render an error with the status from [`Error::http_status`]; 503s
/// carry a `Retry-After` hint so well-behaved clients back off.
pub fn error_response(e: &Error) -> http::Response {
    let status = e.http_status();
    let resp = http::Response::json(status, format!("{{\"error\":\"{e}\"}}"));
    if status == 503 {
        resp.with_header("Retry-After", "1")
    } else {
        resp
    }
}

/// 503 for a drain refusal — the same shape load balancers already
/// handle for saturation.
fn draining_response() -> http::Response {
    http::Response::json(503, "{\"error\":\"draining: not accepting new work\"}".into())
        .with_header("Retry-After", "1")
}

/// Route one HTTP request against the shard pool.
pub fn route(
    pool: &EnginePool,
    metrics: &Metrics,
    defaults: &SearchConfig,
    life: &Lifecycle,
    req: http::Request,
) -> http::Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            // liveness + supervisor detail: the process answers even
            // when every shard is down (that is what /readyz is for)
            let shards: Vec<Json> = pool.shard_health().into_iter().map(Json::str).collect();
            let restarts: Vec<Json> =
                pool.shard_restarts().into_iter().map(|n| Json::num(n as f64)).collect();
            let body = Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("serving", Json::Bool(pool.any_serving())),
                ("draining", Json::Bool(life.draining())),
                ("shards", Json::Arr(shards)),
                ("restarts", Json::Arr(restarts)),
            ]);
            http::Response::json(200, body.to_string())
        }
        ("GET", "/readyz") => {
            if life.draining() {
                draining_response()
            } else if !pool.any_serving() {
                http::Response::json(503, "{\"error\":\"no healthy shard\"}".into())
                    .with_header("Retry-After", "1")
            } else {
                http::Response::json(200, "{\"ready\":true}".into())
            }
        }
        ("POST", "/admin/drain") => {
            life.drain();
            http::Response::json(200, "{\"draining\":true}".into())
        }
        ("GET", "/metrics") => {
            let mut text = metrics.render();
            text.push_str(&pool.render_metrics());
            text.push_str("# HELP erprm_draining 1 while the process refuses new work.\n");
            text.push_str("# TYPE erprm_draining gauge\n");
            text.push_str(&format!(
                "erprm_draining {}\n",
                if life.draining() { 1 } else { 0 }
            ));
            http::Response::text(200, &text)
        }
        ("GET", "/traces") => {
            let items: Vec<Json> =
                pool.tracer().recent(100).iter().map(|t| t.summary()).collect();
            http::Response::json(200, Json::obj(vec![("traces", Json::Arr(items))]).to_string())
        }
        ("GET", "/traces/chrome") => {
            http::Response::json(200, obs::chrome_trace(&pool.tracer().all()).to_string())
        }
        ("GET", "/calibration") => http::Response::json(200, pool.calibration_json()),
        ("GET", p) if p.starts_with("/trace/") => {
            let id = &p["/trace/".len()..];
            match pool.tracer().get(id) {
                Some(t) => http::Response::json(200, t.to_json().to_string()),
                None => http::Response::json(
                    404,
                    "{\"error\":\"no trace retained for that id\"}".into(),
                ),
            }
        }
        ("POST", "/solve") => {
            if life.draining() {
                metrics.record_error(503);
                return draining_response();
            }
            let t0 = Instant::now();
            // id precedence: X-Request-Id header > body request_id field
            // > minted at the door
            let header_rid =
                req.request_id.as_deref().and_then(obs::sanitize_request_id);
            let mut parsed = match api::parse_solve(&req.body, defaults) {
                Ok(p) => p,
                Err(e) => {
                    // even a parse reject leaves a (failure, hence
                    // always-retained) trace under the client's id
                    let rid = header_rid.unwrap_or_else(obs::mint_request_id);
                    let tb = TraceBuilder::start(rid);
                    pool.tracer()
                        .submit(tb.finish("error", e.http_status(), PhaseFlops::default()));
                    metrics.record_error(e.http_status());
                    return error_response(&e);
                }
            };
            if let Some(rid) = header_rid {
                parsed.request_id = rid;
            } else if parsed.request_id.is_empty() {
                parsed.request_id = obs::mint_request_id();
            }
            let rid = parsed.request_id.clone();
            match pool.solve_timed_watched(parsed.clone(), defaults.clone(), req.hangup.as_ref())
            {
                Ok(s) => {
                    metrics.record_ok(
                        t0.elapsed().as_secs_f64() * 1000.0,
                        s.queue_wait_ms,
                        s.outcome.ledger.total_flops(),
                        s.outcome.correct,
                    );
                    http::Response::json(
                        200,
                        api::render_solve(&parsed, &s.outcome, s.queue_wait_ms),
                    )
                    .with_header("X-Request-Id", rid)
                }
                Err(e) => {
                    metrics.record_error(e.http_status());
                    error_response(&e).with_header("X-Request-Id", rid)
                }
            }
        }
        _ => http::Response::json(404, "{\"error\":\"not found\"}".into()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saturated_renders_503_with_retry_after() {
        let r = error_response(&Error::saturated("all queues full"));
        assert_eq!(r.status, 503);
        assert!(r.headers.iter().any(|(k, _)| *k == "Retry-After"));
        assert!(String::from_utf8(r.body).unwrap().contains("saturated"));
    }

    #[test]
    fn parse_errors_render_400_without_retry_after() {
        let r = error_response(&Error::parse("bad json"));
        assert_eq!(r.status, 400);
        assert!(r.headers.is_empty());
    }

    #[test]
    fn deadline_renders_504_without_retry_after() {
        let r = error_response(&Error::deadline("budget was 100ms"));
        assert_eq!(r.status, 504);
        assert!(r.headers.is_empty(), "504 is not a back-off-and-retry signal");
        assert!(String::from_utf8(r.body).unwrap().contains("deadline"));
    }

    #[test]
    fn shard_lost_renders_503_with_retry_after() {
        let r = error_response(&Error::shard_lost("every engine shard is dead"));
        assert_eq!(r.status, 503);
        assert!(r.headers.iter().any(|(k, _)| *k == "Retry-After"));
    }

    #[test]
    fn hangup_renders_499() {
        let r = error_response(&Error::hangup("client disconnected mid-solve"));
        assert_eq!(r.status, 499);
        assert!(r.headers.is_empty(), "nobody is listening for a Retry-After");
    }

    use crate::server::router::testkit::{canned_pool, set_shard_health};
    use crate::server::router::PoolOptions;

    fn get(path: &str) -> http::Request {
        http::Request {
            method: "GET".into(),
            path: path.into(),
            body: Vec::new(),
            request_id: None,
            hangup: None,
        }
    }

    fn post(path: &str, body: &str) -> http::Request {
        http::Request {
            method: "POST".into(),
            path: path.into(),
            body: body.as_bytes().to_vec(),
            request_id: None,
            hangup: None,
        }
    }

    fn body_str(r: &http::Response) -> String {
        String::from_utf8(r.body.clone()).unwrap()
    }

    #[test]
    fn lifecycle_endpoints_gate_admission_and_report_shard_state() {
        let pool = canned_pool(
            PoolOptions { shards: 2, ..PoolOptions::default() },
            std::time::Duration::ZERO,
        );
        let metrics = Metrics::default();
        let cfg = SearchConfig::default();
        let life = Lifecycle::new();

        let h = route(&pool, &metrics, &cfg, &life, get("/healthz"));
        assert_eq!(h.status, 200);
        let hb = body_str(&h);
        assert!(hb.contains("\"shards\":[\"healthy\",\"healthy\"]"), "{hb}");
        assert!(hb.contains("\"draining\":false"), "{hb}");

        let r = route(&pool, &metrics, &cfg, &life, get("/readyz"));
        assert_eq!(r.status, 200, "{}", body_str(&r));

        // a solve goes through before the drain
        let solve_body = r#"{"v0": 4, "ops": [["+",3]]}"#;
        let ok = route(&pool, &metrics, &cfg, &life, post("/solve", solve_body));
        assert_eq!(ok.status, 200, "{}", body_str(&ok));

        let d = route(&pool, &metrics, &cfg, &life, post("/admin/drain", ""));
        assert_eq!(d.status, 200);
        assert!(life.draining());

        let r = route(&pool, &metrics, &cfg, &life, get("/readyz"));
        assert_eq!(r.status, 503, "draining instance must leave rotation");
        assert!(r.headers.iter().any(|(k, _)| *k == "Retry-After"));

        let refused = route(&pool, &metrics, &cfg, &life, post("/solve", solve_body));
        assert_eq!(refused.status, 503, "{}", body_str(&refused));
        assert!(body_str(&refused).contains("draining"));

        // healthz keeps answering during the drain (liveness)
        let h = route(&pool, &metrics, &cfg, &life, get("/healthz"));
        assert_eq!(h.status, 200);
        assert!(body_str(&h).contains("\"draining\":true"));

        let m = route(&pool, &metrics, &cfg, &life, get("/metrics"));
        assert!(body_str(&m).contains("erprm_draining 1"), "{}", body_str(&m));
        pool.shutdown();
    }

    #[test]
    fn readyz_is_503_when_no_shard_serves() {
        let pool = canned_pool(
            PoolOptions {
                shards: 1,
                supervise: crate::server::supervisor::SuperviseOptions {
                    enabled: false,
                    ..Default::default()
                },
                ..PoolOptions::default()
            },
            std::time::Duration::ZERO,
        );
        let metrics = Metrics::default();
        let cfg = SearchConfig::default();
        let life = Lifecycle::new();
        assert_eq!(route(&pool, &metrics, &cfg, &life, get("/readyz")).status, 200);
        set_shard_health(&pool, 0, crate::server::supervisor::HEALTH_DEAD);
        let r = route(&pool, &metrics, &cfg, &life, get("/readyz"));
        assert_eq!(r.status, 503);
        assert!(body_str(&r).contains("no healthy shard"));
        let h = route(&pool, &metrics, &cfg, &life, get("/healthz"));
        assert_eq!(h.status, 200, "liveness still answers");
        assert!(body_str(&h).contains("\"serving\":false"));
        pool.shutdown();
    }
}
