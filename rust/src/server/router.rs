//! The engine actor + request router.
//!
//! The PJRT engine is `!Send` (Rc-based client), so a dedicated thread owns
//! it and executes solve requests sequentially from an mpsc queue; HTTP
//! workers enqueue requests and block on a oneshot-style reply channel.
//! The router keeps per-(lm,prm) warm state in the single engine and
//! surfaces queue depth for backpressure (503 when saturated).

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use crate::config::SearchConfig;
use crate::coordinator::search::SolveOutcome;
use crate::coordinator::{solve_early_rejection, solve_vanilla};
use crate::config::SearchMode;
use crate::harness::temp_for;
use crate::log_error;
use crate::runtime::Engine;
use crate::server::api::SolveRequest;
use crate::util::error::{Error, Result};

type Reply = mpsc::Sender<Result<SolveOutcome>>;

enum Msg {
    Solve(SolveRequest, SearchConfig, Reply),
    Shutdown,
}

/// Handle used by HTTP workers; cheap to clone.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Msg>,
    depth: Arc<AtomicUsize>,
    capacity: usize,
}

impl EngineHandle {
    /// Spawn the engine actor thread. Fails fast (in the caller) if the
    /// artifacts dir is unloadable.
    pub fn spawn(artifacts_dir: PathBuf, _defaults: SearchConfig, capacity: usize) -> Result<EngineHandle> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let depth = Arc::new(AtomicUsize::new(0));
        let depth2 = Arc::clone(&depth);
        std::thread::Builder::new()
            .name("erprm-engine".into())
            .spawn(move || {
                let engine = match Engine::load(&artifacts_dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Shutdown => break,
                        Msg::Solve(req, cfg, reply) => {
                            let res = run_solve(&engine, &req, &cfg);
                            depth2.fetch_sub(1, Ordering::Relaxed);
                            if let Err(e) = &res {
                                log_error!("solve failed: {e}");
                            }
                            let _ = reply.send(res);
                        }
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| Error::invalid("engine thread died during startup"))??;
        Ok(EngineHandle { tx, depth, capacity })
    }

    /// Enqueue a solve; returns Err immediately when saturated (backpressure).
    pub fn solve(&self, req: SolveRequest, mut cfg: SearchConfig) -> Result<SolveOutcome> {
        if self.depth.load(Ordering::Relaxed) >= self.capacity {
            return Err(Error::invalid("queue full"));
        }
        cfg.mode = req.mode;
        cfg.n_beams = req.n_beams;
        cfg.tau = req.tau;
        cfg.validate()?;
        self.depth.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Solve(req, cfg, rtx))
            .map_err(|_| Error::invalid("engine thread gone"))?;
        rrx.recv().map_err(|_| Error::invalid("engine dropped request"))?
    }

    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

fn run_solve(engine: &Engine, req: &SolveRequest, cfg: &SearchConfig) -> Result<SolveOutcome> {
    let temp = temp_for(&req.lm);
    match req.mode {
        SearchMode::Vanilla => solve_vanilla(engine, &req.lm, &req.prm, &req.problem, cfg, temp),
        SearchMode::EarlyRejection => {
            solve_early_rejection(engine, &req.lm, &req.prm, &req.problem, cfg, temp)
        }
    }
}

/// A simple FIFO request queue wrapper for tests/ablation of routing.
#[derive(Default)]
pub struct FifoQueue<T> {
    inner: Mutex<std::collections::VecDeque<T>>,
}

impl<T> FifoQueue<T> {
    pub fn push(&self, item: T) {
        self.inner.lock().unwrap().push_back(item);
    }

    pub fn pop(&self) -> Option<T> {
        self.inner.lock().unwrap().pop_front()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let q = FifoQueue::default();
        q.push(1);
        q.push(2);
        q.push(3);
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert!(q.is_empty());
    }

    #[test]
    fn spawn_fails_fast_without_artifacts() {
        let r = EngineHandle::spawn(
            PathBuf::from("/nonexistent-artifacts"),
            SearchConfig::default(),
            4,
        );
        assert!(r.is_err());
    }
}
