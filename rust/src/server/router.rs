//! The engine shard pool + request router.
//!
//! The PJRT engine is `!Send` (Rc-based client), so each engine lives on a
//! dedicated *shard* thread that owns it outright. [`EnginePool`] fronts N
//! such shards with a least-loaded dispatcher: HTTP workers reserve a slot
//! on the shallowest shard queue, enqueue the request, and block on a
//! oneshot-style reply channel. When every shard queue is at capacity the
//! pool rejects immediately with [`Error::Saturated`], which the HTTP
//! layer renders as **503 Service Unavailable** (never 4xx — 400 stays
//! reserved for parse/validation mistakes).
//!
//! A shard thread drains its queue in one of two modes:
//!
//! * **sequential** (the default): one request runs to completion before
//!   the next is dequeued — simple, but a long solve head-of-line blocks
//!   the queue and compute freed by early rejection mid-request is lost.
//! * **fleet** (`--fleet`): the thread runs the continuous scheduler in
//!   [`crate::fleet`] — up to `max_inflight` requests interleave as
//!   resumable [`crate::coordinator::task::SolveTask`]s, slots freed by
//!   completion or deadline abort are backfilled from the queue, and
//!   identical in-flight requests coalesce onto one engine run.
//!
//! Queue-depth accounting is leak-proof by construction: the caller that
//! reserves a slot holds a [`DepthGuard`] whose `Drop` releases it, so the
//! gauge recovers on every path — send failure, reply-channel failure, and
//! normal completion alike.
//!
//! The pool also carries a seed-stable LRU solve cache keyed on
//! `(problem, mode, n_beams, tau, m_expand, seed, lm, prm)` (see
//! [`crate::server::api::SolveRequest::cache_key`]): because solves are
//! deterministic for a fixed seed, repeated benchmark traffic
//! short-circuits entirely, and a hit returns a byte-identical outcome.
//!
//! # Supervision, retry, and fault injection
//!
//! Shard threads are *supervised*. Each thread runs its body under
//! `catch_unwind` and heartbeats into its [`ShardSlot`] once per
//! scheduler round; a supervisor thread detects panicked (flag) or
//! wedged (stale heartbeat with reserved work) shards and recovers them:
//! swap in a fresh mailbox, requeue the old queue's jobs onto healthy
//! shards, retire the generation (the zombie's writes become no-ops and
//! its drive loop exits at its next check), and respawn the thread with
//! a fresh `Engine`. In-flight jobs on a lost shard surface as the
//! retryable [`Error::ShardLost`] — the dispatcher notices via *custody*
//! tracking (each job carries a packed `(shard, generation)` word that
//! requeues update before the generation bump, so a double read
//! distinguishes "moved" from "lost").
//!
//! `solve_timed` transparently retries retryable failures with capped
//! exponential backoff and seeded jitter, never sleeping past the
//! request's remaining deadline budget. Retrying is *correct* by the
//! same determinism contract the cache relies on: a retried solve is a
//! fresh deterministic solve, and only `Ok` outcomes are ever cached.
//!
//! The `--chaos-*` knob family ([`ChaosOptions`]) injects seed-keyed
//! panics and stalls at shard-tick granularity, which is how the test
//! suite proves byte-identical answers survive recovery.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::batch::{BatchStats, BatchTotals};
use crate::config::{SearchConfig, SearchMode};
use crate::coordinator::policy::{AdaptiveTau, TauPlan};
use crate::coordinator::search::{hash_problem, SolveOutcome};
use crate::coordinator::task::Progress;
use crate::fleet::{
    self, ChaosAction, ChaosOptions, ChaosState, DriveHooks, FleetJob, FleetOptions, FleetStats,
    FleetTotals, Solved, TaskSpec,
};
use crate::harness::temp_for;
use crate::log_debug;
use crate::log_error;
use crate::obs::{mint_request_id, PhaseFlops, TraceBuilder, TraceOptions, TraceRecorder};
use crate::runtime::{Engine, EngineStats};
use crate::server::api::SolveRequest;
use crate::server::http::HangupProbe;
use crate::server::supervisor::{
    backoff_delay, health_name, pack_custody, unpack_custody, RetryOptions, ShardSlot,
    SuperviseOptions, HEALTH_DEAD, HEALTH_HEALTHY, HEALTH_STARTING,
};
use crate::util::error::{Error, Result};
use crate::util::logging;
use crate::util::oneshot;
use crate::util::sync::{lock_unpoisoned, MailRecv, Mailbox};

type Reply = oneshot::Sender<Result<Solved>>;

/// One enqueued request: the parsed solve plus its scheduling envelope.
struct SolveJob {
    req: SolveRequest,
    cfg: SearchConfig,
    enqueued: Instant,
    deadline: Option<Duration>,
    priority: i64,
    reply: Reply,
    /// Request trace, opened at dispatch with the door-side "queue" span
    /// running; the shard closes it and records the rest of the
    /// lifecycle.
    trace: Option<Box<TraceBuilder>>,
    /// Frozen adaptive-tau schedule resolved at admission (see
    /// [`EnginePool::resolve_tau_plan`]); `None` = static `cfg.tau`.
    tau_plan: Option<Arc<TauPlan>>,
    /// Packed `(shard, generation)` custody word (see
    /// [`crate::server::supervisor`]). The dispatcher polls it while
    /// waiting for the reply; supervisor requeues update it *before*
    /// retiring the source generation, so a double read tells a moved
    /// job from a lost one.
    custody: Arc<AtomicU64>,
}

enum Msg {
    Solve(Box<SolveJob>),
    Shutdown,
}

/// One engine shard: a thread owning its own `Engine`, fed through the
/// swappable mailbox on its [`ShardSlot`].
struct Shard {
    /// Supervision state: generation, heartbeat, health, mailbox.
    slot: Arc<ShardSlot<Msg>>,
    /// Requests currently reserved against this shard (queued + executing
    /// + reply pending). Owned by callers via [`DepthGuard`].
    depth: Arc<AtomicUsize>,
    /// Total solves completed by this shard (utilization reporting).
    solved: Arc<AtomicU64>,
    /// Latest engine-stats snapshot published by the shard thread.
    stats: Arc<Mutex<EngineStats>>,
    /// Fleet-mode telemetry (all-zero when the shard runs sequentially).
    fstats: Arc<FleetStats>,
    /// Gang-batcher telemetry (all-zero unless fleet gang mode is on).
    bstats: Arc<BatchStats>,
}

/// Followers of one in-flight single-flight key, waiting on the leader.
type SfWaiters = Vec<oneshot::Sender<Result<Solved>>>;

struct PoolInner {
    shards: Vec<Shard>,
    capacity: usize,
    default_deadline_ms: u64,
    fleet: Option<FleetOptions>,
    cache: Option<Mutex<SolveCache>>,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    /// Pool-level single-flight table (ROADMAP: cross-shard coalescing).
    /// The shard-local fleet coalescer only folds duplicates placed on
    /// its own shard; this table catches identical in-flight requests
    /// *before placement*, so duplicates that least-loaded dispatch would
    /// have scattered across shards ride one engine run instead. `None`
    /// disables (the dedup contract is the same determinism the solve
    /// cache relies on: equal keys are proven byte-identical).
    singleflight: Option<Mutex<HashMap<String, SfWaiters>>>,
    pool_coalesced: AtomicU64,
    /// Request-trace ring + rollups, shared by every shard thread and the
    /// HTTP layer (`/trace/<id>`, `/traces`, `/traces/chrome`).
    tracer: Arc<TraceRecorder>,
    joins: Mutex<Vec<JoinHandle<()>>>,
    /// Transparent-retry policy for retryable dispatch failures.
    retry: RetryOptions,
    supervise: SuperviseOptions,
    /// Deterministic fault injection (`--chaos-*`); `None` when off.
    chaos: Option<Arc<ChaosState>>,
    retries_total: AtomicU64,
    /// Jobs the supervisor moved out of a lost shard's mailbox.
    requeued_total: AtomicU64,
    /// Set by `shutdown()`; stops the supervisor thread.
    stopping: AtomicBool,
    supervisor: Mutex<Option<JoinHandle<()>>>,
    /// The shard thread body, kept so the supervisor can respawn a shard
    /// with a fresh engine. Injectable for artifact-free testing.
    body: ShardBody,
}

/// What runs on a shard thread (inside `catch_unwind`). The real body
/// loads an `Engine` and serves; tests inject canned bodies.
type ShardBody = Arc<dyn Fn(ShardCtx) + Send + Sync>;

/// Everything a shard body needs, bundled so respawns are one call.
struct ShardCtx {
    idx: usize,
    /// The slot generation this body belongs to. All slot writes are
    /// gated on it so a retired zombie cannot corrupt its replacement.
    generation: u64,
    mailbox: Arc<Mailbox<Msg>>,
    slot: Arc<ShardSlot<Msg>>,
    solved: Arc<AtomicU64>,
    stats: Arc<Mutex<EngineStats>>,
    fstats: Arc<FleetStats>,
    bstats: Arc<BatchStats>,
    tracer: Arc<TraceRecorder>,
    chaos: Option<Arc<ChaosState>>,
    /// Present on initial spawn only: reports engine-load success so
    /// `spawn_with` can fail fast. Respawns report through slot health.
    ready: Option<mpsc::Sender<Result<()>>>,
}

impl ShardCtx {
    /// The engine is up: mark the slot serving and ack the spawner.
    fn ready_ok(&mut self) {
        self.slot.mark_ready(self.generation);
        if let Some(tx) = self.ready.take() {
            let _ = tx.send(Ok(()));
        }
    }

    /// Engine load failed. On initial spawn the pool constructor unwinds;
    /// on a respawn the shard is permanently dead.
    fn ready_err(&mut self, e: Error) {
        match self.ready.take() {
            Some(tx) => {
                let _ = tx.send(Err(e));
            }
            None => {
                log_error!("shard {}: respawn failed to load engine: {e}", self.idx);
                self.slot.mark_dead(self.generation);
            }
        }
    }
}

/// Per-round supervision hooks for one shard body: generation-gated
/// heartbeat/retirement plus the chaos draw (tick counter lives on the
/// slot so a respawn resumes the schedule instead of replaying it).
struct SlotHooks {
    slot: Arc<ShardSlot<Msg>>,
    generation: u64,
    chaos: Option<Arc<ChaosState>>,
}

impl DriveHooks for SlotHooks {
    fn beat(&self) {
        self.slot.beat(self.generation);
    }

    fn retired(&self) -> bool {
        self.slot.generation() != self.generation
    }

    fn chaos_tick(&self) -> ChaosAction {
        match &self.chaos {
            Some(c) if c.enabled() => c.tick(self.slot.idx, self.slot.next_tick()),
            _ => ChaosAction::None,
        }
    }
}

/// Handle to the shard pool used by HTTP workers; cheap to clone.
#[derive(Clone)]
pub struct EnginePool {
    inner: Arc<PoolInner>,
}

/// Everything `spawn_with` needs to build a pool.
#[derive(Debug, Clone)]
pub struct PoolOptions {
    /// Engine shard threads.
    pub shards: usize,
    /// Queue slots per shard (must be positive).
    pub capacity: usize,
    /// LRU solve-cache entries; 0 disables caching.
    pub cache_entries: usize,
    /// Default per-request deadline (ms) applied when a request carries
    /// none; 0 disables the default. Honored in both dispatch modes.
    pub default_deadline_ms: u64,
    /// `Some` switches every shard to the fleet scheduler.
    pub fleet: Option<FleetOptions>,
    /// Pool-level single-flight: identical requests coalesce onto one
    /// engine run before placement, so duplicates landing on different
    /// shards no longer both execute.
    pub singleflight: bool,
    /// Paged-KV block pool size per shard (blocks of the manifest's
    /// `kv_block` tokens). `None` defaults to the manifest's exported
    /// `pool_blocks` sizing when present (the geometry block-native
    /// device pools were compiled for) and dense otherwise; `Some(0)`
    /// forces dense per-slot caches; `Some(n)` sets an explicit pool.
    /// Silently falls back to dense on artifact sets exported before
    /// paging existed.
    pub kv_pool_blocks: Option<usize>,
    /// Request-trace retention knobs (`--trace-capacity` /
    /// `--trace-sample`): ring size and success-sampling policy. Failures
    /// are always retained regardless of sampling.
    pub trace: TraceOptions,
    /// Transparent retry of retryable dispatch failures (`--retry-*`).
    pub retry: RetryOptions,
    /// Shard supervision knobs (`--supervise-*` / `--no-supervise`).
    pub supervise: SuperviseOptions,
    /// Deterministic fault injection (`--chaos-*`); default-off.
    pub chaos: ChaosOptions,
}

impl Default for PoolOptions {
    fn default() -> Self {
        PoolOptions {
            shards: 1,
            capacity: 64,
            cache_entries: 0,
            default_deadline_ms: 0,
            fleet: None,
            singleflight: false,
            kv_pool_blocks: None,
            trace: TraceOptions::default(),
            retry: RetryOptions::default(),
            supervise: SuperviseOptions::default(),
            chaos: ChaosOptions::default(),
        }
    }
}

/// Poll slice while a dispatcher waits on a shard reply: between slices
/// it checks the client-disconnect probe and the job's custody word.
/// Short enough that loss detection and hangup propagation are prompt,
/// long enough that a healthy solve costs a handful of wakeups.
const DISPATCH_POLL: Duration = Duration::from_millis(20);

/// Idle tick for a shard body blocking on its mailbox: bounds how stale a
/// heartbeat can go while the shard is simply idle.
const IDLE_TICK: Duration = Duration::from_millis(50);

/// RAII slot reservation against one shard's depth gauge. Dropping the
/// guard releases the slot, so the gauge can never leak — this replaces
/// the old fetch_add/fetch_sub pairing that leaked a slot whenever the
/// engine thread died between enqueue and reply.
struct DepthGuard {
    depth: Arc<AtomicUsize>,
}

impl Drop for DepthGuard {
    fn drop(&mut self) {
        self.depth.fetch_sub(1, Ordering::AcqRel);
    }
}

/// RAII cleanup of one single-flight leadership: removes the key from the
/// table on drop, so followers of a leader that panicked mid-dispatch see
/// their senders dropped (-> internal error on `recv`) instead of hanging
/// on a key nobody owns.
struct SingleFlightGuard<'a> {
    table: &'a Mutex<HashMap<String, SfWaiters>>,
    key: String,
}

impl SingleFlightGuard<'_> {
    /// Claim the accumulated followers (the normal completion path); the
    /// Drop that follows finds nothing left to clean.
    fn take_waiters(self) -> SfWaiters {
        self.table.lock().unwrap().remove(&self.key).unwrap_or_default()
    }
}

impl Drop for SingleFlightGuard<'_> {
    fn drop(&mut self) {
        let _ = self.table.lock().unwrap().remove(&self.key);
    }
}

/// Atomically claim a slot iff `depth < capacity` (CAS loop so concurrent
/// callers can't overshoot the bound).
fn try_reserve(depth: &Arc<AtomicUsize>, capacity: usize) -> Option<DepthGuard> {
    let mut cur = depth.load(Ordering::Relaxed);
    loop {
        if cur >= capacity {
            return None;
        }
        match depth.compare_exchange_weak(cur, cur + 1, Ordering::AcqRel, Ordering::Relaxed) {
            Ok(_) => return Some(DepthGuard { depth: Arc::clone(depth) }),
            Err(now) => cur = now,
        }
    }
}

/// Indices of shards in least-loaded-first order over `(primary,
/// tiebreak)` load signals (stable, so an idle pool drains
/// deterministically from shard 0).
fn placement_order(loads: &[(usize, usize)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..loads.len()).collect();
    idx.sort_by_key(|&i| loads[i]);
    idx
}

impl EnginePool {
    /// Spawn a sequential pool: `n_shards` engine threads (each loads its
    /// own `Engine` from `artifacts_dir`), `capacity` queue slots per
    /// shard, an LRU solve cache of `cache_entries` entries (0 disables).
    pub fn spawn(
        artifacts_dir: PathBuf,
        n_shards: usize,
        capacity: usize,
        cache_entries: usize,
    ) -> Result<EnginePool> {
        EnginePool::spawn_with(
            artifacts_dir,
            PoolOptions { shards: n_shards, capacity, cache_entries, ..PoolOptions::default() },
        )
    }

    /// Spawn with full options (fleet mode included). Fails fast (in the
    /// caller) if any shard's artifacts are unloadable.
    pub fn spawn_with(artifacts_dir: PathBuf, opts: PoolOptions) -> Result<EnginePool> {
        let kv_pool_blocks = opts.kv_pool_blocks;
        let fleet_opts = opts.fleet.clone();
        let body: ShardBody = Arc::new(move |ctx: ShardCtx| {
            real_shard_body(&artifacts_dir, kv_pool_blocks, fleet_opts.clone(), ctx)
        });
        EnginePool::spawn_with_body(opts, body)
    }

    /// Spawn the pool around an injectable shard body (the real one in
    /// production; canned ones in artifact-free tests). The body runs
    /// under `catch_unwind` and is kept for supervisor respawns.
    fn spawn_with_body(opts: PoolOptions, body: ShardBody) -> Result<EnginePool> {
        let n_shards = opts.shards.max(1);
        if opts.capacity == 0 {
            return Err(Error::invalid("shard queue capacity must be positive"));
        }
        if let Some(f) = &opts.fleet {
            if f.max_inflight == 0 {
                return Err(Error::invalid("fleet max_inflight must be positive"));
            }
        }
        let tracer = Arc::new(TraceRecorder::new(opts.trace));
        let chaos = opts.chaos.enabled().then(|| Arc::new(ChaosState::new(opts.chaos)));
        let mut shards = Vec::with_capacity(n_shards);
        let mut joins = Vec::with_capacity(n_shards);
        let mut readies = Vec::with_capacity(n_shards);
        for i in 0..n_shards {
            let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
            let slot = Arc::new(ShardSlot::new(i));
            let depth = Arc::new(AtomicUsize::new(0));
            let solved = Arc::new(AtomicU64::new(0));
            let stats = Arc::new(Mutex::new(EngineStats::default()));
            let fstats = Arc::new(FleetStats::default());
            let bstats = Arc::new(BatchStats::default());
            let ctx = ShardCtx {
                idx: i,
                generation: slot.generation(),
                mailbox: slot.mailbox(),
                slot: Arc::clone(&slot),
                solved: Arc::clone(&solved),
                stats: Arc::clone(&stats),
                fstats: Arc::clone(&fstats),
                bstats: Arc::clone(&bstats),
                tracer: Arc::clone(&tracer),
                chaos: chaos.clone(),
                ready: Some(ready_tx),
            };
            joins.push(spawn_shard_thread(Arc::clone(&body), ctx)?);
            shards.push(Shard { slot, depth, solved, stats, fstats, bstats });
            readies.push(ready_rx);
        }
        let mut startup: Result<()> = Ok(());
        for (i, ready) in readies.into_iter().enumerate() {
            let r = ready
                .recv()
                .map_err(|_| Error::internal(format!("shard {i} died during startup")))
                .and_then(|r| r);
            if startup.is_ok() {
                startup = r;
            }
        }
        if let Err(e) = startup {
            // Unwind: stop any shards that did come up, then join all.
            for s in &shards {
                let mb = s.slot.mailbox();
                let _ = mb.push(Msg::Shutdown);
                mb.close();
            }
            for j in joins {
                let _ = j.join();
            }
            return Err(e);
        }
        let cache = if opts.cache_entries > 0 {
            Some(Mutex::new(SolveCache::new(opts.cache_entries)))
        } else {
            None
        };
        let supervise = opts.supervise.clone();
        let pool = EnginePool {
            inner: Arc::new(PoolInner {
                shards,
                capacity: opts.capacity,
                default_deadline_ms: opts.default_deadline_ms,
                fleet: opts.fleet,
                cache,
                cache_hits: AtomicU64::new(0),
                cache_misses: AtomicU64::new(0),
                singleflight: opts.singleflight.then(|| Mutex::new(HashMap::new())),
                pool_coalesced: AtomicU64::new(0),
                tracer,
                joins: Mutex::new(joins),
                retry: opts.retry,
                supervise,
                chaos,
                retries_total: AtomicU64::new(0),
                requeued_total: AtomicU64::new(0),
                stopping: AtomicBool::new(false),
                supervisor: Mutex::new(None),
                body,
            }),
        };
        if pool.inner.supervise.enabled {
            let handle = spawn_supervisor(Arc::clone(&pool.inner))?;
            *lock_unpoisoned(&pool.inner.supervisor) = Some(handle);
        }
        Ok(pool)
    }

    /// Solve via the least-loaded shard; returns [`Error::Saturated`]
    /// immediately when every live shard queue is full (backpressure),
    /// and short-circuits through the solve cache when enabled. If the
    /// chosen shard thread turns out to be dead, the request fails over
    /// to the next live shard instead of surfacing the infrastructure
    /// fault to the client.
    pub fn solve(&self, req: SolveRequest, cfg: SearchConfig) -> Result<SolveOutcome> {
        self.solve_timed(req, cfg).map(|s| s.outcome)
    }

    /// Like [`EnginePool::solve`], but also reports how long the request
    /// waited for scheduling (`queue_wait_ms`; 0 on a cache hit, the
    /// leader's value when this request coalesced onto an in-flight
    /// single-flight run).
    pub fn solve_timed(&self, req: SolveRequest, cfg: SearchConfig) -> Result<Solved> {
        self.solve_timed_watched(req, cfg, None)
    }

    /// [`EnginePool::solve_timed`] with an optional client-disconnect
    /// probe: while the dispatcher waits for the shard's reply it checks
    /// the probe, and a hung-up client cancels the solve (the abandoned
    /// reply channel tells the fleet nobody is listening) and surfaces
    /// [`Error::Hangup`] (HTTP 499).
    pub fn solve_timed_watched(
        &self,
        mut req: SolveRequest,
        mut cfg: SearchConfig,
        hangup: Option<&Arc<HangupProbe>>,
    ) -> Result<Solved> {
        if req.request_id.is_empty() {
            req.request_id = mint_request_id();
        }
        cfg.mode = req.mode;
        cfg.n_beams = req.n_beams;
        cfg.tau = req.tau;
        if let Err(e) = cfg.validate() {
            // requests bounced at the door still leave a (failure, hence
            // always-retained) trace keyed by their id
            let tb = TraceBuilder::start(req.request_id.clone());
            self.inner.tracer.submit(tb.finish("error", e.http_status(), PhaseFlops::default()));
            return Err(e);
        }
        // Adaptive tau: freeze the rejection schedule for this request
        // against the current calibration table *before* any key is
        // built. The key embeds the table epoch, so cache hits and
        // coalesced duplicates are only ever shared between requests
        // that froze byte-identical plans.
        let tau_plan = self.resolve_tau_plan(&req, &cfg);
        let key = match &tau_plan {
            Some(p) => format!("{}|calib{}", req.cache_key(&cfg), p.epoch),
            None => req.cache_key(&cfg),
        };
        if let Some(cache) = &self.inner.cache {
            if let Some(hit) = cache.lock().unwrap().get(&key) {
                self.inner.cache_hits.fetch_add(1, Ordering::Relaxed);
                // mini-trace: the outcome's ledger is the phase split, so
                // /trace/<id> agrees with the response's flops field
                let mut tb = TraceBuilder::start(req.request_id.clone());
                tb.event("cache_hit", "solve cache");
                self.inner
                    .tracer
                    .submit(tb.finish("cache_hit", 200, PhaseFlops::from_ledger(&hit.ledger)));
                return Ok(Solved { outcome: hit, queue_wait_ms: 0.0 });
            }
            self.inner.cache_misses.fetch_add(1, Ordering::Relaxed);
        }
        // Pool-level single-flight: follow an in-flight leader for the
        // same key instead of dispatching a second engine run (possibly
        // onto a different shard, where the shard-local coalescer could
        // never see the duplicate). Deadline-bounded duplicates join as
        // *followers* with a timed wait capped at their own budget: if
        // the leader finishes in time they ride its result, otherwise
        // the timed `recv` consumes (abandons) the channel and the
        // request 504s on its own deadline instead of inheriting the
        // leader's fate. Bounded requests still never *lead* — a
        // tightly-bounded leader would impose its 504 on unbounded
        // followers — so a bounded request with no leader in flight
        // dispatches solo without claiming the key.
        let deadline = self.effective_deadline(&req);
        let sf_guard = if let Some(sf) = &self.inner.singleflight {
            let mut table = sf.lock().unwrap();
            if let Some(waiters) = table.get_mut(&key) {
                let (tx, rx) = oneshot::channel();
                waiters.push(tx);
                drop(table);
                self.inner.pool_coalesced.fetch_add(1, Ordering::Relaxed);
                let res: Result<Solved> = match deadline {
                    None => rx
                        .recv()
                        .map_err(|_| Error::internal("single-flight leader vanished"))?,
                    Some(budget) => match rx.recv_timeout(budget) {
                        Ok(r) => r,
                        Err(oneshot::RecvTimeoutError::Timeout) => {
                            // the timed recv consumed the receiver, so
                            // the leader's late send bounces harmlessly
                            Err(Error::deadline(format!(
                                "followed an in-flight identical run past the {}ms budget",
                                budget.as_millis()
                            )))
                        }
                        Err(oneshot::RecvTimeoutError::Disconnected) => {
                            return Err(Error::internal("single-flight leader vanished"));
                        }
                    },
                };
                // the follower's own trace ends at the door: it rode the
                // leader's engine run and inherits (or times out of) its
                // result
                let mut tb = TraceBuilder::start(req.request_id.clone());
                tb.event("coalesced", "pool single-flight follower");
                let t = match &res {
                    Ok(s) => {
                        tb.finish("coalesced", 200, PhaseFlops::from_ledger(&s.outcome.ledger))
                    }
                    Err(e) if e.http_status() == 504 => {
                        tb.finish("deadline", 504, PhaseFlops::default())
                    }
                    Err(e) => tb.finish("error", e.http_status(), PhaseFlops::default()),
                };
                self.inner.tracer.submit(t);
                return res;
            }
            if deadline.is_none() {
                table.insert(key.clone(), Vec::new());
                Some(SingleFlightGuard { table: sf, key: key.clone() })
            } else {
                None
            }
        } else {
            None
        };
        let rid = req.request_id.clone();
        // Transparent retry: shard loss (and saturation, under the knob)
        // is retried with capped exponential backoff + seeded jitter,
        // never sleeping past the remaining deadline budget. Correct by
        // determinism: a retried solve is a fresh deterministic solve.
        let t0 = Instant::now();
        let mut attempt: u32 = 0;
        let mut prior: Option<String> = None;
        let res = loop {
            attempt += 1;
            let r = self.dispatch_with_failover(
                req.clone(),
                cfg.clone(),
                tau_plan.clone(),
                attempt,
                prior.take(),
                hangup,
            );
            match r {
                Err(e)
                    if e.is_retryable()
                        || (self.inner.retry.retry_saturated
                            && matches!(e, Error::Saturated(_))) =>
                {
                    let remaining = deadline.map(|d| d.saturating_sub(t0.elapsed()));
                    // jitter draw: stable for a fixed (request, attempt) so
                    // chaos reruns back off identically, yet distinct across
                    // requests so a recovering pool isn't hit in lockstep
                    let draw = crate::util::stats::mix64(
                        hash_problem(&req.problem) ^ cfg.seed ^ ((attempt as u64) << 48),
                    );
                    match backoff_delay(&self.inner.retry, attempt, draw, remaining) {
                        Some(delay) => {
                            self.inner.retries_total.fetch_add(1, Ordering::Relaxed);
                            log_debug!(
                                "retrying {rid} (attempt {} of {}) in {}ms after: {e}",
                                attempt + 1,
                                self.inner.retry.max_attempts,
                                delay.as_millis()
                            );
                            prior = Some(e.to_string());
                            std::thread::sleep(delay);
                        }
                        None => break Err(e),
                    }
                }
                other => break other,
            }
        };
        if let Err(e) = &res {
            if e.http_status() == 503 {
                // saturation bounces never reach a shard, so the shard
                // can't seal their trace — the door does
                let tb = TraceBuilder::start(rid);
                self.inner.tracer.submit(tb.finish("error", 503, PhaseFlops::default()));
            }
        }
        if let Some(g) = sf_guard {
            // fan the leader's result out to every follower; the guard's
            // Drop (which runs even when dispatch panicked) only cleans
            // the table, so followers of a crashed leader error out
            // instead of hanging
            for w in g.take_waiters() {
                let _ = w.send(match &res {
                    Ok(s) => Ok(s.clone()),
                    Err(e) => Err(e.clone_class()),
                });
            }
        }
        if let (Ok(out), Some(cache)) = (&res, &self.inner.cache) {
            cache.lock().unwrap().put(key, out.outcome.clone());
        }
        res
    }

    /// Freeze this request's rejection schedule against the calibration
    /// table. `None` (controller off, vanilla mode) means the task runs
    /// the exact pre-controller static-`cfg.tau` path. The shadow draw is
    /// a pure function of the request identity and table epoch, so every
    /// duplicate that shares a cache/coalescing key froze the same plan.
    fn resolve_tau_plan(&self, req: &SolveRequest, cfg: &SearchConfig) -> Option<Arc<TauPlan>> {
        let hub = self.inner.tracer.calibration();
        let o = hub.opts();
        if !o.adaptive || cfg.mode != SearchMode::EarlyRejection {
            return None;
        }
        let epoch = hub.epoch();
        let stats = hub.bucket_stats(&req.prm);
        let draw = crate::util::stats::mix64(
            hash_problem(&req.problem) ^ cfg.seed ^ o.seed.wrapping_add(epoch),
        );
        let shadow = o.shadow_rate > 0.0
            && (draw >> 11) as f64 / (1u64 << 53) as f64 < o.shadow_rate;
        let ctl = AdaptiveTau {
            min_samples: o.min_samples,
            conf_floor: o.conf_floor,
            aggressiveness: o.aggressiveness,
            min_tau: o.min_tau,
        };
        let plan = ctl.plan(cfg.tau, &stats, shadow, epoch);
        hub.note_plan(&req.prm, &plan);
        Some(Arc::new(plan))
    }

    /// One placement attempt per shard: a dispatch lost to a dying shard
    /// (`Error::ShardLost`) immediately fails over to the next healthy
    /// one; other failures surface as-is.
    fn dispatch_with_failover(
        &self,
        req: SolveRequest,
        cfg: SearchConfig,
        tau_plan: Option<Arc<TauPlan>>,
        attempt: u32,
        prior: Option<String>,
        hangup: Option<&Arc<HangupProbe>>,
    ) -> Result<Solved> {
        let mut last_err = None;
        for _ in 0..self.inner.shards.len() {
            let (idx, guard) = self.reserve()?;
            match self.dispatch(
                idx,
                req.clone(),
                cfg.clone(),
                tau_plan.clone(),
                guard,
                attempt,
                prior.as_deref(),
                hangup,
            ) {
                Err(e) if e.is_retryable() => {
                    log_error!("shard {idx} lost this dispatch; failing request over: {e}");
                    last_err = Some(e);
                }
                other => return other,
            }
        }
        Err(last_err.unwrap_or_else(|| Error::shard_lost("every placement attempt failed")))
    }

    /// Solve on one specific shard, bypassing placement and the cache.
    /// Exists for tests and routing ablations (e.g. proving that seed
    /// determinism survives sharding by running the same request on two
    /// different shards).
    pub fn solve_on_shard(
        &self,
        idx: usize,
        req: SolveRequest,
        mut cfg: SearchConfig,
    ) -> Result<SolveOutcome> {
        if idx >= self.inner.shards.len() {
            return Err(Error::invalid(format!("no shard {idx}")));
        }
        cfg.mode = req.mode;
        cfg.n_beams = req.n_beams;
        cfg.tau = req.tau;
        cfg.validate()?;
        let guard = try_reserve(&self.inner.shards[idx].depth, self.inner.capacity)
            .ok_or_else(|| Error::saturated(format!("shard {idx} queue full")))?;
        let plan = self.resolve_tau_plan(&req, &cfg);
        self.dispatch(idx, req, cfg, plan, guard, 1, None, None).map(|s| s.outcome)
    }

    /// Placement signal per shard, `(primary, tiebreak)`. Sequential
    /// shards place by reserved queue depth. Fleet shards add *projected
    /// slot pressure* — inflight + queued demand against the slot table
    /// (ROADMAP: fleet-aware placement) — to the depth: the depth gauge
    /// alone overstates load on a shard whose requests coalesced onto few
    /// tasks and understates a slot table about to saturate, while the
    /// fleet gauges only refresh once per scheduler round, so keeping the
    /// per-reservation depth inside the primary signal is what spreads a
    /// same-round burst across shards instead of piling it onto whichever
    /// shard last published the lowest projection.
    fn placement_loads(&self) -> Vec<(usize, usize)> {
        self.inner
            .shards
            .iter()
            .map(|s| {
                let depth = s.depth.load(Ordering::Relaxed);
                if self.inner.fleet.is_some() {
                    let f = &s.fstats;
                    let projected =
                        f.inflight.load(Ordering::Relaxed) + f.queued.load(Ordering::Relaxed);
                    (projected + depth, depth)
                } else {
                    (depth, 0)
                }
            })
            .collect()
    }

    /// Claim a queue slot on the least-loaded live, non-full shard.
    /// Healthy shards are preferred; shards mid-restart (`STARTING`) are
    /// a fallback — their mailbox survives the engine load, so queuing on
    /// one beats bouncing the request when it's all that's left.
    /// Permanently dead shards never take traffic.
    fn reserve(&self) -> Result<(usize, DepthGuard)> {
        let loads = self.placement_loads();
        let order = placement_order(&loads);
        let mut any_alive = false;
        for wanted in [HEALTH_HEALTHY, HEALTH_STARTING] {
            for &idx in &order {
                let shard = &self.inner.shards[idx];
                if shard.slot.health() != wanted {
                    continue;
                }
                any_alive = true;
                if let Some(guard) = try_reserve(&shard.depth, self.inner.capacity) {
                    return Ok((idx, guard));
                }
            }
        }
        if !any_alive {
            // retryable: 503 + Retry-After, never a 4xx or a blameless 500
            return Err(Error::shard_lost("every engine shard is dead"));
        }
        Err(Error::saturated(format!(
            "all {} shard queues at capacity {}",
            self.inner.shards.len(),
            self.inner.capacity
        )))
    }

    /// The deadline applied to a request: its own `deadline_ms` if given,
    /// else the pool default (when nonzero). Applies in both modes —
    /// sequential shards enforce it at dequeue and on completion, fleet
    /// shards additionally abort mid-solve.
    fn effective_deadline(&self, req: &SolveRequest) -> Option<Duration> {
        req.deadline_ms
            .or(Some(self.inner.default_deadline_ms).filter(|&ms| ms > 0))
            .map(Duration::from_millis)
    }

    /// Enqueue on shard `idx` and await the reply. The guard is held for
    /// the whole round trip, so the depth gauge releases on every exit
    /// path. While waiting, the dispatcher watches three things between
    /// poll slices:
    ///
    /// * the reply channel — value or sender-dropped (shard panicked with
    ///   the job in flight → retryable [`Error::ShardLost`]);
    /// * the job's custody word — if the generation it names was retired
    ///   and the custody did not change across a confirming re-check (a
    ///   supervisor requeue updates custody *before* the retirement, and
    ///   the second strike gives an in-progress recovery time to land),
    ///   the job is lost → retryable [`Error::ShardLost`];
    /// * the client-disconnect probe — a hung-up client abandons the
    ///   reply channel (cancelling the solve) and returns
    ///   [`Error::Hangup`].
    #[allow(clippy::too_many_arguments)]
    fn dispatch(
        &self,
        idx: usize,
        req: SolveRequest,
        cfg: SearchConfig,
        tau_plan: Option<Arc<TauPlan>>,
        guard: DepthGuard,
        attempt: u32,
        prior: Option<&str>,
        hangup: Option<&Arc<HangupProbe>>,
    ) -> Result<Solved> {
        let _guard = guard;
        let shard = &self.inner.shards[idx];
        let (rtx, rrx) = oneshot::channel();
        // the trace starts here with the "queue" span open; the shard
        // closes it at admission and records the rest of the lifecycle.
        // (solve_timed mints ids; the fallback covers solve_on_shard and
        // direct callers.)
        let mut tb = Box::new(TraceBuilder::start(if req.request_id.is_empty() {
            mint_request_id()
        } else {
            req.request_id.clone()
        }));
        if attempt > 1 {
            tb.event(
                "retry",
                format!("attempt {attempt} after: {}", prior.unwrap_or("retryable failure")),
            );
        }
        tb.begin("queue");
        let custody = Arc::new(AtomicU64::new(pack_custody(idx, shard.slot.generation())));
        let job = SolveJob {
            deadline: self.effective_deadline(&req),
            priority: req.priority,
            req,
            cfg,
            enqueued: Instant::now(),
            reply: rtx,
            trace: Some(tb),
            tau_plan,
            custody: Arc::clone(&custody),
        };
        if shard.slot.mailbox().push(Msg::Solve(Box::new(job))).is_err() {
            // mailbox closed: the supervisor is mid-recovery on this shard
            return Err(Error::shard_lost(format!("engine shard {idx} mailbox closed")));
        }
        let mut strikes = 0u32;
        loop {
            match rrx.poll_for(DISPATCH_POLL) {
                oneshot::Polled::Value(res) => return res,
                oneshot::Polled::Disconnected => {
                    return Err(Error::shard_lost(format!(
                        "engine shard {idx} died mid-request"
                    )));
                }
                oneshot::Polled::Pending => {
                    if let Some(p) = hangup {
                        if p.hung_up() {
                            // dropping rrx abandons the reply channel; the
                            // fleet sees nobody listening and cancels
                            return Err(Error::hangup("client disconnected mid-solve"));
                        }
                    }
                    let c = custody.load(Ordering::SeqCst);
                    let (ci, cg) = unpack_custody(c);
                    let lost = self
                        .inner
                        .shards
                        .get(ci)
                        .is_none_or(|s| s.slot.generation() != cg);
                    if lost && custody.load(Ordering::SeqCst) == c {
                        strikes += 1;
                        if strikes >= 2 {
                            return Err(Error::shard_lost(format!(
                                "engine shard {ci} generation {cg} retired mid-request"
                            )));
                        }
                    } else {
                        strikes = 0;
                    }
                }
            }
        }
    }

    pub fn n_shards(&self) -> usize {
        self.inner.shards.len()
    }

    pub fn capacity_per_shard(&self) -> usize {
        self.inner.capacity
    }

    /// Whether shards run the fleet scheduler (vs sequential dispatch).
    pub fn fleet_enabled(&self) -> bool {
        self.inner.fleet.is_some()
    }

    /// Aggregate fleet counters across shards; `None` in sequential mode.
    pub fn fleet_totals(&self) -> Option<FleetTotals> {
        self.inner.fleet.as_ref()?;
        let mut agg = FleetTotals::default();
        for s in &self.inner.shards {
            FleetStats::merge_totals(&mut agg, s.fstats.totals());
        }
        Some(agg)
    }

    /// Whether shards gang-batch compatible requests into shared device
    /// batches (fleet mode with `gang` on).
    pub fn gang_enabled(&self) -> bool {
        self.inner.fleet.as_ref().map(|f| f.gang).unwrap_or(false)
    }

    /// Aggregate gang-batcher counters across shards; `None` unless gang
    /// mode is on.
    pub fn batch_totals(&self) -> Option<BatchTotals> {
        if !self.gang_enabled() {
            return None;
        }
        let mut agg = BatchTotals::default();
        for s in &self.inner.shards {
            BatchStats::merge_totals(&mut agg, s.bstats.totals());
        }
        Some(agg)
    }

    /// Total reserved slots across all shards.
    pub fn queue_depth(&self) -> usize {
        self.shard_depths().iter().sum()
    }

    /// Per-shard reserved-slot gauges.
    pub fn shard_depths(&self) -> Vec<usize> {
        self.inner.shards.iter().map(|s| s.depth.load(Ordering::Relaxed)).collect()
    }

    /// Per-shard completed-solve counters (utilization reporting).
    pub fn shard_solves(&self) -> Vec<u64> {
        self.inner.shards.iter().map(|s| s.solved.load(Ordering::Relaxed)).collect()
    }

    /// Per-shard liveness: true while the shard is healthy and serving
    /// (false mid-restart or once permanently dead).
    pub fn shard_alive(&self) -> Vec<bool> {
        self.inner.shards.iter().map(|s| s.slot.health() == HEALTH_HEALTHY).collect()
    }

    /// Per-shard health names for `/healthz` ("healthy" / "starting" /
    /// "dead").
    pub fn shard_health(&self) -> Vec<&'static str> {
        self.inner.shards.iter().map(|s| health_name(s.slot.health())).collect()
    }

    /// Per-shard supervisor respawn counters.
    pub fn shard_restarts(&self) -> Vec<u64> {
        self.inner.shards.iter().map(|s| s.slot.restarts()).collect()
    }

    /// Total supervisor respawns across shards.
    pub fn restarts_total(&self) -> u64 {
        self.shard_restarts().iter().sum()
    }

    /// Dispatch attempts the router transparently retried.
    pub fn retries_total(&self) -> u64 {
        self.inner.retries_total.load(Ordering::Relaxed)
    }

    /// Jobs the supervisor moved out of a lost shard's mailbox.
    pub fn requeued_total(&self) -> u64 {
        self.inner.requeued_total.load(Ordering::Relaxed)
    }

    /// Whether any shard can take traffic (healthy or restarting).
    pub fn any_serving(&self) -> bool {
        self.inner.shards.iter().any(|s| s.slot.health() != HEALTH_DEAD)
    }

    /// `(panics, stalls)` injected by the chaos schedule; `None` when
    /// chaos is off.
    pub fn chaos_injected(&self) -> Option<(u64, u64)> {
        self.inner.chaos.as_ref().map(|c| (c.panics_injected(), c.stalls_injected()))
    }

    /// Identical requests that coalesced onto an in-flight engine run at
    /// the pool level (cross-shard single-flight).
    pub fn pool_coalesced(&self) -> u64 {
        self.inner.pool_coalesced.load(Ordering::Relaxed)
    }

    /// Whether the pool-level single-flight table is on.
    pub fn singleflight_enabled(&self) -> bool {
        self.inner.singleflight.is_some()
    }

    /// (hits, misses) of the solve cache; (0, 0) when disabled.
    pub fn cache_counters(&self) -> (u64, u64) {
        (
            self.inner.cache_hits.load(Ordering::Relaxed),
            self.inner.cache_misses.load(Ordering::Relaxed),
        )
    }

    pub fn cache_enabled(&self) -> bool {
        self.inner.cache.is_some()
    }

    /// The pool's request-trace recorder (`/trace/<id>`, `/traces`,
    /// Chrome export, and the benchmarks' FLOPs-saved reporting).
    pub fn tracer(&self) -> &TraceRecorder {
        &self.inner.tracer
    }

    /// The calibration observatory's JSON table (`GET /calibration`):
    /// per-(checkpoint, depth-bucket) partial↔final correlation, sample
    /// counts, confidence verdicts, and the regret ledger.
    pub fn calibration_json(&self) -> String {
        self.inner.tracer.calibration().to_json().to_string()
    }

    /// Engine counters aggregated across all shards. Poison-tolerant: a
    /// shard that panicked mid-publish must not take `/metrics` down
    /// with it (the snapshot is plain counters, valid at every point).
    pub fn engine_stats(&self) -> EngineStats {
        let mut agg = EngineStats::default();
        for s in &self.inner.shards {
            agg.merge(&lock_unpoisoned(&s.stats));
        }
        agg
    }

    /// Pool-level gauges in Prometheus text exposition format (appended
    /// to `/metrics` output; every series carries `# HELP`/`# TYPE`).
    pub fn render_metrics(&self) -> String {
        use crate::obs::MetricWriter;
        let mut w = MetricWriter::new();
        w.gauge("erprm_pool_shards", "Engine shard threads.", self.n_shards() as f64);
        w.gauge(
            "erprm_pool_capacity_per_shard",
            "Queue slots per shard.",
            self.inner.capacity as f64,
        );
        w.gauge(
            "erprm_fleet_enabled",
            "1 when shards run the fleet scheduler.",
            self.fleet_enabled() as u8 as f64,
        );
        let alive = self.shard_alive();
        let restarts = self.shard_restarts();
        for (i, (d, n)) in self.shard_depths().iter().zip(self.shard_solves()).enumerate() {
            let l = format!("shard=\"{i}\"");
            w.gauge_labeled(
                "erprm_shard_queue_depth",
                "Requests reserved against the shard (queued + executing).",
                &l,
                *d as f64,
            );
            w.counter_labeled(
                "erprm_shard_solves_total",
                "Solves completed by the shard.",
                &l,
                n as f64,
            );
            w.gauge_labeled(
                "erprm_shard_alive",
                "0 while the shard is not serving (restarting or dead).",
                &l,
                alive[i] as u8 as f64,
            );
            w.gauge_labeled(
                "erprm_shard_health",
                "1 healthy, 0 mid-restart, -1 permanently dead.",
                &l,
                match self.inner.shards[i].slot.health() {
                    HEALTH_HEALTHY => 1.0,
                    HEALTH_DEAD => -1.0,
                    _ => 0.0,
                },
            );
            w.counter_labeled(
                "erprm_shard_restarts_total",
                "Supervisor respawns of the shard thread.",
                &l,
                restarts[i] as f64,
            );
        }
        w.counter(
            "erprm_retries_total",
            "Dispatch attempts transparently retried by the router.",
            self.retries_total() as f64,
        );
        w.counter(
            "erprm_requeued_total",
            "Queued jobs the supervisor moved off a lost shard.",
            self.requeued_total() as f64,
        );
        if let Some((panics, stalls)) = self.chaos_injected() {
            w.counter(
                "erprm_chaos_panics_injected_total",
                "Shard panics injected by the chaos schedule.",
                panics as f64,
            );
            w.counter(
                "erprm_chaos_stalls_injected_total",
                "Shard stalls injected by the chaos schedule.",
                stalls as f64,
            );
        }
        if self.fleet_enabled() {
            for (i, s) in self.inner.shards.iter().enumerate() {
                let f = &s.fstats;
                let l = format!("shard=\"{i}\"");
                w.gauge_labeled(
                    "erprm_fleet_inflight",
                    "Tasks occupying fleet slots.",
                    &l,
                    f.inflight.load(Ordering::Relaxed) as f64,
                );
                w.gauge_labeled(
                    "erprm_fleet_queued",
                    "Jobs in the shard's admission queue.",
                    &l,
                    f.queued.load(Ordering::Relaxed) as f64,
                );
                w.gauge_labeled(
                    "erprm_fleet_slot_occupancy",
                    "Mean slot-table occupancy over scheduler rounds.",
                    &l,
                    f.occupancy(),
                );
            }
            if let Some(t) = self.fleet_totals() {
                w.counter(
                    "erprm_fleet_admitted_total",
                    "Jobs admitted into fleet slots.",
                    t.admitted as f64,
                );
                w.counter(
                    "erprm_fleet_backfill_total",
                    "Admissions into a slot freed mid-round.",
                    t.backfill as f64,
                );
                w.counter(
                    "erprm_fleet_coalesced_total",
                    "Duplicates folded onto in-flight tasks.",
                    t.coalesced as f64,
                );
                w.counter(
                    "erprm_fleet_expired_total",
                    "Jobs bounced for exhausted deadlines (504).",
                    t.expired as f64,
                );
                w.counter(
                    "erprm_fleet_cancelled_total",
                    "Jobs dropped because every client hung up.",
                    t.cancelled as f64,
                );
                w.counter(
                    "erprm_fleet_forecast_rejected_total",
                    "Jobs bounced by the admission queue-wait forecast.",
                    t.forecast_rejected as f64,
                );
                w.counter(
                    "erprm_fleet_pool_deferred_total",
                    "Backfill rounds deferred for KV block-pool headroom.",
                    t.pool_deferred as f64,
                );
                w.counter(
                    "erprm_fleet_completed_total",
                    "Tasks completed successfully.",
                    t.completed as f64,
                );
                w.counter(
                    "erprm_fleet_failed_total",
                    "Tasks that errored terminally.",
                    t.failed as f64,
                );
            }
        }
        w.gauge(
            "erprm_batch_gang_enabled",
            "1 when fleet shards gang-batch compatible intents.",
            self.gang_enabled() as u8 as f64,
        );
        if let Some(b) = self.batch_totals() {
            w.counter("erprm_batch_gangs_total", "Shared gang device calls.", b.gangs as f64);
            w.counter(
                "erprm_batch_ganged_intents_total",
                "Intents executed inside a gang.",
                b.ganged_intents as f64,
            );
            w.counter(
                "erprm_batch_solo_intents_total",
                "Intents executed solo after waiting.",
                b.solo_intents as f64,
            );
            w.counter(
                "erprm_batch_merged_slots_total",
                "Real slots packed into gang batches.",
                b.merged_slots as f64,
            );
            w.counter(
                "erprm_batch_padding_slots_total",
                "Padding slots wasted in gang batches.",
                b.padding_slots as f64,
            );
            w.counter(
                "erprm_batch_wait_rounds_total",
                "Rounds parked intents waited for partners.",
                b.wait_rounds as f64,
            );
            w.counter(
                "erprm_batch_precompact_total",
                "Caches re-compacted to enable a gang merge.",
                b.precompacts as f64,
            );
            w.counter(
                "erprm_batch_gang_failures_total",
                "Gang device calls that failed.",
                b.gang_failures as f64,
            );
        }
        w.gauge(
            "erprm_pool_singleflight_enabled",
            "1 when the pool-level single-flight table is on.",
            self.singleflight_enabled() as u8 as f64,
        );
        w.counter(
            "erprm_pool_coalesced_total",
            "Requests that rode an in-flight identical run (cross-shard).",
            self.pool_coalesced() as f64,
        );
        let (hits, misses) = self.cache_counters();
        w.counter("erprm_cache_hits_total", "Solve-cache hits.", hits as f64);
        w.counter("erprm_cache_misses_total", "Solve-cache misses.", misses as f64);
        let s = self.engine_stats();
        w.counter(
            "erprm_engine_executions_total",
            "Device program executions.",
            s.executions as f64,
        );
        w.counter("erprm_engine_decode_calls_total", "Decode calls.", s.decode_calls as f64);
        w.counter("erprm_engine_score_calls_total", "Score calls.", s.score_calls as f64);
        w.counter("erprm_engine_merge_calls_total", "KV merge calls.", s.merge_calls as f64);
        // Block-native table edits: gang merges/splits and compactions
        // that were pure host bookkeeping (zero device calls). With
        // block-native attention on, these grow while the device-call
        // counters above stay flat for ganged traffic.
        w.counter(
            "erprm_kv_table_merges_total",
            "Gang merges done as pure block-table edits.",
            s.table_merges as f64,
        );
        w.counter(
            "erprm_kv_table_splits_total",
            "Gang splits done as pure block-table edits.",
            s.table_splits as f64,
        );
        w.counter(
            "erprm_kv_table_compacts_total",
            "Compactions done as pure block-table edits.",
            s.table_compacts as f64,
        );
        // KV re-compaction: junk share of spent cache positions (live
        // utilization signal), compactions run, and positions reclaimed
        w.gauge(
            "erprm_kv_junk_fraction",
            "Junk share of spent KV cache positions.",
            s.junk_fraction(),
        );
        w.counter("erprm_kv_compact_total", "KV re-compactions run.", s.compact_calls as f64);
        w.counter(
            "erprm_kv_reclaimed_positions_total",
            "KV positions reclaimed by re-compaction.",
            s.compact_reclaimed as f64,
        );
        // Paged-KV block pool (summed across shards; all-zero when the
        // pool is off or the artifacts predate paged export)
        w.gauge(
            "erprm_kv_pool_blocks_total",
            "Paged-KV block-pool capacity (all shards).",
            s.pool_blocks_total as f64,
        );
        w.gauge("erprm_kv_pool_blocks_free", "Free paged-KV blocks.", s.pool_blocks_free as f64);
        w.gauge("erprm_kv_pool_hwm", "Block-pool usage high-water mark.", s.pool_hwm as f64);
        // Admission-facing pool pressure in [0, 1]: how close the pool
        // has come to exhaustion (high-water mark over capacity), or the
        // deferred-admission rate when the fleet loop is holding jobs
        // back for block headroom — whichever signal is stronger. 0 on
        // dense engines.
        let occupancy = if s.pool_blocks_total == 0 {
            0.0
        } else {
            s.pool_hwm as f64 / s.pool_blocks_total as f64
        };
        let deferred_rate = match self.fleet_totals() {
            Some(t) if t.pool_deferred + t.admitted > 0 => {
                t.pool_deferred as f64 / (t.pool_deferred + t.admitted) as f64
            }
            _ => 0.0,
        };
        w.gauge(
            "erprm_kv_pool_pressure",
            "Admission-facing KV pool pressure in [0, 1].",
            occupancy.max(deferred_rate).min(1.0),
        );
        w.counter("erprm_engine_compiles_total", "Program compilations.", s.compiles as f64);
        w.gauge(
            "erprm_engine_compile_wall_seconds",
            "Wall seconds spent compiling.",
            s.compile_wall_s,
        );
        w.gauge(
            "erprm_engine_execute_wall_seconds",
            "Wall seconds spent executing.",
            s.execute_wall_s,
        );
        w.counter(
            "erprm_engine_host_bytes_up",
            "Host-to-device bytes transferred.",
            s.host_bytes_up as f64,
        );
        w.counter(
            "erprm_engine_host_bytes_down",
            "Device-to-host bytes transferred.",
            s.host_bytes_down as f64,
        );
        let mut out = w.finish();
        out.push_str(&self.inner.tracer.render_metrics());
        out
    }

    /// Stop the supervisor and all shard threads and wait for them to
    /// exit. The supervisor goes first so it cannot respawn a shard that
    /// is being told to stop.
    pub fn shutdown(&self) {
        self.inner.stopping.store(true, Ordering::SeqCst);
        if let Some(j) = lock_unpoisoned(&self.inner.supervisor).take() {
            let _ = j.join();
        }
        for s in &self.inner.shards {
            let mb = s.slot.mailbox();
            let _ = mb.push(Msg::Shutdown);
            mb.close();
        }
        for j in lock_unpoisoned(&self.inner.joins).drain(..) {
            let _ = j.join();
        }
    }
}

/// Spawn one shard thread around `body`, catching panics: an unwound
/// body flags its slot (generation-gated) so the supervisor recovers it.
fn spawn_shard_thread(body: ShardBody, ctx: ShardCtx) -> std::io::Result<JoinHandle<()>> {
    let slot = Arc::clone(&ctx.slot);
    let generation = ctx.generation;
    std::thread::Builder::new().name(format!("erprm-shard-{}", ctx.idx)).spawn(move || {
        if catch_unwind(AssertUnwindSafe(|| body(ctx))).is_err() {
            slot.note_panic(generation);
        }
    })
}

/// The pool supervisor: detects panicked (flagged) and wedged
/// (stale-heartbeat with reserved work) shards and recovers them —
/// respawn with a fresh engine, requeue their queued jobs, retire the
/// old generation. Consecutive failures back off exponentially so a
/// shard that dies on arrival cannot hot-loop respawns.
fn spawn_supervisor(inner: Arc<PoolInner>) -> std::io::Result<JoinHandle<()>> {
    std::thread::Builder::new().name("erprm-supervisor".into()).spawn(move || {
        let n = inner.shards.len();
        let interval = Duration::from_millis(inner.supervise.interval_ms.max(5));
        let mut consecutive = vec![0u32; n];
        let mut next_allowed = vec![Instant::now(); n];
        while !inner.stopping.load(Ordering::SeqCst) {
            std::thread::sleep(interval);
            for idx in 0..n {
                let slot = &inner.shards[idx].slot;
                if slot.health() == HEALTH_DEAD {
                    continue;
                }
                if Instant::now() < next_allowed[idx] {
                    continue;
                }
                let panicked = slot.take_panicked();
                let healthy = slot.health() == HEALTH_HEALTHY;
                if healthy && !panicked {
                    consecutive[idx] = 0;
                }
                let wedged = !panicked
                    && healthy
                    && inner.shards[idx].depth.load(Ordering::Relaxed) > 0
                    && slot.beat_age_ms() > inner.supervise.stale_ms;
                if !(panicked || wedged) {
                    continue;
                }
                recover_shard(&inner, idx, if panicked { "panicked" } else { "wedged" });
                consecutive[idx] = consecutive[idx].saturating_add(1);
                next_allowed[idx] =
                    Instant::now() + inner.supervise.restart_delay(consecutive[idx] - 1);
            }
        }
    })
}

/// Recover one lost shard: mark restarting, swap in a fresh mailbox,
/// requeue the old mailbox's jobs onto healthy shards (custody updated
/// *before* the generation bump, so waiting dispatchers see "moved" and
/// keep waiting rather than "lost"), retire the old generation (zombie
/// writes become no-ops, its loop exits at the next retirement check),
/// then respawn the thread with a fresh engine.
fn recover_shard(inner: &Arc<PoolInner>, idx: usize, reason: &str) {
    let shard = &inner.shards[idx];
    let slot = &shard.slot;
    slot.set_health(HEALTH_STARTING);
    let fresh = Arc::new(Mailbox::new());
    let old = slot.replace_mailbox(Arc::clone(&fresh));
    old.close();
    let pending = old.drain();
    let new_generation = slot.generation() + 1;
    let mut requeued = 0u64;
    for msg in pending {
        match msg {
            Msg::Shutdown => {
                let _ = fresh.push(Msg::Shutdown);
            }
            Msg::Solve(job) => {
                // least-loaded healthy shard, else this shard's own fresh
                // mailbox (it will serve once the respawn comes up)
                let target = inner
                    .shards
                    .iter()
                    .enumerate()
                    .filter(|(t, s)| *t != idx && s.slot.health() == HEALTH_HEALTHY)
                    .min_by_key(|(_, s)| s.depth.load(Ordering::Relaxed))
                    .map(|(t, _)| t);
                let moved = match target {
                    Some(t) => {
                        let ts = &inner.shards[t].slot;
                        job.custody.store(pack_custody(t, ts.generation()), Ordering::SeqCst);
                        ts.mailbox().push(Msg::Solve(job)).is_ok()
                    }
                    None => {
                        job.custody.store(pack_custody(idx, new_generation), Ordering::SeqCst);
                        fresh.push(Msg::Solve(job)).is_ok()
                    }
                };
                if moved {
                    requeued += 1;
                }
                // a failed push drops the job; its reply sender drops with
                // it and the dispatcher retries via ShardLost
            }
        }
    }
    inner.requeued_total.fetch_add(requeued, Ordering::Relaxed);
    let generation = slot.bump_generation();
    slot.record_restart();
    log_error!(
        "shard {idx} {reason}; respawning (generation {generation}, {requeued} jobs requeued)"
    );
    let ctx = ShardCtx {
        idx,
        generation,
        mailbox: fresh,
        slot: Arc::clone(slot),
        solved: Arc::clone(&shard.solved),
        stats: Arc::clone(&shard.stats),
        fstats: Arc::clone(&shard.fstats),
        bstats: Arc::clone(&shard.bstats),
        tracer: Arc::clone(&inner.tracer),
        chaos: inner.chaos.clone(),
        ready: None,
    };
    match spawn_shard_thread(Arc::clone(&inner.body), ctx) {
        Ok(j) => lock_unpoisoned(&inner.joins).push(j),
        Err(e) => {
            log_error!("shard {idx}: could not spawn replacement thread: {e}");
            slot.set_health(HEALTH_DEAD);
        }
    }
}

/// Body of one shard thread: load the engine, then serve solves until
/// shutdown or retirement — sequentially, or through the fleet scheduler
/// when configured. Publishes an engine-stats snapshot after every
/// solve. This is the production [`ShardBody`]; the supervisor re-runs
/// it (with a fresh `Engine`) when it respawns a shard.
fn real_shard_body(
    artifacts_dir: &std::path::Path,
    kv_pool_blocks: Option<usize>,
    fleet_opts: Option<FleetOptions>,
    mut ctx: ShardCtx,
) {
    let idx = ctx.idx;
    let engine = match Engine::load(artifacts_dir) {
        Ok(e) => {
            ctx.ready_ok();
            e
        }
        Err(e) => {
            ctx.ready_err(e);
            return;
        }
    };
    // pool sizing: an explicit CLI/config value wins; absent one, the
    // manifest's exported `pool_blocks` (the geometry the block-native
    // device pools were compiled for) is the default
    let pool_request = kv_pool_blocks.or(engine.manifest.pool_blocks).unwrap_or(0);
    if pool_request > 0 && !engine.enable_paging(pool_request) {
        // artifacts predate paged export (no kv_block in the manifest):
        // serve dense rather than refusing to start
        log_debug!("shard {idx}: manifest has no kv_block; paged KV off, dense caches");
    }
    let hooks = SlotHooks {
        slot: Arc::clone(&ctx.slot),
        generation: ctx.generation,
        chaos: ctx.chaos.clone(),
    };
    match fleet_opts {
        Some(opts) => {
            let mailbox = Arc::clone(&ctx.mailbox);
            fleet::drive(
                &engine,
                &opts,
                &ctx.fstats,
                &ctx.bstats,
                &ctx.solved,
                &ctx.stats,
                idx,
                &ctx.tracer,
                &hooks,
                |block| {
                    let msg =
                        if block { mailbox.recv_timeout(IDLE_TICK) } else { mailbox.try_recv() };
                    match msg {
                        MailRecv::Item(Msg::Solve(job)) => {
                            fleet::Poll::Job(Box::new(to_fleet_job(*job)))
                        }
                        MailRecv::Item(Msg::Shutdown) => fleet::Poll::Shutdown,
                        MailRecv::Empty => fleet::Poll::Empty,
                        MailRecv::Closed => fleet::Poll::Closed,
                    }
                },
            )
        }
        None => sequential_serve(&engine, &ctx, &hooks),
    }
}

/// The sequential dispatch loop (one request to completion at a time),
/// under the same per-round supervision contract as the fleet: heartbeat
/// every round, exit on retirement, honor the chaos draw per dequeued
/// job (work-aligned, so injection caps are consumed by load, not idle
/// ticks).
fn sequential_serve(engine: &Engine, ctx: &ShardCtx, hooks: &SlotHooks) {
    let idx = ctx.idx;
    let (solved, stats, tracer) = (&ctx.solved, &ctx.stats, &ctx.tracer);
    loop {
        hooks.beat();
        if hooks.retired() {
            break;
        }
        match ctx.mailbox.recv_timeout(IDLE_TICK) {
            MailRecv::Empty => continue,
            MailRecv::Closed | MailRecv::Item(Msg::Shutdown) => break,
            MailRecv::Item(Msg::Solve(job)) => {
                match hooks.chaos_tick() {
                    ChaosAction::Panic => panic!("chaos: injected shard panic (shard {idx})"),
                    ChaosAction::Stall(d) => std::thread::sleep(d),
                    ChaosAction::None => {}
                }
                let SolveJob { req, cfg, enqueued, deadline, reply, mut trace, tau_plan, .. } =
                    *job;
                let now = Instant::now();
                let queue_wait_ms =
                    now.saturating_duration_since(enqueued).as_secs_f64() * 1000.0;
                if let Some(tb) = trace.as_mut() {
                    tb.end(); // close the door-side "queue" span
                    tb.set_queue_wait(queue_wait_ms);
                    tb.set_placement(idx, 0); // sequential: one slot
                }
                if reply.is_closed() {
                    // the client hung up while the job sat in the
                    // queue: don't burn the engine for nobody
                    log_debug!("shard {idx}: dropping abandoned request");
                    if let Some(tb) = trace.take() {
                        tracer.submit(tb.finish("cancelled", 0, PhaseFlops::default()));
                    }
                    continue;
                }
                if let Some(d) = deadline {
                    if now.saturating_duration_since(enqueued) >= d {
                        if let Some(tb) = trace.take() {
                            tracer.submit(tb.finish("deadline", 504, PhaseFlops::default()));
                        }
                        let _ = reply.send(Err(Error::deadline(format!(
                            "spent {queue_wait_ms:.0}ms queued, budget was {}ms",
                            d.as_millis()
                        ))));
                        continue;
                    }
                }
                let _scope = trace.as_ref().map(|tb| logging::request_scope(tb.id()));
                let (solve_res, trace) = run_solve_traced(engine, &req, &cfg, tau_plan, trace);
                // capture the phase split before the 504 contract
                // can swallow the outcome: a too-late solve still
                // spent its FLOPs and the trace should say so
                let phase = solve_res
                    .as_ref()
                    .map(|o| PhaseFlops::from_ledger(&o.ledger))
                    .unwrap_or_default();
                let res = solve_res.and_then(|outcome| {
                    // a sequential solve can't be aborted
                    // mid-flight, but the end-to-end 504
                    // contract still holds: never a late 200
                    match deadline {
                        Some(d) if enqueued.elapsed() >= d => Err(Error::deadline(format!(
                            "solve finished after the {}ms budget",
                            d.as_millis()
                        ))),
                        _ => Ok(Solved { outcome, queue_wait_ms }),
                    }
                });
                if let Some(tb) = trace {
                    let t = match &res {
                        Ok(_) => tb.finish("ok", 200, phase),
                        Err(e) if e.http_status() == 504 => tb.finish("deadline", 504, phase),
                        Err(e) => tb.finish("error", e.http_status(), phase),
                    };
                    tracer.submit(t);
                }
                solved.fetch_add(1, Ordering::Relaxed);
                *lock_unpoisoned(stats) = engine.stats();
                if let Err(e) = &res {
                    log_error!("shard {idx}: solve failed: {e}");
                }
                let _ = reply.send(res);
            }
        }
    }
}

/// Convert a pool job into the fleet scheduler's envelope. The coalescing
/// key is the solve-cache key: equal keys are proven byte-identical, so
/// riding a duplicate's task is exactly as correct as a cache hit.
fn to_fleet_job(job: SolveJob) -> FleetJob {
    // mirror the pool's key derivation: a frozen adaptive plan extends
    // the key with its table epoch, so shard-local coalescing also only
    // folds duplicates that froze byte-identical plans
    let key = match &job.tau_plan {
        Some(p) => format!("{}|calib{}", job.req.cache_key(&job.cfg), p.epoch),
        None => job.req.cache_key(&job.cfg),
    };
    FleetJob {
        spec: TaskSpec {
            problem: job.req.problem.clone(),
            mode: job.cfg.mode,
            lm: job.req.lm.clone(),
            prm: job.req.prm.clone(),
            temp: temp_for(&job.req.lm),
            cfg: job.cfg,
            tau_plan: job.tau_plan,
        },
        key: Some(key),
        enqueued: job.enqueued,
        deadline: job.deadline,
        priority: job.priority,
        reply: job.reply,
        trace: job.trace,
    }
}

/// Run one solve as a [`crate::coordinator::task::SolveTask`] (the same
/// engine-call sequence the old direct solver made — pinned by the
/// integration suite's task-vs-direct equivalence tests) so the trace
/// rides the task, and hand it back at the end. The loop replaces
/// `run_to_completion`, which consumes the task along with the trace.
fn run_solve_traced(
    engine: &Engine,
    req: &SolveRequest,
    cfg: &SearchConfig,
    tau_plan: Option<Arc<TauPlan>>,
    trace: Option<Box<TraceBuilder>>,
) -> (Result<SolveOutcome>, Option<Box<TraceBuilder>>) {
    let spec = TaskSpec {
        problem: req.problem.clone(),
        mode: cfg.mode,
        lm: req.lm.clone(),
        prm: req.prm.clone(),
        temp: temp_for(&req.lm),
        cfg: cfg.clone(),
        tau_plan,
    };
    let mut task = match spec.build() {
        Ok(t) => t,
        Err(e) => return (Err(e), trace),
    };
    task.trace = trace;
    loop {
        match task.advance(engine) {
            Ok(Progress::Working) => {}
            Ok(Progress::Done) => break,
            Err(e) => return (Err(e), task.trace.take()),
        }
    }
    let out = task
        .take_outcome()
        .ok_or_else(|| Error::internal("finished task lost its outcome"));
    (out, task.trace.take())
}

/// Seed-stable LRU cache of solve outcomes. Solves are deterministic for a
/// fixed `(problem, config, seed)` (see `deterministic_solves_with_same_seed`
/// in the integration suite), so a hit is byte-identical to a recompute.
///
/// Recency is a monotonic tick per entry, so the hot path (hits, which
/// happen under the pool-wide cache mutex) is one hash lookup + counter
/// bump — O(1). Only an eviction (miss while full) scans for the
/// least-recently-used entry, and that path is immediately followed by a
/// full engine solve, which dwarfs the scan.
pub struct SolveCache {
    map: HashMap<String, CacheEntry>,
    tick: u64,
    cap: usize,
}

struct CacheEntry {
    out: SolveOutcome,
    last_used: u64,
}

impl SolveCache {
    pub fn new(cap: usize) -> SolveCache {
        assert!(cap > 0, "cache capacity must be positive (0 disables at the pool)");
        SolveCache { map: HashMap::new(), tick: 0, cap }
    }

    /// Lookup; a hit refreshes the entry's recency.
    pub fn get(&mut self, key: &str) -> Option<SolveOutcome> {
        self.tick += 1;
        let tick = self.tick;
        let entry = self.map.get_mut(key)?;
        entry.last_used = tick;
        Some(entry.out.clone())
    }

    /// Insert, evicting the least-recently-used entry at capacity.
    pub fn put(&mut self, key: String, out: SolveOutcome) {
        self.tick += 1;
        let tick = self.tick;
        if let Some(entry) = self.map.get_mut(&key) {
            entry.out = out;
            entry.last_used = tick;
            return;
        }
        if self.map.len() >= self.cap {
            if let Some(evict) = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&evict);
            }
        }
        self.map.insert(key, CacheEntry { out, last_used: tick });
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// A simple FIFO request queue wrapper for tests/ablation of routing.
#[derive(Default)]
pub struct FifoQueue<T> {
    inner: Mutex<std::collections::VecDeque<T>>,
}

impl<T> FifoQueue<T> {
    pub fn push(&self, item: T) {
        self.inner.lock().unwrap().push_back(item);
    }

    pub fn pop(&self) -> Option<T> {
        self.inner.lock().unwrap().pop_front()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Artifact-free pool construction for tests: canned shard bodies that
/// are ready immediately, answer deterministically from the request, and
/// honor the chaos schedule per dequeued job. Shared with the handler
/// tests (drain/health endpoints need a servable pool without engine
/// artifacts).
#[cfg(test)]
pub(crate) mod testkit {
    use super::*;
    use crate::coordinator::flops::FlopsLedger;
    use crate::tokenizer as tk;
    use crate::workload::{OpStep, Problem};

    /// The canned shards' answer function: a pure function of the
    /// request, so recovered/retried workloads can assert byte-identical
    /// results against a fault-free run.
    pub(crate) fn canned_answer(req: &SolveRequest) -> i64 {
        req.problem.v0 * 100 + req.problem.ops.len() as i64
    }

    pub(crate) fn canned_outcome(answer: i64) -> SolveOutcome {
        SolveOutcome {
            answer: Some(answer),
            correct: true,
            best_reward: 0.5,
            steps_executed: 1,
            wall_s: 0.1,
            ledger: FlopsLedger::new(10, 10),
            best_trace: vec![tk::ANS, tk::EOS],
            finished_beams: 1,
        }
    }

    /// A solve request whose canned answer is `v0 * 100 + 1`.
    pub(crate) fn request_for(v0: i64) -> SolveRequest {
        SolveRequest {
            problem: Problem { v0, ops: vec![OpStep { op: tk::PLUS, d: 3 }] },
            mode: SearchMode::EarlyRejection,
            n_beams: 8,
            tau: 8,
            lm: "lm-concise".into(),
            prm: "prm-large".into(),
            deadline_ms: None,
            priority: 0,
            request_id: String::new(),
        }
    }

    /// Spawn a pool of canned shards. `service` simulates per-job engine
    /// time (lets tests pile up a queue deterministically).
    pub(crate) fn canned_pool(opts: PoolOptions, service: Duration) -> EnginePool {
        let body: ShardBody = Arc::new(move |ctx| canned_body(ctx, service));
        EnginePool::spawn_with_body(opts, body).expect("canned pool spawns")
    }

    /// Force shard `idx`'s health byte — lets tests outside this module
    /// (handler drain/ready tests) simulate shard loss without reaching
    /// into the pool's private state.
    pub(crate) fn set_shard_health(pool: &EnginePool, idx: usize, health: u8) {
        pool.inner.shards[idx].slot.set_health(health);
    }

    /// The canned shard body: the same supervision contract as the real
    /// one (ready handshake, per-round heartbeat, retirement checks,
    /// work-aligned chaos draws), minus the engine.
    fn canned_body(mut ctx: ShardCtx, service: Duration) {
        ctx.ready_ok();
        loop {
            ctx.slot.beat(ctx.generation);
            if ctx.slot.generation() != ctx.generation {
                break;
            }
            match ctx.mailbox.recv_timeout(Duration::from_millis(10)) {
                MailRecv::Empty => continue,
                MailRecv::Closed | MailRecv::Item(Msg::Shutdown) => break,
                MailRecv::Item(Msg::Solve(job)) => {
                    if let Some(c) = &ctx.chaos {
                        if c.enabled() {
                            match c.tick(ctx.idx, ctx.slot.next_tick()) {
                                ChaosAction::Panic => {
                                    panic!("chaos: injected shard panic (shard {})", ctx.idx)
                                }
                                ChaosAction::Stall(d) => std::thread::sleep(d),
                                ChaosAction::None => {}
                            }
                        }
                    }
                    if !service.is_zero() {
                        std::thread::sleep(service);
                    }
                    let wait = job.enqueued.elapsed().as_secs_f64() * 1000.0;
                    ctx.solved.fetch_add(1, Ordering::Relaxed);
                    let _ = job.reply.send(Ok(Solved {
                        outcome: canned_outcome(canned_answer(&job.req)),
                        queue_wait_ms: wait,
                    }));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testkit::{canned_answer, canned_outcome, canned_pool, request_for};
    use super::*;
    use crate::config::SearchMode;
    use crate::tokenizer as tk;
    use crate::workload::{OpStep, Problem};

    #[test]
    fn fifo_order() {
        let q = FifoQueue::default();
        q.push(1);
        q.push(2);
        q.push(3);
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert!(q.is_empty());
    }

    #[test]
    fn spawn_fails_fast_without_artifacts() {
        let r = EnginePool::spawn(PathBuf::from("/nonexistent-artifacts"), 2, 4, 0);
        assert!(r.is_err());
        let r = EnginePool::spawn_with(
            PathBuf::from("/nonexistent-artifacts"),
            PoolOptions {
                capacity: 4,
                fleet: Some(FleetOptions::default()),
                ..PoolOptions::default()
            },
        );
        assert!(r.is_err());
    }

    #[test]
    fn spawn_with_rejects_zero_knobs() {
        let r = EnginePool::spawn_with(
            PathBuf::from("/nonexistent-artifacts"),
            PoolOptions { capacity: 0, ..PoolOptions::default() },
        );
        assert!(r.is_err());
        let r = EnginePool::spawn_with(
            PathBuf::from("/nonexistent-artifacts"),
            PoolOptions {
                capacity: 4,
                fleet: Some(FleetOptions { max_inflight: 0, ..FleetOptions::default() }),
                ..PoolOptions::default()
            },
        );
        assert!(r.is_err());
    }

    #[test]
    fn depth_guard_releases_on_drop() {
        let depth = Arc::new(AtomicUsize::new(0));
        let g1 = try_reserve(&depth, 2).expect("slot 1");
        let _g2 = try_reserve(&depth, 2).expect("slot 2");
        assert_eq!(depth.load(Ordering::Relaxed), 2);
        assert!(try_reserve(&depth, 2).is_none(), "at capacity");
        drop(g1);
        assert_eq!(depth.load(Ordering::Relaxed), 1);
        assert!(try_reserve(&depth, 2).is_some(), "slot recovered after drop");
    }

    #[test]
    fn placement_prefers_least_loaded_stably() {
        let loads = |v: &[usize]| v.iter().map(|&d| (d, 0)).collect::<Vec<_>>();
        assert_eq!(placement_order(&loads(&[3, 0, 2, 0])), vec![1, 3, 2, 0]);
        assert_eq!(placement_order(&loads(&[0, 0])), vec![0, 1]);
        assert_eq!(placement_order(&[]), Vec::<usize>::new());
        // the secondary signal (queue depth) breaks projected-load ties:
        // fleet gauges update once per round, depth moves per reservation
        assert_eq!(placement_order(&[(2, 1), (2, 0), (0, 9)]), vec![2, 1, 0]);
    }

    #[test]
    fn fleet_placement_uses_projected_slot_pressure() {
        let shard0 = fake_shard(0);
        let shard1 = fake_shard(1);
        // shard 0 looks empty by depth but its slot table is loaded;
        // shard 1 has a reservation in flight but free slots
        shard0.fstats.inflight.store(6, Ordering::Relaxed);
        shard0.fstats.queued.store(2, Ordering::Relaxed);
        shard0.depth.store(0, Ordering::Relaxed);
        shard1.fstats.inflight.store(1, Ordering::Relaxed);
        shard1.depth.store(1, Ordering::Relaxed);
        let mut pool = fake_pool(vec![shard0, shard1], Vec::new());
        // sequential pools still place by raw depth
        assert_eq!(pool.placement_loads(), vec![(0, 0), (1, 0)]);
        // fleet pools place by inflight + queued + depth: slot pressure
        // dominates, and depth keeps same-round bursts spreading
        let inner = Arc::get_mut(&mut pool.inner).unwrap();
        inner.fleet = Some(FleetOptions::default());
        assert_eq!(pool.placement_loads(), vec![(8, 0), (2, 1)]);
        assert_eq!(placement_order(&pool.placement_loads()), vec![1, 0]);
    }

    #[test]
    fn lru_cache_evicts_oldest() {
        let mut c = SolveCache::new(2);
        c.put("a".into(), canned_outcome(1));
        c.put("b".into(), canned_outcome(2));
        assert!(c.get("a").is_some()); // refresh 'a'; 'b' is now LRU
        c.put("c".into(), canned_outcome(3)); // evicts 'b'
        assert_eq!(c.len(), 2);
        assert!(c.get("b").is_none());
        assert_eq!(c.get("a").unwrap().answer, Some(1));
        assert_eq!(c.get("c").unwrap().answer, Some(3));
    }

    #[test]
    fn lru_cache_overwrite_keeps_len() {
        let mut c = SolveCache::new(2);
        c.put("a".into(), canned_outcome(1));
        c.put("a".into(), canned_outcome(9));
        assert_eq!(c.len(), 1);
        assert_eq!(c.get("a").unwrap().answer, Some(9));
    }

    /// A shard with a live slot (marked healthy) and no serving thread;
    /// pair with [`serve_fake`] to drain its mailbox.
    fn fake_shard(idx: usize) -> Shard {
        let slot = Arc::new(ShardSlot::new(idx));
        slot.mark_ready(slot.generation());
        Shard {
            slot,
            depth: Arc::new(AtomicUsize::new(0)),
            solved: Arc::new(AtomicU64::new(0)),
            stats: Arc::new(Mutex::new(EngineStats::default())),
            fstats: Arc::new(FleetStats::default()),
            bstats: Arc::new(BatchStats::default()),
        }
    }

    /// Drain a fake shard's mailbox on a thread, handing each solve job
    /// to `f`. Exits on shutdown/close like a real body.
    fn serve_fake(
        shard: &Shard,
        f: impl Fn(Box<SolveJob>) + Send + 'static,
    ) -> JoinHandle<()> {
        let mb = shard.slot.mailbox();
        std::thread::spawn(move || loop {
            match mb.recv_timeout(Duration::from_millis(20)) {
                MailRecv::Item(Msg::Solve(job)) => f(job),
                MailRecv::Item(Msg::Shutdown) | MailRecv::Closed => break,
                MailRecv::Empty => {}
            }
        })
    }

    fn fake_pool(shards: Vec<Shard>, joins: Vec<JoinHandle<()>>) -> EnginePool {
        let body: ShardBody = Arc::new(|_| {});
        EnginePool {
            inner: Arc::new(PoolInner {
                shards,
                capacity: 4,
                default_deadline_ms: 0,
                fleet: None,
                cache: None,
                cache_hits: AtomicU64::new(0),
                cache_misses: AtomicU64::new(0),
                singleflight: None,
                pool_coalesced: AtomicU64::new(0),
                tracer: Arc::new(TraceRecorder::new(TraceOptions::default())),
                joins: Mutex::new(joins),
                retry: RetryOptions { base_ms: 2, cap_ms: 8, ..RetryOptions::default() },
                supervise: SuperviseOptions { enabled: false, ..SuperviseOptions::default() },
                chaos: None,
                retries_total: AtomicU64::new(0),
                requeued_total: AtomicU64::new(0),
                stopping: AtomicBool::new(false),
                supervisor: Mutex::new(None),
                body,
            }),
        }
    }

    fn enable_singleflight(pool: &mut EnginePool) {
        let inner = Arc::get_mut(&mut pool.inner).unwrap();
        inner.singleflight = Some(Mutex::new(HashMap::new()));
    }

    fn request() -> SolveRequest {
        request_for(5)
    }

    #[test]
    fn solve_fails_over_from_dead_shard() {
        // shard 0: mailbox already closed => the push fails (ShardLost)
        let shard0 = fake_shard(0);
        shard0.slot.mailbox().close();
        // shard 1: fake engine thread replying a canned error
        let shard1 = fake_shard(1);
        let join = serve_fake(&shard1, |job| {
            let _ = job.reply.send(Err(Error::invalid("fake engine")));
        });
        let pool = fake_pool(vec![shard0, shard1], vec![join]);
        // Placement tries shard 0 first (tie -> lowest index), loses the
        // dispatch, and fails over to shard 1, whose reply comes through
        // (a non-retryable error, so no transparent retry either).
        let err = pool.solve(request(), SearchConfig::default()).unwrap_err();
        assert!(err.to_string().contains("fake engine"), "{err}");
        assert_eq!(pool.retries_total(), 0, "failover is not a retry");
        assert_eq!(pool.queue_depth(), 0, "guards released on both paths");
        pool.shutdown();
    }

    #[test]
    fn all_shards_dead_is_retryable_503_not_client_error() {
        let shard = fake_shard(0);
        shard.slot.mailbox().close();
        shard.slot.set_health(HEALTH_DEAD);
        let pool = fake_pool(vec![shard], Vec::new());
        assert!(!pool.any_serving());
        // Reserve finds nothing placeable: the request surfaces the
        // retryable 503 class (the supervisor may be respawning), never
        // a 4xx and no longer a blameless 500.
        let e1 = pool.solve(request(), SearchConfig::default()).unwrap_err();
        assert_eq!(e1.http_status(), 503, "{e1}");
        assert!(e1.is_retryable(), "{e1}");
        assert!(pool.retries_total() > 0, "the router did retry before giving up");
        assert_eq!(pool.queue_depth(), 0);
    }

    #[test]
    fn solve_timed_passes_queue_wait_through() {
        // fake shard replies with a canned Solved carrying queue telemetry
        let shard = fake_shard(0);
        let join = serve_fake(&shard, |job| {
            let wait = job.enqueued.elapsed().as_secs_f64() * 1000.0;
            let _ =
                job.reply.send(Ok(Solved { outcome: canned_outcome(7), queue_wait_ms: wait }));
        });
        let pool = fake_pool(vec![shard], vec![join]);
        let s = pool.solve_timed(request(), SearchConfig::default()).unwrap();
        assert_eq!(s.outcome.answer, Some(7));
        assert!(s.queue_wait_ms >= 0.0);
        assert_eq!(pool.queue_depth(), 0);
        pool.shutdown();
    }

    #[test]
    fn effective_deadline_prefers_request_over_pool_default() {
        let mut pool = fake_pool(vec![fake_shard(0)], Vec::new());
        // no pool default: only per-request deadlines apply
        assert_eq!(pool.effective_deadline(&request()), None);
        let mut req = request();
        req.deadline_ms = Some(250);
        assert_eq!(pool.effective_deadline(&req), Some(Duration::from_millis(250)));
        // a pool default applies when the request has none — in either
        // dispatch mode, which is why it lives on the pool, not the fleet
        let inner = Arc::get_mut(&mut pool.inner).unwrap();
        inner.default_deadline_ms = 1000;
        assert_eq!(pool.effective_deadline(&request()), Some(Duration::from_millis(1000)));
        assert_eq!(pool.effective_deadline(&req), Some(Duration::from_millis(250)));
        // a zero default means "no default"
        let inner = Arc::get_mut(&mut pool.inner).unwrap();
        inner.default_deadline_ms = 0;
        assert_eq!(pool.effective_deadline(&request()), None);
    }

    #[test]
    fn singleflight_coalesces_concurrent_identical_requests() {
        // fake shard: counts solves, replies after a pause long enough
        // for the followers to pile onto the leader's key
        let shard = fake_shard(0);
        let served = Arc::new(AtomicU64::new(0));
        let served2 = Arc::clone(&served);
        let join = serve_fake(&shard, move |job| {
            served2.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_millis(300));
            let _ = job.reply.send(Ok(Solved { outcome: canned_outcome(7), queue_wait_ms: 1.0 }));
        });
        let mut pool = fake_pool(vec![shard], vec![join]);
        enable_singleflight(&mut pool);
        assert!(pool.singleflight_enabled());
        let leader = {
            let p = pool.clone();
            std::thread::spawn(move || p.solve_timed(request(), SearchConfig::default()))
        };
        std::thread::sleep(Duration::from_millis(50)); // leader holds the key
        let followers: Vec<_> = (0..3)
            .map(|_| {
                let p = pool.clone();
                std::thread::spawn(move || p.solve_timed(request(), SearchConfig::default()))
            })
            .collect();
        // an identical request with a roomy deadline now joins as a
        // *timed* follower: the leader finishes well inside its budget,
        // so it rides the same engine run instead of dispatching its own
        let bounded = {
            let p = pool.clone();
            std::thread::spawn(move || {
                let mut r = request();
                r.deadline_ms = Some(60_000);
                p.solve_timed(r, SearchConfig::default())
            })
        };
        let lead = leader.join().unwrap().unwrap();
        assert_eq!(lead.outcome.answer, Some(7));
        for f in followers {
            let s = f.join().unwrap().expect("follower rides the leader");
            assert_eq!(s.outcome.answer, Some(7));
        }
        let b = bounded.join().unwrap().expect("bounded duplicate rides the leader too");
        assert_eq!(b.outcome.answer, Some(7));
        assert_eq!(
            served.load(Ordering::Relaxed),
            1,
            "one engine run served all five requests, bounded included"
        );
        assert_eq!(pool.pool_coalesced(), 4);
        assert!(pool.render_metrics().contains("erprm_pool_coalesced_total 4"));
        // the table drained: a later request dispatches fresh
        let again = pool.solve_timed(request(), SearchConfig::default()).unwrap();
        assert_eq!(again.outcome.answer, Some(7));
        assert_eq!(served.load(Ordering::Relaxed), 2);
        pool.shutdown();
    }

    #[test]
    fn bounded_followers_abandon_on_their_own_deadline() {
        // fake shard: slow enough that a tightly-bounded follower's
        // budget expires mid-wait, fast enough for the unbounded leader
        let shard = fake_shard(0);
        let served = Arc::new(AtomicU64::new(0));
        let served2 = Arc::clone(&served);
        let join = serve_fake(&shard, move |job| {
            served2.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_millis(250));
            let _ = job.reply.send(Ok(Solved { outcome: canned_outcome(7), queue_wait_ms: 1.0 }));
        });
        let mut pool = fake_pool(vec![shard], vec![join]);
        enable_singleflight(&mut pool);
        let leader = {
            let p = pool.clone();
            std::thread::spawn(move || p.solve_timed(request(), SearchConfig::default()))
        };
        std::thread::sleep(Duration::from_millis(40)); // leader holds the key
        // the bounded duplicate joins, times out on its own budget, and
        // 504s — without dispatching a second engine run and without
        // disturbing the leader (its late send bounces off the abandoned
        // channel)
        let t0 = Instant::now();
        let mut r = request();
        r.deadline_ms = Some(50);
        let fe = pool.solve_timed(r, SearchConfig::default()).unwrap_err();
        assert_eq!(fe.http_status(), 504, "timed follower 504s on its own budget: {fe}");
        assert!(t0.elapsed() < Duration::from_millis(200), "bounded wait, not the leader's");
        let lead = leader.join().unwrap().expect("leader unaffected by the abandon");
        assert_eq!(lead.outcome.answer, Some(7));
        assert_eq!(served.load(Ordering::Relaxed), 1, "the follower never dispatched");
        assert_eq!(pool.pool_coalesced(), 1);
        // a bounded request with no leader in flight never *leads*: it
        // dispatches solo and leaves no table entry behind for others
        let mut r2 = request();
        r2.deadline_ms = Some(60_000);
        let solo = pool.solve_timed(r2, SearchConfig::default()).unwrap();
        assert_eq!(solo.outcome.answer, Some(7));
        assert_eq!(served.load(Ordering::Relaxed), 2);
        pool.shutdown();
    }

    #[test]
    fn tau_plans_freeze_against_the_table_epoch() {
        use crate::obs::CalibOptions;
        let mut pool = fake_pool(vec![fake_shard(0)], Vec::new());
        let req = request();
        let mut cfg = SearchConfig::default();
        cfg.mode = SearchMode::EarlyRejection;
        cfg.tau = req.tau;
        // controller off (default): no plan — the exact pre-controller path
        assert!(pool.resolve_tau_plan(&req, &cfg).is_none());
        // controller on over an empty table: a static fallback plan
        let inner = Arc::get_mut(&mut pool.inner).unwrap();
        inner.tracer = Arc::new(TraceRecorder::new(TraceOptions {
            calib: CalibOptions { adaptive: true, shadow_rate: 0.0, ..Default::default() },
            ..Default::default()
        }));
        let p1 = pool.resolve_tau_plan(&req, &cfg).expect("adaptive ER request gets a plan");
        let p2 = pool.resolve_tau_plan(&req, &cfg).expect("and again");
        assert_eq!(*p1, *p2, "same request against the same epoch freezes the same plan");
        assert!(p1.is_static(), "a thin table falls back to the static tau everywhere");
        assert_eq!(p1.base, req.tau);
        assert_eq!(p1.epoch, 0);
        assert!(!p1.shadow, "shadow_rate 0 never draws a shadow");
        // vanilla requests never get a plan even with the controller on
        let mut vcfg = cfg.clone();
        vcfg.mode = SearchMode::Vanilla;
        assert!(pool.resolve_tau_plan(&req, &vcfg).is_none());
        // the resolves were counted in the ledger
        let doc = crate::util::json::Json::parse(&pool.calibration_json()).unwrap();
        let regret = doc.get("regret").unwrap();
        assert_eq!(
            regret.get("adaptive_requests").and_then(crate::util::json::Json::as_f64),
            Some(2.0)
        );
    }

    #[test]
    fn singleflight_followers_surface_leader_errors_by_class() {
        let shard = fake_shard(0);
        let join = serve_fake(&shard, |job| {
            std::thread::sleep(Duration::from_millis(120));
            let _ = job.reply.send(Err(Error::deadline("budget spent")));
        });
        let mut pool = fake_pool(vec![shard], vec![join]);
        enable_singleflight(&mut pool);
        let leader = {
            let p = pool.clone();
            std::thread::spawn(move || p.solve_timed(request(), SearchConfig::default()))
        };
        std::thread::sleep(Duration::from_millis(40));
        let follower = {
            let p = pool.clone();
            std::thread::spawn(move || p.solve_timed(request(), SearchConfig::default()))
        };
        let le = leader.join().unwrap().unwrap_err();
        let fe = follower.join().unwrap().unwrap_err();
        assert_eq!(le.http_status(), 504);
        assert_eq!(fe.http_status(), 504, "follower renders the leader's class: {fe}");
        pool.shutdown();
    }

    #[test]
    fn cache_key_distinguishes_seed_and_models() {
        let req = SolveRequest {
            problem: Problem { v0: 61, ops: vec![OpStep { op: tk::MINUS, d: 5 }] },
            mode: SearchMode::EarlyRejection,
            n_beams: 8,
            tau: 8,
            lm: "lm-concise".into(),
            prm: "prm-large".into(),
            deadline_ms: None,
            priority: 0,
            request_id: String::new(),
        };
        let cfg = SearchConfig { n_beams: 8, tau: 8, ..SearchConfig::default() };
        let k1 = req.cache_key(&cfg);
        let k2 = req.cache_key(&SearchConfig { seed: 1, ..cfg.clone() });
        assert_ne!(k1, k2, "seed must be part of the cache key");
        let mut req2 = req.clone();
        req2.prm = "prm-small".into();
        assert_ne!(k1, req2.cache_key(&cfg), "prm must be part of the cache key");
        assert_eq!(k1, req.cache_key(&cfg), "key is stable");
    }

    /// Fast supervision knobs for the chaos battery.
    fn fast_supervise() -> SuperviseOptions {
        SuperviseOptions {
            enabled: true,
            interval_ms: 5,
            stale_ms: 10_000,
            restart_backoff_ms: 1,
        }
    }

    fn fast_retry(max_attempts: u32) -> RetryOptions {
        RetryOptions { max_attempts, base_ms: 5, cap_ms: 40, retry_saturated: false }
    }

    /// Run `reqs` through `pool` on client threads; returns answers in
    /// request order (Err stringified for assertion messages).
    fn run_workload(pool: &EnginePool, reqs: Vec<SolveRequest>) -> Vec<Result<i64>> {
        let handles: Vec<_> = reqs
            .into_iter()
            .map(|r| {
                let p = pool.clone();
                std::thread::spawn(move || {
                    p.solve_timed(r, SearchConfig::default())
                        .map(|s| s.outcome.answer.unwrap_or(i64::MIN))
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    }

    #[test]
    fn chaos_panics_recover_with_zero_client_failures_and_identical_answers() {
        let opts = |chaos: ChaosOptions| PoolOptions {
            shards: 2,
            capacity: 16,
            supervise: fast_supervise(),
            retry: fast_retry(6),
            chaos,
            ..PoolOptions::default()
        };
        let chaos = ChaosOptions {
            seed: 7,
            panic_per_tick: 0.3,
            max_panics: 3,
            ..ChaosOptions::default()
        };
        let faulty = canned_pool(opts(chaos), Duration::from_millis(2));
        let clean = canned_pool(opts(ChaosOptions::default()), Duration::from_millis(2));
        let reqs = || (0..24).map(request_for).collect::<Vec<_>>();
        let with_faults = run_workload(&faulty, reqs());
        let without = run_workload(&clean, reqs());
        for (i, (a, b)) in with_faults.iter().zip(&without).enumerate() {
            let a = a.as_ref().expect("zero client-visible failures under chaos");
            let b = b.as_ref().expect("fault-free run");
            assert_eq!(a, b, "request {i}: answers must match the chaos-off run");
            assert_eq!(*a, canned_answer(&request_for(i as i64)), "request {i}");
        }
        let (panics, _) = faulty.chaos_injected().expect("chaos on");
        assert_eq!(panics, 3, "the cap bounds the schedule deterministically");
        assert!(faulty.restarts_total() >= 1, "the supervisor respawned panicked shards");
        let m = faulty.render_metrics();
        assert!(m.contains("erprm_shard_restarts_total"), "{m}");
        assert!(m.contains("erprm_chaos_panics_injected_total 3"), "{m}");
        assert!(m.contains("erprm_retries_total"), "{m}");
        assert_eq!(clean.restarts_total(), 0);
        assert_eq!(clean.chaos_injected(), None);
        faulty.shutdown();
        clean.shutdown();
        // respawn threads registered their joins; nothing left running
        assert_eq!(faulty.queue_depth(), 0);
    }

    #[test]
    fn supervisor_requeues_queued_jobs_from_a_lost_shard() {
        // one shard, slow service: pile three jobs up behind one in
        // flight, then declare the shard lost and watch the supervisor
        // move the queue onto the replacement generation.
        let pool = canned_pool(
            PoolOptions {
                shards: 1,
                capacity: 8,
                supervise: fast_supervise(),
                retry: fast_retry(4),
                ..PoolOptions::default()
            },
            Duration::from_millis(60),
        );
        let clients: Vec<_> = (0..4)
            .map(|i| {
                let p = pool.clone();
                std::thread::spawn(move || {
                    p.solve_timed(request_for(i), SearchConfig::default())
                        .map(|s| s.outcome.answer)
                })
            })
            .collect();
        // wait until one job is in service and three are queued
        let mb_len = || pool.inner.shards[0].slot.mailbox().len();
        let t0 = Instant::now();
        while mb_len() < 3 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(mb_len() >= 3, "three jobs queued behind the in-flight one");
        // simulate a panic report from the serving generation
        let slot = &pool.inner.shards[0].slot;
        slot.note_panic(slot.generation());
        for c in clients {
            let ans = c.join().unwrap().expect("requeued jobs complete on the new generation");
            assert!(ans.is_some());
        }
        assert_eq!(pool.restarts_total(), 1, "one recovery");
        assert!(
            pool.requeued_total() >= 3,
            "the queued jobs were moved, not dropped: {}",
            pool.requeued_total()
        );
        assert_eq!(pool.shard_health(), vec!["healthy"], "replacement is serving");
        let m = pool.render_metrics();
        assert!(m.contains("erprm_requeued_total"), "{m}");
        pool.shutdown();
    }

    #[test]
    fn wedged_shard_is_detected_and_the_request_retried() {
        // chaos stalls the only shard's first job far past stale_ms; the
        // supervisor declares it wedged (reserved work + stale
        // heartbeat), retires it, and the dispatcher's custody check
        // fails the in-flight job over to a transparent retry on the
        // replacement.
        let pool = canned_pool(
            PoolOptions {
                shards: 1,
                capacity: 4,
                supervise: SuperviseOptions { stale_ms: 100, ..fast_supervise() },
                retry: fast_retry(4),
                chaos: ChaosOptions {
                    seed: 11,
                    stall_per_tick: 1.0,
                    stall_ms: 1200,
                    max_stalls: 1,
                    ..ChaosOptions::default()
                },
                ..PoolOptions::default()
            },
            Duration::ZERO,
        );
        let t0 = Instant::now();
        let s = pool.solve_timed(request_for(3), SearchConfig::default()).unwrap();
        assert_eq!(s.outcome.answer, Some(canned_answer(&request_for(3))));
        assert!(
            t0.elapsed() < Duration::from_millis(1100),
            "served by the replacement, not the stalled zombie: {:?}",
            t0.elapsed()
        );
        assert_eq!(pool.restarts_total(), 1, "wedge detected exactly once");
        assert!(pool.retries_total() >= 1, "the lost dispatch was retried");
        assert_eq!(pool.chaos_injected(), Some((0, 1)));
        assert_eq!(pool.shard_health(), vec!["healthy"]);
        // shutdown joins the zombie too (it exits at its retirement
        // check once the injected stall elapses)
        pool.shutdown();
    }

    #[test]
    fn retry_respects_the_deadline_budget() {
        // the only shard's mailbox is closed for good (supervision off),
        // so every dispatch is a retryable loss; a bounded request must
        // give up within its budget instead of sleeping past it.
        let pool = canned_pool(
            PoolOptions {
                shards: 1,
                supervise: SuperviseOptions { enabled: false, ..SuperviseOptions::default() },
                retry: RetryOptions {
                    max_attempts: 50,
                    base_ms: 40,
                    cap_ms: 40,
                    retry_saturated: false,
                },
                ..PoolOptions::default()
            },
            Duration::ZERO,
        );
        pool.inner.shards[0].slot.mailbox().close();
        let mut req = request_for(1);
        req.deadline_ms = Some(120);
        let t0 = Instant::now();
        let e = pool.solve_timed(req, SearchConfig::default()).unwrap_err();
        assert_eq!(e.http_status(), 503, "{e}");
        assert!(t0.elapsed() < Duration::from_millis(400), "{:?}", t0.elapsed());
        let retries = pool.retries_total();
        assert!((1..=6).contains(&retries), "a few retries, nowhere near 50: {retries}");
        pool.shutdown();
    }

    #[test]
    fn failed_outcomes_are_never_cached() {
        // chaos kills the first attempt and retry is off: the request
        // fails 503. The failure must not poison the cache — the next
        // identical request (after recovery) recomputes and succeeds.
        let pool = canned_pool(
            PoolOptions {
                shards: 1,
                cache_entries: 8,
                supervise: fast_supervise(),
                retry: RetryOptions { max_attempts: 1, ..fast_retry(1) },
                chaos: ChaosOptions {
                    seed: 3,
                    panic_per_tick: 1.0,
                    max_panics: 1,
                    ..ChaosOptions::default()
                },
                ..PoolOptions::default()
            },
            Duration::ZERO,
        );
        let e = pool.solve_timed(request_for(9), SearchConfig::default()).unwrap_err();
        assert_eq!(e.http_status(), 503, "{e}");
        assert_eq!(
            pool.inner.cache.as_ref().unwrap().lock().unwrap().len(),
            0,
            "a failed solve must never be cached"
        );
        // wait for the respawn, then the same key succeeds and caches
        let t0 = Instant::now();
        while pool.restarts_total() < 1 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(2));
        }
        let s = pool.solve_timed(request_for(9), SearchConfig::default()).unwrap();
        assert_eq!(s.outcome.answer, Some(canned_answer(&request_for(9))));
        let (hits, misses) = pool.cache_counters();
        assert_eq!((hits, misses), (0, 2), "both solves missed; nothing was served stale");
        let s2 = pool.solve_timed(request_for(9), SearchConfig::default()).unwrap();
        assert_eq!(s2.outcome.answer, Some(canned_answer(&request_for(9))));
        assert_eq!(pool.cache_counters().0, 1, "the Ok outcome was cached");
        pool.shutdown();
    }

    #[test]
    fn healthz_accessors_report_per_shard_state() {
        let pool = canned_pool(
            PoolOptions {
                shards: 2,
                supervise: SuperviseOptions { enabled: false, ..SuperviseOptions::default() },
                ..PoolOptions::default()
            },
            Duration::ZERO,
        );
        assert_eq!(pool.shard_health(), vec!["healthy", "healthy"]);
        assert_eq!(pool.shard_alive(), vec![true, true]);
        assert!(pool.any_serving());
        assert_eq!(pool.shard_restarts(), vec![0, 0]);
        pool.inner.shards[1].slot.set_health(HEALTH_DEAD);
        assert_eq!(pool.shard_health(), vec!["healthy", "dead"]);
        assert_eq!(pool.shard_alive(), vec![true, false]);
        assert!(pool.any_serving());
        let m = pool.render_metrics();
        assert!(m.contains("erprm_shard_health"), "{m}");
        pool.inner.shards[0].slot.set_health(HEALTH_DEAD);
        assert!(!pool.any_serving());
        // both marked dead: restore so shutdown's pushes are harmless
        pool.shutdown();
    }
}
