//! The engine shard pool + request router.
//!
//! The PJRT engine is `!Send` (Rc-based client), so each engine lives on a
//! dedicated *shard* thread that owns it outright. [`EnginePool`] fronts N
//! such shards with a least-loaded dispatcher: HTTP workers reserve a slot
//! on the shallowest shard queue, enqueue the request, and block on a
//! oneshot-style reply channel. When every shard queue is at capacity the
//! pool rejects immediately with [`Error::Saturated`], which the HTTP
//! layer renders as **503 Service Unavailable** (never 4xx — 400 stays
//! reserved for parse/validation mistakes).
//!
//! A shard thread drains its queue in one of two modes:
//!
//! * **sequential** (the default): one request runs to completion before
//!   the next is dequeued — simple, but a long solve head-of-line blocks
//!   the queue and compute freed by early rejection mid-request is lost.
//! * **fleet** (`--fleet`): the thread runs the continuous scheduler in
//!   [`crate::fleet`] — up to `max_inflight` requests interleave as
//!   resumable [`crate::coordinator::task::SolveTask`]s, slots freed by
//!   completion or deadline abort are backfilled from the queue, and
//!   identical in-flight requests coalesce onto one engine run.
//!
//! Queue-depth accounting is leak-proof by construction: the caller that
//! reserves a slot holds a [`DepthGuard`] whose `Drop` releases it, so the
//! gauge recovers on every path — send failure, reply-channel failure, and
//! normal completion alike.
//!
//! The pool also carries a seed-stable LRU solve cache keyed on
//! `(problem, mode, n_beams, tau, m_expand, seed, lm, prm)` (see
//! [`crate::server::api::SolveRequest::cache_key`]): because solves are
//! deterministic for a fixed seed, repeated benchmark traffic
//! short-circuits entirely, and a hit returns a byte-identical outcome.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::batch::{BatchStats, BatchTotals};
use crate::config::{SearchConfig, SearchMode};
use crate::coordinator::policy::{AdaptiveTau, TauPlan};
use crate::coordinator::search::{hash_problem, SolveOutcome};
use crate::coordinator::task::Progress;
use crate::fleet::{self, FleetJob, FleetOptions, FleetStats, FleetTotals, Solved, TaskSpec};
use crate::harness::temp_for;
use crate::log_debug;
use crate::log_error;
use crate::obs::{mint_request_id, PhaseFlops, TraceBuilder, TraceOptions, TraceRecorder};
use crate::runtime::{Engine, EngineStats};
use crate::server::api::SolveRequest;
use crate::util::error::{Error, Result};
use crate::util::logging;
use crate::util::oneshot;

type Reply = oneshot::Sender<Result<Solved>>;

/// One enqueued request: the parsed solve plus its scheduling envelope.
struct SolveJob {
    req: SolveRequest,
    cfg: SearchConfig,
    enqueued: Instant,
    deadline: Option<Duration>,
    priority: i64,
    reply: Reply,
    /// Request trace, opened at dispatch with the door-side "queue" span
    /// running; the shard closes it and records the rest of the
    /// lifecycle.
    trace: Option<Box<TraceBuilder>>,
    /// Frozen adaptive-tau schedule resolved at admission (see
    /// [`EnginePool::resolve_tau_plan`]); `None` = static `cfg.tau`.
    tau_plan: Option<Arc<TauPlan>>,
}

enum Msg {
    Solve(Box<SolveJob>),
    Shutdown,
}

/// One engine shard: a thread owning its own `Engine`, fed by `tx`.
struct Shard {
    tx: mpsc::Sender<Msg>,
    /// Requests currently reserved against this shard (queued + executing
    /// + reply pending). Owned by callers via [`DepthGuard`].
    depth: Arc<AtomicUsize>,
    /// Total solves completed by this shard (utilization reporting).
    solved: Arc<AtomicU64>,
    /// Latest engine-stats snapshot published by the shard thread.
    stats: Arc<Mutex<EngineStats>>,
    /// Fleet-mode telemetry (all-zero when the shard runs sequentially).
    fstats: Arc<FleetStats>,
    /// Gang-batcher telemetry (all-zero unless fleet gang mode is on).
    bstats: Arc<BatchStats>,
    /// Set when the shard thread is observed dead (send/reply failure);
    /// placement skips dead shards so they can't keep attracting traffic
    /// with their permanently-empty queues.
    dead: AtomicBool,
}

/// Followers of one in-flight single-flight key, waiting on the leader.
type SfWaiters = Vec<oneshot::Sender<Result<Solved>>>;

struct PoolInner {
    shards: Vec<Shard>,
    capacity: usize,
    default_deadline_ms: u64,
    fleet: Option<FleetOptions>,
    cache: Option<Mutex<SolveCache>>,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    /// Pool-level single-flight table (ROADMAP: cross-shard coalescing).
    /// The shard-local fleet coalescer only folds duplicates placed on
    /// its own shard; this table catches identical in-flight requests
    /// *before placement*, so duplicates that least-loaded dispatch would
    /// have scattered across shards ride one engine run instead. `None`
    /// disables (the dedup contract is the same determinism the solve
    /// cache relies on: equal keys are proven byte-identical).
    singleflight: Option<Mutex<HashMap<String, SfWaiters>>>,
    pool_coalesced: AtomicU64,
    /// Request-trace ring + rollups, shared by every shard thread and the
    /// HTTP layer (`/trace/<id>`, `/traces`, `/traces/chrome`).
    tracer: Arc<TraceRecorder>,
    joins: Mutex<Vec<JoinHandle<()>>>,
}

/// Handle to the shard pool used by HTTP workers; cheap to clone.
#[derive(Clone)]
pub struct EnginePool {
    inner: Arc<PoolInner>,
}

/// Everything `spawn_with` needs to build a pool.
#[derive(Debug, Clone)]
pub struct PoolOptions {
    /// Engine shard threads.
    pub shards: usize,
    /// Queue slots per shard (must be positive).
    pub capacity: usize,
    /// LRU solve-cache entries; 0 disables caching.
    pub cache_entries: usize,
    /// Default per-request deadline (ms) applied when a request carries
    /// none; 0 disables the default. Honored in both dispatch modes.
    pub default_deadline_ms: u64,
    /// `Some` switches every shard to the fleet scheduler.
    pub fleet: Option<FleetOptions>,
    /// Pool-level single-flight: identical requests coalesce onto one
    /// engine run before placement, so duplicates landing on different
    /// shards no longer both execute.
    pub singleflight: bool,
    /// Paged-KV block pool size per shard (blocks of the manifest's
    /// `kv_block` tokens). `None` defaults to the manifest's exported
    /// `pool_blocks` sizing when present (the geometry block-native
    /// device pools were compiled for) and dense otherwise; `Some(0)`
    /// forces dense per-slot caches; `Some(n)` sets an explicit pool.
    /// Silently falls back to dense on artifact sets exported before
    /// paging existed.
    pub kv_pool_blocks: Option<usize>,
    /// Request-trace retention knobs (`--trace-capacity` /
    /// `--trace-sample`): ring size and success-sampling policy. Failures
    /// are always retained regardless of sampling.
    pub trace: TraceOptions,
}

/// RAII slot reservation against one shard's depth gauge. Dropping the
/// guard releases the slot, so the gauge can never leak — this replaces
/// the old fetch_add/fetch_sub pairing that leaked a slot whenever the
/// engine thread died between enqueue and reply.
struct DepthGuard {
    depth: Arc<AtomicUsize>,
}

impl Drop for DepthGuard {
    fn drop(&mut self) {
        self.depth.fetch_sub(1, Ordering::AcqRel);
    }
}

/// RAII cleanup of one single-flight leadership: removes the key from the
/// table on drop, so followers of a leader that panicked mid-dispatch see
/// their senders dropped (-> internal error on `recv`) instead of hanging
/// on a key nobody owns.
struct SingleFlightGuard<'a> {
    table: &'a Mutex<HashMap<String, SfWaiters>>,
    key: String,
}

impl SingleFlightGuard<'_> {
    /// Claim the accumulated followers (the normal completion path); the
    /// Drop that follows finds nothing left to clean.
    fn take_waiters(self) -> SfWaiters {
        self.table.lock().unwrap().remove(&self.key).unwrap_or_default()
    }
}

impl Drop for SingleFlightGuard<'_> {
    fn drop(&mut self) {
        let _ = self.table.lock().unwrap().remove(&self.key);
    }
}

/// Atomically claim a slot iff `depth < capacity` (CAS loop so concurrent
/// callers can't overshoot the bound).
fn try_reserve(depth: &Arc<AtomicUsize>, capacity: usize) -> Option<DepthGuard> {
    let mut cur = depth.load(Ordering::Relaxed);
    loop {
        if cur >= capacity {
            return None;
        }
        match depth.compare_exchange_weak(cur, cur + 1, Ordering::AcqRel, Ordering::Relaxed) {
            Ok(_) => return Some(DepthGuard { depth: Arc::clone(depth) }),
            Err(now) => cur = now,
        }
    }
}

/// Indices of shards in least-loaded-first order over `(primary,
/// tiebreak)` load signals (stable, so an idle pool drains
/// deterministically from shard 0).
fn placement_order(loads: &[(usize, usize)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..loads.len()).collect();
    idx.sort_by_key(|&i| loads[i]);
    idx
}

impl EnginePool {
    /// Spawn a sequential pool: `n_shards` engine threads (each loads its
    /// own `Engine` from `artifacts_dir`), `capacity` queue slots per
    /// shard, an LRU solve cache of `cache_entries` entries (0 disables).
    pub fn spawn(
        artifacts_dir: PathBuf,
        n_shards: usize,
        capacity: usize,
        cache_entries: usize,
    ) -> Result<EnginePool> {
        EnginePool::spawn_with(
            artifacts_dir,
            PoolOptions {
                shards: n_shards,
                capacity,
                cache_entries,
                default_deadline_ms: 0,
                fleet: None,
                singleflight: false,
                kv_pool_blocks: None,
                trace: TraceOptions::default(),
            },
        )
    }

    /// Spawn with full options (fleet mode included). Fails fast (in the
    /// caller) if any shard's artifacts are unloadable.
    pub fn spawn_with(artifacts_dir: PathBuf, opts: PoolOptions) -> Result<EnginePool> {
        let n_shards = opts.shards.max(1);
        if opts.capacity == 0 {
            return Err(Error::invalid("shard queue capacity must be positive"));
        }
        if let Some(f) = &opts.fleet {
            if f.max_inflight == 0 {
                return Err(Error::invalid("fleet max_inflight must be positive"));
            }
        }
        let tracer = Arc::new(TraceRecorder::new(opts.trace));
        let mut shards = Vec::with_capacity(n_shards);
        let mut joins = Vec::with_capacity(n_shards);
        let mut readies = Vec::with_capacity(n_shards);
        for i in 0..n_shards {
            let (tx, rx) = mpsc::channel::<Msg>();
            let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
            let depth = Arc::new(AtomicUsize::new(0));
            let solved = Arc::new(AtomicU64::new(0));
            let stats = Arc::new(Mutex::new(EngineStats::default()));
            let fstats = Arc::new(FleetStats::default());
            let bstats = Arc::new(BatchStats::default());
            let dir = artifacts_dir.clone();
            let solved2 = Arc::clone(&solved);
            let stats2 = Arc::clone(&stats);
            let fstats2 = Arc::clone(&fstats);
            let bstats2 = Arc::clone(&bstats);
            let fleet_opts = opts.fleet.clone();
            let kv_pool_blocks = opts.kv_pool_blocks;
            let tracer2 = Arc::clone(&tracer);
            let join = std::thread::Builder::new()
                .name(format!("erprm-shard-{i}"))
                .spawn(move || {
                    shard_main(
                        i, dir, kv_pool_blocks, rx, ready_tx, solved2, stats2, fleet_opts,
                        fstats2, bstats2, tracer2,
                    )
                })?;
            shards.push(Shard {
                tx,
                depth,
                solved,
                stats,
                fstats,
                bstats,
                dead: AtomicBool::new(false),
            });
            joins.push(join);
            readies.push(ready_rx);
        }
        let mut startup: Result<()> = Ok(());
        for (i, ready) in readies.into_iter().enumerate() {
            let r = ready
                .recv()
                .map_err(|_| Error::internal(format!("shard {i} died during startup")))
                .and_then(|r| r);
            if startup.is_ok() {
                startup = r;
            }
        }
        if let Err(e) = startup {
            // Unwind: stop any shards that did come up, then join all.
            for s in &shards {
                let _ = s.tx.send(Msg::Shutdown);
            }
            for j in joins {
                let _ = j.join();
            }
            return Err(e);
        }
        let cache = if opts.cache_entries > 0 {
            Some(Mutex::new(SolveCache::new(opts.cache_entries)))
        } else {
            None
        };
        Ok(EnginePool {
            inner: Arc::new(PoolInner {
                shards,
                capacity: opts.capacity,
                default_deadline_ms: opts.default_deadline_ms,
                fleet: opts.fleet,
                cache,
                cache_hits: AtomicU64::new(0),
                cache_misses: AtomicU64::new(0),
                singleflight: opts.singleflight.then(|| Mutex::new(HashMap::new())),
                pool_coalesced: AtomicU64::new(0),
                tracer,
                joins: Mutex::new(joins),
            }),
        })
    }

    /// Solve via the least-loaded shard; returns [`Error::Saturated`]
    /// immediately when every live shard queue is full (backpressure),
    /// and short-circuits through the solve cache when enabled. If the
    /// chosen shard thread turns out to be dead, the request fails over
    /// to the next live shard instead of surfacing the infrastructure
    /// fault to the client.
    pub fn solve(&self, req: SolveRequest, cfg: SearchConfig) -> Result<SolveOutcome> {
        self.solve_timed(req, cfg).map(|s| s.outcome)
    }

    /// Like [`EnginePool::solve`], but also reports how long the request
    /// waited for scheduling (`queue_wait_ms`; 0 on a cache hit, the
    /// leader's value when this request coalesced onto an in-flight
    /// single-flight run).
    pub fn solve_timed(&self, mut req: SolveRequest, mut cfg: SearchConfig) -> Result<Solved> {
        if req.request_id.is_empty() {
            req.request_id = mint_request_id();
        }
        cfg.mode = req.mode;
        cfg.n_beams = req.n_beams;
        cfg.tau = req.tau;
        if let Err(e) = cfg.validate() {
            // requests bounced at the door still leave a (failure, hence
            // always-retained) trace keyed by their id
            let tb = TraceBuilder::start(req.request_id.clone());
            self.inner.tracer.submit(tb.finish("error", e.http_status(), PhaseFlops::default()));
            return Err(e);
        }
        // Adaptive tau: freeze the rejection schedule for this request
        // against the current calibration table *before* any key is
        // built. The key embeds the table epoch, so cache hits and
        // coalesced duplicates are only ever shared between requests
        // that froze byte-identical plans.
        let tau_plan = self.resolve_tau_plan(&req, &cfg);
        let key = match &tau_plan {
            Some(p) => format!("{}|calib{}", req.cache_key(&cfg), p.epoch),
            None => req.cache_key(&cfg),
        };
        if let Some(cache) = &self.inner.cache {
            if let Some(hit) = cache.lock().unwrap().get(&key) {
                self.inner.cache_hits.fetch_add(1, Ordering::Relaxed);
                // mini-trace: the outcome's ledger is the phase split, so
                // /trace/<id> agrees with the response's flops field
                let mut tb = TraceBuilder::start(req.request_id.clone());
                tb.event("cache_hit", "solve cache");
                self.inner
                    .tracer
                    .submit(tb.finish("cache_hit", 200, PhaseFlops::from_ledger(&hit.ledger)));
                return Ok(Solved { outcome: hit, queue_wait_ms: 0.0 });
            }
            self.inner.cache_misses.fetch_add(1, Ordering::Relaxed);
        }
        // Pool-level single-flight: follow an in-flight leader for the
        // same key instead of dispatching a second engine run (possibly
        // onto a different shard, where the shard-local coalescer could
        // never see the duplicate). Deadline-bounded duplicates join as
        // *followers* with a timed wait capped at their own budget: if
        // the leader finishes in time they ride its result, otherwise
        // the timed `recv` consumes (abandons) the channel and the
        // request 504s on its own deadline instead of inheriting the
        // leader's fate. Bounded requests still never *lead* — a
        // tightly-bounded leader would impose its 504 on unbounded
        // followers — so a bounded request with no leader in flight
        // dispatches solo without claiming the key.
        let deadline = self.effective_deadline(&req);
        let sf_guard = if let Some(sf) = &self.inner.singleflight {
            let mut table = sf.lock().unwrap();
            if let Some(waiters) = table.get_mut(&key) {
                let (tx, rx) = oneshot::channel();
                waiters.push(tx);
                drop(table);
                self.inner.pool_coalesced.fetch_add(1, Ordering::Relaxed);
                let res: Result<Solved> = match deadline {
                    None => rx
                        .recv()
                        .map_err(|_| Error::internal("single-flight leader vanished"))?,
                    Some(budget) => match rx.recv_timeout(budget) {
                        Ok(r) => r,
                        Err(oneshot::RecvTimeoutError::Timeout) => {
                            // the timed recv consumed the receiver, so
                            // the leader's late send bounces harmlessly
                            Err(Error::deadline(format!(
                                "followed an in-flight identical run past the {}ms budget",
                                budget.as_millis()
                            )))
                        }
                        Err(oneshot::RecvTimeoutError::Disconnected) => {
                            return Err(Error::internal("single-flight leader vanished"));
                        }
                    },
                };
                // the follower's own trace ends at the door: it rode the
                // leader's engine run and inherits (or times out of) its
                // result
                let mut tb = TraceBuilder::start(req.request_id.clone());
                tb.event("coalesced", "pool single-flight follower");
                let t = match &res {
                    Ok(s) => {
                        tb.finish("coalesced", 200, PhaseFlops::from_ledger(&s.outcome.ledger))
                    }
                    Err(e) if e.http_status() == 504 => {
                        tb.finish("deadline", 504, PhaseFlops::default())
                    }
                    Err(e) => tb.finish("error", e.http_status(), PhaseFlops::default()),
                };
                self.inner.tracer.submit(t);
                return res;
            }
            if deadline.is_none() {
                table.insert(key.clone(), Vec::new());
                Some(SingleFlightGuard { table: sf, key: key.clone() })
            } else {
                None
            }
        } else {
            None
        };
        let rid = req.request_id.clone();
        let res = self.dispatch_with_failover(req, cfg, tau_plan);
        if let Err(e) = &res {
            if e.http_status() == 503 {
                // saturation bounces never reach a shard, so the shard
                // can't seal their trace — the door does
                let tb = TraceBuilder::start(rid);
                self.inner.tracer.submit(tb.finish("error", 503, PhaseFlops::default()));
            }
        }
        if let Some(g) = sf_guard {
            // fan the leader's result out to every follower; the guard's
            // Drop (which runs even when dispatch panicked) only cleans
            // the table, so followers of a crashed leader error out
            // instead of hanging
            for w in g.take_waiters() {
                let _ = w.send(match &res {
                    Ok(s) => Ok(s.clone()),
                    Err(e) => Err(e.clone_class()),
                });
            }
        }
        if let (Ok(out), Some(cache)) = (&res, &self.inner.cache) {
            cache.lock().unwrap().put(key, out.outcome.clone());
        }
        res
    }

    /// Freeze this request's rejection schedule against the calibration
    /// table. `None` (controller off, vanilla mode) means the task runs
    /// the exact pre-controller static-`cfg.tau` path. The shadow draw is
    /// a pure function of the request identity and table epoch, so every
    /// duplicate that shares a cache/coalescing key froze the same plan.
    fn resolve_tau_plan(&self, req: &SolveRequest, cfg: &SearchConfig) -> Option<Arc<TauPlan>> {
        let hub = self.inner.tracer.calibration();
        let o = hub.opts();
        if !o.adaptive || cfg.mode != SearchMode::EarlyRejection {
            return None;
        }
        let epoch = hub.epoch();
        let stats = hub.bucket_stats(&req.prm);
        let draw = crate::util::stats::mix64(
            hash_problem(&req.problem) ^ cfg.seed ^ o.seed.wrapping_add(epoch),
        );
        let shadow = o.shadow_rate > 0.0
            && (draw >> 11) as f64 / (1u64 << 53) as f64 < o.shadow_rate;
        let ctl = AdaptiveTau {
            min_samples: o.min_samples,
            conf_floor: o.conf_floor,
            aggressiveness: o.aggressiveness,
            min_tau: o.min_tau,
        };
        let plan = ctl.plan(cfg.tau, &stats, shadow, epoch);
        hub.note_plan(&req.prm, &plan);
        Some(Arc::new(plan))
    }

    /// One placement attempt per shard: a dispatch that dies marks its
    /// shard dead, and the next reserve() skips it.
    fn dispatch_with_failover(
        &self,
        req: SolveRequest,
        cfg: SearchConfig,
        tau_plan: Option<Arc<TauPlan>>,
    ) -> Result<Solved> {
        let mut last_err = None;
        for _ in 0..self.inner.shards.len() {
            let (idx, guard) = self.reserve()?;
            match self.dispatch(idx, req.clone(), cfg.clone(), tau_plan.clone(), guard) {
                Err(e) if self.inner.shards[idx].dead.load(Ordering::Relaxed) => {
                    log_error!("shard {idx} dead; failing request over: {e}");
                    last_err = Some(e);
                }
                other => return other,
            }
        }
        Err(last_err.unwrap_or_else(|| Error::internal("every engine shard is dead")))
    }

    /// Solve on one specific shard, bypassing placement and the cache.
    /// Exists for tests and routing ablations (e.g. proving that seed
    /// determinism survives sharding by running the same request on two
    /// different shards).
    pub fn solve_on_shard(
        &self,
        idx: usize,
        req: SolveRequest,
        mut cfg: SearchConfig,
    ) -> Result<SolveOutcome> {
        if idx >= self.inner.shards.len() {
            return Err(Error::invalid(format!("no shard {idx}")));
        }
        cfg.mode = req.mode;
        cfg.n_beams = req.n_beams;
        cfg.tau = req.tau;
        cfg.validate()?;
        let guard = try_reserve(&self.inner.shards[idx].depth, self.inner.capacity)
            .ok_or_else(|| Error::saturated(format!("shard {idx} queue full")))?;
        let plan = self.resolve_tau_plan(&req, &cfg);
        self.dispatch(idx, req, cfg, plan, guard).map(|s| s.outcome)
    }

    /// Placement signal per shard, `(primary, tiebreak)`. Sequential
    /// shards place by reserved queue depth. Fleet shards add *projected
    /// slot pressure* — inflight + queued demand against the slot table
    /// (ROADMAP: fleet-aware placement) — to the depth: the depth gauge
    /// alone overstates load on a shard whose requests coalesced onto few
    /// tasks and understates a slot table about to saturate, while the
    /// fleet gauges only refresh once per scheduler round, so keeping the
    /// per-reservation depth inside the primary signal is what spreads a
    /// same-round burst across shards instead of piling it onto whichever
    /// shard last published the lowest projection.
    fn placement_loads(&self) -> Vec<(usize, usize)> {
        self.inner
            .shards
            .iter()
            .map(|s| {
                let depth = s.depth.load(Ordering::Relaxed);
                if self.inner.fleet.is_some() {
                    let f = &s.fstats;
                    let projected =
                        f.inflight.load(Ordering::Relaxed) + f.queued.load(Ordering::Relaxed);
                    (projected + depth, depth)
                } else {
                    (depth, 0)
                }
            })
            .collect()
    }

    /// Claim a queue slot on the least-loaded live, non-full shard.
    fn reserve(&self) -> Result<(usize, DepthGuard)> {
        let loads = self.placement_loads();
        let mut any_alive = false;
        for idx in placement_order(&loads) {
            let shard = &self.inner.shards[idx];
            if shard.dead.load(Ordering::Relaxed) {
                continue;
            }
            any_alive = true;
            if let Some(guard) = try_reserve(&shard.depth, self.inner.capacity) {
                return Ok((idx, guard));
            }
        }
        if !any_alive {
            return Err(Error::internal("every engine shard is dead"));
        }
        Err(Error::saturated(format!(
            "all {} shard queues at capacity {}",
            self.inner.shards.len(),
            self.inner.capacity
        )))
    }

    /// The deadline applied to a request: its own `deadline_ms` if given,
    /// else the pool default (when nonzero). Applies in both modes —
    /// sequential shards enforce it at dequeue and on completion, fleet
    /// shards additionally abort mid-solve.
    fn effective_deadline(&self, req: &SolveRequest) -> Option<Duration> {
        req.deadline_ms
            .or(Some(self.inner.default_deadline_ms).filter(|&ms| ms > 0))
            .map(Duration::from_millis)
    }

    /// Enqueue on shard `idx` and await the reply. The guard is held for
    /// the whole round trip, so the depth gauge releases on every exit
    /// path, including a dead shard thread — which is also marked dead
    /// here so placement stops routing to it (an empty queue on a dead
    /// shard would otherwise look maximally attractive forever).
    fn dispatch(
        &self,
        idx: usize,
        req: SolveRequest,
        cfg: SearchConfig,
        tau_plan: Option<Arc<TauPlan>>,
        guard: DepthGuard,
    ) -> Result<Solved> {
        let _guard = guard;
        let shard = &self.inner.shards[idx];
        let (rtx, rrx) = oneshot::channel();
        // the trace starts here with the "queue" span open; the shard
        // closes it at admission and records the rest of the lifecycle.
        // (solve_timed mints ids; the fallback covers solve_on_shard and
        // direct callers.)
        let mut tb = Box::new(TraceBuilder::start(if req.request_id.is_empty() {
            mint_request_id()
        } else {
            req.request_id.clone()
        }));
        tb.begin("queue");
        let job = SolveJob {
            deadline: self.effective_deadline(&req),
            priority: req.priority,
            req,
            cfg,
            enqueued: Instant::now(),
            reply: rtx,
            trace: Some(tb),
            tau_plan,
        };
        if shard.tx.send(Msg::Solve(Box::new(job))).is_err() {
            shard.dead.store(true, Ordering::Relaxed);
            return Err(Error::internal(format!("engine shard {idx} gone")));
        }
        match rrx.recv() {
            Ok(res) => res,
            Err(_) => {
                shard.dead.store(true, Ordering::Relaxed);
                Err(Error::internal(format!("engine shard {idx} died mid-request")))
            }
        }
    }

    pub fn n_shards(&self) -> usize {
        self.inner.shards.len()
    }

    pub fn capacity_per_shard(&self) -> usize {
        self.inner.capacity
    }

    /// Whether shards run the fleet scheduler (vs sequential dispatch).
    pub fn fleet_enabled(&self) -> bool {
        self.inner.fleet.is_some()
    }

    /// Aggregate fleet counters across shards; `None` in sequential mode.
    pub fn fleet_totals(&self) -> Option<FleetTotals> {
        self.inner.fleet.as_ref()?;
        let mut agg = FleetTotals::default();
        for s in &self.inner.shards {
            FleetStats::merge_totals(&mut agg, s.fstats.totals());
        }
        Some(agg)
    }

    /// Whether shards gang-batch compatible requests into shared device
    /// batches (fleet mode with `gang` on).
    pub fn gang_enabled(&self) -> bool {
        self.inner.fleet.as_ref().map(|f| f.gang).unwrap_or(false)
    }

    /// Aggregate gang-batcher counters across shards; `None` unless gang
    /// mode is on.
    pub fn batch_totals(&self) -> Option<BatchTotals> {
        if !self.gang_enabled() {
            return None;
        }
        let mut agg = BatchTotals::default();
        for s in &self.inner.shards {
            BatchStats::merge_totals(&mut agg, s.bstats.totals());
        }
        Some(agg)
    }

    /// Total reserved slots across all shards.
    pub fn queue_depth(&self) -> usize {
        self.shard_depths().iter().sum()
    }

    /// Per-shard reserved-slot gauges.
    pub fn shard_depths(&self) -> Vec<usize> {
        self.inner.shards.iter().map(|s| s.depth.load(Ordering::Relaxed)).collect()
    }

    /// Per-shard completed-solve counters (utilization reporting).
    pub fn shard_solves(&self) -> Vec<u64> {
        self.inner.shards.iter().map(|s| s.solved.load(Ordering::Relaxed)).collect()
    }

    /// Per-shard liveness (false once a shard thread has been observed
    /// dead and placement stopped routing to it).
    pub fn shard_alive(&self) -> Vec<bool> {
        self.inner.shards.iter().map(|s| !s.dead.load(Ordering::Relaxed)).collect()
    }

    /// Identical requests that coalesced onto an in-flight engine run at
    /// the pool level (cross-shard single-flight).
    pub fn pool_coalesced(&self) -> u64 {
        self.inner.pool_coalesced.load(Ordering::Relaxed)
    }

    /// Whether the pool-level single-flight table is on.
    pub fn singleflight_enabled(&self) -> bool {
        self.inner.singleflight.is_some()
    }

    /// (hits, misses) of the solve cache; (0, 0) when disabled.
    pub fn cache_counters(&self) -> (u64, u64) {
        (
            self.inner.cache_hits.load(Ordering::Relaxed),
            self.inner.cache_misses.load(Ordering::Relaxed),
        )
    }

    pub fn cache_enabled(&self) -> bool {
        self.inner.cache.is_some()
    }

    /// The pool's request-trace recorder (`/trace/<id>`, `/traces`,
    /// Chrome export, and the benchmarks' FLOPs-saved reporting).
    pub fn tracer(&self) -> &TraceRecorder {
        &self.inner.tracer
    }

    /// The calibration observatory's JSON table (`GET /calibration`):
    /// per-(checkpoint, depth-bucket) partial↔final correlation, sample
    /// counts, confidence verdicts, and the regret ledger.
    pub fn calibration_json(&self) -> String {
        self.inner.tracer.calibration().to_json().to_string()
    }

    /// Engine counters aggregated across all shards.
    pub fn engine_stats(&self) -> EngineStats {
        let mut agg = EngineStats::default();
        for s in &self.inner.shards {
            agg.merge(&s.stats.lock().unwrap());
        }
        agg
    }

    /// Pool-level gauges in Prometheus text exposition format (appended
    /// to `/metrics` output; every series carries `# HELP`/`# TYPE`).
    pub fn render_metrics(&self) -> String {
        use crate::obs::MetricWriter;
        let mut w = MetricWriter::new();
        w.gauge("erprm_pool_shards", "Engine shard threads.", self.n_shards() as f64);
        w.gauge(
            "erprm_pool_capacity_per_shard",
            "Queue slots per shard.",
            self.inner.capacity as f64,
        );
        w.gauge(
            "erprm_fleet_enabled",
            "1 when shards run the fleet scheduler.",
            self.fleet_enabled() as u8 as f64,
        );
        let alive = self.shard_alive();
        for (i, (d, n)) in self.shard_depths().iter().zip(self.shard_solves()).enumerate() {
            let l = format!("shard=\"{i}\"");
            w.gauge_labeled(
                "erprm_shard_queue_depth",
                "Requests reserved against the shard (queued + executing).",
                &l,
                *d as f64,
            );
            w.counter_labeled(
                "erprm_shard_solves_total",
                "Solves completed by the shard.",
                &l,
                n as f64,
            );
            w.gauge_labeled(
                "erprm_shard_alive",
                "0 once the shard thread was observed dead.",
                &l,
                alive[i] as u8 as f64,
            );
        }
        if self.fleet_enabled() {
            for (i, s) in self.inner.shards.iter().enumerate() {
                let f = &s.fstats;
                let l = format!("shard=\"{i}\"");
                w.gauge_labeled(
                    "erprm_fleet_inflight",
                    "Tasks occupying fleet slots.",
                    &l,
                    f.inflight.load(Ordering::Relaxed) as f64,
                );
                w.gauge_labeled(
                    "erprm_fleet_queued",
                    "Jobs in the shard's admission queue.",
                    &l,
                    f.queued.load(Ordering::Relaxed) as f64,
                );
                w.gauge_labeled(
                    "erprm_fleet_slot_occupancy",
                    "Mean slot-table occupancy over scheduler rounds.",
                    &l,
                    f.occupancy(),
                );
            }
            if let Some(t) = self.fleet_totals() {
                w.counter(
                    "erprm_fleet_admitted_total",
                    "Jobs admitted into fleet slots.",
                    t.admitted as f64,
                );
                w.counter(
                    "erprm_fleet_backfill_total",
                    "Admissions into a slot freed mid-round.",
                    t.backfill as f64,
                );
                w.counter(
                    "erprm_fleet_coalesced_total",
                    "Duplicates folded onto in-flight tasks.",
                    t.coalesced as f64,
                );
                w.counter(
                    "erprm_fleet_expired_total",
                    "Jobs bounced for exhausted deadlines (504).",
                    t.expired as f64,
                );
                w.counter(
                    "erprm_fleet_cancelled_total",
                    "Jobs dropped because every client hung up.",
                    t.cancelled as f64,
                );
                w.counter(
                    "erprm_fleet_forecast_rejected_total",
                    "Jobs bounced by the admission queue-wait forecast.",
                    t.forecast_rejected as f64,
                );
                w.counter(
                    "erprm_fleet_pool_deferred_total",
                    "Backfill rounds deferred for KV block-pool headroom.",
                    t.pool_deferred as f64,
                );
                w.counter(
                    "erprm_fleet_completed_total",
                    "Tasks completed successfully.",
                    t.completed as f64,
                );
                w.counter(
                    "erprm_fleet_failed_total",
                    "Tasks that errored terminally.",
                    t.failed as f64,
                );
            }
        }
        w.gauge(
            "erprm_batch_gang_enabled",
            "1 when fleet shards gang-batch compatible intents.",
            self.gang_enabled() as u8 as f64,
        );
        if let Some(b) = self.batch_totals() {
            w.counter("erprm_batch_gangs_total", "Shared gang device calls.", b.gangs as f64);
            w.counter(
                "erprm_batch_ganged_intents_total",
                "Intents executed inside a gang.",
                b.ganged_intents as f64,
            );
            w.counter(
                "erprm_batch_solo_intents_total",
                "Intents executed solo after waiting.",
                b.solo_intents as f64,
            );
            w.counter(
                "erprm_batch_merged_slots_total",
                "Real slots packed into gang batches.",
                b.merged_slots as f64,
            );
            w.counter(
                "erprm_batch_padding_slots_total",
                "Padding slots wasted in gang batches.",
                b.padding_slots as f64,
            );
            w.counter(
                "erprm_batch_wait_rounds_total",
                "Rounds parked intents waited for partners.",
                b.wait_rounds as f64,
            );
            w.counter(
                "erprm_batch_precompact_total",
                "Caches re-compacted to enable a gang merge.",
                b.precompacts as f64,
            );
            w.counter(
                "erprm_batch_gang_failures_total",
                "Gang device calls that failed.",
                b.gang_failures as f64,
            );
        }
        w.gauge(
            "erprm_pool_singleflight_enabled",
            "1 when the pool-level single-flight table is on.",
            self.singleflight_enabled() as u8 as f64,
        );
        w.counter(
            "erprm_pool_coalesced_total",
            "Requests that rode an in-flight identical run (cross-shard).",
            self.pool_coalesced() as f64,
        );
        let (hits, misses) = self.cache_counters();
        w.counter("erprm_cache_hits_total", "Solve-cache hits.", hits as f64);
        w.counter("erprm_cache_misses_total", "Solve-cache misses.", misses as f64);
        let s = self.engine_stats();
        w.counter(
            "erprm_engine_executions_total",
            "Device program executions.",
            s.executions as f64,
        );
        w.counter("erprm_engine_decode_calls_total", "Decode calls.", s.decode_calls as f64);
        w.counter("erprm_engine_score_calls_total", "Score calls.", s.score_calls as f64);
        w.counter("erprm_engine_merge_calls_total", "KV merge calls.", s.merge_calls as f64);
        // Block-native table edits: gang merges/splits and compactions
        // that were pure host bookkeeping (zero device calls). With
        // block-native attention on, these grow while the device-call
        // counters above stay flat for ganged traffic.
        w.counter(
            "erprm_kv_table_merges_total",
            "Gang merges done as pure block-table edits.",
            s.table_merges as f64,
        );
        w.counter(
            "erprm_kv_table_splits_total",
            "Gang splits done as pure block-table edits.",
            s.table_splits as f64,
        );
        w.counter(
            "erprm_kv_table_compacts_total",
            "Compactions done as pure block-table edits.",
            s.table_compacts as f64,
        );
        // KV re-compaction: junk share of spent cache positions (live
        // utilization signal), compactions run, and positions reclaimed
        w.gauge(
            "erprm_kv_junk_fraction",
            "Junk share of spent KV cache positions.",
            s.junk_fraction(),
        );
        w.counter("erprm_kv_compact_total", "KV re-compactions run.", s.compact_calls as f64);
        w.counter(
            "erprm_kv_reclaimed_positions_total",
            "KV positions reclaimed by re-compaction.",
            s.compact_reclaimed as f64,
        );
        // Paged-KV block pool (summed across shards; all-zero when the
        // pool is off or the artifacts predate paged export)
        w.gauge(
            "erprm_kv_pool_blocks_total",
            "Paged-KV block-pool capacity (all shards).",
            s.pool_blocks_total as f64,
        );
        w.gauge("erprm_kv_pool_blocks_free", "Free paged-KV blocks.", s.pool_blocks_free as f64);
        w.gauge("erprm_kv_pool_hwm", "Block-pool usage high-water mark.", s.pool_hwm as f64);
        // Admission-facing pool pressure in [0, 1]: how close the pool
        // has come to exhaustion (high-water mark over capacity), or the
        // deferred-admission rate when the fleet loop is holding jobs
        // back for block headroom — whichever signal is stronger. 0 on
        // dense engines.
        let occupancy = if s.pool_blocks_total == 0 {
            0.0
        } else {
            s.pool_hwm as f64 / s.pool_blocks_total as f64
        };
        let deferred_rate = match self.fleet_totals() {
            Some(t) if t.pool_deferred + t.admitted > 0 => {
                t.pool_deferred as f64 / (t.pool_deferred + t.admitted) as f64
            }
            _ => 0.0,
        };
        w.gauge(
            "erprm_kv_pool_pressure",
            "Admission-facing KV pool pressure in [0, 1].",
            occupancy.max(deferred_rate).min(1.0),
        );
        w.counter("erprm_engine_compiles_total", "Program compilations.", s.compiles as f64);
        w.gauge(
            "erprm_engine_compile_wall_seconds",
            "Wall seconds spent compiling.",
            s.compile_wall_s,
        );
        w.gauge(
            "erprm_engine_execute_wall_seconds",
            "Wall seconds spent executing.",
            s.execute_wall_s,
        );
        w.counter(
            "erprm_engine_host_bytes_up",
            "Host-to-device bytes transferred.",
            s.host_bytes_up as f64,
        );
        w.counter(
            "erprm_engine_host_bytes_down",
            "Device-to-host bytes transferred.",
            s.host_bytes_down as f64,
        );
        let mut out = w.finish();
        out.push_str(&self.inner.tracer.render_metrics());
        out
    }

    /// Stop all shard threads and wait for them to exit.
    pub fn shutdown(&self) {
        for s in &self.inner.shards {
            let _ = s.tx.send(Msg::Shutdown);
        }
        for j in self.inner.joins.lock().unwrap().drain(..) {
            let _ = j.join();
        }
    }
}

/// Body of one shard thread: load the engine, then serve solves until
/// shutdown — sequentially, or through the fleet scheduler when
/// configured. Publishes an engine-stats snapshot after every solve.
#[allow(clippy::too_many_arguments)]
fn shard_main(
    idx: usize,
    artifacts_dir: PathBuf,
    kv_pool_blocks: Option<usize>,
    rx: mpsc::Receiver<Msg>,
    ready_tx: mpsc::Sender<Result<()>>,
    solved: Arc<AtomicU64>,
    stats: Arc<Mutex<EngineStats>>,
    fleet_opts: Option<FleetOptions>,
    fstats: Arc<FleetStats>,
    bstats: Arc<BatchStats>,
    tracer: Arc<TraceRecorder>,
) {
    let engine = match Engine::load(&artifacts_dir) {
        Ok(e) => {
            let _ = ready_tx.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    // pool sizing: an explicit CLI/config value wins; absent one, the
    // manifest's exported `pool_blocks` (the geometry the block-native
    // device pools were compiled for) is the default
    let pool_request = kv_pool_blocks.or(engine.manifest.pool_blocks).unwrap_or(0);
    if pool_request > 0 && !engine.enable_paging(pool_request) {
        // artifacts predate paged export (no kv_block in the manifest):
        // serve dense rather than refusing to start
        log_debug!("shard {idx}: manifest has no kv_block; paged KV off, dense caches");
    }
    match fleet_opts {
        Some(opts) => {
            fleet::drive(&engine, &opts, &fstats, &bstats, &solved, &stats, idx, &tracer, |block| {
                let msg = if block {
                    rx.recv().map_err(|_| mpsc::TryRecvError::Disconnected)
                } else {
                    rx.try_recv()
                };
                match msg {
                    Ok(Msg::Solve(job)) => fleet::Poll::Job(Box::new(to_fleet_job(*job))),
                    Ok(Msg::Shutdown) => fleet::Poll::Shutdown,
                    Err(mpsc::TryRecvError::Empty) => fleet::Poll::Empty,
                    Err(mpsc::TryRecvError::Disconnected) => fleet::Poll::Closed,
                }
            })
        }
        None => {
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Shutdown => break,
                    Msg::Solve(job) => {
                        let SolveJob {
                            req, cfg, enqueued, deadline, reply, mut trace, tau_plan, ..
                        } = *job;
                        let now = Instant::now();
                        let queue_wait_ms =
                            now.saturating_duration_since(enqueued).as_secs_f64() * 1000.0;
                        if let Some(tb) = trace.as_mut() {
                            tb.end(); // close the door-side "queue" span
                            tb.set_queue_wait(queue_wait_ms);
                            tb.set_placement(idx, 0); // sequential: one slot
                        }
                        if reply.is_closed() {
                            // the client hung up while the job sat in the
                            // queue: don't burn the engine for nobody
                            log_debug!("shard {idx}: dropping abandoned request");
                            if let Some(tb) = trace.take() {
                                tracer.submit(tb.finish("cancelled", 0, PhaseFlops::default()));
                            }
                            continue;
                        }
                        if let Some(d) = deadline {
                            if now.saturating_duration_since(enqueued) >= d {
                                if let Some(tb) = trace.take() {
                                    tracer
                                        .submit(tb.finish("deadline", 504, PhaseFlops::default()));
                                }
                                let _ = reply.send(Err(Error::deadline(format!(
                                    "spent {queue_wait_ms:.0}ms queued, budget was {}ms",
                                    d.as_millis()
                                ))));
                                continue;
                            }
                        }
                        let _scope = trace.as_ref().map(|tb| logging::request_scope(tb.id()));
                        let (solve_res, trace) =
                            run_solve_traced(&engine, &req, &cfg, tau_plan, trace);
                        // capture the phase split before the 504 contract
                        // can swallow the outcome: a too-late solve still
                        // spent its FLOPs and the trace should say so
                        let phase = solve_res
                            .as_ref()
                            .map(|o| PhaseFlops::from_ledger(&o.ledger))
                            .unwrap_or_default();
                        let res = solve_res.and_then(|outcome| {
                            // a sequential solve can't be aborted
                            // mid-flight, but the end-to-end 504
                            // contract still holds: never a late 200
                            match deadline {
                                Some(d) if enqueued.elapsed() >= d => Err(Error::deadline(
                                    format!(
                                        "solve finished after the {}ms budget",
                                        d.as_millis()
                                    ),
                                )),
                                _ => Ok(Solved { outcome, queue_wait_ms }),
                            }
                        });
                        if let Some(tb) = trace {
                            let t = match &res {
                                Ok(_) => tb.finish("ok", 200, phase),
                                Err(e) if e.http_status() == 504 => {
                                    tb.finish("deadline", 504, phase)
                                }
                                Err(e) => tb.finish("error", e.http_status(), phase),
                            };
                            tracer.submit(t);
                        }
                        solved.fetch_add(1, Ordering::Relaxed);
                        *stats.lock().unwrap() = engine.stats();
                        if let Err(e) = &res {
                            log_error!("shard {idx}: solve failed: {e}");
                        }
                        let _ = reply.send(res);
                    }
                }
            }
        }
    }
}

/// Convert a pool job into the fleet scheduler's envelope. The coalescing
/// key is the solve-cache key: equal keys are proven byte-identical, so
/// riding a duplicate's task is exactly as correct as a cache hit.
fn to_fleet_job(job: SolveJob) -> FleetJob {
    // mirror the pool's key derivation: a frozen adaptive plan extends
    // the key with its table epoch, so shard-local coalescing also only
    // folds duplicates that froze byte-identical plans
    let key = match &job.tau_plan {
        Some(p) => format!("{}|calib{}", job.req.cache_key(&job.cfg), p.epoch),
        None => job.req.cache_key(&job.cfg),
    };
    FleetJob {
        spec: TaskSpec {
            problem: job.req.problem.clone(),
            mode: job.cfg.mode,
            lm: job.req.lm.clone(),
            prm: job.req.prm.clone(),
            temp: temp_for(&job.req.lm),
            cfg: job.cfg,
            tau_plan: job.tau_plan,
        },
        key: Some(key),
        enqueued: job.enqueued,
        deadline: job.deadline,
        priority: job.priority,
        reply: job.reply,
        trace: job.trace,
    }
}

/// Run one solve as a [`crate::coordinator::task::SolveTask`] (the same
/// engine-call sequence the old direct solver made — pinned by the
/// integration suite's task-vs-direct equivalence tests) so the trace
/// rides the task, and hand it back at the end. The loop replaces
/// `run_to_completion`, which consumes the task along with the trace.
fn run_solve_traced(
    engine: &Engine,
    req: &SolveRequest,
    cfg: &SearchConfig,
    tau_plan: Option<Arc<TauPlan>>,
    trace: Option<Box<TraceBuilder>>,
) -> (Result<SolveOutcome>, Option<Box<TraceBuilder>>) {
    let spec = TaskSpec {
        problem: req.problem.clone(),
        mode: cfg.mode,
        lm: req.lm.clone(),
        prm: req.prm.clone(),
        temp: temp_for(&req.lm),
        cfg: cfg.clone(),
        tau_plan,
    };
    let mut task = match spec.build() {
        Ok(t) => t,
        Err(e) => return (Err(e), trace),
    };
    task.trace = trace;
    loop {
        match task.advance(engine) {
            Ok(Progress::Working) => {}
            Ok(Progress::Done) => break,
            Err(e) => return (Err(e), task.trace.take()),
        }
    }
    let out = task
        .take_outcome()
        .ok_or_else(|| Error::internal("finished task lost its outcome"));
    (out, task.trace.take())
}

/// Seed-stable LRU cache of solve outcomes. Solves are deterministic for a
/// fixed `(problem, config, seed)` (see `deterministic_solves_with_same_seed`
/// in the integration suite), so a hit is byte-identical to a recompute.
///
/// Recency is a monotonic tick per entry, so the hot path (hits, which
/// happen under the pool-wide cache mutex) is one hash lookup + counter
/// bump — O(1). Only an eviction (miss while full) scans for the
/// least-recently-used entry, and that path is immediately followed by a
/// full engine solve, which dwarfs the scan.
pub struct SolveCache {
    map: HashMap<String, CacheEntry>,
    tick: u64,
    cap: usize,
}

struct CacheEntry {
    out: SolveOutcome,
    last_used: u64,
}

impl SolveCache {
    pub fn new(cap: usize) -> SolveCache {
        assert!(cap > 0, "cache capacity must be positive (0 disables at the pool)");
        SolveCache { map: HashMap::new(), tick: 0, cap }
    }

    /// Lookup; a hit refreshes the entry's recency.
    pub fn get(&mut self, key: &str) -> Option<SolveOutcome> {
        self.tick += 1;
        let tick = self.tick;
        let entry = self.map.get_mut(key)?;
        entry.last_used = tick;
        Some(entry.out.clone())
    }

    /// Insert, evicting the least-recently-used entry at capacity.
    pub fn put(&mut self, key: String, out: SolveOutcome) {
        self.tick += 1;
        let tick = self.tick;
        if let Some(entry) = self.map.get_mut(&key) {
            entry.out = out;
            entry.last_used = tick;
            return;
        }
        if self.map.len() >= self.cap {
            if let Some(evict) = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&evict);
            }
        }
        self.map.insert(key, CacheEntry { out, last_used: tick });
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// A simple FIFO request queue wrapper for tests/ablation of routing.
#[derive(Default)]
pub struct FifoQueue<T> {
    inner: Mutex<std::collections::VecDeque<T>>,
}

impl<T> FifoQueue<T> {
    pub fn push(&self, item: T) {
        self.inner.lock().unwrap().push_back(item);
    }

    pub fn pop(&self) -> Option<T> {
        self.inner.lock().unwrap().pop_front()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SearchMode;
    use crate::coordinator::flops::FlopsLedger;
    use crate::tokenizer as tk;
    use crate::workload::{OpStep, Problem};

    #[test]
    fn fifo_order() {
        let q = FifoQueue::default();
        q.push(1);
        q.push(2);
        q.push(3);
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert!(q.is_empty());
    }

    #[test]
    fn spawn_fails_fast_without_artifacts() {
        let r = EnginePool::spawn(PathBuf::from("/nonexistent-artifacts"), 2, 4, 0);
        assert!(r.is_err());
        let r = EnginePool::spawn_with(
            PathBuf::from("/nonexistent-artifacts"),
            PoolOptions {
                shards: 1,
                capacity: 4,
                cache_entries: 0,
                default_deadline_ms: 0,
                fleet: Some(FleetOptions::default()),
                singleflight: false,
                kv_pool_blocks: None,
                trace: TraceOptions::default(),
            },
        );
        assert!(r.is_err());
    }

    #[test]
    fn spawn_with_rejects_zero_knobs() {
        let r = EnginePool::spawn_with(
            PathBuf::from("/nonexistent-artifacts"),
            PoolOptions {
                shards: 1,
                capacity: 0,
                cache_entries: 0,
                default_deadline_ms: 0,
                fleet: None,
                singleflight: false,
                kv_pool_blocks: None,
                trace: TraceOptions::default(),
            },
        );
        assert!(r.is_err());
        let r = EnginePool::spawn_with(
            PathBuf::from("/nonexistent-artifacts"),
            PoolOptions {
                shards: 1,
                capacity: 4,
                cache_entries: 0,
                default_deadline_ms: 0,
                fleet: Some(FleetOptions { max_inflight: 0, ..FleetOptions::default() }),
                singleflight: false,
                kv_pool_blocks: None,
                trace: TraceOptions::default(),
            },
        );
        assert!(r.is_err());
    }

    #[test]
    fn depth_guard_releases_on_drop() {
        let depth = Arc::new(AtomicUsize::new(0));
        let g1 = try_reserve(&depth, 2).expect("slot 1");
        let _g2 = try_reserve(&depth, 2).expect("slot 2");
        assert_eq!(depth.load(Ordering::Relaxed), 2);
        assert!(try_reserve(&depth, 2).is_none(), "at capacity");
        drop(g1);
        assert_eq!(depth.load(Ordering::Relaxed), 1);
        assert!(try_reserve(&depth, 2).is_some(), "slot recovered after drop");
    }

    #[test]
    fn placement_prefers_least_loaded_stably() {
        let loads = |v: &[usize]| v.iter().map(|&d| (d, 0)).collect::<Vec<_>>();
        assert_eq!(placement_order(&loads(&[3, 0, 2, 0])), vec![1, 3, 2, 0]);
        assert_eq!(placement_order(&loads(&[0, 0])), vec![0, 1]);
        assert_eq!(placement_order(&[]), Vec::<usize>::new());
        // the secondary signal (queue depth) breaks projected-load ties:
        // fleet gauges update once per round, depth moves per reservation
        assert_eq!(placement_order(&[(2, 1), (2, 0), (0, 9)]), vec![2, 1, 0]);
    }

    #[test]
    fn fleet_placement_uses_projected_slot_pressure() {
        let (tx0, _rx0) = mpsc::channel::<Msg>();
        let (tx1, _rx1) = mpsc::channel::<Msg>();
        let shard0 = fake_shard(tx0);
        let shard1 = fake_shard(tx1);
        // shard 0 looks empty by depth but its slot table is loaded;
        // shard 1 has a reservation in flight but free slots
        shard0.fstats.inflight.store(6, Ordering::Relaxed);
        shard0.fstats.queued.store(2, Ordering::Relaxed);
        shard0.depth.store(0, Ordering::Relaxed);
        shard1.fstats.inflight.store(1, Ordering::Relaxed);
        shard1.depth.store(1, Ordering::Relaxed);
        let mut pool = fake_pool(vec![shard0, shard1], Vec::new());
        // sequential pools still place by raw depth
        assert_eq!(pool.placement_loads(), vec![(0, 0), (1, 0)]);
        // fleet pools place by inflight + queued + depth: slot pressure
        // dominates, and depth keeps same-round bursts spreading
        let inner = Arc::get_mut(&mut pool.inner).unwrap();
        inner.fleet = Some(FleetOptions::default());
        assert_eq!(pool.placement_loads(), vec![(8, 0), (2, 1)]);
        assert_eq!(placement_order(&pool.placement_loads()), vec![1, 0]);
    }

    fn outcome(answer: i64) -> SolveOutcome {
        SolveOutcome {
            answer: Some(answer),
            correct: true,
            best_reward: 0.5,
            steps_executed: 1,
            wall_s: 0.1,
            ledger: FlopsLedger::new(10, 10),
            best_trace: vec![tk::ANS, tk::EOS],
            finished_beams: 1,
        }
    }

    #[test]
    fn lru_cache_evicts_oldest() {
        let mut c = SolveCache::new(2);
        c.put("a".into(), outcome(1));
        c.put("b".into(), outcome(2));
        assert!(c.get("a").is_some()); // refresh 'a'; 'b' is now LRU
        c.put("c".into(), outcome(3)); // evicts 'b'
        assert_eq!(c.len(), 2);
        assert!(c.get("b").is_none());
        assert_eq!(c.get("a").unwrap().answer, Some(1));
        assert_eq!(c.get("c").unwrap().answer, Some(3));
    }

    #[test]
    fn lru_cache_overwrite_keeps_len() {
        let mut c = SolveCache::new(2);
        c.put("a".into(), outcome(1));
        c.put("a".into(), outcome(9));
        assert_eq!(c.len(), 1);
        assert_eq!(c.get("a").unwrap().answer, Some(9));
    }

    fn fake_shard(tx: mpsc::Sender<Msg>) -> Shard {
        Shard {
            tx,
            depth: Arc::new(AtomicUsize::new(0)),
            solved: Arc::new(AtomicU64::new(0)),
            stats: Arc::new(Mutex::new(EngineStats::default())),
            fstats: Arc::new(FleetStats::default()),
            bstats: Arc::new(BatchStats::default()),
            dead: AtomicBool::new(false),
        }
    }

    fn fake_pool(shards: Vec<Shard>, joins: Vec<JoinHandle<()>>) -> EnginePool {
        EnginePool {
            inner: Arc::new(PoolInner {
                shards,
                capacity: 4,
                default_deadline_ms: 0,
                fleet: None,
                cache: None,
                cache_hits: AtomicU64::new(0),
                cache_misses: AtomicU64::new(0),
                singleflight: None,
                pool_coalesced: AtomicU64::new(0),
                tracer: Arc::new(TraceRecorder::new(TraceOptions::default())),
                joins: Mutex::new(joins),
            }),
        }
    }

    fn enable_singleflight(pool: &mut EnginePool) {
        let inner = Arc::get_mut(&mut pool.inner).unwrap();
        inner.singleflight = Some(Mutex::new(HashMap::new()));
    }

    fn request() -> SolveRequest {
        SolveRequest {
            problem: Problem { v0: 5, ops: vec![OpStep { op: tk::PLUS, d: 3 }] },
            mode: SearchMode::EarlyRejection,
            n_beams: 8,
            tau: 8,
            lm: "lm-concise".into(),
            prm: "prm-large".into(),
            deadline_ms: None,
            priority: 0,
            request_id: String::new(),
        }
    }

    #[test]
    fn solve_fails_over_from_dead_shard() {
        // shard 0: receiver already dropped => first send marks it dead
        let (tx0, rx0) = mpsc::channel::<Msg>();
        drop(rx0);
        // shard 1: fake engine thread replying a canned error
        let (tx1, rx1) = mpsc::channel::<Msg>();
        let join = std::thread::spawn(move || {
            while let Ok(msg) = rx1.recv() {
                match msg {
                    Msg::Shutdown => break,
                    Msg::Solve(job) => {
                        let _ = job.reply.send(Err(Error::invalid("fake engine")));
                    }
                }
            }
        });
        let pool = fake_pool(vec![fake_shard(tx0), fake_shard(tx1)], vec![join]);
        // Placement tries shard 0 first (tie -> lowest index), discovers it
        // dead, and fails over to shard 1, whose reply comes through.
        let err = pool.solve(request(), SearchConfig::default()).unwrap_err();
        assert!(err.to_string().contains("fake engine"), "{err}");
        assert_eq!(pool.shard_alive(), vec![false, true]);
        assert_eq!(pool.queue_depth(), 0, "guards released on both paths");
        pool.shutdown();
    }

    #[test]
    fn all_shards_dead_is_internal_not_client_error() {
        let (tx0, rx0) = mpsc::channel::<Msg>();
        drop(rx0);
        let pool = fake_pool(vec![fake_shard(tx0)], Vec::new());
        // First call trips over the dead shard; both calls must surface a
        // 500-class error, never a 4xx.
        let e1 = pool.solve(request(), SearchConfig::default()).unwrap_err();
        assert_eq!(e1.http_status(), 500, "{e1}");
        let e2 = pool.solve(request(), SearchConfig::default()).unwrap_err();
        assert_eq!(e2.http_status(), 500, "{e2}");
        assert_eq!(pool.queue_depth(), 0);
    }

    #[test]
    fn solve_timed_passes_queue_wait_through() {
        // fake shard replies with a canned Solved carrying queue telemetry
        let (tx, rx) = mpsc::channel::<Msg>();
        let join = std::thread::spawn(move || {
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Shutdown => break,
                    Msg::Solve(job) => {
                        let wait = job.enqueued.elapsed().as_secs_f64() * 1000.0;
                        let _ = job
                            .reply
                            .send(Ok(Solved { outcome: outcome(7), queue_wait_ms: wait }));
                    }
                }
            }
        });
        let pool = fake_pool(vec![fake_shard(tx)], vec![join]);
        let s = pool.solve_timed(request(), SearchConfig::default()).unwrap();
        assert_eq!(s.outcome.answer, Some(7));
        assert!(s.queue_wait_ms >= 0.0);
        assert_eq!(pool.queue_depth(), 0);
        pool.shutdown();
    }

    #[test]
    fn effective_deadline_prefers_request_over_pool_default() {
        let (tx, _rx) = mpsc::channel::<Msg>();
        let mut pool = fake_pool(vec![fake_shard(tx)], Vec::new());
        // no pool default: only per-request deadlines apply
        assert_eq!(pool.effective_deadline(&request()), None);
        let mut req = request();
        req.deadline_ms = Some(250);
        assert_eq!(pool.effective_deadline(&req), Some(Duration::from_millis(250)));
        // a pool default applies when the request has none — in either
        // dispatch mode, which is why it lives on the pool, not the fleet
        let inner = Arc::get_mut(&mut pool.inner).unwrap();
        inner.default_deadline_ms = 1000;
        assert_eq!(pool.effective_deadline(&request()), Some(Duration::from_millis(1000)));
        assert_eq!(pool.effective_deadline(&req), Some(Duration::from_millis(250)));
        // a zero default means "no default"
        let inner = Arc::get_mut(&mut pool.inner).unwrap();
        inner.default_deadline_ms = 0;
        assert_eq!(pool.effective_deadline(&request()), None);
    }

    #[test]
    fn singleflight_coalesces_concurrent_identical_requests() {
        // fake shard: counts solves, replies after a pause long enough
        // for the followers to pile onto the leader's key
        let (tx, rx) = mpsc::channel::<Msg>();
        let served = Arc::new(AtomicU64::new(0));
        let served2 = Arc::clone(&served);
        let join = std::thread::spawn(move || {
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Shutdown => break,
                    Msg::Solve(job) => {
                        served2.fetch_add(1, Ordering::Relaxed);
                        std::thread::sleep(Duration::from_millis(300));
                        let _ = job
                            .reply
                            .send(Ok(Solved { outcome: outcome(7), queue_wait_ms: 1.0 }));
                    }
                }
            }
        });
        let mut pool = fake_pool(vec![fake_shard(tx)], vec![join]);
        enable_singleflight(&mut pool);
        assert!(pool.singleflight_enabled());
        let leader = {
            let p = pool.clone();
            std::thread::spawn(move || p.solve_timed(request(), SearchConfig::default()))
        };
        std::thread::sleep(Duration::from_millis(50)); // leader holds the key
        let followers: Vec<_> = (0..3)
            .map(|_| {
                let p = pool.clone();
                std::thread::spawn(move || p.solve_timed(request(), SearchConfig::default()))
            })
            .collect();
        // an identical request with a roomy deadline now joins as a
        // *timed* follower: the leader finishes well inside its budget,
        // so it rides the same engine run instead of dispatching its own
        let bounded = {
            let p = pool.clone();
            std::thread::spawn(move || {
                let mut r = request();
                r.deadline_ms = Some(60_000);
                p.solve_timed(r, SearchConfig::default())
            })
        };
        let lead = leader.join().unwrap().unwrap();
        assert_eq!(lead.outcome.answer, Some(7));
        for f in followers {
            let s = f.join().unwrap().expect("follower rides the leader");
            assert_eq!(s.outcome.answer, Some(7));
        }
        let b = bounded.join().unwrap().expect("bounded duplicate rides the leader too");
        assert_eq!(b.outcome.answer, Some(7));
        assert_eq!(
            served.load(Ordering::Relaxed),
            1,
            "one engine run served all five requests, bounded included"
        );
        assert_eq!(pool.pool_coalesced(), 4);
        assert!(pool.render_metrics().contains("erprm_pool_coalesced_total 4"));
        // the table drained: a later request dispatches fresh
        let again = pool.solve_timed(request(), SearchConfig::default()).unwrap();
        assert_eq!(again.outcome.answer, Some(7));
        assert_eq!(served.load(Ordering::Relaxed), 2);
        pool.shutdown();
    }

    #[test]
    fn bounded_followers_abandon_on_their_own_deadline() {
        // fake shard: slow enough that a tightly-bounded follower's
        // budget expires mid-wait, fast enough for the unbounded leader
        let (tx, rx) = mpsc::channel::<Msg>();
        let served = Arc::new(AtomicU64::new(0));
        let served2 = Arc::clone(&served);
        let join = std::thread::spawn(move || {
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Shutdown => break,
                    Msg::Solve(job) => {
                        served2.fetch_add(1, Ordering::Relaxed);
                        std::thread::sleep(Duration::from_millis(250));
                        let _ = job
                            .reply
                            .send(Ok(Solved { outcome: outcome(7), queue_wait_ms: 1.0 }));
                    }
                }
            }
        });
        let mut pool = fake_pool(vec![fake_shard(tx)], vec![join]);
        enable_singleflight(&mut pool);
        let leader = {
            let p = pool.clone();
            std::thread::spawn(move || p.solve_timed(request(), SearchConfig::default()))
        };
        std::thread::sleep(Duration::from_millis(40)); // leader holds the key
        // the bounded duplicate joins, times out on its own budget, and
        // 504s — without dispatching a second engine run and without
        // disturbing the leader (its late send bounces off the abandoned
        // channel)
        let t0 = Instant::now();
        let mut r = request();
        r.deadline_ms = Some(50);
        let fe = pool.solve_timed(r, SearchConfig::default()).unwrap_err();
        assert_eq!(fe.http_status(), 504, "timed follower 504s on its own budget: {fe}");
        assert!(t0.elapsed() < Duration::from_millis(200), "bounded wait, not the leader's");
        let lead = leader.join().unwrap().expect("leader unaffected by the abandon");
        assert_eq!(lead.outcome.answer, Some(7));
        assert_eq!(served.load(Ordering::Relaxed), 1, "the follower never dispatched");
        assert_eq!(pool.pool_coalesced(), 1);
        // a bounded request with no leader in flight never *leads*: it
        // dispatches solo and leaves no table entry behind for others
        let mut r2 = request();
        r2.deadline_ms = Some(60_000);
        let solo = pool.solve_timed(r2, SearchConfig::default()).unwrap();
        assert_eq!(solo.outcome.answer, Some(7));
        assert_eq!(served.load(Ordering::Relaxed), 2);
        pool.shutdown();
    }

    #[test]
    fn tau_plans_freeze_against_the_table_epoch() {
        use crate::obs::CalibOptions;
        let (tx, _rx) = mpsc::channel::<Msg>();
        let mut pool = fake_pool(vec![fake_shard(tx)], Vec::new());
        let req = request();
        let mut cfg = SearchConfig::default();
        cfg.mode = SearchMode::EarlyRejection;
        cfg.tau = req.tau;
        // controller off (default): no plan — the exact pre-controller path
        assert!(pool.resolve_tau_plan(&req, &cfg).is_none());
        // controller on over an empty table: a static fallback plan
        let inner = Arc::get_mut(&mut pool.inner).unwrap();
        inner.tracer = Arc::new(TraceRecorder::new(TraceOptions {
            calib: CalibOptions { adaptive: true, shadow_rate: 0.0, ..Default::default() },
            ..Default::default()
        }));
        let p1 = pool.resolve_tau_plan(&req, &cfg).expect("adaptive ER request gets a plan");
        let p2 = pool.resolve_tau_plan(&req, &cfg).expect("and again");
        assert_eq!(*p1, *p2, "same request against the same epoch freezes the same plan");
        assert!(p1.is_static(), "a thin table falls back to the static tau everywhere");
        assert_eq!(p1.base, req.tau);
        assert_eq!(p1.epoch, 0);
        assert!(!p1.shadow, "shadow_rate 0 never draws a shadow");
        // vanilla requests never get a plan even with the controller on
        let mut vcfg = cfg.clone();
        vcfg.mode = SearchMode::Vanilla;
        assert!(pool.resolve_tau_plan(&req, &vcfg).is_none());
        // the resolves were counted in the ledger
        let doc = crate::util::json::Json::parse(&pool.calibration_json()).unwrap();
        let regret = doc.get("regret").unwrap();
        assert_eq!(
            regret.get("adaptive_requests").and_then(crate::util::json::Json::as_f64),
            Some(2.0)
        );
    }

    #[test]
    fn singleflight_followers_surface_leader_errors_by_class() {
        let (tx, rx) = mpsc::channel::<Msg>();
        let join = std::thread::spawn(move || {
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Shutdown => break,
                    Msg::Solve(job) => {
                        std::thread::sleep(Duration::from_millis(120));
                        let _ = job.reply.send(Err(Error::deadline("budget spent")));
                    }
                }
            }
        });
        let mut pool = fake_pool(vec![fake_shard(tx)], vec![join]);
        enable_singleflight(&mut pool);
        let leader = {
            let p = pool.clone();
            std::thread::spawn(move || p.solve_timed(request(), SearchConfig::default()))
        };
        std::thread::sleep(Duration::from_millis(40));
        let follower = {
            let p = pool.clone();
            std::thread::spawn(move || p.solve_timed(request(), SearchConfig::default()))
        };
        let le = leader.join().unwrap().unwrap_err();
        let fe = follower.join().unwrap().unwrap_err();
        assert_eq!(le.http_status(), 504);
        assert_eq!(fe.http_status(), 504, "follower renders the leader's class: {fe}");
        pool.shutdown();
    }

    #[test]
    fn cache_key_distinguishes_seed_and_models() {
        let req = SolveRequest {
            problem: Problem { v0: 61, ops: vec![OpStep { op: tk::MINUS, d: 5 }] },
            mode: SearchMode::EarlyRejection,
            n_beams: 8,
            tau: 8,
            lm: "lm-concise".into(),
            prm: "prm-large".into(),
            deadline_ms: None,
            priority: 0,
            request_id: String::new(),
        };
        let cfg = SearchConfig { n_beams: 8, tau: 8, ..SearchConfig::default() };
        let k1 = req.cache_key(&cfg);
        let k2 = req.cache_key(&SearchConfig { seed: 1, ..cfg.clone() });
        assert_ne!(k1, k2, "seed must be part of the cache key");
        let mut req2 = req.clone();
        req2.prm = "prm-small".into();
        assert_ne!(k1, req2.cache_key(&cfg), "prm must be part of the cache key");
        assert_eq!(k1, req.cache_key(&cfg), "key is stable");
    }
}
