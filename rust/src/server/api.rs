//! JSON API schema for the serving endpoints.
//!
//! POST /solve
//!   {"v0": 61, "ops": [["-",5],["*",6],["+",4]],
//!    "mode": "er"|"vanilla", "n_beams": 16, "tau": 8,
//!    "lm": "lm-concise", "prm": "prm-large",
//!    "deadline_ms": 2000, "priority": 5}           (mode.. optional)
//! -> {"answer": 40, "correct": null|bool, "reward": 0.93,
//!     "flops": 1.2e9, "lm_flops": ..., "prm_flops": ...,
//!     "steps": 4, "wall_ms": 812.3, "queue_wait_ms": 3.1,
//!     "trace": "61-5:60 ..."}
//!
//! `deadline_ms` bounds the request end to end (queued + solving); when
//! it elapses the server answers **504**. `priority` orders admission in
//! fleet mode (higher first; the aging guard prevents starvation).
//! `queue_wait_ms` is scheduling delay — subtract it from `wall_ms`'s
//! transport-inclusive sibling (client-measured latency) to separate
//! queueing from compute.
//!
//! GET /healthz -> {"ok": true}
//! GET /metrics -> text counters

use crate::config::{SearchConfig, SearchMode};
use crate::coordinator::search::SolveOutcome;
use crate::tokenizer as tk;
use crate::util::error::{Error, Result};
use crate::util::json::Json;
use crate::workload::{OpStep, Problem};

/// A parsed /solve request.
#[derive(Debug, Clone)]
pub struct SolveRequest {
    pub problem: Problem,
    pub mode: SearchMode,
    pub n_beams: usize,
    pub tau: usize,
    pub lm: String,
    pub prm: String,
    /// End-to-end time budget; `None` = unbounded (or the serve-wide
    /// fleet default). Not part of the cache key — it schedules the
    /// solve, it doesn't change it.
    pub deadline_ms: Option<u64>,
    /// Admission priority (higher first, 0 = default class).
    pub priority: i64,
    /// Trace key, echoed in the response. Minted at the HTTP door when
    /// the client supplied neither an `X-Request-Id` header nor a
    /// `request_id` body field. Not part of the cache key — it names
    /// the request, it doesn't change the solve.
    pub request_id: String,
}

impl SolveRequest {
    /// Stable key for the pool's solve cache. Covers every input that can
    /// change a solve's outcome: the problem itself, the search mode and
    /// its axes (N, tau, M), the sampling seed, and both checkpoints.
    /// Solves are deterministic given all of these, so equal keys imply
    /// byte-identical outcomes.
    pub fn cache_key(&self, cfg: &SearchConfig) -> String {
        let ops: Vec<String> =
            self.problem.ops.iter().map(|s| format!("{}.{}", s.op, s.d)).collect();
        format!(
            "{}|{}|{}|{}|{}|{}|{}|{}|{}",
            self.problem.v0,
            ops.join(","),
            cfg.mode.name(),
            cfg.n_beams,
            cfg.tau,
            cfg.m_expand,
            cfg.seed,
            self.lm,
            self.prm
        )
    }
}

pub fn parse_solve(body: &[u8], defaults: &SearchConfig) -> Result<SolveRequest> {
    let text = std::str::from_utf8(body).map_err(|_| Error::parse("body is not utf-8"))?;
    let j = Json::parse(text)?;
    let v0 = j.req("v0")?.as_i64().ok_or_else(|| Error::parse("v0 must be a number"))?;
    if !(0..tk::MOD).contains(&v0) {
        return Err(Error::invalid("v0 out of range [0,99]"));
    }
    let ops_json = j.req("ops")?.as_arr().ok_or_else(|| Error::parse("ops must be an array"))?;
    if ops_json.is_empty() || ops_json.len() > 6 {
        return Err(Error::invalid("ops must have 1..=6 entries"));
    }
    let mut ops = Vec::new();
    for o in ops_json {
        let pair = o.as_arr().ok_or_else(|| Error::parse("op must be [\"+\", d]"))?;
        let op = match pair.first().and_then(Json::as_str) {
            Some("+") => tk::PLUS,
            Some("-") => tk::MINUS,
            Some("*") => tk::TIMES,
            other => return Err(Error::parse(format!("bad op {other:?}"))),
        };
        let d = pair.get(1).and_then(Json::as_i64).ok_or_else(|| Error::parse("bad operand"))?;
        if !(1..=9).contains(&d) {
            return Err(Error::invalid("operand must be in 1..=9"));
        }
        ops.push(OpStep { op, d });
    }
    let mode = match j.get("mode").and_then(Json::as_str) {
        Some(m) => SearchMode::parse(m)?,
        None => defaults.mode,
    };
    let deadline_ms = match j.get("deadline_ms") {
        None => None,
        Some(v) => match v.as_i64() {
            Some(ms) if ms > 0 => Some(ms as u64),
            _ => return Err(Error::invalid("deadline_ms must be a positive integer")),
        },
    };
    let priority = match j.get("priority") {
        None => 0,
        Some(v) => v
            .as_i64()
            .ok_or_else(|| Error::parse("priority must be an integer"))?,
    };
    Ok(SolveRequest {
        problem: Problem { v0, ops },
        mode,
        n_beams: j.get("n_beams").and_then(Json::as_usize).unwrap_or(defaults.n_beams),
        tau: j.get("tau").and_then(Json::as_usize).unwrap_or(defaults.tau),
        lm: j.get("lm").and_then(Json::as_str).unwrap_or("lm-concise").to_string(),
        prm: j.get("prm").and_then(Json::as_str).unwrap_or("prm-large").to_string(),
        deadline_ms,
        priority,
        request_id: j
            .get("request_id")
            .and_then(Json::as_str)
            .and_then(crate::obs::sanitize_request_id)
            .unwrap_or_default(),
    })
}

/// Render a solve response. `queue_wait_ms` is the scheduling delay the
/// pool measured (enqueue → dispatch/admission), so clients can tell a
/// slow solve from a busy server.
pub fn render_solve(req: &SolveRequest, out: &SolveOutcome, queue_wait_ms: f64) -> String {
    let r = out.ledger.report();
    Json::obj(vec![
        ("request_id", Json::str(&req.request_id)),
        ("answer", out.answer.map(|a| Json::num(a as f64)).unwrap_or(Json::Null)),
        ("expected", Json::num(req.problem.answer() as f64)),
        ("correct", Json::Bool(out.correct)),
        ("reward", Json::num(out.best_reward as f64)),
        ("flops", Json::num(r.total_flops)),
        ("lm_flops", Json::num(r.lm_flops)),
        ("prm_flops", Json::num(r.prm_flops)),
        ("steps", Json::num(out.steps_executed as f64)),
        ("wall_ms", Json::num(out.wall_s * 1000.0)),
        ("queue_wait_ms", Json::num(queue_wait_ms)),
        ("finished_beams", Json::num(out.finished_beams as f64)),
        ("trace", Json::str(tk::detok(&out.best_trace))),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn defaults() -> SearchConfig {
        SearchConfig::default()
    }

    #[test]
    fn parse_full_request() {
        let body = br#"{"v0": 61, "ops": [["-",5],["*",6]], "mode": "er", "n_beams": 8, "tau": 4}"#;
        let r = parse_solve(body, &defaults()).unwrap();
        assert_eq!(r.problem.v0, 61);
        assert_eq!(r.problem.ops.len(), 2);
        assert_eq!(r.problem.ops[1].op, tk::TIMES);
        assert_eq!(r.n_beams, 8);
        assert_eq!(r.tau, 4);
        assert_eq!(r.mode, SearchMode::EarlyRejection);
    }

    #[test]
    fn parse_applies_defaults() {
        let body = br#"{"v0": 5, "ops": [["+",3]]}"#;
        let r = parse_solve(body, &defaults()).unwrap();
        assert_eq!(r.n_beams, defaults().n_beams);
        assert_eq!(r.lm, "lm-concise");
        assert_eq!(r.deadline_ms, None, "no deadline unless requested");
        assert_eq!(r.priority, 0, "default priority class");
    }

    #[test]
    fn parse_deadline_and_priority() {
        let body = br#"{"v0": 5, "ops": [["+",3]], "deadline_ms": 2500, "priority": -2}"#;
        let r = parse_solve(body, &defaults()).unwrap();
        assert_eq!(r.deadline_ms, Some(2500));
        assert_eq!(r.priority, -2);
        // zero/negative deadlines are configuration mistakes, not requests
        assert!(parse_solve(br#"{"v0": 5, "ops": [["+",3]], "deadline_ms": 0}"#, &defaults())
            .is_err());
        assert!(parse_solve(br#"{"v0": 5, "ops": [["+",3]], "deadline_ms": -5}"#, &defaults())
            .is_err());
        assert!(parse_solve(br#"{"v0": 5, "ops": [["+",3]], "priority": "high"}"#, &defaults())
            .is_err());
    }

    #[test]
    fn parse_accepts_body_request_id() {
        let body = br#"{"v0": 5, "ops": [["+",3]], "request_id": "client-7"}"#;
        let r = parse_solve(body, &defaults()).unwrap();
        assert_eq!(r.request_id, "client-7");
        // absent or junk ids are left for the door to mint
        let r = parse_solve(br#"{"v0": 5, "ops": [["+",3]]}"#, &defaults()).unwrap();
        assert_eq!(r.request_id, "");
        let r = parse_solve(br#"{"v0": 5, "ops": [["+",3]], "request_id": "  "}"#, &defaults())
            .unwrap();
        assert_eq!(r.request_id, "");
    }

    #[test]
    fn cache_key_ignores_request_id() {
        let a = parse_solve(br#"{"v0": 5, "ops": [["+",3]], "request_id": "a"}"#, &defaults())
            .unwrap();
        let b = parse_solve(br#"{"v0": 5, "ops": [["+",3]], "request_id": "b"}"#, &defaults())
            .unwrap();
        let cfg = defaults();
        assert_eq!(a.cache_key(&cfg), b.cache_key(&cfg), "ids must not defeat caching");
    }

    #[test]
    fn parse_rejects_bad_input() {
        assert!(parse_solve(b"not json", &defaults()).is_err());
        assert!(parse_solve(br#"{"v0": 500, "ops": [["+",3]]}"#, &defaults()).is_err());
        assert!(parse_solve(br#"{"v0": 5, "ops": []}"#, &defaults()).is_err());
        assert!(parse_solve(br#"{"v0": 5, "ops": [["%",3]]}"#, &defaults()).is_err());
        assert!(parse_solve(br#"{"v0": 5, "ops": [["+",77]]}"#, &defaults()).is_err());
    }

    #[test]
    fn cache_key_covers_problem_and_mode() {
        let a = parse_solve(br#"{"v0": 5, "ops": [["+",3]]}"#, &defaults()).unwrap();
        let b = parse_solve(br#"{"v0": 5, "ops": [["+",4]]}"#, &defaults()).unwrap();
        let c = parse_solve(br#"{"v0": 5, "ops": [["+",3]], "mode": "vanilla"}"#, &defaults()).unwrap();
        let cfg = defaults();
        let key = |r: &SolveRequest| {
            let mut c = cfg.clone();
            c.mode = r.mode;
            r.cache_key(&c)
        };
        assert_ne!(key(&a), key(&b));
        assert_ne!(key(&a), key(&c));
        assert_eq!(key(&a), key(&a));
    }

    #[test]
    fn render_roundtrips_as_json() {
        use crate::coordinator::flops::FlopsLedger;
        let req = parse_solve(br#"{"v0": 5, "ops": [["+",3]]}"#, &defaults()).unwrap();
        let out = SolveOutcome {
            answer: Some(8),
            correct: true,
            best_reward: 0.9,
            steps_executed: 1,
            wall_s: 0.5,
            ledger: FlopsLedger::new(10, 10),
            best_trace: vec![tk::ANS, tk::DIG0, tk::DIG0 + 8, tk::EOS],
            finished_beams: 2,
        };
        let s = render_solve(&req, &out, 12.5);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("answer").unwrap().as_i64(), Some(8));
        assert_eq!(j.get("correct").unwrap().as_bool(), Some(true));
        assert!(j.get("trace").unwrap().as_str().unwrap().contains("A08"));
        let qw = j.get("queue_wait_ms").unwrap().as_f64().unwrap();
        assert!((qw - 12.5).abs() < 1e-9, "queue wait must round-trip: {qw}");
    }
}
