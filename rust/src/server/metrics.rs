//! Serving metrics: counters + latency/queue-wait/FLOPs histograms,
//! text-exposable.
//!
//! Error counters are split by class so backpressure (5xx, retryable) is
//! distinguishable from client mistakes (4xx) on dashboards; pool-level
//! gauges (per-shard queue depth, fleet slot occupancy, cache hits) are
//! appended by `EnginePool::render_metrics`.
//!
//! Latency and queue wait are fixed-bucket histograms (0–60s in 100ms
//! bins), so p50/p95/p99 tails are derivable on `/metrics` instead of the
//! sums-only view that hid tail latency entirely. Queue wait is recorded
//! separately from end-to-end latency: under load the difference between
//! "the solver is slow" and "the queue is long" is the difference between
//! adding shards and adding capacity.

use std::sync::Mutex;

use crate::util::stats::Histogram;

#[derive(Debug)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug)]
struct Inner {
    requests: u64,
    errors: u64,
    errors_4xx: u64,
    errors_5xx: u64,
    correct: u64,
    latency_ms: Histogram,
    queue_wait_ms: Histogram,
    flops: Histogram,
    started: std::time::Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            inner: Mutex::new(Inner {
                requests: 0,
                errors: 0,
                errors_4xx: 0,
                errors_5xx: 0,
                correct: 0,
                latency_ms: Histogram::new(0.0, 60_000.0, 600),
                queue_wait_ms: Histogram::new(0.0, 60_000.0, 600),
                flops: Histogram::new(0.0, 1e12, 200),
                started: std::time::Instant::now(),
            }),
        }
    }
}

impl Metrics {
    /// Record a served request: end-to-end latency, time it spent queued
    /// before a shard picked it up, compute spent, and correctness.
    pub fn record_ok(&self, latency_ms: f64, queue_wait_ms: f64, flops: f64, correct: bool) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        m.correct += correct as u64;
        m.latency_ms.record(latency_ms);
        m.queue_wait_ms.record(queue_wait_ms);
        m.flops.record(flops);
    }

    /// Record a failed request, classified by the HTTP status it rendered
    /// as (4xx = client mistake, 5xx = server fault/backpressure).
    pub fn record_error(&self, status: u16) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        m.errors += 1;
        if (400..500).contains(&status) {
            m.errors_4xx += 1;
        } else {
            m.errors_5xx += 1;
        }
    }

    /// Render in Prometheus text exposition format: every series carries
    /// its `# HELP`/`# TYPE` header (the golden test pins validity).
    pub fn render(&self) -> String {
        use crate::obs::MetricWriter;
        let m = self.inner.lock().unwrap();
        let up = m.started.elapsed().as_secs_f64();
        let qps = if up > 0.0 { m.requests as f64 / up } else { 0.0 };
        let mut w = MetricWriter::new();
        w.counter("erprm_requests_total", "Requests served (including failures).", m.requests as f64);
        w.counter("erprm_errors_total", "Requests that resolved to an error.", m.errors as f64);
        w.counter("erprm_errors_4xx_total", "Client-error (4xx) responses.", m.errors_4xx as f64);
        w.counter(
            "erprm_errors_5xx_total",
            "Server-fault / backpressure (5xx) responses.",
            m.errors_5xx as f64,
        );
        w.counter("erprm_correct_total", "Solves whose answer was correct.", m.correct as f64);
        w.gauge("erprm_uptime_seconds", "Seconds since metrics start.", up);
        w.gauge("erprm_throughput_rps", "Requests per second since start.", qps);
        let quants = |w: &mut MetricWriter, base: &str, help: &str, h: &Histogram| {
            w.gauge(&format!("{base}_mean"), help, h.mean());
            w.gauge(&format!("{base}_p50"), help, h.quantile(0.5));
            w.gauge(&format!("{base}_p95"), help, h.quantile(0.95));
            w.gauge(&format!("{base}_p99"), help, h.quantile(0.99));
        };
        quants(&mut w, "erprm_latency_ms", "End-to-end request latency (ms).", &m.latency_ms);
        quants(
            &mut w,
            "erprm_queue_wait_ms",
            "Scheduling delay before a shard picked the request up (ms).",
            &m.queue_wait_ms,
        );
        w.gauge("erprm_flops_mean", "Mean analytic FLOPs per solved request.", m.flops.mean());
        w.finish()
    }

    pub fn snapshot(&self) -> (u64, u64, u64) {
        let m = self.inner.lock().unwrap();
        (m.requests, m.errors, m.correct)
    }

    /// (4xx, 5xx) error counts.
    pub fn error_split(&self) -> (u64, u64) {
        let m = self.inner.lock().unwrap();
        (m.errors_4xx, m.errors_5xx)
    }

    /// (mean, p50, p95, p99) of recorded queue wait, for tests/reports.
    pub fn queue_wait_summary(&self) -> (f64, f64, f64, f64) {
        let m = self.inner.lock().unwrap();
        (
            m.queue_wait_ms.mean(),
            m.queue_wait_ms.quantile(0.5),
            m.queue_wait_ms.quantile(0.95),
            m.queue_wait_ms.quantile(0.99),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_renders() {
        let m = Metrics::default();
        m.record_ok(12.0, 1.5, 1e9, true);
        m.record_ok(20.0, 2.5, 2e9, false);
        m.record_error(400);
        let (req, err, corr) = m.snapshot();
        assert_eq!((req, err, corr), (3, 1, 1));
        let text = m.render();
        assert!(text.contains("erprm_requests_total 3"));
        assert!(text.contains("erprm_errors_total 1"));
        assert!(text.contains("latency_ms_p50"));
        assert!(text.contains("latency_ms_p99"));
        assert!(text.contains("queue_wait_ms_p99"));
    }

    #[test]
    fn render_is_valid_exposition_format() {
        // golden gate: every erprm_* series must carry # HELP / # TYPE
        let m = Metrics::default();
        m.record_ok(12.0, 1.5, 1e9, true);
        m.record_error(503);
        let text = m.render();
        crate::obs::check_exposition(&text).unwrap();
        for series in [
            "erprm_requests_total",
            "erprm_errors_total",
            "erprm_errors_4xx_total",
            "erprm_errors_5xx_total",
            "erprm_correct_total",
            "erprm_uptime_seconds",
            "erprm_throughput_rps",
            "erprm_latency_ms_p99",
            "erprm_queue_wait_ms_p95",
            "erprm_flops_mean",
        ] {
            assert!(text.contains(&format!("# TYPE {series} ")), "missing TYPE for {series}");
            assert!(text.contains(&format!("# HELP {series} ")), "missing HELP for {series}");
        }
    }

    #[test]
    fn errors_split_by_class() {
        let m = Metrics::default();
        m.record_error(400);
        m.record_error(400);
        m.record_error(503);
        assert_eq!(m.error_split(), (2, 1));
        let text = m.render();
        assert!(text.contains("erprm_errors_4xx_total 2"));
        assert!(text.contains("erprm_errors_5xx_total 1"));
        assert!(text.contains("erprm_errors_total 3"));
    }

    #[test]
    fn tail_latency_is_derivable() {
        // 99 fast requests and one slow one: p50 stays low, p99 sees the
        // straggler — the sums-only view couldn't show this at all.
        let m = Metrics::default();
        for _ in 0..99 {
            m.record_ok(100.0, 10.0, 1e9, true);
        }
        m.record_ok(5_000.0, 4_000.0, 1e9, true);
        let text = m.render();
        let grab = |key: &str| -> f64 {
            text.lines()
                .find(|l| l.starts_with(key))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
                .unwrap_or_else(|| panic!("missing {key} in {text}"))
        };
        assert!(grab("erprm_latency_ms_p50") < 200.0);
        assert!(grab("erprm_latency_ms_p99") > 1_000.0);
        let (mean, p50, _p95, p99) = m.queue_wait_summary();
        assert!(p50 < 100.0, "p50 queue wait {p50}");
        assert!(p99 > 1_000.0, "p99 queue wait {p99}");
        assert!(mean > 10.0 && mean < p99, "mean {mean} must sit between bulk and tail");
    }
}
