//! Serving metrics: counters + latency/FLOPs histograms, text-exposable.

use std::sync::Mutex;

use crate::util::stats::Histogram;

#[derive(Debug)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug)]
struct Inner {
    requests: u64,
    errors: u64,
    correct: u64,
    latency_ms: Histogram,
    flops: Histogram,
    started: std::time::Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            inner: Mutex::new(Inner {
                requests: 0,
                errors: 0,
                correct: 0,
                latency_ms: Histogram::new(0.0, 60_000.0, 600),
                flops: Histogram::new(0.0, 1e12, 200),
                started: std::time::Instant::now(),
            }),
        }
    }
}

impl Metrics {
    pub fn record_ok(&self, latency_ms: f64, flops: f64, correct: bool) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        m.correct += correct as u64;
        m.latency_ms.record(latency_ms);
        m.flops.record(flops);
    }

    pub fn record_error(&self) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        m.errors += 1;
    }

    /// Render in a Prometheus-flavoured text format.
    pub fn render(&self) -> String {
        let m = self.inner.lock().unwrap();
        let up = m.started.elapsed().as_secs_f64();
        let qps = if up > 0.0 { m.requests as f64 / up } else { 0.0 };
        format!(
            "erprm_requests_total {}\n\
             erprm_errors_total {}\n\
             erprm_correct_total {}\n\
             erprm_uptime_seconds {:.1}\n\
             erprm_throughput_rps {:.4}\n\
             erprm_latency_ms_mean {:.2}\n\
             erprm_latency_ms_p50 {:.2}\n\
             erprm_latency_ms_p95 {:.2}\n\
             erprm_flops_mean {:.3e}\n",
            m.requests,
            m.errors,
            m.correct,
            up,
            qps,
            m.latency_ms.mean(),
            m.latency_ms.quantile(0.5),
            m.latency_ms.quantile(0.95),
            m.flops.mean(),
        )
    }

    pub fn snapshot(&self) -> (u64, u64, u64) {
        let m = self.inner.lock().unwrap();
        (m.requests, m.errors, m.correct)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_renders() {
        let m = Metrics::default();
        m.record_ok(12.0, 1e9, true);
        m.record_ok(20.0, 2e9, false);
        m.record_error();
        let (req, err, corr) = m.snapshot();
        assert_eq!((req, err, corr), (3, 1, 1));
        let text = m.render();
        assert!(text.contains("erprm_requests_total 3"));
        assert!(text.contains("erprm_errors_total 1"));
        assert!(text.contains("latency_ms_p50"));
    }
}
