//! Minimal HTTP/1.1 server over std::net (no tokio offline).
//!
//! Supports what the API needs: GET/POST, Content-Length bodies, keep-alive
//! off (Connection: close), bounded body size, per-connection handling on
//! the shared thread pool.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::log_warn;
use crate::util::error::{Error, Result};
use crate::util::threadpool::ThreadPool;

#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    /// Client-supplied `X-Request-Id` header, if any — the handler
    /// echoes it (or a minted id) on every `/solve` response and keys
    /// the request's trace with it.
    pub request_id: Option<String>,
    /// Live disconnect probe for the connection this request arrived
    /// on. `serve` attaches one per accepted connection; requests built
    /// by hand (tests, benchmarks) leave it `None` and long-running
    /// handlers simply never observe a hangup.
    pub hangup: Option<Arc<HangupProbe>>,
}

/// Client-disconnect probe for long-running handlers.
///
/// HTTP/1.1 over `std::net` gives a handler no callback when the peer
/// goes away mid-solve; the only signal is the socket itself. The probe
/// holds a dup of the connection's stream and answers "has the client
/// hung up?" with a non-blocking one-byte `peek`: `Ok(0)` is an orderly
/// EOF, a reset-class error is an abortive close, and `WouldBlock`
/// means the peer is still waiting. The router's dispatch loop polls
/// this between completion checks and fails an abandoned wait with
/// [`Error::Hangup`] (HTTP 499) instead of holding the worker until the
/// solve lands.
///
/// The dup shares its file-status flags with the fd `write_response`
/// later uses, so the probe flips `O_NONBLOCK` on only for the peek and
/// restores it before returning; probe and response writer run on the
/// same worker thread, so the toggle cannot race the write.
pub struct HangupProbe {
    stream: TcpStream,
}

impl std::fmt::Debug for HangupProbe {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "HangupProbe({:?})", self.stream.peer_addr().ok())
    }
}

impl HangupProbe {
    pub fn new(stream: TcpStream) -> HangupProbe {
        HangupProbe { stream }
    }

    /// True once the peer has closed its end of the connection.
    pub fn hung_up(&self) -> bool {
        if self.stream.set_nonblocking(true).is_err() {
            return false;
        }
        let mut buf = [0u8; 1];
        let gone = match self.stream.peek(&mut buf) {
            Ok(0) => true,  // orderly shutdown
            Ok(_) => false, // early pipelined bytes: peer is alive
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
            Err(_) => true, // reset/abort
        };
        let _ = self.stream.set_nonblocking(false);
        gone
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Extra response headers (e.g. `Retry-After` on 503).
    pub headers: Vec<(&'static str, String)>,
}

impl Response {
    pub fn json(status: u16, body: String) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: body.into_bytes(),
            headers: Vec::new(),
        }
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response {
            status,
            content_type: "text/plain",
            body: body.as_bytes().to_vec(),
            headers: Vec::new(),
        }
    }

    /// Attach an extra header (builder style).
    pub fn with_header(mut self, name: &'static str, value: impl Into<String>) -> Response {
        self.headers.push((name, value.into()));
        self
    }

    fn status_line(&self) -> &'static str {
        match self.status {
            200 => "200 OK",
            400 => "400 Bad Request",
            404 => "404 Not Found",
            405 => "405 Method Not Allowed",
            413 => "413 Payload Too Large",
            429 => "429 Too Many Requests",
            499 => "499 Client Closed Request",
            500 => "500 Internal Server Error",
            503 => "503 Service Unavailable",
            504 => "504 Gateway Timeout",
            _ => "200 OK",
        }
    }
}

/// Parse one request from a stream (Content-Length bodies only).
pub fn read_request(stream: &mut TcpStream, max_body: usize) -> Result<Request> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| Error::parse("empty request line"))?.to_string();
    let path = parts.next().ok_or_else(|| Error::parse("missing path"))?.to_string();

    let mut content_length = 0usize;
    let mut request_id = None;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v
                    .trim()
                    .parse()
                    .map_err(|_| Error::parse("bad content-length"))?;
            } else if k.eq_ignore_ascii_case("x-request-id") {
                request_id = Some(v.trim().to_string());
            }
        }
    }
    if content_length > max_body {
        return Err(Error::invalid("body too large"));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Request { method, path, body, request_id, hangup: None })
}

pub fn write_response(stream: &mut TcpStream, resp: &Response) -> Result<()> {
    let mut head = format!(
        "HTTP/1.1 {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n",
        resp.status_line(),
        resp.content_type,
        resp.body.len()
    );
    for (k, v) in &resp.headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()?;
    Ok(())
}

/// Serve until `stop` flips. `handler` must be cheap to clone across the
/// pool (Arc closure). Each accepted connection is dispatched onto the
/// thread pool's workers, so up to `pool.size()` requests are handled
/// concurrently — the old forwarder-thread adapter ran every handler
/// inline on one thread, serializing the entire serve path and defeating
/// both the worker pool and the engine shard pool behind it.
pub fn serve(
    addr: &str,
    pool: &ThreadPool,
    max_body: usize,
    stop: Arc<AtomicBool>,
    handler: Arc<dyn Fn(Request) -> Response + Send + Sync>,
) -> Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let stop2 = Arc::clone(&stop);
    let handler = Arc::clone(&handler);
    let max = max_body;
    let sender = pool.sender();
    std::thread::Builder::new()
        .name("erprm-accept".into())
        .spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((mut stream, _)) => {
                        let h = Arc::clone(&handler);
                        let accepted = sender.submit(Box::new(move || {
                            let resp = match read_request(&mut stream, max) {
                                Ok(mut req) => {
                                    req.hangup = stream
                                        .try_clone()
                                        .ok()
                                        .map(|s| Arc::new(HangupProbe::new(s)));
                                    h(req)
                                }
                                Err(e) => {
                                    Response::json(400, format!("{{\"error\":\"{e}\"}}"))
                                }
                            };
                            if let Err(e) = write_response(&mut stream, &resp) {
                                log_warn!("write response: {e}");
                            }
                        }));
                        if !accepted {
                            log_warn!("worker pool shut down; dropping connection");
                            break;
                        }
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(e) => {
                        log_warn!("accept: {e}");
                    }
                }
            }
        })?;
    Ok(local)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(reqbytes: &[u8], handler: impl Fn(Request) -> Response + Send + Sync + 'static) -> String {
        let pool = ThreadPool::new(2);
        let stop = Arc::new(AtomicBool::new(false));
        let addr = serve("127.0.0.1:0", &pool, 1024, Arc::clone(&stop), Arc::new(handler)).unwrap();
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(reqbytes).unwrap();
        let mut out = String::new();
        let _ = s.read_to_string(&mut out);
        stop.store(true, Ordering::Relaxed);
        out
    }

    #[test]
    fn get_roundtrip() {
        let out = roundtrip(b"GET /healthz HTTP/1.1\r\n\r\n", |req| {
            assert_eq!(req.method, "GET");
            assert_eq!(req.path, "/healthz");
            Response::json(200, "{\"ok\":true}".into())
        });
        assert!(out.starts_with("HTTP/1.1 200"));
        assert!(out.contains("{\"ok\":true}"));
    }

    #[test]
    fn post_with_body() {
        let body = b"{\"x\":1}";
        let req = format!(
            "POST /solve HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            std::str::from_utf8(body).unwrap()
        );
        let out = roundtrip(req.as_bytes(), |req| {
            assert_eq!(req.body, b"{\"x\":1}");
            Response::json(200, String::from_utf8(req.body).unwrap())
        });
        assert!(out.contains("{\"x\":1}"));
    }

    #[test]
    fn oversized_body_rejected() {
        let req = "POST /x HTTP/1.1\r\nContent-Length: 99999\r\n\r\n";
        let out = roundtrip(req.as_bytes(), |_| Response::text(200, "nope"));
        assert!(out.starts_with("HTTP/1.1 400"), "{out}");
    }

    #[test]
    fn connections_are_handled_concurrently() {
        // 4 requests x 100ms handler on a 4-worker pool must overlap;
        // the old single-forwarder path took >400ms serially.
        let pool = ThreadPool::new(4);
        let stop = Arc::new(AtomicBool::new(false));
        let addr = serve(
            "127.0.0.1:0",
            &pool,
            1024,
            Arc::clone(&stop),
            Arc::new(|_| {
                std::thread::sleep(std::time::Duration::from_millis(100));
                Response::text(200, "ok")
            }),
        )
        .unwrap();
        let t0 = std::time::Instant::now();
        let joins: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(move || {
                    let mut s = TcpStream::connect(addr).unwrap();
                    s.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
                    let mut out = String::new();
                    let _ = s.read_to_string(&mut out);
                    assert!(out.starts_with("HTTP/1.1 200"), "{out}");
                })
            })
            .collect();
        for j in joins {
            j.join().unwrap();
        }
        let elapsed = t0.elapsed();
        stop.store(true, Ordering::Relaxed);
        assert!(
            elapsed < std::time::Duration::from_millis(350),
            "handlers did not overlap: {elapsed:?}"
        );
    }

    #[test]
    fn request_id_header_is_captured() {
        let out = roundtrip(b"GET /healthz HTTP/1.1\r\nX-Request-Id: abc-123\r\n\r\n", |req| {
            assert_eq!(req.request_id.as_deref(), Some("abc-123"));
            Response::text(200, "ok")
        });
        assert!(out.starts_with("HTTP/1.1 200"), "{out}");
        // absent header -> None
        let out = roundtrip(b"GET /healthz HTTP/1.1\r\n\r\n", |req| {
            assert_eq!(req.request_id, None);
            Response::text(200, "ok")
        });
        assert!(out.starts_with("HTTP/1.1 200"), "{out}");
    }

    #[test]
    fn hangup_probe_detects_client_disconnect() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        let probe = HangupProbe::new(server_side);
        assert!(!probe.hung_up(), "connected peer reads as alive");
        drop(client);
        // EOF can take a beat to propagate through the loopback
        let t0 = std::time::Instant::now();
        while !probe.hung_up() && t0.elapsed() < std::time::Duration::from_secs(2) {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(probe.hung_up(), "dropped client must read as hung up");
    }

    #[test]
    fn serve_attaches_a_probe_to_each_request() {
        let out = roundtrip(b"GET /healthz HTTP/1.1\r\n\r\n", |req| {
            let p = req.hangup.as_deref().expect("serve attaches a probe");
            assert!(!p.hung_up(), "client is still waiting on the response");
            Response::text(200, "ok")
        });
        assert!(out.starts_with("HTTP/1.1 200"), "{out}");
    }

    #[test]
    fn status_line_knows_client_closed_request() {
        assert_eq!(Response::text(499, "x").status_line(), "499 Client Closed Request");
    }

    #[test]
    fn extra_headers_are_emitted() {
        let out = roundtrip(b"GET /busy HTTP/1.1\r\n\r\n", |_| {
            Response::json(503, "{\"error\":\"saturated\"}".into()).with_header("Retry-After", "1")
        });
        assert!(out.starts_with("HTTP/1.1 503"), "{out}");
        assert!(out.contains("Retry-After: 1\r\n"), "{out}");
    }
}
