//! Leveled stderr logger, configured via `ERPRM_LOG` (error|warn|info|debug).
//!
//! `ERPRM_LOG_FORMAT=json` switches output to one JSON object per line
//! (`ts`, `level`, `module`, `msg`, and `request_id` when the emitting
//! thread is inside a traced request scope — see [`request_scope`]), so
//! fleet logs can be joined against `/trace/<id>` documents.

use std::cell::RefCell;
use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::time::Instant;

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static JSON: AtomicBool = AtomicBool::new(false);
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

thread_local! {
    /// Stack of request ids the current thread is working inside (a
    /// stack, not a cell: a shard thread finishing one task can emit a
    /// log mid-advance of another).
    static REQUEST: RefCell<Vec<String>> = const { RefCell::new(Vec::new()) };
}

pub fn init_from_env() {
    let lvl = match std::env::var("ERPRM_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        _ => Level::Info,
    };
    set_level(lvl);
    set_json(matches!(std::env::var("ERPRM_LOG_FORMAT").as_deref(), Ok("json")));
    START.get_or_init(Instant::now);
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Switch between human text and JSON-lines output.
pub fn set_json(on: bool) {
    JSON.store(on, Ordering::Relaxed);
}

pub fn json_mode() -> bool {
    JSON.load(Ordering::Relaxed)
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Tag every log line this thread emits with `id` until the returned
/// guard drops. Scopes nest; the innermost wins.
pub fn request_scope(id: &str) -> RequestScope {
    REQUEST.with(|r| r.borrow_mut().push(id.to_string()));
    RequestScope(())
}

/// The request id the current thread is scoped to, if any.
pub fn current_request() -> Option<String> {
    REQUEST.with(|r| r.borrow().last().cloned())
}

/// RAII guard popping the thread's request-id scope on drop.
pub struct RequestScope(());

impl Drop for RequestScope {
    fn drop(&mut self) {
        REQUEST.with(|r| {
            r.borrow_mut().pop();
        });
    }
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let mut err = std::io::stderr().lock();
    if json_mode() {
        let mut pairs = vec![
            ("ts", Json::num(t)),
            (
                "level",
                Json::str(match l {
                    Level::Error => "error",
                    Level::Warn => "warn",
                    Level::Info => "info",
                    Level::Debug => "debug",
                }),
            ),
            ("module", Json::str(module)),
            ("msg", Json::str(msg.to_string())),
        ];
        if let Some(rid) = current_request() {
            pairs.push(("request_id", Json::str(rid)));
        }
        let _ = writeln!(err, "{}", Json::obj(pairs).to_string());
        return;
    }
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    let _ = writeln!(err, "[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn request_scopes_nest_and_unwind() {
        assert_eq!(current_request(), None);
        {
            let _a = request_scope("outer");
            assert_eq!(current_request().as_deref(), Some("outer"));
            {
                let _b = request_scope("inner");
                assert_eq!(current_request().as_deref(), Some("inner"));
            }
            assert_eq!(current_request().as_deref(), Some("outer"));
        }
        assert_eq!(current_request(), None);
    }

    #[test]
    fn json_line_shape() {
        // render the same payload the json branch writes and check it
        // parses with the expected fields (stderr itself isn't captured)
        let _s = request_scope("r-1");
        let mut pairs = vec![
            ("ts", Json::num(1.5)),
            ("level", Json::str("info")),
            ("module", Json::str("erprm::test")),
            ("msg", Json::str("hello \"quoted\" msg")),
        ];
        if let Some(rid) = current_request() {
            pairs.push(("request_id", Json::str(rid)));
        }
        let line = Json::obj(pairs).to_string();
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(parsed.get("request_id").and_then(Json::as_str), Some("r-1"));
        assert_eq!(parsed.get("msg").and_then(Json::as_str), Some("hello \"quoted\" msg"));
        assert!(!line.contains('\n'));
    }
}
