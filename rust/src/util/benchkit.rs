//! Benchmark harness (criterion is unavailable offline).
//!
//! Two roles:
//! * `bench_fn` — micro/endpoint timing with warmup, repeated samples, and
//!   robust statistics (mean / p50 / p95 / min), for the L3 hot-path
//!   benches.
//! * `Table` — aligned text tables used by every paper-table/figure bench
//!   to print the same rows/series the paper reports, plus tee-to-file so
//!   `cargo bench` leaves machine-readable records under target/bench-out/.

use std::io::Write as _;
use std::time::{Duration, Instant};

use super::stats;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<42} {:>10} {:>10} {:>10} {:>10}  ({} samples)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.min_ns),
            self.samples
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Time `f` with warmup; samples until `max_samples` or `budget` elapses.
pub fn bench_fn<F: FnMut()>(name: &str, warmup: usize, max_samples: usize, budget: Duration, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(max_samples);
    let start = Instant::now();
    while samples.len() < max_samples && (samples.len() < 3 || start.elapsed() < budget) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    BenchResult {
        name: name.to_string(),
        samples: samples.len(),
        mean_ns: stats::mean(&samples),
        p50_ns: stats::quantile(&samples, 0.5),
        p95_ns: stats::quantile(&samples, 0.95),
        min_ns: samples.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

/// Header printed once per bench binary.
pub fn bench_header(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "{:<42} {:>10} {:>10} {:>10} {:>10}",
        "benchmark", "mean", "p50", "p95", "min"
    );
}

// ---------------------------------------------------------------- tables

/// Aligned text table for paper-style output.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n## {}\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.columns, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Print and also tee to target/bench-out/<slug>.txt.
    pub fn emit(&self, slug: &str) {
        let rendered = self.render();
        print!("{rendered}");
        let dir = std::path::Path::new("target/bench-out");
        if std::fs::create_dir_all(dir).is_ok() {
            if let Ok(mut f) = std::fs::File::create(dir.join(format!("{slug}.txt"))) {
                let _ = f.write_all(rendered.as_bytes());
            }
        }
    }
}

/// Format a FLOPs count in the paper's style (scaled scientific, 2 d.p.).
pub fn fmt_flops(f: f64) -> String {
    if f >= 1e12 {
        format!("{:.2}e12", f / 1e12)
    } else if f >= 1e9 {
        format!("{:.2}e9", f / 1e9)
    } else if f >= 1e6 {
        format!("{:.2}e6", f / 1e6)
    } else {
        format!("{f:.0}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_fn_counts_samples() {
        let r = bench_fn("noop", 2, 10, Duration::from_secs(5), || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.samples, 10);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.p95_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }

    #[test]
    fn table_render_aligned() {
        let mut t = Table::new("T", &["a", "long-col"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100".into(), "x".into()]);
        let s = t.render();
        assert!(s.contains("## T"));
        assert!(s.contains("long-col"));
        let lines: Vec<&str> = s.lines().filter(|l| !l.is_empty()).collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn fmt_flops_scales() {
        assert_eq!(fmt_flops(2.5e12), "2.50e12");
        assert_eq!(fmt_flops(3.1e9), "3.10e9");
        assert_eq!(fmt_flops(4.2e6), "4.20e6");
        assert_eq!(fmt_flops(123.0), "123");
    }
}
