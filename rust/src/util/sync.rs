//! Poison-tolerant synchronization primitives for the supervised fleet.
//!
//! Shard threads are now allowed to panic (and be respawned by the pool
//! supervisor), which makes `Mutex` poisoning a live hazard: a panic
//! between `lock()` and drop poisons the mutex, and every later
//! `lock().unwrap()` on another thread turns one crashed tick into a
//! pool-wide metrics/stats cascade. [`lock_unpoisoned`] recovers the
//! guard instead — safe here because every shared value guarded this way
//! (engine stats snapshots, mailboxes) is overwritten wholesale rather
//! than mutated incrementally, so a half-finished write cannot persist.
//!
//! [`Mailbox`] is the panic-surviving job queue that replaces per-shard
//! `mpsc` channels: the queue lives in an `Arc` held by dispatchers and
//! the supervisor, so when a shard thread dies its queued jobs remain
//! drainable (for requeueing onto healthy shards) instead of vanishing
//! with the channel's receiving half.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Lock a mutex, recovering the guard if a previous holder panicked.
/// Use for state that is overwritten wholesale (snapshots, swaps), where
/// observing a pre-panic value is indistinguishable from benign staleness.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// What a timed mailbox receive produced.
#[derive(Debug, PartialEq, Eq)]
pub enum MailRecv<T> {
    /// A queued item.
    Item(T),
    /// The budget elapsed with the mailbox open but empty — the idle
    /// tick that lets a blocked shard loop keep heartbeating.
    Empty,
    /// The mailbox is closed and drained; no item will ever arrive.
    Closed,
}

struct MailState<T> {
    queue: VecDeque<T>,
    closed: bool,
}

/// Multi-producer multi-consumer FIFO that outlives its consumer thread.
///
/// Unlike `mpsc`, dropping (or killing) the consuming thread does not
/// destroy the queue: any holder of the `Arc<Mailbox>` can still
/// [`drain`](Mailbox::drain) pending items — the supervisor's requeue
/// path — or [`close`](Mailbox::close) it so producers fail fast.
pub struct Mailbox<T> {
    state: Mutex<MailState<T>>,
    cv: Condvar,
}

impl<T> Default for Mailbox<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Mailbox<T> {
    pub fn new() -> Self {
        Mailbox {
            state: Mutex::new(MailState { queue: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue an item; `Err(item)` hands it back if the mailbox is
    /// closed (shard retired — the caller should pick another shard).
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut st = lock_unpoisoned(&self.state);
        if st.closed {
            return Err(item);
        }
        st.queue.push_back(item);
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    /// Dequeue without blocking.
    pub fn try_recv(&self) -> MailRecv<T> {
        let mut st = lock_unpoisoned(&self.state);
        match st.queue.pop_front() {
            Some(item) => MailRecv::Item(item),
            None if st.closed => MailRecv::Closed,
            None => MailRecv::Empty,
        }
    }

    /// Dequeue, blocking up to `budget`. Returns [`MailRecv::Empty`] on
    /// timeout so idle consumers wake periodically (to heartbeat, check
    /// retirement) instead of parking forever.
    pub fn recv_timeout(&self, budget: Duration) -> MailRecv<T> {
        let deadline = Instant::now() + budget;
        let mut st = lock_unpoisoned(&self.state);
        loop {
            if let Some(item) = st.queue.pop_front() {
                return MailRecv::Item(item);
            }
            if st.closed {
                return MailRecv::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return MailRecv::Empty;
            }
            st = self
                .cv
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(PoisonError::into_inner)
                .0;
        }
    }

    /// Close the mailbox: subsequent pushes fail, consumers drain what
    /// remains and then see [`MailRecv::Closed`].
    pub fn close(&self) {
        lock_unpoisoned(&self.state).closed = true;
        self.cv.notify_all();
    }

    /// Take every queued item at once — the supervisor's requeue path
    /// after a shard dies. Usually preceded by [`close`](Mailbox::close)
    /// so no new item lands behind the drain.
    pub fn drain(&self) -> Vec<T> {
        let mut st = lock_unpoisoned(&self.state);
        st.queue.drain(..).collect()
    }

    /// Number of queued items.
    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.state).queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_unpoisoned_recovers_after_panic() {
        let m = Arc::new(Mutex::new(5u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        assert_eq!(*lock_unpoisoned(&m), 5, "guard recovered, value intact");
        *lock_unpoisoned(&m) = 7;
        assert_eq!(*lock_unpoisoned(&m), 7);
    }

    #[test]
    fn mailbox_fifo_round_trip() {
        let mb = Mailbox::new();
        mb.push(1).unwrap();
        mb.push(2).unwrap();
        assert_eq!(mb.len(), 2);
        assert_eq!(mb.try_recv(), MailRecv::Item(1));
        assert_eq!(mb.recv_timeout(Duration::from_millis(5)), MailRecv::Item(2));
        assert_eq!(mb.try_recv(), MailRecv::Empty);
    }

    #[test]
    fn recv_timeout_returns_empty_not_forever() {
        let mb: Mailbox<u8> = Mailbox::new();
        let t0 = Instant::now();
        assert_eq!(mb.recv_timeout(Duration::from_millis(20)), MailRecv::Empty);
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn close_fails_pushes_and_drains_to_closed() {
        let mb = Mailbox::new();
        mb.push("queued").unwrap();
        mb.close();
        assert_eq!(mb.push("late"), Err("late"), "push after close bounces");
        assert_eq!(mb.try_recv(), MailRecv::Item("queued"), "queued items still drain");
        assert_eq!(mb.try_recv(), MailRecv::Closed);
        assert_eq!(mb.recv_timeout(Duration::from_secs(1)), MailRecv::Closed);
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let mb: Arc<Mailbox<u8>> = Arc::new(Mailbox::new());
        let mb2 = Arc::clone(&mb);
        let j = std::thread::spawn(move || mb2.recv_timeout(Duration::from_secs(10)));
        std::thread::sleep(Duration::from_millis(20));
        mb.close();
        assert_eq!(j.join().unwrap(), MailRecv::Closed);
    }

    #[test]
    fn drain_survives_consumer_panic() {
        // the supervisor scenario: consumer thread dies mid-service, the
        // queue must still be drainable by another Arc holder
        let mb: Arc<Mailbox<u32>> = Arc::new(Mailbox::new());
        for i in 0..4 {
            mb.push(i).unwrap();
        }
        let mb2 = Arc::clone(&mb);
        let _ = std::thread::spawn(move || {
            let _got = mb2.try_recv();
            panic!("shard dies holding nothing");
        })
        .join();
        mb.close();
        assert_eq!(mb.drain(), vec![1, 2, 3], "remaining jobs recoverable");
        assert_eq!(mb.try_recv(), MailRecv::Closed);
    }

    #[test]
    fn push_wakes_blocked_consumer() {
        let mb: Arc<Mailbox<&str>> = Arc::new(Mailbox::new());
        let mb2 = Arc::clone(&mb);
        let j = std::thread::spawn(move || mb2.recv_timeout(Duration::from_secs(10)));
        std::thread::sleep(Duration::from_millis(20));
        mb.push("wake").unwrap();
        assert_eq!(j.join().unwrap(), MailRecv::Item("wake"));
    }
}
