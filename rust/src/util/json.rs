//! Minimal JSON parser/writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar needed by the artifacts manifest, config
//! files, the HTTP API, and results files: objects, arrays, strings with
//! escapes (incl. `\uXXXX`), numbers (int/float/exponent), bool, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use super::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(Error::parse(format!("trailing bytes at {}", p.i)));
        }
        Ok(v)
    }

    // ------------------------------------------------------------ accessors

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest loading convenience.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::parse(format!("missing key '{key}'")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ------------------------------------------------------------- builders

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // -------------------------------------------------------------- writer

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::parse(format!(
                "expected '{}' at byte {}",
                c as char, self.i
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::parse(format!("unexpected {:?} at {}", other, self.i))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(Error::parse(format!("bad literal at {}", self.i)))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| Error::parse("non-utf8 number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::parse(format!("bad number '{s}'")))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::parse("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(Error::parse("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| Error::parse("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::parse("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(Error::parse("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| Error::parse("non-utf8 string"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(Error::parse(format!("bad array at {}", self.i))),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(Error::parse(format!("bad object at {}", self.i))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""Aé""#).unwrap(), Json::Str("Aé".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"x\"y"],"n":-7}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn int_formatting_no_decimals() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn req_errors_with_key() {
        let v = Json::parse("{}").unwrap();
        let e = v.req("missing").unwrap_err();
        assert!(e.to_string().contains("missing"));
    }
}
