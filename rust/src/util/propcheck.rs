//! Property-based testing harness (proptest is unavailable offline).
//!
//! `check` runs a property over `n` randomized cases generated from a
//! seeded RNG; on failure it re-derives the failing seed and attempts
//! greedy shrinking through a user-provided `shrink` function, then panics
//! with the minimal counterexample and the seed needed to replay it.
//!
//! Used for the coordinator invariants (routing, batching, beam state) —
//! see `coordinator::*` test modules.

use super::rng::Rng;

pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_iters: usize,
}

impl Default for Config {
    fn default() -> Self {
        // ERPRM_PROPTEST_CASES scales coverage in CI vs local runs; the
        // propcheck-long feature (the CI soak job) raises the default
        // without touching the environment.
        let default_cases = if cfg!(feature = "propcheck-long") { 1024 } else { 64 };
        let cases = std::env::var("ERPRM_PROPTEST_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(default_cases);
        Config { cases, seed: 0x5EED, max_shrink_iters: 200 }
    }
}

/// Check `prop` over `cases` random inputs from `gen`.
///
/// `gen`: produce a case from an RNG.  `prop`: return Err(reason) on failure.
/// `shrink`: propose smaller variants of a failing case (may be empty).
pub fn check<T, G, P, S>(name: &str, cfg: Config, mut gen: G, prop: P, shrink: S)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
    S: Fn(&T) -> Vec<T>,
{
    for case_idx in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case_idx as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let case = gen(&mut rng);
        if let Err(first_reason) = prop(&case) {
            // greedy shrink
            let mut best = case.clone();
            let mut best_reason = first_reason;
            let mut iters = 0;
            'outer: loop {
                for candidate in shrink(&best) {
                    iters += 1;
                    if iters > cfg.max_shrink_iters {
                        break 'outer;
                    }
                    if let Err(r) = prop(&candidate) {
                        best = candidate;
                        best_reason = r;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (case {case_idx}, seed {case_seed:#x})\n\
                 reason: {best_reason}\nminimal counterexample: {best:?}"
            );
        }
    }
}

/// Convenience: check with no shrinking.
pub fn check_simple<T, G, P>(name: &str, gen: G, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    check(name, Config::default(), gen, prop, |_| Vec::new());
}

/// Shrinker for vectors: halves and single-removals.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.is_empty() {
        return out;
    }
    out.push(v[..v.len() / 2].to_vec());
    out.push(v[v.len() / 2..].to_vec());
    if v.len() <= 8 {
        for i in 0..v.len() {
            let mut w = v.to_vec();
            w.remove(i);
            out.push(w);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_quiet() {
        check_simple("sum-nonneg", |rng| (0..8).map(|_| rng.below(10)).collect::<Vec<_>>(), |v| {
            if v.iter().sum::<usize>() < usize::MAX {
                Ok(())
            } else {
                Err("overflow".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check_simple("always-fails", |rng| rng.below(100), |_| Err("nope".into()));
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn shrinking_reduces_case() {
        // property: no vector contains a 7. shrinker should isolate a small one.
        check(
            "no-sevens",
            Config { cases: 200, ..Default::default() },
            |rng| (0..rng.below(20) + 1).map(|_| rng.below(10) as u32).collect::<Vec<u32>>(),
            |v| {
                if v.contains(&7) {
                    Err("has 7".into())
                } else {
                    Ok(())
                }
            },
            |v| shrink_vec(v),
        );
    }

    #[test]
    fn shrink_vec_produces_smaller() {
        let v = vec![1, 2, 3, 4];
        for s in shrink_vec(&v) {
            assert!(s.len() < v.len());
        }
    }
}
