//! Hand-rolled substrate utilities (no serde/clap/tokio/criterion offline).

pub mod benchkit;
pub mod cli;
pub mod error;
pub mod json;
pub mod logging;
pub mod oneshot;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod threadpool;
