//! Statistics toolkit: moments, quantiles, correlations, OLS, histograms.
//!
//! Used by the correlation studies (Fig. 2 / Fig. 4 reproduce Pearson,
//! Kendall tau and an R² linear fit), the theory validation (Sec. 4), and
//! the latency metrics of the server.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated quantile, q in [0,1]; input need not be sorted.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let (mx, my) = (mean(xs), mean(ys));
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        cov += dx * dy;
        vx += dx * dx;
        vy += dy * dy;
    }
    if vx <= 0.0 || vy <= 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Kendall tau-b (handles ties). O(n²) — fine at study sizes.
pub fn kendall_tau(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let (mut conc, mut disc, mut tx, mut ty) = (0i64, 0i64, 0i64, 0i64);
    for i in 0..n {
        for j in (i + 1)..n {
            let dx = xs[i] - xs[j];
            let dy = ys[i] - ys[j];
            if dx == 0.0 && dy == 0.0 {
                tx += 1;
                ty += 1;
            } else if dx == 0.0 {
                tx += 1;
            } else if dy == 0.0 {
                ty += 1;
            } else if dx * dy > 0.0 {
                conc += 1;
            } else {
                disc += 1;
            }
        }
    }
    let n0 = (n * (n - 1) / 2) as i64;
    let denom = (((n0 - tx) as f64) * ((n0 - ty) as f64)).sqrt();
    if denom <= 0.0 {
        return 0.0;
    }
    (conc - disc) as f64 / denom
}

/// Spearman rank correlation (average ranks on ties).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    pearson(&ranks(xs), &ranks(ys))
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut r = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            r[idx[k]] = avg;
        }
        i = j + 1;
    }
    r
}

/// Ordinary least squares y = a + b x.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OlsFit {
    pub intercept: f64,
    pub slope: f64,
    pub r2: f64,
}

pub fn ols(xs: &[f64], ys: &[f64]) -> OlsFit {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.len() < 2 {
        return OlsFit { intercept: 0.0, slope: 0.0, r2: 0.0 };
    }
    let (mx, my) = (mean(xs), mean(ys));
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for i in 0..xs.len() {
        sxy += (xs[i] - mx) * (ys[i] - my);
        sxx += (xs[i] - mx).powi(2);
    }
    let slope = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    let intercept = my - slope * mx;
    let mut ss_res = 0.0;
    let mut ss_tot = 0.0;
    for i in 0..xs.len() {
        ss_res += (ys[i] - (intercept + slope * xs[i])).powi(2);
        ss_tot += (ys[i] - my).powi(2);
    }
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 0.0 };
    let _ = n;
    OlsFit { intercept, slope, r2 }
}

/// Fixed-bin histogram over [lo, hi).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub count: u64,
    pub underflow: u64,
    pub overflow: u64,
    sum: f64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], count: 0, underflow: 0, overflow: 0, sum: 0.0 }
    }

    pub fn record(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.bins.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[idx.min(n - 1)] += 1;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate quantile from bin midpoints (under/overflow clamp).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64) as u64;
        let mut seen = self.underflow;
        if seen > target {
            return self.lo;
        }
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        for (i, &b) in self.bins.iter().enumerate() {
            seen += b;
            if seen > target {
                return self.lo + (i as f64 + 0.5) * w;
            }
        }
        self.hi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((variance(&[1.0, 2.0, 3.0]) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn quantiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.5);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = ys.iter().map(|y| -y).collect();
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn kendall_known() {
        let xs = [1.0, 2.0, 3.0];
        assert!((kendall_tau(&xs, &[1.0, 2.0, 3.0]) - 1.0).abs() < 1e-12);
        assert!((kendall_tau(&xs, &[3.0, 2.0, 1.0]) + 1.0).abs() < 1e-12);
        // one swap in 4 elements: tau = (C-D)/n0 = (5-1)/6
        let t = kendall_tau(&[1.0, 2.0, 3.0, 4.0], &[1.0, 3.0, 2.0, 4.0]);
        assert!((t - 4.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [10.0, 100.0, 1000.0, 10000.0];
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ols_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let fit = ols(&xs, &ys);
        assert!((fit.slope - 2.0).abs() < 1e-12);
        assert!((fit.intercept - 1.0).abs() < 1e-12);
        assert!((fit.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ols_noisy_r2_below_one() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|&x| x + if (x as i64) % 2 == 0 { 3.0 } else { -3.0 })
            .collect();
        let fit = ols(&xs, &ys);
        assert!(fit.r2 < 1.0 && fit.r2 > 0.9);
    }

    #[test]
    fn histogram_basics() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..100 {
            h.record(i as f64 / 10.0);
        }
        assert_eq!(h.count, 100);
        assert_eq!(h.overflow, 0);
        assert!((h.mean() - 4.95).abs() < 1e-9);
        let p50 = h.quantile(0.5);
        assert!((p50 - 4.5).abs() <= 1.0, "p50 {p50}");
    }

    #[test]
    fn histogram_overflow() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.record(-1.0);
        h.record(2.0);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
    }
}
