//! Statistics toolkit: moments, quantiles, correlations, OLS, histograms,
//! and incremental (streaming) correlation kernels.
//!
//! Used by the correlation studies (Fig. 2 / Fig. 4 reproduce Pearson,
//! Kendall tau and an R² linear fit), the theory validation (Sec. 4), the
//! latency metrics of the server, and the online calibration observatory
//! (`obs::calibration`), which streams partial↔final reward pairs through
//! [`StreamingPearson`] / [`StreamingKendall`] one finished request at a
//! time.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated quantile, q in [0,1]; input need not be sorted.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let (mx, my) = (mean(xs), mean(ys));
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        cov += dx * dy;
        vx += dx * dx;
        vy += dy * dy;
    }
    if vx <= 0.0 || vy <= 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Kendall tau-b (handles ties). O(n²) — fine at study sizes.
pub fn kendall_tau(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let (mut conc, mut disc, mut tx, mut ty) = (0i64, 0i64, 0i64, 0i64);
    for i in 0..n {
        for j in (i + 1)..n {
            let dx = xs[i] - xs[j];
            let dy = ys[i] - ys[j];
            if dx == 0.0 && dy == 0.0 {
                tx += 1;
                ty += 1;
            } else if dx == 0.0 {
                tx += 1;
            } else if dy == 0.0 {
                ty += 1;
            } else if dx * dy > 0.0 {
                conc += 1;
            } else {
                disc += 1;
            }
        }
    }
    let n0 = (n * (n - 1) / 2) as i64;
    let denom = (((n0 - tx) as f64) * ((n0 - ty) as f64)).sqrt();
    if denom <= 0.0 {
        return 0.0;
    }
    (conc - disc) as f64 / denom
}

/// Spearman rank correlation (average ranks on ties).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    pearson(&ranks(xs), &ranks(ys))
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut r = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            r[idx[k]] = avg;
        }
        i = j + 1;
    }
    r
}

/// Ordinary least squares y = a + b x.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OlsFit {
    pub intercept: f64,
    pub slope: f64,
    pub r2: f64,
}

pub fn ols(xs: &[f64], ys: &[f64]) -> OlsFit {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.len() < 2 {
        return OlsFit { intercept: 0.0, slope: 0.0, r2: 0.0 };
    }
    let (mx, my) = (mean(xs), mean(ys));
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for i in 0..xs.len() {
        sxy += (xs[i] - mx) * (ys[i] - my);
        sxx += (xs[i] - mx).powi(2);
    }
    let slope = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    let intercept = my - slope * mx;
    let mut ss_res = 0.0;
    let mut ss_tot = 0.0;
    for i in 0..xs.len() {
        ss_res += (ys[i] - (intercept + slope * xs[i])).powi(2);
        ss_tot += (ys[i] - my).powi(2);
    }
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 0.0 };
    let _ = n;
    OlsFit { intercept, slope, r2 }
}

/// Incremental Pearson correlation (Welford co-moment form).
///
/// One `push` per (x, y) pair keeps running means and centered second
/// moments; `corr()` is available at any point without revisiting the
/// stream. `merge` combines two accumulators (parallel shards) exactly.
/// The batch [`pearson`] and this kernel agree to floating-point noise on
/// the same corpus (cross-checked in the tests and in
/// `harness::correlation`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StreamingPearson {
    n: u64,
    mean_x: f64,
    mean_y: f64,
    m2x: f64,
    m2y: f64,
    cxy: f64,
}

impl StreamingPearson {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> u64 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.n += 1;
        let n = self.n as f64;
        let dx = x - self.mean_x;
        self.mean_x += dx / n;
        let dy = y - self.mean_y;
        self.mean_y += dy / n;
        let dy2 = y - self.mean_y;
        self.m2x += dx * (x - self.mean_x);
        self.m2y += dy * dy2;
        self.cxy += dx * dy2;
    }

    /// Pearson r; 0 when degenerate (n < 2 or a constant margin), matching
    /// the batch [`pearson`] convention.
    pub fn corr(&self) -> f64 {
        if self.n < 2 || self.m2x <= 0.0 || self.m2y <= 0.0 {
            return 0.0;
        }
        self.cxy / (self.m2x.sqrt() * self.m2y.sqrt())
    }

    /// Fisher-z lower confidence bound on r at critical value `z`
    /// (1.96 ≈ 95%). Returns -1 when n < 4 (the transform needs n-3 > 0),
    /// i.e. "no evidence" — callers gating on a confidence floor treat it
    /// as not proven.
    pub fn corr_lower(&self, z: f64) -> f64 {
        if self.n < 4 {
            return -1.0;
        }
        let r = self.corr().clamp(-0.999_999, 0.999_999);
        let zr = r.atanh() - z / ((self.n - 3) as f64).sqrt();
        zr.tanh()
    }

    /// Exact parallel combine (Chan et al. pairwise update).
    pub fn merge(&mut self, o: &Self) {
        if o.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = o.clone();
            return;
        }
        let (na, nb) = (self.n as f64, o.n as f64);
        let n = na + nb;
        let dx = o.mean_x - self.mean_x;
        let dy = o.mean_y - self.mean_y;
        self.m2x += o.m2x + dx * dx * na * nb / n;
        self.m2y += o.m2y + dy * dy * na * nb / n;
        self.cxy += o.cxy + dx * dy * na * nb / n;
        self.mean_x += dx * nb / n;
        self.mean_y += dy * nb / n;
        self.n += o.n;
    }
}

/// Incremental Kendall tau-b over a seed-stable bounded reservoir.
///
/// Exact concordance needs all pairs, so the stream is sketched: the first
/// `cap` samples are kept verbatim, after which each arrival replaces a
/// reservoir slot with the classic `j = mix(seed, i) % i` rule — a pure
/// function of (seed, arrival index), so the sketch is deterministic for a
/// given stream order and byte-identical across process restarts. While
/// the stream fits the reservoir (`seen <= cap`) `corr()` equals the batch
/// [`kendall_tau`] exactly. The O(cap²) recompute is lazy and cached.
#[derive(Debug, Clone)]
pub struct StreamingKendall {
    cap: usize,
    seed: u64,
    seen: u64,
    buf: Vec<(f64, f64)>,
    dirty: bool,
    cached: f64,
}

/// SplitMix64 finalizer: a cheap, seed-stable bijection used for the
/// Kendall reservoir's eviction draw and the adaptive-tau controller's
/// deterministic shadow-sampling decision (a pure function of the
/// request key and table epoch, so coalesced duplicates agree).
pub(crate) fn mix64(mut x: u64) -> u64 {
    // splitmix64 finalizer
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

impl StreamingKendall {
    pub fn new(cap: usize, seed: u64) -> Self {
        StreamingKendall {
            cap: cap.max(2),
            seed,
            seen: 0,
            buf: Vec::new(),
            dirty: false,
            cached: 0.0,
        }
    }

    /// Total samples offered (not the reservoir occupancy).
    pub fn len(&self) -> u64 {
        self.seen
    }

    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.seen += 1;
        if self.buf.len() < self.cap {
            self.buf.push((x, y));
            self.dirty = true;
        } else {
            let j = (mix64(self.seed ^ self.seen) % self.seen) as usize;
            if j < self.cap {
                self.buf[j] = (x, y);
                self.dirty = true;
            }
        }
    }

    /// Kendall tau-b of the reservoir contents.
    pub fn corr(&mut self) -> f64 {
        if self.dirty {
            let xs: Vec<f64> = self.buf.iter().map(|p| p.0).collect();
            let ys: Vec<f64> = self.buf.iter().map(|p| p.1).collect();
            self.cached = kendall_tau(&xs, &ys);
            self.dirty = false;
        }
        self.cached
    }
}

/// Fixed-bin histogram over [lo, hi).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub count: u64,
    pub underflow: u64,
    pub overflow: u64,
    sum: f64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], count: 0, underflow: 0, overflow: 0, sum: 0.0 }
    }

    pub fn record(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.bins.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[idx.min(n - 1)] += 1;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate quantile from bin midpoints (under/overflow clamp).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64) as u64;
        let mut seen = self.underflow;
        if seen > target {
            return self.lo;
        }
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        for (i, &b) in self.bins.iter().enumerate() {
            seen += b;
            if seen > target {
                return self.lo + (i as f64 + 0.5) * w;
            }
        }
        self.hi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((variance(&[1.0, 2.0, 3.0]) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn quantiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.5);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = ys.iter().map(|y| -y).collect();
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn kendall_known() {
        let xs = [1.0, 2.0, 3.0];
        assert!((kendall_tau(&xs, &[1.0, 2.0, 3.0]) - 1.0).abs() < 1e-12);
        assert!((kendall_tau(&xs, &[3.0, 2.0, 1.0]) + 1.0).abs() < 1e-12);
        // one swap in 4 elements: tau = (C-D)/n0 = (5-1)/6
        let t = kendall_tau(&[1.0, 2.0, 3.0, 4.0], &[1.0, 3.0, 2.0, 4.0]);
        assert!((t - 4.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [10.0, 100.0, 1000.0, 10000.0];
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ols_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let fit = ols(&xs, &ys);
        assert!((fit.slope - 2.0).abs() < 1e-12);
        assert!((fit.intercept - 1.0).abs() < 1e-12);
        assert!((fit.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ols_noisy_r2_below_one() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|&x| x + if (x as i64) % 2 == 0 { 3.0 } else { -3.0 })
            .collect();
        let fit = ols(&xs, &ys);
        assert!(fit.r2 < 1.0 && fit.r2 > 0.9);
    }

    #[test]
    fn histogram_basics() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..100 {
            h.record(i as f64 / 10.0);
        }
        assert_eq!(h.count, 100);
        assert_eq!(h.overflow, 0);
        assert!((h.mean() - 4.95).abs() < 1e-9);
        let p50 = h.quantile(0.5);
        assert!((p50 - 4.5).abs() <= 1.0, "p50 {p50}");
    }

    fn corpus(seed: u64, n: usize) -> (Vec<f64>, Vec<f64>) {
        // deterministic noisy-linear corpus with ties in both margins
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        let mut s = seed;
        for i in 0..n {
            s = super::mix64(s ^ i as u64);
            let x = ((s % 17) as f64) / 16.0;
            let y = x * 0.7 + ((s >> 32) % 13) as f64 / 13.0 * 0.4;
            xs.push(x);
            ys.push(y);
        }
        (xs, ys)
    }

    #[test]
    fn streaming_pearson_matches_batch() {
        let (xs, ys) = corpus(42, 257);
        let mut sp = StreamingPearson::new();
        for i in 0..xs.len() {
            sp.push(xs[i], ys[i]);
        }
        assert_eq!(sp.len(), 257);
        assert!((sp.corr() - pearson(&xs, &ys)).abs() < 1e-12, "{} vs {}", sp.corr(), pearson(&xs, &ys));
    }

    #[test]
    fn streaming_pearson_degenerate_margins_are_zero() {
        let mut sp = StreamingPearson::new();
        sp.push(1.0, 2.0);
        assert_eq!(sp.corr(), 0.0, "n < 2");
        sp.push(1.0, 5.0); // constant x margin
        assert_eq!(sp.corr(), 0.0);
        assert_eq!(sp.corr_lower(1.96), -1.0, "n < 4 carries no evidence");
    }

    #[test]
    fn streaming_pearson_merge_equals_single_pass() {
        let (xs, ys) = corpus(7, 100);
        let mut whole = StreamingPearson::new();
        let mut a = StreamingPearson::new();
        let mut b = StreamingPearson::new();
        for i in 0..xs.len() {
            whole.push(xs[i], ys[i]);
            if i < 37 { a.push(xs[i], ys[i]) } else { b.push(xs[i], ys[i]) }
        }
        a.merge(&b);
        assert_eq!(a.len(), whole.len());
        assert!((a.corr() - whole.corr()).abs() < 1e-12);
        // merging into an empty accumulator is a copy
        let mut e = StreamingPearson::new();
        e.merge(&whole);
        assert!((e.corr() - whole.corr()).abs() < 1e-15);
    }

    #[test]
    fn corr_lower_bound_tightens_with_samples() {
        let mk = |n: usize| {
            let mut sp = StreamingPearson::new();
            let (xs, ys) = corpus(3, n);
            for i in 0..n {
                sp.push(xs[i], ys[i]);
            }
            sp
        };
        let small = mk(8);
        let big = mk(512);
        assert!(small.corr_lower(1.96) < small.corr());
        assert!(big.corr_lower(1.96) < big.corr());
        // same generator => similar r, but the bound closes in as n grows
        assert!(
            big.corr() - big.corr_lower(1.96) < small.corr() - small.corr_lower(1.96),
            "wide at n=8, tight at n=512"
        );
    }

    #[test]
    fn streaming_kendall_exact_under_cap() {
        let (xs, ys) = corpus(11, 64);
        let mut sk = StreamingKendall::new(64, 9);
        for i in 0..xs.len() {
            sk.push(xs[i], ys[i]);
        }
        assert_eq!(sk.corr(), kendall_tau(&xs, &ys), "reservoir holds the full corpus");
    }

    #[test]
    fn streaming_kendall_sketch_is_deterministic_and_bounded() {
        let (xs, ys) = corpus(5, 400);
        let run = || {
            let mut sk = StreamingKendall::new(48, 123);
            for i in 0..xs.len() {
                sk.push(xs[i], ys[i]);
            }
            sk.corr()
        };
        let (t1, t2) = (run(), run());
        assert_eq!(t1, t2, "same stream + seed => same sketch");
        assert!(t1.abs() <= 1.0);
        // the sketch still sees the positive association
        assert!(t1 > 0.2, "tau {t1}");
        let exact = kendall_tau(&xs, &ys);
        assert!((t1 - exact).abs() < 0.35, "sketch {t1} vs exact {exact}");
    }

    #[test]
    fn histogram_overflow() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.record(-1.0);
        h.record(2.0);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
    }
}
