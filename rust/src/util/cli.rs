//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Conventions: `binary <subcommand> [--key value | --flag] [positional…]`.
//! Typed getters with defaults keep call sites terse.

use std::collections::BTreeMap;

use super::error::{Error, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::parse("bare '--' not supported"));
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::parse(format!("--{name} expects an integer, got '{v}'"))),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::parse(format!("--{name} expects an integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::parse(format!("--{name} expects a number, got '{v}'"))),
        }
    }

    /// Integer option with a lower bound, for knobs where zero (or too
    /// small) is a configuration mistake, e.g. `--capacity`.
    pub fn get_usize_min(&self, name: &str, default: usize, min: usize) -> Result<usize> {
        let v = self.get_usize(name, default)?;
        if v < min {
            return Err(Error::parse(format!("--{name} must be >= {min}, got {v}")));
        }
        Ok(v)
    }

    /// Comma-separated list of integers, e.g. `--taus 4,8,16`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| Error::parse(format!("--{name}: bad integer '{p}'")))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse(&["serve", "path/to/x"]);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.positional, vec!["path/to/x"]);
    }

    #[test]
    fn options_both_styles() {
        let a = parse(&["run", "--n", "32", "--tau=8"]);
        assert_eq!(a.get("n"), Some("32"));
        assert_eq!(a.get("tau"), Some("8"));
    }

    #[test]
    fn flags() {
        let a = parse(&["run", "--verbose", "--n", "4"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 4);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["run", "--fast"]);
        assert!(a.flag("fast"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["x", "--f", "2.5", "--list", "1,2,3"]);
        assert_eq!(a.get_f64("f", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_usize_list("list", &[]).unwrap(), vec![1, 2, 3]);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn bad_values_error() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn bounded_getter_enforces_min() {
        let a = parse(&["x", "--capacity", "0", "--workers", "4"]);
        assert!(a.get_usize_min("capacity", 64, 1).is_err());
        assert_eq!(a.get_usize_min("workers", 2, 1).unwrap(), 4);
        assert_eq!(a.get_usize_min("absent", 7, 1).unwrap(), 7);
    }
}
