//! Fixed-size thread pool (tokio is unavailable offline).
//!
//! Drives the HTTP server's connection handling and parallel experiment
//! sweeps. Jobs are `FnOnce` closures; `join` blocks until the queue
//! drains; dropping the pool shuts workers down cleanly.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::Duration;

pub type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    executed: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let executed = Arc::new(AtomicUsize::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            let executed = Arc::clone(&executed);
            let stop = Arc::clone(&stop);
            workers.push(
                thread::Builder::new()
                    .name(format!("erprm-worker-{i}"))
                    .spawn(move || loop {
                        // recv with a timeout so shutdown works even while
                        // detached JobSenders keep the channel open.
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv_timeout(Duration::from_millis(50))
                        };
                        match job {
                            Ok(job) => {
                                job();
                                executed.fetch_add(1, Ordering::Relaxed);
                                let (lock, cv) = &*pending;
                                let mut p = lock.lock().unwrap();
                                *p -= 1;
                                if *p == 0 {
                                    cv.notify_all();
                                }
                            }
                            Err(mpsc::RecvTimeoutError::Timeout) => {
                                if stop.load(Ordering::Relaxed) {
                                    break;
                                }
                            }
                            Err(mpsc::RecvTimeoutError::Disconnected) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx: Some(tx), workers, pending, executed, stop }
    }

    /// Submit a job; panics if the pool is shut down.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.pending;
        *lock.lock().unwrap() += 1;
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Block until all submitted jobs have completed.
    pub fn join(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }

    /// Total jobs executed since creation.
    pub fn executed(&self) -> usize {
        self.executed.load(Ordering::Relaxed)
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// A cloneable submit handle decoupled from the pool's borrow (the
    /// HTTP accept thread owns one). Jobs submitted through it run on the
    /// pool's workers and count toward `join`. Dropping the pool still
    /// shuts workers down (bounded by one recv timeout) even while a
    /// `JobSender` is alive; submits after that point return false.
    pub fn sender(&self) -> JobSender {
        JobSender {
            tx: self.tx.as_ref().expect("pool shut down").clone(),
            pending: Arc::clone(&self.pending),
        }
    }
}

/// Detached submit handle for [`ThreadPool`]; see [`ThreadPool::sender`].
#[derive(Clone)]
pub struct JobSender {
    tx: mpsc::Sender<Job>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl JobSender {
    /// Submit a job; returns false (dropping the job) if the pool has
    /// shut down.
    pub fn submit(&self, job: Job) -> bool {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        if self.tx.send(job).is_err() {
            // Pool gone: undo the pending count so a racing join() can't
            // wedge waiting for a job that will never run.
            let (lock, cv) = &*self.pending;
            let mut p = lock.lock().unwrap();
            *p -= 1;
            if *p == 0 {
                cv.notify_all();
            }
            return false;
        }
        true
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Closing our sender ends workers once the queue drains (when no
        // detached JobSender is alive); the stop flag covers the case
        // where one is, bounding shutdown to one recv timeout.
        self.stop.store(true, Ordering::Relaxed);
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run a closure over each item with bounded parallelism, collecting results
/// in input order. Convenience for experiment sweeps.
pub fn parallel_map<T, R, F>(pool: &ThreadPool, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let n = items.len();
    let f = Arc::new(f);
    let results: Arc<Mutex<Vec<Option<R>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    for (i, item) in items.into_iter().enumerate() {
        let f = Arc::clone(&f);
        let results = Arc::clone(&results);
        pool.execute(move || {
            let r = f(item);
            results.lock().unwrap()[i] = Some(r);
        });
    }
    pool.join();
    Arc::try_unwrap(results)
        .ok()
        .expect("all workers done")
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("job completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
        assert_eq!(pool.executed(), 100);
    }

    #[test]
    fn join_waits_for_slow_jobs() {
        let pool = ThreadPool::new(2);
        let done = Arc::new(AtomicU64::new(0));
        for _ in 0..4 {
            let d = Arc::clone(&done);
            pool.execute(move || {
                thread::sleep(std::time::Duration::from_millis(20));
                d.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(done.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = parallel_map(&pool, (0..50).collect::<Vec<i32>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<i32>>());
    }

    #[test]
    fn drop_shuts_down() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        pool.join();
        drop(pool); // must not hang
    }

    #[test]
    fn detached_sender_runs_jobs_on_workers() {
        let pool = ThreadPool::new(4);
        let sender = pool.sender();
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..20 {
            let c = Arc::clone(&counter);
            assert!(sender.submit(Box::new(move || {
                c.fetch_add(1, Ordering::Relaxed);
            })));
        }
        pool.join();
        assert_eq!(counter.load(Ordering::Relaxed), 20);
        assert_eq!(pool.executed(), 20);
        drop(sender);
        drop(pool); // must not hang once the sender is gone
    }

    #[test]
    fn submit_after_shutdown_reports_failure() {
        let pool = ThreadPool::new(1);
        let sender = pool.sender();
        drop(pool);
        assert!(!sender.submit(Box::new(|| {})));
    }
}
