//! One-shot reply channel with hang-up detection.
//!
//! `std::sync::mpsc` gives a sender no way to learn that the receiver was
//! dropped short of actually sending — but the fleet drive loop needs to
//! notice *mid-solve* that every client waiting on a task has gone away
//! (HTTP connection died, dispatcher thread unwound) so the slot can be
//! reclaimed instead of running to completion for nobody. This channel is
//! the mpsc-oneshot we actually need: `send`/`recv` once, plus
//! [`Sender::is_closed`] observable at any time.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

struct State<T> {
    value: Option<T>,
    sender_gone: bool,
    receiver_gone: bool,
}

struct Inner<T> {
    state: Mutex<State<T>>,
    cv: Condvar,
}

/// Sending half; deliver at most one value with [`Sender::send`].
pub struct Sender<T> {
    inner: Arc<Inner<T>>,
}

/// Receiving half; consume the value with [`Receiver::recv`].
pub struct Receiver<T> {
    inner: Arc<Inner<T>>,
}

/// The sender hung up without delivering a value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecvError;

/// Why a timed receive returned without a value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecvTimeoutError {
    /// The budget ran out with the sender still live. `recv_timeout`
    /// consumes the receiver, so returning this *is* the abandon: the
    /// channel is marked hung-up and a later `send` hands the value back
    /// to the sender harmlessly — the abandon-and-504 path for
    /// deadline-bounded single-flight followers.
    Timeout,
    /// The sender dropped without delivering a value.
    Disconnected,
}

/// Outcome of a borrowed, non-consuming timed poll ([`Receiver::poll_for`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Polled<T> {
    /// The value arrived.
    Value(T),
    /// The sender dropped without delivering a value.
    Disconnected,
    /// The budget elapsed with the sender still live. Unlike
    /// [`Receiver::recv_timeout`] this does *not* abandon the channel —
    /// the receiver is untouched and the caller may poll again.
    Pending,
}

pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
    let inner = Arc::new(Inner {
        state: Mutex::new(State { value: None, sender_gone: false, receiver_gone: false }),
        cv: Condvar::new(),
    });
    (Sender { inner: Arc::clone(&inner) }, Receiver { inner })
}

impl<T> Sender<T> {
    /// Deliver the value. `Err(v)` hands it back when the receiver is
    /// gone or a value was already delivered.
    pub fn send(&self, v: T) -> Result<(), T> {
        let mut st = self.inner.state.lock().unwrap();
        if st.receiver_gone || st.value.is_some() {
            return Err(v);
        }
        st.value = Some(v);
        drop(st);
        self.inner.cv.notify_all();
        Ok(())
    }

    /// Whether the receiving side has hung up (nobody will ever read a
    /// reply) — the fleet loop's client-disconnect signal.
    pub fn is_closed(&self) -> bool {
        self.inner.state.lock().unwrap().receiver_gone
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        self.inner.state.lock().unwrap().sender_gone = true;
        self.inner.cv.notify_all();
    }
}

impl<T> Receiver<T> {
    /// Block until the value arrives; `Err(RecvError)` if the sender
    /// dropped without delivering one.
    pub fn recv(self) -> Result<T, RecvError> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if let Some(v) = st.value.take() {
                return Ok(v);
            }
            if st.sender_gone {
                return Err(RecvError);
            }
            st = self.inner.cv.wait(st).unwrap();
        }
    }

    /// Wait up to `budget` for the value *without* consuming the
    /// receiver. This is the dispatcher's supervised-wait primitive: it
    /// polls in bounded slices so that between slices it can check
    /// side-band conditions — did the owning shard's generation retire?
    /// did the HTTP client hang up? — none of which the channel itself
    /// can observe. `Pending` leaves the channel fully intact.
    pub fn poll_for(&self, budget: Duration) -> Polled<T> {
        let deadline = Instant::now() + budget;
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if let Some(v) = st.value.take() {
                return Polled::Value(v);
            }
            if st.sender_gone {
                return Polled::Disconnected;
            }
            let now = Instant::now();
            if now >= deadline {
                return Polled::Pending;
            }
            st = self.inner.cv.wait_timeout(st, deadline - now).unwrap().0;
        }
    }

    /// Block until the value arrives or `budget` elapses. Consumes the
    /// receiver either way; on `Timeout` the implied drop is the abandon
    /// signal the sender observes via [`Sender::is_closed`] / a failed
    /// `send`.
    pub fn recv_timeout(self, budget: Duration) -> Result<T, RecvTimeoutError> {
        let deadline = Instant::now() + budget;
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if let Some(v) = st.value.take() {
                return Ok(v);
            }
            if st.sender_gone {
                return Err(RecvTimeoutError::Disconnected);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvTimeoutError::Timeout);
            }
            st = self.inner.cv.wait_timeout(st, deadline - now).unwrap().0;
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        self.inner.state.lock().unwrap().receiver_gone = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_round_trips() {
        let (tx, rx) = channel();
        assert!(!tx.is_closed());
        tx.send(7).unwrap();
        assert_eq!(rx.recv(), Ok(7));
    }

    #[test]
    fn receiver_drop_observable_by_sender() {
        let (tx, rx) = channel::<i32>();
        drop(rx);
        assert!(tx.is_closed(), "hang-up must be visible before any send");
        assert_eq!(tx.send(1), Err(1), "send hands the value back");
    }

    #[test]
    fn sender_drop_unblocks_receiver() {
        let (tx, rx) = channel::<i32>();
        let j = std::thread::spawn(move || rx.recv());
        drop(tx);
        assert_eq!(j.join().unwrap(), Err(RecvError));
    }

    #[test]
    fn send_before_recv_across_threads() {
        let (tx, rx) = channel();
        let j = std::thread::spawn(move || {
            tx.send("done").unwrap();
        });
        assert_eq!(rx.recv(), Ok("done"));
        j.join().unwrap();
    }

    #[test]
    fn recv_timeout_delivers_value_or_times_out() {
        let (tx, rx) = channel();
        tx.send(9).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_millis(1)), Ok(9));

        let (tx, rx) = channel::<i32>();
        let t0 = Instant::now();
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(30)),
            Err(RecvTimeoutError::Timeout)
        );
        assert!(t0.elapsed() >= Duration::from_millis(30));
        drop(tx);
    }

    #[test]
    fn recv_timeout_sees_sender_hangup() {
        let (tx, rx) = channel::<i32>();
        let j = std::thread::spawn(move || rx.recv_timeout(Duration::from_secs(5)));
        drop(tx);
        assert_eq!(j.join().unwrap(), Err(RecvTimeoutError::Disconnected));
    }

    #[test]
    fn abandon_after_timeout_bounces_the_late_send() {
        // the single-flight follower contract: timeout, drop the
        // receiver, and the leader's eventual send must fail cleanly
        let (tx, rx) = channel::<i32>();
        assert_eq!(rx.recv_timeout(Duration::from_millis(5)), Err(RecvTimeoutError::Timeout));
        // receiver consumed by recv_timeout -> dropped -> hang-up visible
        assert!(tx.is_closed());
        assert_eq!(tx.send(42), Err(42));
    }

    #[test]
    fn recv_timeout_wakes_on_late_send() {
        let (tx, rx) = channel();
        let j = std::thread::spawn(move || rx.recv_timeout(Duration::from_secs(10)));
        std::thread::sleep(Duration::from_millis(20));
        tx.send("late").unwrap();
        assert_eq!(j.join().unwrap(), Ok("late"));
    }

    #[test]
    fn poll_for_is_non_consuming() {
        let (tx, rx) = channel();
        assert_eq!(rx.poll_for(Duration::from_millis(5)), Polled::Pending);
        assert_eq!(rx.poll_for(Duration::from_millis(5)), Polled::Pending, "still pollable");
        assert!(!tx.is_closed(), "pending poll must not abandon the channel");
        tx.send(11).unwrap();
        assert_eq!(rx.poll_for(Duration::from_millis(5)), Polled::Value(11));
    }

    #[test]
    fn poll_for_sees_disconnect() {
        let (tx, rx) = channel::<i32>();
        drop(tx);
        assert_eq!(rx.poll_for(Duration::from_secs(5)), Polled::Disconnected);
    }

    #[test]
    fn poll_for_wakes_on_late_send() {
        let (tx, rx) = channel();
        let j = std::thread::spawn(move || rx.poll_for(Duration::from_secs(10)));
        std::thread::sleep(Duration::from_millis(20));
        tx.send("late").unwrap();
        assert_eq!(j.join().unwrap(), Polled::Value("late"));
    }

    #[test]
    fn second_send_is_rejected() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        assert_eq!(tx.send(2), Err(2));
        assert_eq!(rx.recv(), Ok(1));
    }
}
