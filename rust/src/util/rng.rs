//! Deterministic PRNG + sampling distributions (no `rand` crate offline).
//!
//! xoshiro256** seeded via SplitMix64 — fast, high-quality, reproducible
//! across runs; every experiment takes an explicit seed so paper tables
//! regenerate bit-identically.

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-beam / per-request RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Gumbel(0,1) — for the Gumbel-max categorical trick.
    pub fn gumbel(&mut self) -> f64 {
        -(-self.f64().max(1e-300).ln()).ln()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical needs positive mass");
        let mut r = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample from logits at a temperature (Gumbel-max; temp<=0 = argmax).
    pub fn sample_logits(&mut self, logits: &[f32], temp: f32) -> usize {
        let mut best = 0;
        let mut best_v = f64::NEG_INFINITY;
        for (i, &l) in logits.iter().enumerate() {
            let v = if temp > 1e-4 {
                l as f64 / temp as f64 + self.gumbel()
            } else {
                l as f64
            };
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        best
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let n = r.range(-5, 5);
            assert!((-5..=5).contains(&n));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn categorical_frequencies() {
        let mut r = Rng::new(5);
        let w = [1.0, 3.0];
        let mut c = [0usize; 2];
        for _ in 0..8000 {
            c[r.categorical(&w)] += 1;
        }
        let frac = c[1] as f64 / 8000.0;
        assert!((frac - 0.75).abs() < 0.03, "frac {frac}");
    }

    #[test]
    fn sample_logits_greedy_at_zero_temp() {
        let mut r = Rng::new(9);
        let logits = [0.1f32, 5.0, -2.0];
        for _ in 0..20 {
            assert_eq!(r.sample_logits(&logits, 0.0), 1);
        }
    }

    #[test]
    fn sample_logits_temp_spreads() {
        let mut r = Rng::new(13);
        let logits = [1.0f32, 1.0, 1.0];
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[r.sample_logits(&logits, 1.0)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(1);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
