//! Crate-wide error type.

use std::fmt;

/// Unified error for the serving stack.
#[derive(Debug)]
pub enum Error {
    /// Malformed JSON / manifest / config input.
    Parse(String),
    /// I/O failure (artifacts, sockets, weights).
    Io(std::io::Error),
    /// PJRT / XLA runtime failure.
    Xla(String),
    /// Invariant violation in the coordinator (a bug or bad request).
    Invalid(String),
    /// Serving backpressure: every shard queue is at capacity. Maps to
    /// HTTP 503 Service Unavailable (retryable), never 4xx.
    Saturated(String),
    /// The request's deadline elapsed before a result was produced
    /// (queued too long or aborted mid-solve by the fleet scheduler).
    /// Maps to HTTP 504 Gateway Timeout.
    Deadline(String),
    /// The engine shard holding the request died (panicked, wedged, or
    /// was retired by the supervisor) before delivering a result. The
    /// router treats this as *retryable* — a retried solve is a fresh
    /// deterministic solve, so replaying it on a healthy shard yields a
    /// byte-identical answer. If retries are exhausted it surfaces as
    /// HTTP 503 + Retry-After (the pool is respawning the shard), never
    /// 4xx.
    ShardLost(String),
    /// The HTTP client hung up mid-solve (TCP close observed by the
    /// socket probe). Nobody is left to read the answer, so the solve is
    /// cancelled; maps to HTTP 499 (client closed request) in logs and
    /// metrics only.
    Hangup(String),
    /// Server-side infrastructure fault (e.g. an engine shard thread
    /// died). Maps to HTTP 500 — never blamed on the client.
    Internal(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Invalid(m) => write!(f, "invalid: {m}"),
            Error::Saturated(m) => write!(f, "saturated: {m}"),
            Error::Deadline(m) => write!(f, "deadline exceeded: {m}"),
            Error::ShardLost(m) => write!(f, "shard lost: {m}"),
            Error::Hangup(m) => write!(f, "client hangup: {m}"),
            Error::Internal(m) => write!(f, "internal: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Shorthand constructors.
impl Error {
    pub fn parse(m: impl Into<String>) -> Self {
        Error::Parse(m.into())
    }
    pub fn invalid(m: impl Into<String>) -> Self {
        Error::Invalid(m.into())
    }
    pub fn saturated(m: impl Into<String>) -> Self {
        Error::Saturated(m.into())
    }
    pub fn deadline(m: impl Into<String>) -> Self {
        Error::Deadline(m.into())
    }
    pub fn shard_lost(m: impl Into<String>) -> Self {
        Error::ShardLost(m.into())
    }
    pub fn hangup(m: impl Into<String>) -> Self {
        Error::Hangup(m.into())
    }
    pub fn internal(m: impl Into<String>) -> Self {
        Error::Internal(m.into())
    }

    /// Whether the router may transparently retry this failure on
    /// another shard. Only `ShardLost` is unconditionally retryable: the
    /// job never produced a result, and a fresh dispatch is a fresh
    /// deterministic solve. (`Saturated` is additionally retryable under
    /// the `retry_saturated` knob — decided at the router, not here.)
    pub fn is_retryable(&self) -> bool {
        matches!(self, Error::ShardLost(_))
    }

    /// Rebuild an error of the same class (`Error` is not `Clone` because
    /// of the `Io` payload) so every request attached to one engine run —
    /// fleet riders, pool single-flight followers — renders the same HTTP
    /// status: a deadline abort stays 504, backpressure stays 503, never
    /// a retry-suggesting 500.
    pub fn clone_class(&self) -> Error {
        match self {
            Error::Parse(m) => Error::Parse(m.clone()),
            Error::Xla(m) => Error::Xla(m.clone()),
            Error::Invalid(m) => Error::Invalid(m.clone()),
            Error::Saturated(m) => Error::Saturated(m.clone()),
            Error::Deadline(m) => Error::Deadline(m.clone()),
            Error::ShardLost(m) => Error::ShardLost(m.clone()),
            Error::Hangup(m) => Error::Hangup(m.clone()),
            other => Error::Internal(other.to_string()),
        }
    }

    /// The HTTP status this error renders as: client mistakes are 4xx,
    /// backpressure and shard loss are 503 (retryable), deadline expiry
    /// is 504, client hangup is 499 (nginx convention — logged, never
    /// actually read by the departed client), runtime/infrastructure
    /// faults are 500.
    pub fn http_status(&self) -> u16 {
        match self {
            Error::Parse(_) | Error::Invalid(_) => 400,
            Error::Hangup(_) => 499,
            Error::Saturated(_) | Error::ShardLost(_) => 503,
            Error::Deadline(_) => 504,
            Error::Io(_) | Error::Xla(_) | Error::Internal(_) => 500,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_variants() {
        assert!(Error::parse("x").to_string().contains("parse"));
        assert!(Error::invalid("y").to_string().contains("invalid"));
        assert!(Error::saturated("z").to_string().contains("saturated"));
        assert!(Error::deadline("w").to_string().contains("deadline"));
        let io: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(io.to_string().contains("gone"));
    }

    #[test]
    fn clone_class_preserves_http_status() {
        for e in [
            Error::parse("a"),
            Error::invalid("b"),
            Error::saturated("c"),
            Error::deadline("d"),
            Error::internal("e"),
            Error::Xla("f".into()),
            Error::shard_lost("g"),
            Error::hangup("h"),
        ] {
            assert_eq!(e.clone_class().http_status(), e.http_status(), "{e}");
        }
        let io: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert_eq!(io.clone_class().http_status(), 500);
        assert!(io.clone_class().to_string().contains("gone"), "message survives");
    }

    #[test]
    fn http_status_mapping() {
        assert_eq!(Error::parse("x").http_status(), 400);
        assert_eq!(Error::invalid("x").http_status(), 400);
        assert_eq!(Error::saturated("x").http_status(), 503);
        assert_eq!(Error::shard_lost("x").http_status(), 503, "retryable, never 4xx/500");
        assert_eq!(Error::hangup("x").http_status(), 499);
        assert_eq!(Error::deadline("x").http_status(), 504);
        assert_eq!(Error::internal("x").http_status(), 500);
        assert_eq!(Error::Xla("x".into()).http_status(), 500);
        let io: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert_eq!(io.http_status(), 500);
    }

    #[test]
    fn only_shard_loss_is_retryable() {
        assert!(Error::shard_lost("x").is_retryable());
        for e in [
            Error::parse("a"),
            Error::invalid("b"),
            Error::saturated("c"),
            Error::deadline("d"),
            Error::internal("e"),
            Error::hangup("f"),
            Error::Xla("g".into()),
        ] {
            assert!(!e.is_retryable(), "{e}");
        }
    }
}
