//! Crate-wide error type.

use std::fmt;

/// Unified error for the serving stack.
#[derive(Debug)]
pub enum Error {
    /// Malformed JSON / manifest / config input.
    Parse(String),
    /// I/O failure (artifacts, sockets, weights).
    Io(std::io::Error),
    /// PJRT / XLA runtime failure.
    Xla(String),
    /// Invariant violation in the coordinator (a bug or bad request).
    Invalid(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Invalid(m) => write!(f, "invalid: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Shorthand constructors.
impl Error {
    pub fn parse(m: impl Into<String>) -> Self {
        Error::Parse(m.into())
    }
    pub fn invalid(m: impl Into<String>) -> Self {
        Error::Invalid(m.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_variants() {
        assert!(Error::parse("x").to_string().contains("parse"));
        assert!(Error::invalid("y").to_string().contains("invalid"));
        let io: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(io.to_string().contains("gone"));
    }
}
