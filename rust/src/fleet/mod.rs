//! The fleet scheduler: continuous cross-request batching per shard.
//!
//! The sequential serve path runs each request to completion on its shard
//! before touching the next one, so the compute that early rejection frees
//! mid-step simply evaporates — and a long solve head-of-line blocks every
//! request queued behind it. The fleet turns each shard thread into a
//! continuous scheduler instead:
//!
//! * every in-flight request is a resumable
//!   [`crate::coordinator::task::SolveTask`] parked in a **slot table**
//!   (`--max-inflight` slots per shard);
//! * the shard loop advances each occupied slot by one bounded unit of
//!   engine work per round (one lockstep decode block, one scoring pass,
//!   one reject/expand transition) — short requests overtake long ones
//!   instead of waiting behind them;
//! * a slot freed by completion, failure, or deadline abort is immediately
//!   **backfilled** from the admission queue — the engine never idles
//!   while work is waiting. Within a task, the early-rejection shrink
//!   (phase B at b2 < b1) is exactly what makes interleaving profitable:
//!   the rounds a request spends in its narrow completion phase are cheap,
//!   so the freed capacity goes to other requests' wide prefix phases;
//! * identical in-flight requests **coalesce**: solves are deterministic
//!   for a fixed `(problem, config, seed)` (the LRU-cache contract), so a
//!   duplicate admission rides the running task and the engine pays once;
//! * the [`queue::AdmissionQueue`] enforces the fairness/deadline policy:
//!   highest priority first, FIFO within a priority, with an aging guard
//!   that force-schedules any request waiting longer than `fair_after_ms`
//!   so low-priority work cannot starve, and per-request deadlines that
//!   reject queued work (and abort in-flight work) past its budget with
//!   HTTP 504.
//!
//! Determinism: a task owns all of its state (KV caches, RNG streams,
//! ledger), so its [`crate::coordinator::search::SolveOutcome`] is
//! byte-identical (modulo wall-clock) whether it ran alone or interleaved
//! with any number of other tasks — the integration suite pins this.
//!
//! With `--gang`, the shard loop goes one level deeper than interleaving:
//! tasks are driven cooperatively (`SolveTask::poll`) so their decode and
//! score calls are *yielded* as intents, and the gang batcher
//! ([`crate::batch`]) merges compatible intents (same checkpoint, same
//! program class, same temperature) into one shared device batch via the
//! exported `merge_bA_bB_to_bC` KV programs — true cross-request device
//! batching, not just time-slicing. An intent waits at most
//! `gang_max_wait` rounds for partners before running solo.

pub mod chaos;
pub mod queue;
pub mod shard;
pub mod stats;

use crate::coordinator::search::SolveOutcome;

pub use chaos::{ChaosAction, ChaosOptions, ChaosState};
pub use queue::{AdmissionQueue, FleetJob, TaskSpec};
pub use shard::{drive, DriveHooks, NoHooks, Poll};
pub use stats::{FleetStats, FleetTotals};

/// A completed solve plus its scheduling telemetry. `queue_wait_ms` is
/// enqueue → admission (how long scheduling delayed the request), which
/// clients subtract from end-to-end latency to get service time.
#[derive(Debug, Clone)]
pub struct Solved {
    pub outcome: SolveOutcome,
    pub queue_wait_ms: f64,
}

/// Fleet-mode knobs (per shard). The serve-wide default deadline lives on
/// the pool (`PoolOptions::default_deadline_ms`) because both dispatch
/// modes honor it, not just the fleet.
#[derive(Debug, Clone)]
pub struct FleetOptions {
    /// Slot-table size: how many requests one shard interleaves.
    pub max_inflight: usize,
    /// Aging guard: a queued request older than this is scheduled next
    /// regardless of priority, so nothing starves.
    pub fair_after_ms: u64,
    /// Gang batching: merge compatible in-flight tasks' decode/score
    /// calls into shared device batches (needs artifacts exported with
    /// merge programs; degrades to solo calls without them).
    pub gang: bool,
    /// Scheduler rounds a yielded intent may wait for gang partners
    /// before executing solo (0 = never wait). A task that is alone in
    /// the slot table never waits at all.
    pub gang_max_wait: u64,
}

impl Default for FleetOptions {
    fn default() -> Self {
        FleetOptions { max_inflight: 8, fair_after_ms: 500, gang: false, gang_max_wait: 1 }
    }
}
