//! The per-shard fleet loop: slot table + round-robin task advancement.
//!
//! One call to [`drive`] takes over a shard thread that owns an `Engine`
//! and runs until its message source shuts down. Each scheduler round:
//!
//! 1. **Ingest** — drain newly arrived jobs into the admission queue
//!    (blocking only when completely idle, so the loop never spins).
//! 2. **Expire** — bounce queued jobs whose deadline elapsed (HTTP 504).
//! 3. **Coalesce** — fold queued duplicates of an in-flight task onto it.
//! 4. **Backfill** — admit queued jobs into free slots, building each a
//!    resumable [`SolveTask`].
//! 5. **Advance** — give every occupied slot one bounded unit of engine
//!    work; completed/failed/expired tasks reply and free their slot for
//!    the next round's backfill.
//!
//! The engine stays `!Send`-confined to this thread; only host-side job
//! envelopes cross the channel.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::coordinator::task::{Progress, SolveTask};
use crate::fleet::queue::{AdmissionQueue, FleetJob, ReplyTx};
use crate::fleet::stats::FleetStats;
use crate::fleet::{FleetOptions, Solved};
use crate::log_error;
use crate::runtime::{Engine, EngineStats};
use crate::util::error::Error;

/// One poll of the shard's message source.
pub enum Poll {
    /// A new job arrived.
    Job(Box<FleetJob>),
    /// Graceful shutdown requested: finish in-flight + queued work, then
    /// exit.
    Shutdown,
    /// Nothing waiting right now (non-blocking poll only).
    Empty,
    /// The channel is gone; exit after draining in-flight work.
    Closed,
}

/// One request attached to a running task (the admitting job or a
/// coalesced duplicate).
struct Waiter {
    reply: ReplyTx,
    queue_wait_ms: f64,
}

/// An occupied slot.
struct Running {
    task: SolveTask,
    key: Option<String>,
    /// Latest deadline among attached requests; the task aborts only when
    /// every rider's budget is spent.
    deadline_at: Option<Instant>,
    /// True once any attached request is unbounded (no deadline).
    unbounded: bool,
    primary: Waiter,
    riders: Vec<Waiter>,
}

impl Running {
    /// Fold another request's deadline into the task's abort threshold.
    fn extend_deadline(&mut self, d: Option<Instant>) {
        match d {
            None => self.unbounded = true,
            Some(t) => {
                self.deadline_at = Some(match self.deadline_at {
                    Some(cur) => cur.max(t),
                    None => t,
                });
            }
        }
    }

    fn expired(&self, now: Instant) -> bool {
        !self.unbounded && self.deadline_at.map(|t| now >= t).unwrap_or(false)
    }
}

/// Drive one shard's fleet loop until the source closes. `poll(true)`
/// must block for the next message; `poll(false)` must return
/// immediately. `solved`/`engine_stats` are the pool-level per-shard
/// counters the sequential path also maintains.
pub fn drive(
    engine: &Engine,
    opts: &FleetOptions,
    stats: &FleetStats,
    solved: &AtomicU64,
    engine_stats: &Mutex<EngineStats>,
    mut poll: impl FnMut(bool) -> Poll,
) {
    let n_slots = opts.max_inflight.max(1);
    let mut slots: Vec<Option<Running>> = (0..n_slots).map(|_| None).collect();
    let mut queue = AdmissionQueue::new(Duration::from_millis(opts.fair_after_ms.max(1)));
    let mut inflight = 0usize;
    let mut shutdown = false;

    loop {
        // ---- 1. ingest
        if inflight == 0 && queue.is_empty() {
            if shutdown {
                break;
            }
            match poll(true) {
                Poll::Job(j) => queue.push(*j),
                Poll::Shutdown => shutdown = true,
                Poll::Closed => break,
                Poll::Empty => {}
            }
            continue; // re-check idle/shutdown with the new state
        }
        loop {
            match poll(false) {
                Poll::Job(j) => queue.push(*j),
                Poll::Shutdown => shutdown = true,
                Poll::Closed => {
                    shutdown = true;
                    break;
                }
                Poll::Empty => break,
            }
        }
        let now = Instant::now();

        // ---- 2. expire queued work
        for job in queue.expire(now) {
            stats.expired_total.fetch_add(1, Ordering::Relaxed);
            let _ = job.reply.send(Err(Error::deadline(format!(
                "spent {:.0}ms queued, budget was {}ms",
                job.waited_ms(now),
                job.deadline.map(|d| d.as_millis()).unwrap_or(0)
            ))));
        }

        // ---- 3. coalesce queued duplicates onto in-flight tasks
        let dups = queue.drain_matching(|j| {
            j.key.is_some()
                && slots
                    .iter()
                    .flatten()
                    .any(|r| r.key.is_some() && r.key == j.key)
        });
        for job in dups {
            let r = slots
                .iter_mut()
                .flatten()
                .find(|r| r.key == job.key)
                .expect("matched above");
            r.extend_deadline(job.deadline_at());
            r.riders.push(Waiter { reply: job.reply, queue_wait_ms: job.waited_ms(now) });
            stats.coalesced_total.fetch_add(1, Ordering::Relaxed);
        }

        // ---- 4. backfill free slots from the queue
        while inflight < n_slots {
            let Some(job) = queue.pop(now) else { break };
            let wait_ms = job.waited_ms(now);
            // a duplicate of a slot filled earlier this same round (burst
            // of identical requests hitting an idle shard) rides it too —
            // step 3 only sees tasks that were in flight before backfill
            if job.key.is_some() {
                if let Some(r) = slots.iter_mut().flatten().find(|r| r.key == job.key) {
                    r.extend_deadline(job.deadline_at());
                    r.riders.push(Waiter { reply: job.reply, queue_wait_ms: wait_ms });
                    stats.coalesced_total.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
            }
            match job.spec.build() {
                Err(e) => {
                    stats.failed_total.fetch_add(1, Ordering::Relaxed);
                    let _ = job.reply.send(Err(e));
                }
                Ok(task) => {
                    if inflight > 0 {
                        stats.backfill_total.fetch_add(1, Ordering::Relaxed);
                    }
                    stats.admitted_total.fetch_add(1, Ordering::Relaxed);
                    let idx = slots
                        .iter()
                        .position(Option::is_none)
                        .expect("inflight < n_slots implies a free slot");
                    let deadline_at = job.deadline_at();
                    let mut running = Running {
                        task,
                        key: job.key,
                        deadline_at: None,
                        unbounded: false,
                        primary: Waiter { reply: job.reply, queue_wait_ms: wait_ms },
                        riders: Vec::new(),
                    };
                    running.extend_deadline(deadline_at);
                    slots[idx] = Some(running);
                    inflight += 1;
                }
            }
        }

        // ---- 5. advance every occupied slot by one unit of work
        if inflight > 0 {
            stats.record_round(inflight, n_slots);
        }
        for idx in 0..slots.len() {
            let Some(r) = slots[idx].as_mut() else { continue };
            if r.expired(Instant::now()) {
                let r = slots[idx].take().expect("checked occupied");
                inflight -= 1;
                stats.expired_total.fetch_add(1, Ordering::Relaxed);
                reply_error(r, Error::deadline("aborted mid-solve: deadline elapsed"));
                continue;
            }
            match r.task.advance(engine) {
                Ok(Progress::Working) => {}
                Ok(Progress::Done) => {
                    let mut r = slots[idx].take().expect("checked occupied");
                    inflight -= 1;
                    solved.fetch_add(1, Ordering::Relaxed);
                    *engine_stats.lock().unwrap() = engine.stats();
                    if r.expired(Instant::now()) {
                        // budget blew during the final advance: the 504
                        // contract beats returning a too-late 200
                        stats.expired_total.fetch_add(1, Ordering::Relaxed);
                        reply_error(
                            r,
                            Error::deadline("deadline elapsed during the final solve step"),
                        );
                        continue;
                    }
                    match r.task.take_outcome() {
                        Some(out) => {
                            stats.completed_total.fetch_add(1, Ordering::Relaxed);
                            for w in r.riders {
                                let _ = w.reply.send(Ok(Solved {
                                    outcome: out.clone(),
                                    queue_wait_ms: w.queue_wait_ms,
                                }));
                            }
                            let _ = r.primary.reply.send(Ok(Solved {
                                outcome: out,
                                queue_wait_ms: r.primary.queue_wait_ms,
                            }));
                        }
                        None => {
                            stats.failed_total.fetch_add(1, Ordering::Relaxed);
                            reply_error(r, Error::internal("finished task lost its outcome"));
                        }
                    }
                }
                Err(e) => {
                    let r = slots[idx].take().expect("checked occupied");
                    inflight -= 1;
                    stats.failed_total.fetch_add(1, Ordering::Relaxed);
                    *engine_stats.lock().unwrap() = engine.stats();
                    log_error!("fleet task failed in state '{}': {e}", r.task.state_name());
                    reply_error(r, e);
                }
            }
        }
        stats.inflight.store(inflight, Ordering::Relaxed);
        stats.queued.store(queue.len(), Ordering::Relaxed);
    }
    stats.inflight.store(0, Ordering::Relaxed);
    stats.queued.store(0, Ordering::Relaxed);
}

/// Deliver one error to every request attached to a slot. `Error` is not
/// `Clone`, so riders get a reconstructed copy — same variant where the
/// message suffices to rebuild it, so a deadline abort renders 504 for
/// every attached request, never a retry-suggesting 500.
fn reply_error(r: Running, e: Error) {
    fn same_class(e: &Error) -> Error {
        match e {
            Error::Parse(m) => Error::Parse(m.clone()),
            Error::Xla(m) => Error::Xla(m.clone()),
            Error::Invalid(m) => Error::Invalid(m.clone()),
            Error::Saturated(m) => Error::Saturated(m.clone()),
            Error::Deadline(m) => Error::Deadline(m.clone()),
            other => Error::Internal(other.to_string()),
        }
    }
    for w in r.riders {
        let _ = w.reply.send(Err(same_class(&e)));
    }
    let _ = r.primary.reply.send(Err(e));
}
