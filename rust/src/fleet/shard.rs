//! The per-shard fleet loop: slot table + round-robin task advancement.
//!
//! One call to [`drive`] takes over a shard thread that owns an `Engine`
//! and runs until its message source shuts down. Each scheduler round:
//!
//! 1. **Ingest** — drain newly arrived jobs into the admission queue
//!    (blocking only when completely idle, so the loop never spins).
//!    Deadline-aware admission bounces bounded jobs whose queue-wait
//!    forecast (slot pressure x mean service time, stretched by KV
//!    block-pool scarcity, discounting queued duplicates that will ride
//!    in-flight tasks) already exceeds their budget — 504 at the door
//!    instead of a doomed slot occupation.
//! 2. **Expire / cancel** — bounce queued jobs whose deadline elapsed
//!    (HTTP 504) and drop queued jobs whose client already hung up.
//! 3. **Coalesce** — fold queued duplicates of an in-flight task onto it.
//! 4. **Backfill** — admit queued jobs into free slots, building each a
//!    resumable [`SolveTask`]. With paged KV on, admission additionally
//!    waits for block-pool headroom (two fresh caches' worth), so pool
//!    exhaustion degrades to queueing rather than mid-flight failure.
//! 5. **Advance** — give every occupied slot one bounded unit of engine
//!    work; completed/failed/expired tasks reply and free their slot.
//!    A slot whose every attached reply channel is closed (client
//!    disconnect) is treated like an expired deadline: cancelled, freed,
//!    backfilled next round. With gang batching on, tasks are *polled*
//!    cooperatively instead: yielded decode/score intents park in the
//!    slot and step 6 packs them.
//! 6. **Gang dispatch** (`--gang`) — group parked intents by
//!    (checkpoint, program, temperature), pack them largest-first into
//!    merged batch variants ([`crate::batch::plan_gangs_costed`], gated
//!    by the wall-clock cost model calibrated from this engine's own
//!    call timings), and run one shared device call per gang; leftovers
//!    execute solo once they have waited `gang_max_wait` rounds
//!    (immediately when the task is alone). Yielded KV re-compaction
//!    intents also run here — always solo and immediately, since a
//!    repack has nothing to share and everything behind it waits.
//!
//! The engine stays `!Send`-confined to this thread; only host-side job
//! envelopes cross the channel.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::batch::{self, BatchStats};
use crate::coordinator::task::{IntentKind, Progress, SolveTask, Step};
use crate::fleet::chaos::ChaosAction;
use crate::fleet::queue::{admission_forecast_ms, AdmissionQueue, FleetJob, ReplyTx};
use crate::fleet::stats::FleetStats;
use crate::fleet::{FleetOptions, Solved};
use crate::log_error;
use crate::obs::{PhaseFlops, TraceRecorder};
use crate::runtime::{Engine, EngineStats};
use crate::util::error::Error;
use crate::util::logging;
use crate::util::sync::lock_unpoisoned;

/// One poll of the shard's message source.
pub enum Poll {
    /// A new job arrived.
    Job(Box<FleetJob>),
    /// Graceful shutdown requested: finish in-flight + queued work, then
    /// exit.
    Shutdown,
    /// Nothing waiting right now (non-blocking poll only).
    Empty,
    /// The channel is gone; exit after draining in-flight work.
    Closed,
}

/// Supervision hooks the drive loop consults once per scheduler round.
/// The pool supervisor's heartbeat/retirement/chaos plumbing implements
/// this; standalone callers (benchmarks, tests) use [`NoHooks`]. This is
/// also the seam the ROADMAP's router tier reuses: a shard driven over a
/// remote transport supplies hooks that report pod liveness instead of
/// thread liveness.
pub trait DriveHooks {
    /// Record liveness for this round (the supervisor's wedge detector
    /// reads it). Called at the top of every round, including idle ones.
    fn beat(&self) {}
    /// True once this shard generation has been retired (the supervisor
    /// respawned the shard after declaring it wedged). The loop exits
    /// immediately, dropping its state: every job still attached was
    /// already requeued or failed by the supervisor, and any late reply
    /// from this zombie would bounce off an abandoned channel.
    fn retired(&self) -> bool {
        false
    }
    /// Deterministic fault-injection draw for this round.
    fn chaos_tick(&self) -> ChaosAction {
        ChaosAction::None
    }
}

/// No supervision: never retired, no heartbeats, no chaos.
pub struct NoHooks;

impl DriveHooks for NoHooks {}

/// One request attached to a running task (the admitting job or a
/// coalesced duplicate).
struct Waiter {
    reply: ReplyTx,
    queue_wait_ms: f64,
}

/// An occupied slot.
struct Running {
    task: SolveTask,
    key: Option<String>,
    /// Latest deadline among attached requests; the task aborts only when
    /// every rider's budget is spent.
    deadline_at: Option<Instant>,
    /// True once any attached request is unbounded (no deadline).
    unbounded: bool,
    /// When the task entered its slot (service-time estimation).
    admitted_at: Instant,
    /// Rounds the task's yielded intent has been parked awaiting gang
    /// partners; `None` = no intent parked (gang mode only).
    parked: Option<u64>,
    primary: Waiter,
    riders: Vec<Waiter>,
}

impl Running {
    /// Fold another request's deadline into the task's abort threshold.
    fn extend_deadline(&mut self, d: Option<Instant>) {
        match d {
            None => self.unbounded = true,
            Some(t) => {
                self.deadline_at = Some(match self.deadline_at {
                    Some(cur) => cur.max(t),
                    None => t,
                });
            }
        }
    }

    fn expired(&self, now: Instant) -> bool {
        !self.unbounded && self.deadline_at.map(|t| now >= t).unwrap_or(false)
    }

    /// Every attached client hung up: nobody will read a result, so the
    /// slot is better spent on queued work (ROADMAP: client disconnect
    /// cancellation).
    fn abandoned(&self) -> bool {
        self.primary.reply.is_closed() && self.riders.iter().all(|w| w.reply.is_closed())
    }
}

/// What one slot's turn in the advance pass produced.
enum SlotTick {
    /// Task parked an intent (gang mode) or simply progressed.
    Working,
    /// Task finished; run the completion protocol.
    Done,
    /// Task errored terminally.
    Failed(Error),
}

/// Drive one shard's fleet loop until the source closes. `poll(true)`
/// must block for the next message; `poll(false)` must return
/// immediately. `solved`/`engine_stats` are the pool-level per-shard
/// counters the sequential path also maintains; `bstats` is the gang
/// batcher's telemetry (all-zero with `gang` off).
#[allow(clippy::too_many_arguments)]
pub fn drive(
    engine: &Engine,
    opts: &FleetOptions,
    stats: &FleetStats,
    bstats: &BatchStats,
    solved: &AtomicU64,
    engine_stats: &Mutex<EngineStats>,
    shard: usize,
    tracer: &TraceRecorder,
    hooks: &dyn DriveHooks,
    mut poll: impl FnMut(bool) -> Poll,
) {
    let n_slots = opts.max_inflight.max(1);
    let mut slots: Vec<Option<Running>> = (0..n_slots).map(|_| None).collect();
    let mut queue = AdmissionQueue::new(Duration::from_millis(opts.fair_after_ms.max(1)));
    let mut inflight = 0usize;
    let mut shutdown = false;
    // running mean of task service time (admission -> completion), the
    // admission forecast's per-job cost estimate
    let mut mean_service_ms = 0.0f64;
    let mut completed_n = 0u64;

    loop {
        // ---- 0. supervision: heartbeat, retirement, fault injection.
        // The beat fires on idle rounds too (the blocking poll below is
        // bounded by the mailbox's recv timeout), so a live-but-idle
        // shard never looks wedged.
        hooks.beat();
        if hooks.retired() {
            break;
        }
        match hooks.chaos_tick() {
            ChaosAction::Panic => panic!("chaos: injected shard panic (shard {shard})"),
            ChaosAction::Stall(d) => std::thread::sleep(d),
            ChaosAction::None => {}
        }

        // ---- 1. ingest
        if inflight == 0 && queue.is_empty() {
            if shutdown {
                break;
            }
            match poll(true) {
                Poll::Job(j) => admit(
                    *j,
                    engine,
                    &mut queue,
                    &slots,
                    inflight,
                    n_slots,
                    mean_service_ms,
                    stats,
                    tracer,
                ),
                Poll::Shutdown => shutdown = true,
                Poll::Closed => break,
                Poll::Empty => {}
            }
            continue; // re-check idle/shutdown with the new state
        }
        loop {
            match poll(false) {
                Poll::Job(j) => admit(
                    *j,
                    engine,
                    &mut queue,
                    &slots,
                    inflight,
                    n_slots,
                    mean_service_ms,
                    stats,
                    tracer,
                ),
                Poll::Shutdown => shutdown = true,
                Poll::Closed => {
                    shutdown = true;
                    break;
                }
                Poll::Empty => break,
            }
        }
        let now = Instant::now();

        // ---- 2. expire queued work; drop queued work nobody waits for
        for mut job in queue.expire(now) {
            stats.expired_total.fetch_add(1, Ordering::Relaxed);
            if let Some(mut tb) = job.trace.take() {
                tb.set_queue_wait(job.waited_ms(now));
                tracer.submit(tb.finish("deadline", 504, PhaseFlops::default()));
            }
            let _ = job.reply.send(Err(Error::deadline(format!(
                "spent {:.0}ms queued, budget was {}ms",
                job.waited_ms(now),
                job.deadline.map(|d| d.as_millis()).unwrap_or(0)
            ))));
        }
        for mut job in queue.drain_matching(|j| j.reply.is_closed()) {
            // the receiver is gone; there is nobody to reply to
            stats.cancelled_total.fetch_add(1, Ordering::Relaxed);
            if let Some(mut tb) = job.trace.take() {
                tb.set_queue_wait(job.waited_ms(now));
                tracer.submit(tb.finish("cancelled", 0, PhaseFlops::default()));
            }
        }

        // ---- 3. coalesce queued duplicates onto in-flight tasks
        let dups = queue.drain_matching(|j| {
            j.key.is_some()
                && slots
                    .iter()
                    .flatten()
                    .any(|r| r.key.is_some() && r.key == j.key)
        });
        for mut job in dups {
            let r = slots
                .iter_mut()
                .flatten()
                .find(|r| r.key == job.key)
                .expect("matched above");
            r.extend_deadline(job.deadline_at());
            // the rider's own trace ends here: its outcome is whatever
            // the in-flight task it joined produces
            if let Some(mut tb) = job.trace.take() {
                tb.end(); // close the door-side "queue" span
                tb.set_queue_wait(job.waited_ms(now));
                tracer.submit(tb.finish("coalesced", 200, PhaseFlops::default()));
            }
            r.riders.push(Waiter { reply: job.reply, queue_wait_ms: job.waited_ms(now) });
            stats.coalesced_total.fetch_add(1, Ordering::Relaxed);
        }

        // ---- 4. backfill free slots from the queue
        while inflight < n_slots {
            // paged KV: admitting a request needs pool headroom for two
            // fresh caches (LM + PRM). Without it the job stays queued —
            // exhaustion degrades to queueing, never to a failed alloc
            // mid-flight. In-flight rejections free blocks every tick, so
            // the gate reopens on its own. Always true on dense engines.
            if !engine.pool_has_headroom() {
                if !queue.is_empty() {
                    stats.pool_deferred_total.fetch_add(1, Ordering::Relaxed);
                }
                break;
            }
            let Some(mut job) = queue.pop(now) else { break };
            let wait_ms = job.waited_ms(now);
            // a duplicate of a slot filled earlier this same round (burst
            // of identical requests hitting an idle shard) rides it too —
            // step 3 only sees tasks that were in flight before backfill
            if job.key.is_some() {
                if let Some(r) = slots.iter_mut().flatten().find(|r| r.key == job.key) {
                    r.extend_deadline(job.deadline_at());
                    if let Some(mut tb) = job.trace.take() {
                        tb.end();
                        tb.set_queue_wait(wait_ms);
                        tracer.submit(tb.finish("coalesced", 200, PhaseFlops::default()));
                    }
                    r.riders.push(Waiter { reply: job.reply, queue_wait_ms: wait_ms });
                    stats.coalesced_total.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
            }
            match job.spec.build() {
                Err(e) => {
                    stats.failed_total.fetch_add(1, Ordering::Relaxed);
                    if let Some(mut tb) = job.trace.take() {
                        tb.set_queue_wait(wait_ms);
                        tracer.submit(tb.finish("error", e.http_status(), PhaseFlops::default()));
                    }
                    let _ = job.reply.send(Err(e));
                }
                Ok(mut task) => {
                    if inflight > 0 {
                        stats.backfill_total.fetch_add(1, Ordering::Relaxed);
                    }
                    stats.admitted_total.fetch_add(1, Ordering::Relaxed);
                    let idx = slots
                        .iter()
                        .position(Option::is_none)
                        .expect("inflight < n_slots implies a free slot");
                    // hand the trace to the task: the door-side "queue"
                    // span closes, placement is pinned, and every span
                    // from here on is recorded by the task itself
                    if let Some(mut tb) = job.trace.take() {
                        tb.end();
                        tb.set_queue_wait(wait_ms);
                        tb.set_placement(shard, idx);
                        task.trace = Some(tb);
                    }
                    let deadline_at = job.deadline_at();
                    let mut running = Running {
                        task,
                        key: job.key,
                        deadline_at: None,
                        unbounded: false,
                        admitted_at: now,
                        parked: None,
                        primary: Waiter { reply: job.reply, queue_wait_ms: wait_ms },
                        riders: Vec::new(),
                    };
                    running.extend_deadline(deadline_at);
                    slots[idx] = Some(running);
                    inflight += 1;
                }
            }
        }

        // ---- 5. advance every occupied slot by one unit of work
        if inflight > 0 {
            stats.record_round(inflight, n_slots);
        }
        for idx in 0..slots.len() {
            let Some(r) = slots[idx].as_mut() else { continue };
            if r.abandoned() {
                let mut r = slots[idx].take().expect("checked occupied");
                inflight -= 1;
                stats.cancelled_total.fetch_add(1, Ordering::Relaxed);
                if let Some(tb) = r.task.trace.take() {
                    tracer.submit(tb.finish("cancelled", 0, PhaseFlops::default()));
                }
                continue; // no reply possible: every receiver is gone
            }
            if r.expired(Instant::now()) {
                let r = slots[idx].take().expect("checked occupied");
                inflight -= 1;
                stats.expired_total.fetch_add(1, Ordering::Relaxed);
                reply_error_traced(
                    r,
                    Error::deadline("aborted mid-solve: deadline elapsed"),
                    tracer,
                );
                continue;
            }
            let _scope = r.task.trace.as_ref().map(|tb| logging::request_scope(tb.id()));
            let tick = if opts.gang {
                if let Some(age) = r.parked {
                    // intent still waiting for partners; step 6 decides
                    r.parked = Some(age + 1);
                    SlotTick::Working
                } else {
                    match r.task.poll(engine) {
                        Ok(Step::Yielded) => {
                            r.parked = Some(0);
                            SlotTick::Working
                        }
                        Ok(Step::Progressed(Progress::Working)) => SlotTick::Working,
                        Ok(Step::Progressed(Progress::Done)) => SlotTick::Done,
                        Err(e) => SlotTick::Failed(e),
                    }
                }
            } else {
                match r.task.advance(engine) {
                    Ok(Progress::Working) => SlotTick::Working,
                    Ok(Progress::Done) => SlotTick::Done,
                    Err(e) => SlotTick::Failed(e),
                }
            };
            match tick {
                SlotTick::Working => {}
                SlotTick::Done => {
                    let r = slots[idx].take().expect("checked occupied");
                    inflight -= 1;
                    finish_task(
                        r,
                        engine,
                        stats,
                        solved,
                        engine_stats,
                        &mut mean_service_ms,
                        &mut completed_n,
                        tracer,
                    );
                }
                SlotTick::Failed(e) => {
                    let r = slots[idx].take().expect("checked occupied");
                    inflight -= 1;
                    stats.failed_total.fetch_add(1, Ordering::Relaxed);
                    *lock_unpoisoned(engine_stats) = engine.stats();
                    log_error!("fleet task failed in state '{}': {e}", r.task.state_name());
                    reply_error_traced(r, e, tracer);
                }
            }
        }

        // ---- 6. gang dispatch: pack parked intents into shared batches
        if opts.gang && inflight > 0 {
            dispatch_gangs(
                engine,
                &mut slots,
                &mut inflight,
                opts.gang_max_wait,
                stats,
                bstats,
                engine_stats,
                tracer,
            );
        }
        stats.inflight.store(inflight, Ordering::Relaxed);
        stats.queued.store(queue.len(), Ordering::Relaxed);
    }
    stats.inflight.store(0, Ordering::Relaxed);
    stats.queued.store(0, Ordering::Relaxed);
}

/// Instantaneous KV pool pressure in `[0, 1]` for the admission
/// forecast: 0 with ample free blocks, 1 once free blocks fall to the
/// backfill gate's admission floor (the point where backfill stops
/// draining the queue entirely). Ramps linearly over three floors of
/// headroom above the gate so forecasts stretch *before* the gate
/// closes. Always 0 on dense engines.
fn pool_pressure(engine: &Engine) -> f64 {
    let Some(ps) = engine.pool_stats() else { return 0.0 };
    let floor = engine.pool_admission_floor();
    if floor == 0 {
        return 0.0;
    }
    let above = ps.blocks_free.saturating_sub(floor) as f64;
    (1.0 - above / (3.0 * floor as f64)).clamp(0.0, 1.0)
}

/// Deadline-aware admission (step 1): bounce a bounded job whose
/// queue-wait forecast already exceeds its remaining budget. A duplicate
/// of an in-flight task is exempt — it never waits for a slot, it rides
/// the running task at the next coalesce pass (step 3) — and queued
/// duplicates of in-flight tasks are likewise discounted from the drain
/// this job waits behind.
#[allow(clippy::too_many_arguments)]
fn admit(
    mut job: FleetJob,
    engine: &Engine,
    queue: &mut AdmissionQueue,
    slots: &[Option<Running>],
    inflight: usize,
    n_slots: usize,
    mean_service_ms: f64,
    stats: &FleetStats,
    tracer: &TraceRecorder,
) {
    let coalescible = job.key.is_some() && slots.iter().flatten().any(|r| r.key == job.key);
    if coalescible {
        queue.push(job);
        return;
    }
    if let Some(d) = job.deadline {
        let now = Instant::now();
        let remaining_ms = (d.as_secs_f64() * 1000.0 - job.waited_ms(now)).max(0.0);
        let dup_riders = queue.count_matching(|j| {
            j.key.is_some() && slots.iter().flatten().any(|r| r.key == j.key)
        });
        let forecast = admission_forecast_ms(
            queue.len() - dup_riders,
            inflight,
            n_slots,
            mean_service_ms,
            pool_pressure(engine),
        );
        if forecast > remaining_ms {
            stats.forecast_rejected_total.fetch_add(1, Ordering::Relaxed);
            if let Some(mut tb) = job.trace.take() {
                tb.event("forecast_reject", format!("forecast_ms={forecast:.0}"));
                tracer.submit(tb.finish("deadline", 504, PhaseFlops::default()));
            }
            let _ = job.reply.send(Err(Error::deadline(format!(
                "queue-wait forecast {forecast:.0}ms exceeds the remaining \
                 {remaining_ms:.0}ms budget"
            ))));
            return;
        }
    }
    queue.push(job);
}

/// Completion protocol for a finished task: publish stats, fold the
/// service-time sample into the admission forecast, honor the 504
/// contract, seal + submit the trace, and fan the outcome out to every
/// attached request.
#[allow(clippy::too_many_arguments)]
fn finish_task(
    mut r: Running,
    engine: &Engine,
    stats: &FleetStats,
    solved: &AtomicU64,
    engine_stats: &Mutex<EngineStats>,
    mean_service_ms: &mut f64,
    completed_n: &mut u64,
    tracer: &TraceRecorder,
) {
    solved.fetch_add(1, Ordering::Relaxed);
    *lock_unpoisoned(engine_stats) = engine.stats();
    let service_ms = r.admitted_at.elapsed().as_secs_f64() * 1000.0;
    *completed_n += 1;
    *mean_service_ms += (service_ms - *mean_service_ms) / *completed_n as f64;
    if r.expired(Instant::now()) {
        // budget blew during the final advance: the 504 contract beats
        // returning a too-late 200
        stats.expired_total.fetch_add(1, Ordering::Relaxed);
        reply_error_traced(
            r,
            Error::deadline("deadline elapsed during the final solve step"),
            tracer,
        );
        return;
    }
    match r.task.take_outcome() {
        Some(out) => {
            stats.completed_total.fetch_add(1, Ordering::Relaxed);
            if let Some(tb) = r.task.trace.take() {
                tracer.submit(tb.finish("ok", 200, PhaseFlops::from_ledger(&out.ledger)));
            }
            for w in r.riders {
                let _ = w.reply.send(Ok(Solved {
                    outcome: out.clone(),
                    queue_wait_ms: w.queue_wait_ms,
                }));
            }
            let _ = r.primary.reply.send(Ok(Solved {
                outcome: out,
                queue_wait_ms: r.primary.queue_wait_ms,
            }));
        }
        None => {
            stats.failed_total.fetch_add(1, Ordering::Relaxed);
            reply_error_traced(r, Error::internal("finished task lost its outcome"), tracer);
        }
    }
}

/// Step 6: group parked intents by gang key, pack each group largest-fit
/// into merge variants, dispatch each gang as one shared device call, and
/// solo-execute leftovers that waited long enough (or are alone).
#[allow(clippy::too_many_arguments)]
fn dispatch_gangs(
    engine: &Engine,
    slots: &mut [Option<Running>],
    inflight: &mut usize,
    max_wait: u64,
    stats: &FleetStats,
    bstats: &BatchStats,
    engine_stats: &Mutex<EngineStats>,
    tracer: &TraceRecorder,
) {
    /// One parked intent's scheduling view.
    struct ParkedIntent {
        slot: usize,
        key: (IntentKind, String, u32),
        batch: usize,
        age: u64,
    }
    let mut parked: Vec<ParkedIntent> = Vec::new();
    for (i, s) in slots.iter().enumerate() {
        let Some(r) = s else { continue };
        let (Some(age), Some(intent)) = (r.parked, r.task.intent()) else { continue };
        let (kind, ckpt, temp_bits) = intent.gang_key();
        parked.push(ParkedIntent {
            slot: i,
            key: (kind, ckpt.to_string(), temp_bits),
            batch: intent.batch,
            age,
        });
    }
    let mut keys: Vec<(IntentKind, String, u32)> = Vec::new();
    for p in &parked {
        if !keys.contains(&p.key) {
            keys.push(p.key.clone());
        }
    }
    // one stats snapshot per round: every group's cost model derives
    // from it (cloning the per-width maps per group per round would be
    // pure churn on the scheduler hot path)
    let stats_snapshot = if keys.iter().any(|k| k.0 != IntentKind::Compact) {
        Some(engine.stats())
    } else {
        None
    };
    for key in keys {
        let group: Vec<&ParkedIntent> = parked.iter().filter(|p| p.key == key).collect();
        if key.0 == IntentKind::Compact {
            // compactions are per-cache repacks with nothing to share:
            // execute each immediately, never waiting for partners
            for p in &group {
                solo_execute(engine, slots, inflight, p.slot, stats, engine_stats, tracer);
            }
            continue;
        }
        let batches: Vec<usize> = group.iter().map(|p| p.batch).collect();
        let Ok(arch) = engine.manifest.arch_for_checkpoint(&key.1) else { continue };
        // wall-clock packing: joins that would lose time to padding or
        // merge overhead stay solo (accept-all until timings exist)
        let model = stats_snapshot
            .as_ref()
            .and_then(|s| batch::WallModel::from_stats(s, key.0, engine.block_native()));
        let gangs = batch::plan_gangs_costed(
            &batches,
            |a, b| engine.manifest.merge_variant(a, b).ok().filter(|&c| arch.has_merge(a, b, c)),
            model.as_ref(),
        );
        let mut in_gang = vec![false; group.len()];
        for g in &gangs {
            for &m in &g.members {
                in_gang[m] = true;
            }
            let member_slots: Vec<usize> = g.members.iter().map(|&m| group[m].slot).collect();
            let real_slots: usize = g.members.iter().map(|&m| group[m].batch).sum();
            // borrow the member tasks in the planner's merge order
            let mut grabbed: Vec<(usize, &mut SolveTask)> = slots
                .iter_mut()
                .enumerate()
                .filter_map(|(i, s)| {
                    if member_slots.contains(&i) {
                        s.as_mut().map(|r| (i, &mut r.task))
                    } else {
                        None
                    }
                })
                .collect();
            grabbed.sort_by_key(|(i, _)| {
                member_slots.iter().position(|&x| x == *i).expect("member slot")
            });
            let mut tasks: Vec<&mut SolveTask> = grabbed.into_iter().map(|(_, t)| t).collect();
            match batch::execute_gang(engine, &mut tasks) {
                Ok((variant, precompacted)) => {
                    bstats.record_gang(g.members.len(), real_slots, variant);
                    bstats.precompact_total.fetch_add(precompacted as u64, Ordering::Relaxed);
                    for &si in &member_slots {
                        if let Some(r) = slots[si].as_mut() {
                            r.parked = None;
                        }
                    }
                }
                Err(e) => {
                    // a merged call cannot attribute the fault: every
                    // member surfaces the error and frees its slot
                    bstats.gang_failures_total.fetch_add(1, Ordering::Relaxed);
                    log_error!("gang of {} failed: {e}", member_slots.len());
                    for &si in &member_slots {
                        if let Some(r) = slots[si].take() {
                            *inflight -= 1;
                            stats.failed_total.fetch_add(1, Ordering::Relaxed);
                            reply_error_traced(r, e.clone_class(), tracer);
                        }
                    }
                }
            }
            *lock_unpoisoned(engine_stats) = engine.stats();
        }
        // leftovers: solo once they waited max_wait rounds, or when no
        // partner can exist (the task is alone in the slot table)
        for (gi, p) in group.iter().enumerate() {
            if in_gang[gi] {
                continue;
            }
            let alone = *inflight <= 1;
            if p.age >= max_wait || alone {
                if solo_execute(engine, slots, inflight, p.slot, stats, engine_stats, tracer) {
                    bstats.solo_intents_total.fetch_add(1, Ordering::Relaxed);
                }
            } else {
                bstats.wait_rounds_total.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// Execute one slot's parked intent on its own cache with the shared
/// failure protocol (errors free the slot and reply to every rider).
/// Returns whether the intent executed successfully.
#[allow(clippy::too_many_arguments)]
fn solo_execute(
    engine: &Engine,
    slots: &mut [Option<Running>],
    inflight: &mut usize,
    slot: usize,
    stats: &FleetStats,
    engine_stats: &Mutex<EngineStats>,
    tracer: &TraceRecorder,
) -> bool {
    let Some(r) = slots[slot].as_mut() else { return false };
    match r.task.execute_intent(engine) {
        Ok(()) => {
            r.parked = None;
            true
        }
        Err(e) => {
            let r = slots[slot].take().expect("checked occupied");
            *inflight -= 1;
            stats.failed_total.fetch_add(1, Ordering::Relaxed);
            *lock_unpoisoned(engine_stats) = engine.stats();
            log_error!("fleet task failed in state '{}': {e}", r.task.state_name());
            reply_error_traced(r, e, tracer);
            false
        }
    }
}

/// Seal + submit the slot's trace with the error's outcome class, then
/// deliver the error to every attached request. `finish` closes any
/// spans the abnormal exit left open.
fn reply_error_traced(mut r: Running, e: Error, tracer: &TraceRecorder) {
    if let Some(tb) = r.task.trace.take() {
        let outcome = if e.http_status() == 504 { "deadline" } else { "error" };
        tracer.submit(tb.finish(outcome, e.http_status(), PhaseFlops::default()));
    }
    reply_error(r, e);
}

/// Deliver one error to every request attached to a slot.
fn reply_error(r: Running, e: Error) {
    for w in r.riders {
        let _ = w.reply.send(Err(e.clone_class()));
    }
    let _ = r.primary.reply.send(Err(e));
}
