//! Deterministic fault injection for the shard fleet.
//!
//! The supervision/retry stack can only be trusted if its failure paths
//! are *exercised*, and the repo's signature invariant — byte-identical
//! answers for a fixed (problem, config, seed) — can only be proven to
//! survive recovery if the faults themselves are reproducible. So chaos
//! here is not random: every injection decision is a pure function of
//! `(seed, shard index, tick counter)` through the same SplitMix64
//! finalizer the controller uses for shadow sampling. Re-running a
//! chaos-enabled workload with the same seed injects the same panics at
//! the same ticks; the acceptance test then asserts the answers match
//! the chaos-off run bit for bit.
//!
//! Tick counters are *persistent per shard slot* (they live on the
//! supervisor's slot state, not the thread): a respawned shard resumes
//! the schedule where its predecessor died instead of replaying tick 0,
//! which would otherwise re-inject the same panic forever (a crash-loop
//! livelock). Injection caps (`max_panics`, `max_stalls`) are enforced
//! with CAS so tests terminate even with aggressive probabilities.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::stats::mix64;

/// Knob family behind `--chaos-*`. All-zero (the default) disables
/// injection entirely — `enabled()` gates every draw.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ChaosOptions {
    /// Seed for the injection schedule; same seed → same faults.
    pub seed: u64,
    /// Probability in [0,1] that a given shard tick panics.
    pub panic_per_tick: f64,
    /// Cap on total injected panics (0 = unlimited).
    pub max_panics: u64,
    /// Probability in [0,1] that a given shard tick stalls (sleeps) —
    /// simulates a wedged engine call for heartbeat-staleness testing.
    pub stall_per_tick: f64,
    /// Stall duration in milliseconds.
    pub stall_ms: u64,
    /// Cap on total injected stalls (0 = unlimited).
    pub max_stalls: u64,
    /// If set, this shard index runs slow: every tick sleeps `slow_ms`.
    pub slow_shard: Option<usize>,
    /// Per-tick sleep for the slow shard, in milliseconds.
    pub slow_ms: u64,
}

impl ChaosOptions {
    pub fn enabled(&self) -> bool {
        self.panic_per_tick > 0.0 || self.stall_per_tick > 0.0 || self.slow_shard.is_some()
    }
}

/// What a chaos draw decided for this tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosAction {
    /// Proceed normally.
    None,
    /// Panic the shard thread (the supervisor must recover).
    Panic,
    /// Sleep for the given duration before proceeding (wedge/slow-shard
    /// simulation).
    Stall(Duration),
}

/// Shared injection state: options plus CAS-guarded injection counters,
/// held in an `Arc` by every shard body and the pool (for `/metrics`).
#[derive(Debug)]
pub struct ChaosState {
    opts: ChaosOptions,
    panics: AtomicU64,
    stalls: AtomicU64,
}

/// Map a mixed draw to a uniform float in [0, 1).
fn unit(x: u64) -> f64 {
    (x >> 11) as f64 / (1u64 << 53) as f64
}

impl ChaosState {
    pub fn new(opts: ChaosOptions) -> Self {
        ChaosState { opts, panics: AtomicU64::new(0), stalls: AtomicU64::new(0) }
    }

    pub fn enabled(&self) -> bool {
        self.opts.enabled()
    }

    /// Total panics injected so far.
    pub fn panics_injected(&self) -> u64 {
        self.panics.load(Ordering::Relaxed)
    }

    /// Total stalls injected so far.
    pub fn stalls_injected(&self) -> u64 {
        self.stalls.load(Ordering::Relaxed)
    }

    /// Seed-stable per-(shard, tick, salt) draw in [0, 1).
    fn draw(&self, shard: usize, tick: u64, salt: u64) -> f64 {
        let x = self
            .opts
            .seed
            .wrapping_add((shard as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add(tick.wrapping_mul(0xD1B5_4A32_D192_ED03))
            .wrapping_add(salt);
        unit(mix64(x))
    }

    /// Try to consume one slot under `cap` (0 = unlimited); false once
    /// the cap is reached.
    fn consume(counter: &AtomicU64, cap: u64) -> bool {
        counter
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| {
                if cap != 0 && n >= cap {
                    None
                } else {
                    Some(n + 1)
                }
            })
            .is_ok()
    }

    /// Decide this tick's fate for `shard`. Panic draws are evaluated
    /// before stall draws so a given (seed, shard, tick) always resolves
    /// the same way regardless of cap state elsewhere.
    pub fn tick(&self, shard: usize, tick: u64) -> ChaosAction {
        if self.opts.panic_per_tick > 0.0
            && self.draw(shard, tick, 0x70_61_6e_69_63) < self.opts.panic_per_tick
            && Self::consume(&self.panics, self.opts.max_panics)
        {
            return ChaosAction::Panic;
        }
        if self.opts.stall_per_tick > 0.0
            && self.draw(shard, tick, 0x73_74_61_6c_6c) < self.opts.stall_per_tick
            && Self::consume(&self.stalls, self.opts.max_stalls)
        {
            return ChaosAction::Stall(Duration::from_millis(self.opts.stall_ms));
        }
        if self.opts.slow_shard == Some(shard) && self.opts.slow_ms > 0 {
            return ChaosAction::Stall(Duration::from_millis(self.opts.slow_ms));
        }
        ChaosAction::None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts(panic_p: f64, stall_p: f64) -> ChaosOptions {
        ChaosOptions {
            seed: 42,
            panic_per_tick: panic_p,
            stall_per_tick: stall_p,
            stall_ms: 7,
            ..ChaosOptions::default()
        }
    }

    #[test]
    fn default_is_disabled_and_inert() {
        let st = ChaosState::new(ChaosOptions::default());
        assert!(!st.enabled());
        for tick in 0..1000 {
            assert_eq!(st.tick(0, tick), ChaosAction::None);
        }
        assert_eq!(st.panics_injected(), 0);
    }

    #[test]
    fn schedule_is_a_pure_function_of_seed_shard_tick() {
        let a = ChaosState::new(opts(0.1, 0.1));
        let b = ChaosState::new(opts(0.1, 0.1));
        for shard in 0..3 {
            for tick in 0..500 {
                assert_eq!(a.tick(shard, tick), b.tick(shard, tick), "shard {shard} tick {tick}");
            }
        }
        let other_seed =
            ChaosState::new(ChaosOptions { seed: 43, ..opts(0.1, 0.1) });
        let same = (0..500).filter(|&t| a.tick(9, t) == other_seed.tick(9, t)).count();
        assert!(same < 500, "a different seed must change the schedule");
    }

    #[test]
    fn rates_are_roughly_honored() {
        let st = ChaosState::new(opts(0.2, 0.0));
        let panics = (0..5000).filter(|&t| st.tick(1, t) == ChaosAction::Panic).count();
        assert!((600..1400).contains(&panics), "~20% of 5000, got {panics}");
        assert_eq!(st.panics_injected() as usize, panics);
    }

    #[test]
    fn caps_bound_injection_counts() {
        let st = ChaosState::new(ChaosOptions {
            max_panics: 3,
            max_stalls: 2,
            ..opts(1.0, 1.0)
        });
        let mut panics = 0;
        let mut stalls = 0;
        for tick in 0..100 {
            match st.tick(0, tick) {
                ChaosAction::Panic => panics += 1,
                ChaosAction::Stall(_) => stalls += 1,
                ChaosAction::None => {}
            }
        }
        assert_eq!(panics, 3, "panic cap respected");
        assert_eq!(stalls, 2, "stall cap respected");
        assert_eq!(st.panics_injected(), 3);
        assert_eq!(st.stalls_injected(), 2);
    }

    #[test]
    fn slow_shard_stalls_only_that_shard() {
        let st = ChaosState::new(ChaosOptions {
            slow_shard: Some(1),
            slow_ms: 9,
            ..ChaosOptions::default()
        });
        assert!(st.enabled());
        assert_eq!(st.tick(0, 0), ChaosAction::None);
        assert_eq!(st.tick(1, 0), ChaosAction::Stall(Duration::from_millis(9)));
    }
}
