//! Fleet telemetry: lock-free per-shard counters the serving metrics
//! endpoint aggregates.
//!
//! All counters are plain atomics so the shard loop can bump them without
//! taking a lock on its hot path; `/metrics` reads are racy snapshots,
//! which is fine for gauges.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Counters for one fleet shard. The pool holds one `Arc<FleetStats>` per
/// shard and renders them under `erprm_fleet_*`.
#[derive(Debug, Default)]
pub struct FleetStats {
    /// Requests currently occupying a slot (gauge).
    pub inflight: AtomicUsize,
    /// Requests waiting in the admission queue (gauge).
    pub queued: AtomicUsize,
    /// Tasks admitted into a slot.
    pub admitted_total: AtomicU64,
    /// Admissions that joined a loop with other requests already in
    /// flight — i.e. a slot was backfilled mid-run instead of the shard
    /// starting from idle. The continuous-batching win in one number.
    pub backfill_total: AtomicU64,
    /// Duplicate requests that rode an identical in-flight task instead
    /// of occupying a slot (single-flight coalescing).
    pub coalesced_total: AtomicU64,
    /// Requests rejected or aborted because their deadline elapsed.
    pub expired_total: AtomicU64,
    /// Requests dropped because every attached client hung up (closed
    /// reply channel) before completion — queued or mid-flight.
    pub cancelled_total: AtomicU64,
    /// Bounded requests bounced at admission because the queue-wait
    /// forecast (slot pressure x mean service time) already exceeded
    /// their deadline budget.
    pub forecast_rejected_total: AtomicU64,
    /// Backfill rounds deferred because the paged-KV pool lacked the
    /// admission floor of free blocks — the job stays queued (degrade to
    /// queueing, never corrupt) until in-flight work frees blocks.
    pub pool_deferred_total: AtomicU64,
    /// Tasks that ran to a successful outcome.
    pub completed_total: AtomicU64,
    /// Tasks that ended in an engine/validation error.
    pub failed_total: AtomicU64,
    /// Occupied-slot samples accumulated while the loop was busy…
    pub occupied_slot_ticks: AtomicU64,
    /// …out of this many slot samples (occupancy = occupied / total).
    pub slot_ticks: AtomicU64,
}

/// A plain snapshot of the monotonic counters (for tests and `/metrics`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FleetTotals {
    pub admitted: u64,
    pub backfill: u64,
    pub coalesced: u64,
    pub expired: u64,
    pub cancelled: u64,
    pub forecast_rejected: u64,
    pub pool_deferred: u64,
    pub completed: u64,
    pub failed: u64,
}

impl FleetStats {
    /// Mean slot occupancy while the shard loop was busy, in [0, 1].
    /// Measures how full the slot table ran — i.e. how much cross-request
    /// overlap backfill actually achieved — not idle time.
    pub fn occupancy(&self) -> f64 {
        let total = self.slot_ticks.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        self.occupied_slot_ticks.load(Ordering::Relaxed) as f64 / total as f64
    }

    /// Record one busy scheduler round: `occupied` of `slots` slots held
    /// a task while the round ran.
    pub fn record_round(&self, occupied: usize, slots: usize) {
        self.occupied_slot_ticks.fetch_add(occupied as u64, Ordering::Relaxed);
        self.slot_ticks.fetch_add(slots as u64, Ordering::Relaxed);
    }

    pub fn totals(&self) -> FleetTotals {
        FleetTotals {
            admitted: self.admitted_total.load(Ordering::Relaxed),
            backfill: self.backfill_total.load(Ordering::Relaxed),
            coalesced: self.coalesced_total.load(Ordering::Relaxed),
            expired: self.expired_total.load(Ordering::Relaxed),
            cancelled: self.cancelled_total.load(Ordering::Relaxed),
            forecast_rejected: self.forecast_rejected_total.load(Ordering::Relaxed),
            pool_deferred: self.pool_deferred_total.load(Ordering::Relaxed),
            completed: self.completed_total.load(Ordering::Relaxed),
            failed: self.failed_total.load(Ordering::Relaxed),
        }
    }

    /// Fold another shard's totals into an aggregate (for `/metrics`).
    pub fn merge_totals(into: &mut FleetTotals, other: FleetTotals) {
        into.admitted += other.admitted;
        into.backfill += other.backfill;
        into.coalesced += other.coalesced;
        into.expired += other.expired;
        into.cancelled += other.cancelled;
        into.forecast_rejected += other.forecast_rejected;
        into.pool_deferred += other.pool_deferred;
        into.completed += other.completed;
        into.failed += other.failed;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_is_ratio_of_busy_rounds() {
        let s = FleetStats::default();
        assert_eq!(s.occupancy(), 0.0, "no samples yet");
        s.record_round(4, 4);
        s.record_round(2, 4);
        s.record_round(1, 4);
        assert!((s.occupancy() - 7.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn totals_snapshot_and_merge() {
        let s = FleetStats::default();
        s.admitted_total.fetch_add(3, Ordering::Relaxed);
        s.backfill_total.fetch_add(2, Ordering::Relaxed);
        s.coalesced_total.fetch_add(1, Ordering::Relaxed);
        let mut agg = FleetTotals::default();
        FleetStats::merge_totals(&mut agg, s.totals());
        FleetStats::merge_totals(&mut agg, s.totals());
        assert_eq!(agg.admitted, 6);
        assert_eq!(agg.backfill, 4);
        assert_eq!(agg.coalesced, 2);
        assert_eq!(agg.expired, 0);
    }
}
