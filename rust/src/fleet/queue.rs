//! Admission queue: the fairness/deadline policy in front of the slots.
//!
//! Scheduling rule, in priority order:
//! 1. **Aging guard** — any job waiting longer than `fair_after` is
//!    scheduled next (oldest first), regardless of priority. This bounds
//!    starvation: sustained high-priority load can delay low-priority
//!    work by at most `fair_after` plus one slot turnover.
//! 2. **Priority** — higher `priority` first.
//! 3. **FIFO** — arrival order within a priority class.
//!
//! Deadlines are absolute (`enqueued + deadline`); `expire` sweeps jobs
//! whose budget elapsed while queued so they fail fast with 504 instead of
//! wasting a slot. All methods take `now` explicitly, which keeps the
//! policy deterministic and directly testable without sleeping.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::{SearchConfig, SearchMode};
use crate::coordinator::policy::TauPlan;
use crate::coordinator::task::SolveTask;
use crate::fleet::Solved;
use crate::obs::TraceBuilder;
use crate::util::error::Result;
use crate::util::oneshot;
use crate::workload::Problem;

/// Reply channel a solve result is delivered on. A oneshot with hang-up
/// detection: `is_closed()` is how the drive loop notices a client
/// disconnect mid-flight and reclaims the slot (see `shard.rs`).
pub type ReplyTx = oneshot::Sender<Result<Solved>>;

/// Everything needed to build a [`SolveTask`] shard-side. Host data only,
/// so it crosses the HTTP-worker → shard-thread boundary (the task itself
/// holds `!Send` device handles and never leaves the shard).
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub problem: Problem,
    pub mode: SearchMode,
    pub lm: String,
    pub prm: String,
    pub cfg: SearchConfig,
    pub temp: f32,
    /// Frozen adaptive-tau schedule resolved at admission; `None` = the
    /// static `cfg.tau`. Shared (`Arc`) because coalesced duplicates and
    /// the cache key both refer to the same frozen plan.
    pub tau_plan: Option<Arc<TauPlan>>,
}

impl TaskSpec {
    /// Instantiate the resumable task (validates the config).
    pub fn build(&self) -> Result<SolveTask> {
        let mut task = match self.mode {
            SearchMode::Vanilla => {
                SolveTask::vanilla(self.problem.clone(), &self.lm, &self.prm, &self.cfg, self.temp)?
            }
            SearchMode::EarlyRejection => SolveTask::early_rejection(
                self.problem.clone(),
                &self.lm,
                &self.prm,
                &self.cfg,
                self.temp,
            )?,
        };
        task.tau_plan = self.tau_plan.clone();
        Ok(task)
    }
}

/// One queued request: the task recipe plus its scheduling envelope.
pub struct FleetJob {
    pub spec: TaskSpec,
    /// Coalescing key (the pool's cache key); `None` disables coalescing
    /// for this job.
    pub key: Option<String>,
    pub enqueued: Instant,
    /// Time budget from enqueue; `None` = unbounded.
    pub deadline: Option<Duration>,
    /// Higher runs first (0 = default class).
    pub priority: i64,
    pub reply: ReplyTx,
    /// Request trace, begun at the door with its "queue" span open; moves
    /// into the task at admission (or is sealed here on the bounce paths:
    /// forecast 504, queue expiry, client hang-up, coalesce).
    pub trace: Option<Box<TraceBuilder>>,
}

impl FleetJob {
    /// Absolute expiry instant, if bounded.
    pub fn deadline_at(&self) -> Option<Instant> {
        self.deadline.map(|d| self.enqueued + d)
    }

    pub fn expired(&self, now: Instant) -> bool {
        self.deadline_at().map(|t| now >= t).unwrap_or(false)
    }

    /// How long this job has waited, in milliseconds.
    pub fn waited_ms(&self, now: Instant) -> f64 {
        now.saturating_duration_since(self.enqueued).as_secs_f64() * 1000.0
    }
}

/// Queue-wait forecast for a newly arrived job: everything ahead of it
/// (queued + in flight) drains `slots` wide at `mean_service_ms` apiece.
/// Deadline-aware admission bounces a bounded job whose forecast already
/// exceeds its remaining budget, so it fails fast with 504 instead of
/// burning slot time before the inevitable abort. Returns 0 until a
/// service-time estimate exists (never reject on no data).
///
/// `queued` should exclude coalescible duplicates of in-flight tasks —
/// those never occupy a slot, they ride the running task at the next
/// coalesce pass, so counting them as full jobs inflates the forecast
/// and 504s work the shard would have absorbed for free.
///
/// `pool_pressure` in `[0, 1]` stretches the forecast by the KV block
/// pool's scarcity: backfill defers admissions whenever the pool lacks
/// two fresh caches' worth of headroom, so under pressure the effective
/// drain rate drops even with slots free. Modeled as a `1 / (1 - p)`
/// slowdown (clamped at 0.95 so a saturated pool forecasts 20x, not
/// infinity — blocks do return as in-flight work completes). Pass 0.0
/// on dense engines.
pub fn admission_forecast_ms(
    queued: usize,
    inflight: usize,
    slots: usize,
    mean_service_ms: f64,
    pool_pressure: f64,
) -> f64 {
    if slots == 0 || mean_service_ms <= 0.0 {
        return 0.0;
    }
    let slowdown = 1.0 / (1.0 - pool_pressure.clamp(0.0, 0.95));
    ((queued + inflight) as f64 / slots as f64) * mean_service_ms * slowdown
}

/// The per-shard admission queue. O(n) selection per pop — queues are
/// bounded by the shard's capacity (tens of entries), so scan cost is
/// noise next to one engine call.
pub struct AdmissionQueue {
    jobs: Vec<(u64, FleetJob)>,
    next_seq: u64,
    fair_after: Duration,
}

impl AdmissionQueue {
    pub fn new(fair_after: Duration) -> AdmissionQueue {
        AdmissionQueue { jobs: Vec::new(), next_seq: 0, fair_after }
    }

    pub fn push(&mut self, job: FleetJob) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.jobs.push((seq, job));
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Next job under the fairness policy (see module docs).
    pub fn pop(&mut self, now: Instant) -> Option<FleetJob> {
        if self.jobs.is_empty() {
            return None;
        }
        // aging guard: oldest job past fair_after wins outright
        let starving = self
            .jobs
            .iter()
            .enumerate()
            .filter(|(_, (_, j))| now.saturating_duration_since(j.enqueued) >= self.fair_after)
            .min_by_key(|(_, (seq, _))| *seq)
            .map(|(i, _)| i);
        let pick = starving.unwrap_or_else(|| {
            // highest priority, then arrival order
            self.jobs
                .iter()
                .enumerate()
                .min_by_key(|(_, (seq, j))| (std::cmp::Reverse(j.priority), *seq))
                .map(|(i, _)| i)
                .expect("non-empty queue")
        });
        Some(self.jobs.remove(pick).1)
    }

    /// Remove and return every queued job whose deadline has elapsed.
    pub fn expire(&mut self, now: Instant) -> Vec<FleetJob> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.jobs.len() {
            if self.jobs[i].1.expired(now) {
                out.push(self.jobs.remove(i).1);
            } else {
                i += 1;
            }
        }
        out
    }

    /// How many queued jobs match `pred`, without removing them (used by
    /// the admission forecast to discount coalescible duplicates that
    /// will never occupy a slot).
    pub fn count_matching(&self, mut pred: impl FnMut(&FleetJob) -> bool) -> usize {
        self.jobs.iter().filter(|(_, j)| pred(j)).count()
    }

    /// Remove and return every queued job matching `pred` (used to
    /// coalesce queued duplicates onto an in-flight task).
    pub fn drain_matching(&mut self, mut pred: impl FnMut(&FleetJob) -> bool) -> Vec<FleetJob> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.jobs.len() {
            if pred(&self.jobs[i].1) {
                out.push(self.jobs.remove(i).1);
            } else {
                i += 1;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer as tk;
    use crate::workload::OpStep;

    fn spec() -> TaskSpec {
        TaskSpec {
            problem: Problem { v0: 5, ops: vec![OpStep { op: tk::PLUS, d: 3 }] },
            mode: SearchMode::EarlyRejection,
            lm: "lm-concise".into(),
            prm: "prm-large".into(),
            cfg: SearchConfig::default(),
            temp: 0.5,
            tau_plan: None,
        }
    }

    fn job(
        base: Instant,
        key: &str,
        priority: i64,
        deadline_ms: Option<u64>,
    ) -> (FleetJob, oneshot::Receiver<Result<Solved>>) {
        let (tx, rx) = oneshot::channel();
        (
            FleetJob {
                spec: spec(),
                key: Some(key.to_string()),
                enqueued: base,
                deadline: deadline_ms.map(Duration::from_millis),
                priority,
                reply: tx,
                trace: None,
            },
            rx,
        )
    }

    fn key_of(j: &FleetJob) -> &str {
        j.key.as_deref().unwrap()
    }

    #[test]
    fn fifo_within_priority() {
        let base = Instant::now();
        let mut q = AdmissionQueue::new(Duration::from_millis(500));
        let (a, _ra) = job(base, "a", 0, None);
        let (b, _rb) = job(base, "b", 0, None);
        let (c, _rc) = job(base, "c", 0, None);
        q.push(a);
        q.push(b);
        q.push(c);
        assert_eq!(key_of(&q.pop(base).unwrap()), "a");
        assert_eq!(key_of(&q.pop(base).unwrap()), "b");
        assert_eq!(key_of(&q.pop(base).unwrap()), "c");
        assert!(q.pop(base).is_none());
    }

    #[test]
    fn priority_beats_arrival_order() {
        let base = Instant::now();
        let mut q = AdmissionQueue::new(Duration::from_millis(500));
        let (lo, _r1) = job(base, "lo", 0, None);
        let (hi, _r2) = job(base, "hi", 5, None);
        let (mid, _r3) = job(base, "mid", 2, None);
        q.push(lo);
        q.push(hi);
        q.push(mid);
        assert_eq!(key_of(&q.pop(base).unwrap()), "hi");
        assert_eq!(key_of(&q.pop(base).unwrap()), "mid");
        assert_eq!(key_of(&q.pop(base).unwrap()), "lo");
    }

    #[test]
    fn aging_guard_prevents_starvation() {
        let base = Instant::now();
        let mut q = AdmissionQueue::new(Duration::from_millis(500));
        let (old_lo, _r1) = job(base, "old-lo", 0, None);
        let (fresh_hi, _r2) = job(base + Duration::from_millis(490), "hi", 9, None);
        q.push(old_lo);
        q.push(fresh_hi);
        // before the guard trips, priority wins…
        let now = base + Duration::from_millis(499);
        assert_eq!(key_of(&q.pop(now).unwrap()), "hi");
        let (hi2, _r3) = job(now, "hi2", 9, None);
        q.push(hi2);
        // …but once the low-priority job has waited fair_after, it goes
        // first no matter what outranks it
        let later = base + Duration::from_millis(501);
        assert_eq!(key_of(&q.pop(later).unwrap()), "old-lo");
        assert_eq!(key_of(&q.pop(later).unwrap()), "hi2");
    }

    #[test]
    fn expire_sweeps_only_past_deadline() {
        let base = Instant::now();
        let mut q = AdmissionQueue::new(Duration::from_millis(500));
        let (tight, _r1) = job(base, "tight", 0, Some(10));
        let (loose, _r2) = job(base, "loose", 0, Some(10_000));
        let (unbounded, _r3) = job(base, "unbounded", 0, None);
        q.push(tight);
        q.push(loose);
        q.push(unbounded);
        let expired = q.expire(base + Duration::from_millis(11));
        assert_eq!(expired.len(), 1);
        assert_eq!(key_of(&expired[0]), "tight");
        assert_eq!(q.len(), 2);
        assert!(!q.pop(base).unwrap().expired(base));
    }

    #[test]
    fn drain_matching_pulls_duplicates() {
        let base = Instant::now();
        let mut q = AdmissionQueue::new(Duration::from_millis(500));
        let (a, _r1) = job(base, "dup", 0, None);
        let (b, _r2) = job(base, "other", 0, None);
        let (c, _r3) = job(base, "dup", 0, None);
        q.push(a);
        q.push(b);
        q.push(c);
        let dups = q.drain_matching(|j| j.key.as_deref() == Some("dup"));
        assert_eq!(dups.len(), 2);
        assert_eq!(q.len(), 1);
        assert_eq!(key_of(&q.pop(base).unwrap()), "other");
    }

    #[test]
    fn job_deadline_accounting() {
        let base = Instant::now();
        let (j, _r) = job(base, "x", 0, Some(100));
        assert!(!j.expired(base + Duration::from_millis(99)));
        assert!(j.expired(base + Duration::from_millis(100)));
        assert!((j.waited_ms(base + Duration::from_millis(250)) - 250.0).abs() < 1.0);
        let (u, _r2) = job(base, "y", 0, None);
        assert!(u.deadline_at().is_none());
        assert!(!u.expired(base + Duration::from_secs(3600)));
    }

    #[test]
    fn forecast_scales_with_pressure_and_never_fires_blind() {
        // no service-time estimate yet: never reject
        assert_eq!(admission_forecast_ms(10, 8, 4, 0.0, 0.0), 0.0);
        // zero slots can't forecast either
        assert_eq!(admission_forecast_ms(10, 8, 0, 100.0, 0.0), 0.0);
        // 12 jobs ahead draining 4 wide at 100ms each -> ~300ms wait
        let f = admission_forecast_ms(8, 4, 4, 100.0, 0.0);
        assert!((f - 300.0).abs() < 1e-9);
        // more slots, shorter forecast
        assert!(admission_forecast_ms(8, 4, 8, 100.0, 0.0) < f);
    }

    #[test]
    fn forecast_stretches_under_pool_pressure() {
        let base = admission_forecast_ms(8, 4, 4, 100.0, 0.0);
        // half-scarce pool: drain rate halves, forecast doubles
        let half = admission_forecast_ms(8, 4, 4, 100.0, 0.5);
        assert!((half - base * 2.0).abs() < 1e-9);
        // saturated pool clamps at a 20x slowdown, never infinity/NaN
        let sat = admission_forecast_ms(8, 4, 4, 100.0, 1.0);
        assert!((sat - base * 20.0).abs() < 1e-6);
        assert!(sat.is_finite());
        // out-of-range inputs clamp rather than shrink the forecast
        assert_eq!(admission_forecast_ms(8, 4, 4, 100.0, -3.0), base);
    }

    #[test]
    fn count_matching_leaves_queue_intact() {
        let base = Instant::now();
        let mut q = AdmissionQueue::new(Duration::from_millis(500));
        let (a, _r1) = job(base, "dup", 0, None);
        let (b, _r2) = job(base, "other", 0, None);
        let (c, _r3) = job(base, "dup", 0, None);
        q.push(a);
        q.push(b);
        q.push(c);
        assert_eq!(q.count_matching(|j| j.key.as_deref() == Some("dup")), 2);
        assert_eq!(q.len(), 3, "counting must not drain");
    }

    #[test]
    fn closed_reply_channels_are_observable_for_queue_sweeps() {
        let base = Instant::now();
        let mut q = AdmissionQueue::new(Duration::from_millis(500));
        let (alive, _keep) = job(base, "alive", 0, None);
        let (gone, dead_rx) = job(base, "gone", 0, None);
        drop(dead_rx); // client hung up while queued
        q.push(alive);
        q.push(gone);
        let dropped = q.drain_matching(|j| j.reply.is_closed());
        assert_eq!(dropped.len(), 1);
        assert_eq!(key_of(&dropped[0]), "gone");
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn spec_builds_a_task() {
        let s = spec();
        let t = s.build().unwrap();
        assert!(!t.is_done());
        // invalid configs surface at build, before a slot is occupied
        let bad = TaskSpec {
            cfg: SearchConfig { tau: 0, ..SearchConfig::default() },
            ..spec()
        };
        assert!(bad.build().is_err());
    }
}
