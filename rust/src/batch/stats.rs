//! Gang-batcher telemetry: lock-free per-shard counters rendered under
//! `erprm_batch_*` on `/metrics`.

use std::sync::atomic::{AtomicU64, Ordering};

/// Counters for one shard's gang batcher (all-zero when `--gang` is off).
#[derive(Debug, Default)]
pub struct BatchStats {
    /// Merged device calls dispatched (each served >= 2 requests).
    pub gangs_total: AtomicU64,
    /// Intents that rode a merged call.
    pub ganged_intents_total: AtomicU64,
    /// Intents executed solo (no compatible partner in time).
    pub solo_intents_total: AtomicU64,
    /// Real beam slots shipped inside merged batches…
    pub merged_slots_total: AtomicU64,
    /// …and the padding slots the merge variants carried along.
    pub padding_slots_total: AtomicU64,
    /// Scheduler rounds intents spent parked waiting for partners.
    pub wait_rounds_total: AtomicU64,
    /// Member caches re-compacted right before a chain-merge (frontier
    /// alignment; see `planner::GANG_PRECOMPACT_JUNK`).
    pub precompact_total: AtomicU64,
    /// Gangs whose merged execution failed (every member surfaced the
    /// error).
    pub gang_failures_total: AtomicU64,
}

/// Plain snapshot for `/metrics` aggregation and tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BatchTotals {
    pub gangs: u64,
    pub ganged_intents: u64,
    pub solo_intents: u64,
    pub merged_slots: u64,
    pub padding_slots: u64,
    pub wait_rounds: u64,
    pub precompacts: u64,
    pub gang_failures: u64,
}

impl BatchStats {
    /// Record one dispatched gang of `members` intents totalling
    /// `real_slots` beam slots inside a `variant`-sized device batch.
    pub fn record_gang(&self, members: usize, real_slots: usize, variant: usize) {
        self.gangs_total.fetch_add(1, Ordering::Relaxed);
        self.ganged_intents_total.fetch_add(members as u64, Ordering::Relaxed);
        self.merged_slots_total.fetch_add(real_slots as u64, Ordering::Relaxed);
        self.padding_slots_total
            .fetch_add(variant.saturating_sub(real_slots) as u64, Ordering::Relaxed);
    }

    pub fn totals(&self) -> BatchTotals {
        BatchTotals {
            gangs: self.gangs_total.load(Ordering::Relaxed),
            ganged_intents: self.ganged_intents_total.load(Ordering::Relaxed),
            solo_intents: self.solo_intents_total.load(Ordering::Relaxed),
            merged_slots: self.merged_slots_total.load(Ordering::Relaxed),
            padding_slots: self.padding_slots_total.load(Ordering::Relaxed),
            wait_rounds: self.wait_rounds_total.load(Ordering::Relaxed),
            precompacts: self.precompact_total.load(Ordering::Relaxed),
            gang_failures: self.gang_failures_total.load(Ordering::Relaxed),
        }
    }

    /// Fold another shard's totals into an aggregate (for `/metrics`).
    pub fn merge_totals(into: &mut BatchTotals, other: BatchTotals) {
        into.gangs += other.gangs;
        into.ganged_intents += other.ganged_intents;
        into.solo_intents += other.solo_intents;
        into.merged_slots += other.merged_slots;
        into.padding_slots += other.padding_slots;
        into.wait_rounds += other.wait_rounds;
        into.precompacts += other.precompacts;
        into.gang_failures += other.gang_failures;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_gang_accumulates_slots_and_padding() {
        let s = BatchStats::default();
        s.record_gang(2, 12, 16); // 8+4 real slots inside a b16 variant
        s.record_gang(3, 24, 32);
        let t = s.totals();
        assert_eq!(t.gangs, 2);
        assert_eq!(t.ganged_intents, 5);
        assert_eq!(t.merged_slots, 36);
        assert_eq!(t.padding_slots, 4 + 8);
    }

    #[test]
    fn totals_merge() {
        let s = BatchStats::default();
        s.record_gang(2, 8, 8);
        s.solo_intents_total.fetch_add(3, Ordering::Relaxed);
        let mut agg = BatchTotals::default();
        BatchStats::merge_totals(&mut agg, s.totals());
        BatchStats::merge_totals(&mut agg, s.totals());
        assert_eq!(agg.gangs, 2);
        assert_eq!(agg.solo_intents, 6);
        assert_eq!(agg.padding_slots, 0);
    }
}
