//! Gang batching: true cross-request device batching.
//!
//! The fleet scheduler interleaves many requests on one engine, but until
//! this module each request still *decoded in its own device batch* — the
//! compute early rejection frees mid-step could backfill another request's
//! turn, yet never its batch. The gang batcher closes that gap:
//!
//! * a [`crate::coordinator::task::SolveTask`] driven cooperatively
//!   (`poll`) *yields* its prepared engine calls as
//!   [`crate::coordinator::task::DecodeIntent`]s instead of executing
//!   them;
//! * the [`planner`] groups compatible intents — same checkpoint, same
//!   program class (decode vs score), same temperature — and packs them
//!   largest-first into one merged batch variant via the exported
//!   `merge_bA_bB_to_bC` KV-concat programs;
//! * one shared `decode_bN`/`score_bN` call runs for the whole gang; the
//!   outputs are split back per member (`resize`/`gather` programs) and
//!   absorbed into each task exactly as a solo call would have been.
//!
//! Determinism: every per-slot computation in the exported programs reads
//! only its own row (RoPE positions, validity mask, RNG keys are per-slot
//! arguments), so a member's sampled tokens and scores are the same
//! whether its slots ran alone or inside a shared batch — gang-batched
//! [`crate::coordinator::search::SolveOutcome`]s are byte-identical to
//! solo solves, which the integration suite pins. The one observable
//! difference is cache pacing: a merged call writes at the *max* of the
//! members' lockstep frontiers, so a request ganged with longer partners
//! spends physical cache positions faster and could hit the (gracefully
//! handled) exhaustion path earlier than it would alone.
//!
//! Scheduling: a yielded intent may wait up to `gang_max_wait` scheduler
//! rounds for partners; after that (or when it is the only task in
//! flight) it executes solo, so a lone request never stalls. Old artifact
//! sets without merge programs degrade to all-solo execution.
//!
//! Packing is wall-clock-aware: joins that save an invocation but lose
//! wall time to padding (a narrow joiner forcing a chain into the next
//! variant up) are rejected by the [`planner::WallModel`], calibrated
//! live from the engine's per-width call timings. And because merged
//! writes land at the max of the members' frontiers, the executor
//! re-compacts junk-heavy member caches before each chain-merge
//! (`compact_bN` programs) so the union gap — the cache-pacing tax the
//! module doc above describes — is reclaimed instead of compounding.
//!
//! On a *block-native* engine (attention kernels index the shared block
//! pool through per-call block tables) the entire gang assembly
//! collapses into host bookkeeping: `kv_merge` concatenates the members'
//! block tables, `kv_split` forks each member's slice back out, and the
//! merged call is just `decode_blocktab_bN` over the concatenated table
//! — zero merge/split device invocations, no union-gap copies, and no
//! pre-compaction (per-slot frontiers mean a merged gang never forms a
//! junk gap to reclaim). The [`planner::WallModel`] reflects this by
//! zeroing its merge/split cost terms, so joins are judged on padding
//! alone.

pub mod planner;
pub mod stats;

pub use planner::{
    execute_gang, plan_gangs, plan_gangs_costed, Gang, WallModel, GANG_PRECOMPACT_JUNK,
};
pub use stats::{BatchStats, BatchTotals};
