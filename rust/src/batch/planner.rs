//! Gang planning (pure, host-side) and merged execution (device-side).
//!
//! Planning packs one compatibility group's pending intents into gangs
//! greedily, largest batch first: the seed opens a merge chain and every
//! later intent that still fits an exported merge variant joins it. The
//! chain's destination variant is fixed by the exporter per source pair
//! (`merge_bA_bB_to_bC` with `c = variant(a + b)`), so feasibility is a
//! manifest probe, injected as a closure to keep planning testable
//! without artifacts.
//!
//! Execution turns a planned gang into exactly one shared `decode_bN` /
//! `score_bN` invocation: chain-merge the member caches (packing live
//! slots densely at the front), run the shared call with concatenated
//! per-slot inputs, split each member's slot range back out, and let each
//! task absorb its own output rows. Per-slot math in the exported
//! programs never crosses rows, so each member's results are the ones its
//! solo call would have produced.

use crate::coordinator::task::{GangOut, IntentKind, SolveTask};
use crate::runtime::{Engine, KvSet};
use crate::util::error::{Error, Result};

/// One planned gang: positions into the planner's input list in merge
/// order (largest batch first, stable by arrival), plus the merged batch
/// variant the chain lands in.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Gang {
    pub members: Vec<usize>,
    pub variant: usize,
}

/// Pack one compatible group's pending intents (their cache batches, in
/// arrival order) into gangs of >= 2 members. `can_merge(a, b)` reports
/// the merged variant when the artifact set can merge an `a`-batch cache
/// with a `b`-batch cache (`a >= b`), else `None`. Inputs left out of
/// every gang are the caller's to execute solo.
pub fn plan_gangs(
    batches: &[usize],
    can_merge: impl Fn(usize, usize) -> Option<usize>,
) -> Vec<Gang> {
    let mut order: Vec<usize> = (0..batches.len()).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(batches[i]), i));
    let mut assigned = vec![false; batches.len()];
    let mut gangs = Vec::new();
    for si in 0..order.len() {
        let seed = order[si];
        if assigned[seed] {
            continue;
        }
        let mut members = vec![seed];
        let mut chain = batches[seed];
        for &cand in order.iter().skip(si + 1) {
            if assigned[cand] {
                continue;
            }
            if let Some(v) = can_merge(chain, batches[cand]) {
                members.push(cand);
                chain = v;
                assigned[cand] = true;
            }
        }
        if members.len() >= 2 {
            assigned[seed] = true;
            gangs.push(Gang { members, variant: chain });
        }
        // a 1-member chain stays unassigned: the caller executes it solo
    }
    gangs
}

/// Union-gather index for one merge step: the accumulator's `a_real` live
/// slots stay packed at the front, all of the joiner's `b_batch` slots
/// follow (offset by the accumulator's full device batch `a_batch`), and
/// variant padding replays slot 0.
fn merge_index(a_real: usize, a_batch: usize, b_batch: usize, c: usize) -> Vec<i32> {
    let mut idx = Vec::with_capacity(c);
    idx.extend((0..a_real).map(|i| i as i32));
    idx.extend((0..b_batch).map(|j| (a_batch + j) as i32));
    idx.resize(c, 0);
    idx
}

/// Execute one gang as a single merged device call and route each
/// member's output rows back into its task. `tasks` must be in the
/// planner's merge order with their intents still parked; on error the
/// caller fails every member (their intents are unusable afterwards).
/// Returns the merged batch variant actually dispatched.
pub fn execute_gang(engine: &Engine, tasks: &mut [&mut SolveTask]) -> Result<usize> {
    if tasks.len() < 2 {
        return Err(Error::internal("execute_gang wants >= 2 members"));
    }
    let (kind, ckpt, temp) = {
        let i0 = tasks[0]
            .intent()
            .ok_or_else(|| Error::internal("gang member lost its intent"))?;
        (i0.kind, i0.ckpt.clone(), i0.temp)
    };
    let mut batches = Vec::with_capacity(tasks.len());
    for t in tasks.iter() {
        let it = t.intent().ok_or_else(|| Error::internal("gang member lost its intent"))?;
        if (it.kind, it.ckpt.as_str(), it.temp.to_bits())
            != (kind, ckpt.as_str(), temp.to_bits())
        {
            return Err(Error::internal("incompatible intents packed into one gang"));
        }
        batches.push(it.batch);
    }
    let mut offsets = Vec::with_capacity(batches.len());
    let mut real = 0usize;
    for &b in &batches {
        offsets.push(real);
        real += b;
    }

    // 1. chain-merge the member caches (live slots densely packed).
    let mut merged = {
        let mut kvs: Vec<&KvSet> = Vec::with_capacity(tasks.len());
        for t in tasks.iter() {
            kvs.push(t.gang_kv()?);
        }
        let c = engine.manifest.merge_variant(batches[0], batches[1])?;
        let idx = merge_index(batches[0], batches[0], batches[1], c);
        let mut acc = engine.kv_merge(&ckpt, kvs[0], kvs[1], &idx)?;
        let mut acc_real = batches[0] + batches[1];
        for (i, kv) in kvs.iter().enumerate().skip(2) {
            let c = engine.manifest.merge_variant(acc.batch, batches[i])?;
            let idx = merge_index(acc_real, acc.batch, batches[i], c);
            acc = engine.kv_merge(&ckpt, &acc, kv, &idx)?;
            acc_real += batches[i];
        }
        acc
    };

    // 2. one shared device call, 3. split back + absorb per member.
    match kind {
        IntentKind::Decode => {
            let db = engine.manifest.decode_block;
            let mut prev = vec![crate::tokenizer::PAD; merged.batch];
            let mut keys = vec![0u32; merged.batch * 2];
            for (t, (&off, &b)) in tasks.iter().zip(offsets.iter().zip(&batches)) {
                let (p, k) = t
                    .intent()
                    .and_then(|i| i.decode_inputs())
                    .ok_or_else(|| Error::internal("decode gang holds a non-decode intent"))?;
                prev[off..off + b].copy_from_slice(p);
                keys[off * 2..(off + b) * 2].copy_from_slice(k);
            }
            let sampled = engine.lm_decode_block(&ckpt, &mut merged, &prev, temp, &keys)?;
            for i in 0..tasks.len() {
                let kv = engine.kv_split(&ckpt, &merged, offsets[i], batches[i])?;
                let rows = &sampled[offsets[i] * db..(offsets[i] + batches[i]) * db];
                tasks[i].gang_absorb(kv, GangOut::Tokens(rows))?;
            }
        }
        IntentKind::Score => {
            let sb = engine.manifest.score_block;
            let mut toks = vec![crate::tokenizer::PAD; merged.batch * sb];
            for (t, (&off, &b)) in tasks.iter().zip(offsets.iter().zip(&batches)) {
                let mt = t
                    .intent()
                    .and_then(|i| i.score_tokens())
                    .ok_or_else(|| Error::internal("score gang holds a non-score intent"))?;
                toks[off * sb..(off + b) * sb].copy_from_slice(mt);
            }
            let scores = engine.prm_score_block(&ckpt, &mut merged, &toks)?;
            for i in 0..tasks.len() {
                let kv = engine.kv_split(&ckpt, &merged, offsets[i], batches[i])?;
                let rows = &scores[offsets[i] * sb..(offsets[i] + batches[i]) * sb];
                tasks[i].gang_absorb(kv, GangOut::Scores(rows))?;
            }
        }
    }
    Ok(merged.batch)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Merge-capability model of the standard export: variants
    /// [4, 8, 16, 32, 64], every a >= b pair whose sum fits.
    fn cm(a: usize, b: usize) -> Option<usize> {
        const V: [usize; 5] = [4, 8, 16, 32, 64];
        if a < b {
            return None;
        }
        V.iter().copied().find(|&c| c >= a + b)
    }

    #[test]
    fn pairs_same_width_gang_up() {
        let gangs = plan_gangs(&[8, 8], cm);
        assert_eq!(gangs, vec![Gang { members: vec![0, 1], variant: 16 }]);
    }

    #[test]
    fn largest_fit_packs_mixed_widths() {
        // sorted largest-first: 16 seeds, 8 joins (-> 32), 4 joins (-> 64)
        let gangs = plan_gangs(&[8, 4, 16], cm);
        assert_eq!(gangs, vec![Gang { members: vec![2, 0, 1], variant: 64 }]);
    }

    #[test]
    fn equal_widths_keep_arrival_order() {
        let gangs = plan_gangs(&[8, 8, 8], cm);
        assert_eq!(gangs, vec![Gang { members: vec![0, 1, 2], variant: 32 }]);
    }

    #[test]
    fn oversize_members_stay_solo() {
        // two b64 caches cannot share any exported variant
        assert!(plan_gangs(&[64, 64], cm).is_empty());
        // one lone intent never forms a gang
        assert!(plan_gangs(&[8], cm).is_empty());
        assert!(plan_gangs(&[], cm).is_empty());
    }

    #[test]
    fn no_merge_programs_degrades_to_all_solo() {
        assert!(plan_gangs(&[8, 8, 4], |_, _| None).is_empty());
    }

    #[test]
    fn chain_respects_capability_holes() {
        // capability that only merges equal widths (a == b)
        let eq = |a: usize, b: usize| if a == b { cm(a, b) } else { None };
        let gangs = plan_gangs(&[8, 4, 8, 4], eq);
        // 8s pair into 16; the 16-chain can't take the 4s, but the 4s
        // then pair with each other
        assert_eq!(gangs.len(), 2);
        assert_eq!(gangs[0], Gang { members: vec![0, 2], variant: 16 });
        assert_eq!(gangs[1], Gang { members: vec![1, 3], variant: 8 });
    }

    #[test]
    fn merge_index_packs_live_slots_and_pads_with_zero() {
        assert_eq!(merge_index(4, 4, 4, 8), vec![0, 1, 2, 3, 4, 5, 6, 7]);
        // accumulator at variant 8 with 6 live slots + a b4 joiner -> b16
        assert_eq!(
            merge_index(6, 8, 4, 16),
            vec![0, 1, 2, 3, 4, 5, 8, 9, 10, 11, 0, 0, 0, 0, 0, 0]
        );
    }
}
