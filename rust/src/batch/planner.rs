//! Gang planning (pure, host-side) and merged execution (device-side).
//!
//! Planning packs one compatibility group's pending intents into gangs
//! greedily, largest batch first: the seed opens a merge chain and every
//! later intent that still fits an exported merge variant joins it. The
//! chain's destination variant is fixed by the exporter per source pair
//! (`merge_bA_bB_to_bC` with `c = variant(a + b)`), so feasibility is a
//! manifest probe, injected as a closure to keep planning testable
//! without artifacts.
//!
//! Packing is wall-clock-aware, not invocation-count-aware: a feasible
//! join is only taken when the [`WallModel`] — calibrated from the
//! engine's observed per-width decode/score walls plus merge/split
//! overhead — estimates the merged call cheaper than letting the joiner
//! run solo. Folding a b4 joiner into a b32 chain that must widen to b64
//! saves one invocation but pays 28 padding rows of attention; on real
//! accelerators that loses wall-clock, which is the objective that
//! matters (ROADMAP: gang-aware packing cost model). Until enough
//! timings exist the model is `None` and planning degrades to the old
//! largest-first accept-all.
//!
//! Execution turns a planned gang into exactly one shared `decode_bN` /
//! `score_bN` invocation: re-compact members whose junk share crossed
//! [`GANG_PRECOMPACT_JUNK`] (aligned dense frontiers shrink the
//! max-frontier union gap the laggards would otherwise inherit),
//! chain-merge the member caches (packing live slots densely at the
//! front), run the shared call with concatenated per-slot inputs, split
//! each member's slot range back out, and let each task absorb its own
//! output rows. Per-slot math in the exported programs never crosses
//! rows, so each member's results are the ones its solo call would have
//! produced.
//!
//! On a *block-native* engine the entire assembly collapses into host
//! bookkeeping: `kv_merge` concatenates the members' block tables,
//! `kv_split` forks each member's slice back out, and the shared call
//! indexes the device pool through the union table — zero merge/split
//! device invocations, no union-gap copies, and no pre-compaction (each
//! slot keeps its own write frontier, so the laggard gap never forms).
//! The [`WallModel`] reflects this by zeroing its merge/split terms,
//! which widens the set of joins that pay.

use crate::coordinator::task::{GangOut, IntentKind, SolveTask};
use crate::runtime::{Engine, EngineStats, KvSet};
use crate::util::error::{Error, Result};

/// Junk share above which a gang member's cache is re-compacted before
/// the chain-merge. Low enough to keep merged frontiers aligned, high
/// enough that a nearly-dense cache never pays a repack call. Pre-merge
/// compaction is proactive, so `SearchConfig::compact_junk = 1.0` (the
/// documented proactive-off switch) disables it too — enforced in
/// `SolveTask::gang_precompact`.
pub const GANG_PRECOMPACT_JUNK: f64 = 0.25;

/// One planned gang: positions into the planner's input list in merge
/// order (largest batch first, stable by arrival), plus the merged batch
/// variant the chain lands in.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Gang {
    pub members: Vec<usize>,
    pub variant: usize,
}

/// Wall-clock cost model for gang packing, calibrated from the engine's
/// observed timings: a weighted least-squares regression `base + slope ×
/// width` over the per-batch-width mean decode/score call walls, plus the
/// mean merge and gather (split-back) overheads. A regression (rather
/// than the old point interpolation) smooths single-width noise — one
/// slow warmup call at b16 no longer carves a spike into the curve every
/// estimate between b8 and b32 reads through — and cleanly separates the
/// fixed per-call overhead (`base`) from the marginal per-slot cost
/// (`slope`), which is exactly the decomposition `join_pays` reasons
/// about. `None` until the engine has samples at two distinct widths for
/// the program class — planning then falls back to accept-all, and the
/// model sharpens as traffic flows.
#[derive(Debug, Clone, PartialEq)]
pub struct WallModel {
    /// Fixed per-call overhead (s): dispatch, host transfers, kernel launch.
    base_s: f64,
    /// Marginal cost of one more batch slot (s/slot).
    slope_s: f64,
    /// Mean wall of one `merge_bA_bB_to_bC` step.
    merge_step_s: f64,
    /// Mean wall of one gather/resize call (the per-member split-back).
    split_step_s: f64,
}

/// Sample-decay constant for the regression weights: a width observed
/// `calls` times carries weight `1 - SAMPLE_DECAY^calls`, saturating at 1.
/// Influence grows with evidence, but a steady-state width hammered
/// thousands of times can never outvote the rest of the grid by raw call
/// count — the fit keeps tracking the full width range, not the mode.
const SAMPLE_DECAY: f64 = 0.9;

fn sample_weight(calls: u64) -> f64 {
    1.0 - SAMPLE_DECAY.powi(calls.min(1 << 16) as i32)
}

/// Weighted least-squares fit of `y = base + slope * x` over
/// `(width, mean_s, weight)` samples, clamped to the physically
/// meaningful quadrant (walls are nonnegative and never shrink with
/// width): a negative slope degrades to the flat weighted mean, a
/// negative base to a through-origin fit.
fn fit_line(samples: &[(usize, f64, f64)]) -> (f64, f64) {
    let (mut sw, mut sx, mut sy, mut sxx, mut sxy) = (0.0, 0.0, 0.0, 0.0, 0.0);
    for &(b, y, w) in samples {
        let x = b as f64;
        sw += w;
        sx += w * x;
        sy += w * y;
        sxx += w * x * x;
        sxy += w * x * y;
    }
    let denom = sw * sxx - sx * sx;
    if denom.abs() < 1e-12 || sw <= 0.0 {
        // all weight at one width: proportional-through-zero
        return if sx > 0.0 { (0.0, sy / sx) } else { (0.0, 0.0) };
    }
    let mut slope = (sw * sxy - sx * sy) / denom;
    let mut base = (sy - slope * sx) / sw;
    if slope < 0.0 {
        slope = 0.0;
        base = sy / sw;
    } else if base < 0.0 {
        base = 0.0;
        slope = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    }
    (base, slope)
}

impl WallModel {
    /// Calibrate from engine counters for one program class. Returns
    /// `None` until calls at two distinct batch widths exist: with no
    /// samples there is nothing to estimate from, and with a single
    /// width the only available extrapolation is proportional-through-
    /// zero, which attributes no fixed per-call overhead, rejects every
    /// join, and would then never collect the wider-width samples that
    /// could correct it. Accept-all is the right prior for both.
    ///
    /// `block_native` engines pay no merge or split device calls — gang
    /// assembly and teardown are host block-table edits — so those terms
    /// are zero and `join_pays` reduces to the pure widening-vs-solo-call
    /// trade. (The engine's `gather_wall_s` still accumulates from
    /// `copy_blocktab` beam-divergence copies, which would otherwise leak
    /// into the split term and veto gangs for a cost they never pay.)
    pub fn from_stats(
        stats: &EngineStats,
        kind: IntentKind,
        block_native: bool,
    ) -> Option<WallModel> {
        let map = match kind {
            IntentKind::Decode => &stats.decode_wall,
            IntentKind::Score => &stats.score_wall,
            IntentKind::Compact => return None, // compactions are never ganged
        };
        let samples: Vec<(usize, f64, f64)> = map
            .iter()
            .filter(|(_, w)| w.calls > 0)
            .map(|(&b, w)| (b, w.mean_s(), sample_weight(w.calls)))
            .collect();
        if samples.len() < 2 {
            return None;
        }
        let (base_s, slope_s) = fit_line(&samples);
        if block_native {
            return Some(WallModel { base_s, slope_s, merge_step_s: 0.0, split_step_s: 0.0 });
        }
        let merge_step_s = if stats.merge_calls > 0 {
            stats.merge_wall_s / stats.merge_calls as f64
        } else {
            0.0
        };
        let split_step_s = if stats.gather_calls > 0 {
            stats.gather_wall_s / stats.gather_calls as f64
        } else {
            0.0
        };
        Some(WallModel { base_s, slope_s, merge_step_s, split_step_s })
    }

    /// Build directly from calibration points (tests / simulations),
    /// equally weighted. A single point fits proportional-through-zero.
    pub fn from_points(
        points: Vec<(usize, f64)>,
        merge_step_s: f64,
        split_step_s: f64,
    ) -> Option<WallModel> {
        if points.is_empty() {
            return None;
        }
        let samples: Vec<(usize, f64, f64)> = points.iter().map(|&(b, w)| (b, w, 1.0)).collect();
        let (base_s, slope_s) = if samples.len() == 1 {
            let (b0, w0) = points[0];
            (0.0, if b0 > 0 { w0 / b0 as f64 } else { 0.0 })
        } else {
            fit_line(&samples)
        };
        Some(WallModel { base_s, slope_s, merge_step_s, split_step_s })
    }

    /// Estimated wall of one call at `width`: the fitted
    /// `base + slope × width`.
    pub fn call_s(&self, width: usize) -> f64 {
        (self.base_s + self.slope_s * width as f64).max(0.0)
    }

    /// Fitted fixed per-call overhead (s).
    pub fn base_s(&self) -> f64 {
        self.base_s
    }

    /// Fitted marginal per-slot cost (s/slot).
    pub fn slope_s(&self) -> f64 {
        self.slope_s
    }

    /// Whether folding a `joiner`-batch intent into a chain currently at
    /// `chain_variant` (landing in `new_variant`) is estimated cheaper
    /// than running the joiner solo: the gang pays one merge, the
    /// joiner's split-back, and the widening of the shared call, and
    /// saves the joiner's own invocation. `first_join` additionally
    /// charges the seed's split-back — a k-member gang performs k splits
    /// but only k-1 joins, and the seed pays no split when it stays solo.
    pub fn join_pays(
        &self,
        chain_variant: usize,
        joiner: usize,
        new_variant: usize,
        first_join: bool,
    ) -> bool {
        let widen = self.call_s(new_variant) - self.call_s(chain_variant);
        let splits = if first_join { 2.0 } else { 1.0 };
        let gang_extra = self.merge_step_s + splits * self.split_step_s + widen;
        gang_extra < self.call_s(joiner)
    }
}

/// Pack one compatible group's pending intents (their cache batches, in
/// arrival order) into gangs of >= 2 members. `can_merge(a, b)` reports
/// the merged variant when the artifact set can merge an `a`-batch cache
/// with a `b`-batch cache (`a >= b`), else `None`. Inputs left out of
/// every gang are the caller's to execute solo. Accepts every feasible
/// join (invocation-count objective) — the serving path uses
/// [`plan_gangs_costed`] with a calibrated [`WallModel`] instead.
pub fn plan_gangs(
    batches: &[usize],
    can_merge: impl Fn(usize, usize) -> Option<usize>,
) -> Vec<Gang> {
    plan_gangs_costed(batches, can_merge, None)
}

/// [`plan_gangs`] with a wall-clock acceptance test: a feasible join is
/// taken only when `model` estimates the merged call cheaper than the
/// joiner's solo invocation (`None` = accept all, the cold-start prior).
pub fn plan_gangs_costed(
    batches: &[usize],
    can_merge: impl Fn(usize, usize) -> Option<usize>,
    model: Option<&WallModel>,
) -> Vec<Gang> {
    let mut order: Vec<usize> = (0..batches.len()).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(batches[i]), i));
    let mut assigned = vec![false; batches.len()];
    let mut gangs = Vec::new();
    for si in 0..order.len() {
        let seed = order[si];
        if assigned[seed] {
            continue;
        }
        let mut members = vec![seed];
        let mut chain = batches[seed];
        for &cand in order.iter().skip(si + 1) {
            if assigned[cand] {
                continue;
            }
            if let Some(v) = can_merge(chain, batches[cand]) {
                if let Some(m) = model {
                    if !m.join_pays(chain, batches[cand], v, members.len() == 1) {
                        continue;
                    }
                }
                members.push(cand);
                chain = v;
                assigned[cand] = true;
            }
        }
        if members.len() >= 2 {
            assigned[seed] = true;
            gangs.push(Gang { members, variant: chain });
        }
        // a 1-member chain stays unassigned: the caller executes it solo
    }
    gangs
}

/// Union-gather index for one merge step: the accumulator's `a_real` live
/// slots stay packed at the front, all of the joiner's `b_batch` slots
/// follow (offset by the accumulator's full device batch `a_batch`), and
/// variant padding replays slot 0.
fn merge_index(a_real: usize, a_batch: usize, b_batch: usize, c: usize) -> Vec<i32> {
    let mut idx = Vec::with_capacity(c);
    idx.extend((0..a_real).map(|i| i as i32));
    idx.extend((0..b_batch).map(|j| (a_batch + j) as i32));
    idx.resize(c, 0);
    idx
}

/// Execute one gang as a single merged device call and route each
/// member's output rows back into its task. `tasks` must be in the
/// planner's merge order with their intents still parked; on error the
/// caller fails every member (their intents are unusable afterwards).
/// Returns the merged batch variant actually dispatched and how many
/// members were re-compacted before the merge.
pub fn execute_gang(engine: &Engine, tasks: &mut [&mut SolveTask]) -> Result<(usize, usize)> {
    if tasks.len() < 2 {
        return Err(Error::internal("execute_gang wants >= 2 members"));
    }
    let (kind, ckpt, temp) = {
        let i0 = tasks[0]
            .intent()
            .ok_or_else(|| Error::internal("gang member lost its intent"))?;
        (i0.kind, i0.ckpt.clone(), i0.temp)
    };
    if kind == IntentKind::Compact {
        return Err(Error::internal("compact intents are never ganged"));
    }
    // Align frontiers before the union: a member whose cache is mostly
    // junk would drag every laggard's effective length down (the merged
    // frontier is the max), so re-compact the junk-heavy ones first.
    // Paged members skip this: their merge is a block-table
    // concatenation, and a laggard's union gap costs free-list blocks it
    // never reserves rather than a device-wide gather to avoid.
    let mut precompacted = 0usize;
    for t in tasks.iter_mut() {
        if t.gang_kv()?.paged() {
            continue;
        }
        if t.gang_precompact(engine, GANG_PRECOMPACT_JUNK)? {
            precompacted += 1;
        }
    }
    let mut batches = Vec::with_capacity(tasks.len());
    for t in tasks.iter() {
        let it = t.intent().ok_or_else(|| Error::internal("gang member lost its intent"))?;
        if (it.kind, it.ckpt.as_str(), it.temp.to_bits())
            != (kind, ckpt.as_str(), temp.to_bits())
        {
            return Err(Error::internal("incompatible intents packed into one gang"));
        }
        batches.push(it.batch);
    }
    let mut offsets = Vec::with_capacity(batches.len());
    let mut real = 0usize;
    for &b in &batches {
        offsets.push(real);
        real += b;
    }
    // every member's timeline shows the shared call it rode (on error
    // the span stays open; the failure path seals it at finish)
    let span_name = match kind {
        IntentKind::Decode => "gang:decode",
        IntentKind::Score => "gang:score",
        IntentKind::Compact => unreachable!("rejected above"),
    };
    let span_detail = format!("members={} slots={real}", tasks.len());
    for t in tasks.iter_mut() {
        if let Some(tb) = t.trace.as_mut() {
            tb.begin_detail(span_name, span_detail.clone());
        }
    }

    // 1. chain-merge the member caches (live slots densely packed).
    let mut merged = {
        let mut kvs: Vec<&KvSet> = Vec::with_capacity(tasks.len());
        for t in tasks.iter() {
            kvs.push(t.gang_kv()?);
        }
        let c = engine.manifest.merge_variant(batches[0], batches[1])?;
        let idx = merge_index(batches[0], batches[0], batches[1], c);
        let mut acc = engine.kv_merge(&ckpt, kvs[0], kvs[1], &idx)?;
        let mut acc_real = batches[0] + batches[1];
        for (i, kv) in kvs.iter().enumerate().skip(2) {
            let c = engine.manifest.merge_variant(acc.batch, batches[i])?;
            let idx = merge_index(acc_real, acc.batch, batches[i], c);
            acc = engine.kv_merge(&ckpt, &acc, kv, &idx)?;
            acc_real += batches[i];
        }
        acc
    };

    // 2. one shared device call, 3. split back + absorb per member.
    match kind {
        IntentKind::Decode => {
            let db = engine.manifest.decode_block;
            let mut prev = vec![crate::tokenizer::PAD; merged.batch];
            let mut keys = vec![0u32; merged.batch * 2];
            for (t, (&off, &b)) in tasks.iter().zip(offsets.iter().zip(&batches)) {
                let (p, k) = t
                    .intent()
                    .and_then(|i| i.decode_inputs())
                    .ok_or_else(|| Error::internal("decode gang holds a non-decode intent"))?;
                prev[off..off + b].copy_from_slice(p);
                keys[off * 2..(off + b) * 2].copy_from_slice(k);
            }
            let sampled = engine.lm_decode_block(&ckpt, &mut merged, &prev, temp, &keys)?;
            for i in 0..tasks.len() {
                let kv = engine.kv_split(&ckpt, &merged, offsets[i], batches[i])?;
                let rows = &sampled[offsets[i] * db..(offsets[i] + batches[i]) * db];
                tasks[i].gang_absorb(kv, GangOut::Tokens(rows))?;
            }
        }
        IntentKind::Score => {
            let sb = engine.manifest.score_block;
            let mut toks = vec![crate::tokenizer::PAD; merged.batch * sb];
            for (t, (&off, &b)) in tasks.iter().zip(offsets.iter().zip(&batches)) {
                let mt = t
                    .intent()
                    .and_then(|i| i.score_tokens())
                    .ok_or_else(|| Error::internal("score gang holds a non-score intent"))?;
                toks[off * sb..(off + b) * sb].copy_from_slice(mt);
            }
            let scores = engine.prm_score_block(&ckpt, &mut merged, &toks)?;
            for i in 0..tasks.len() {
                let kv = engine.kv_split(&ckpt, &merged, offsets[i], batches[i])?;
                let rows = &scores[offsets[i] * sb..(offsets[i] + batches[i]) * sb];
                tasks[i].gang_absorb(kv, GangOut::Scores(rows))?;
            }
        }
        IntentKind::Compact => unreachable!("rejected above"),
    }
    for t in tasks.iter_mut() {
        if let Some(tb) = t.trace.as_mut() {
            tb.end();
        }
    }
    Ok((merged.batch, precompacted))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Merge-capability model of the standard export: variants
    /// [4, 8, 16, 32, 64], every a >= b pair whose sum fits.
    fn cm(a: usize, b: usize) -> Option<usize> {
        const V: [usize; 5] = [4, 8, 16, 32, 64];
        if a < b {
            return None;
        }
        V.iter().copied().find(|&c| c >= a + b)
    }

    #[test]
    fn pairs_same_width_gang_up() {
        let gangs = plan_gangs(&[8, 8], cm);
        assert_eq!(gangs, vec![Gang { members: vec![0, 1], variant: 16 }]);
    }

    #[test]
    fn largest_fit_packs_mixed_widths() {
        // sorted largest-first: 16 seeds, 8 joins (-> 32), 4 joins (-> 64)
        let gangs = plan_gangs(&[8, 4, 16], cm);
        assert_eq!(gangs, vec![Gang { members: vec![2, 0, 1], variant: 64 }]);
    }

    #[test]
    fn equal_widths_keep_arrival_order() {
        let gangs = plan_gangs(&[8, 8, 8], cm);
        assert_eq!(gangs, vec![Gang { members: vec![0, 1, 2], variant: 32 }]);
    }

    #[test]
    fn oversize_members_stay_solo() {
        // two b64 caches cannot share any exported variant
        assert!(plan_gangs(&[64, 64], cm).is_empty());
        // one lone intent never forms a gang
        assert!(plan_gangs(&[8], cm).is_empty());
        assert!(plan_gangs(&[], cm).is_empty());
    }

    #[test]
    fn no_merge_programs_degrades_to_all_solo() {
        assert!(plan_gangs(&[8, 8, 4], |_, _| None).is_empty());
    }

    #[test]
    fn chain_respects_capability_holes() {
        // capability that only merges equal widths (a == b)
        let eq = |a: usize, b: usize| if a == b { cm(a, b) } else { None };
        let gangs = plan_gangs(&[8, 4, 8, 4], eq);
        // 8s pair into 16; the 16-chain can't take the 4s, but the 4s
        // then pair with each other
        assert_eq!(gangs.len(), 2);
        assert_eq!(gangs[0], Gang { members: vec![0, 2], variant: 16 });
        assert_eq!(gangs[1], Gang { members: vec![1, 3], variant: 8 });
    }

    /// An overhead-free model with linear per-slot cost: every feasible
    /// join pays (widening by the joiner's slots costs what the joiner's
    /// solo call would, minus its share of fixed overhead), so costed
    /// planning matches accept-all.
    fn linear_model() -> WallModel {
        WallModel::from_points(
            vec![(4, 0.05), (8, 0.06), (16, 0.08), (32, 0.12), (64, 0.20)],
            0.001,
            0.001,
        )
        .unwrap()
    }

    #[test]
    fn costed_planning_without_model_matches_accept_all() {
        let batches = [8usize, 4, 16, 8];
        assert_eq!(plan_gangs(&batches, cm), plan_gangs_costed(&batches, cm, None));
    }

    #[test]
    fn cheap_overhead_model_accepts_the_same_gangs() {
        let m = linear_model();
        let batches = [8usize, 8];
        assert_eq!(plan_gangs_costed(&batches, cm, Some(&m)), plan_gangs(&batches, cm));
    }

    #[test]
    fn padding_blowup_is_rejected_by_wall_clock() {
        // b32 chain + b4 joiner must widen 32 -> 64: +0.08s of width for a
        // joiner whose solo call costs 0.05s. Invocation counting says
        // merge; wall-clock says don't.
        let m = linear_model();
        assert!(!m.join_pays(32, 4, 64, true));
        let gangs = plan_gangs_costed(&[32, 4], cm, Some(&m));
        assert!(gangs.is_empty(), "{gangs:?}");
        // the same joiner into a b8 chain lands in b16: +0.02s of width
        // for 0.05s saved -> pays
        assert!(m.join_pays(8, 4, 16, true));
        assert_eq!(
            plan_gangs_costed(&[8, 4], cm, Some(&m)),
            vec![Gang { members: vec![0, 1], variant: 16 }]
        );
    }

    #[test]
    fn heavy_merge_overhead_disables_ganging() {
        // merge + split cost more than any solo call saves
        let m = WallModel::from_points(vec![(8, 0.05), (16, 0.06)], 0.5, 0.5).unwrap();
        assert!(plan_gangs_costed(&[8, 8, 8], cm, Some(&m)).is_empty());
    }

    #[test]
    fn first_join_charges_the_seeds_split_back() {
        // zero widening (flat walls), merge free, split 0.04s, solo call
        // 0.05s: one split alone would pay, but a 2-member gang performs
        // TWO kv_split calls (seed + joiner) = 0.08s for 0.05s saved
        let m = WallModel::from_points(vec![(8, 0.05), (16, 0.05)], 0.0, 0.04).unwrap();
        assert!(!m.join_pays(8, 8, 16, true), "seed's split must be charged");
        assert!(m.join_pays(16, 8, 32, false), "later joins pay one split only");
        assert!(plan_gangs_costed(&[8, 8], cm, Some(&m)).is_empty());
    }

    #[test]
    fn wall_model_fits_and_extrapolates() {
        // two points determine the line exactly: base 0, slope 0.0125
        let m = WallModel::from_points(vec![(8, 0.1), (16, 0.2)], 0.0, 0.0).unwrap();
        assert!((m.call_s(8) - 0.1).abs() < 1e-12, "on the line");
        assert!((m.call_s(12) - 0.15).abs() < 1e-12);
        assert!((m.call_s(32) - 0.4).abs() < 1e-12, "extrapolation up");
        assert!((m.call_s(4) - 0.05).abs() < 1e-12, "extrapolation down");
        let single = WallModel::from_points(vec![(8, 0.1)], 0.0, 0.0).unwrap();
        assert!((single.call_s(16) - 0.2).abs() < 1e-12, "proportional from one point");
        assert!(WallModel::from_points(vec![], 0.0, 0.0).is_none());
    }

    /// Pin the least-squares fit against synthetic timings worked out by
    /// hand: x = {4, 8, 12}, y = {0.05, 0.06, 0.10} (equal weights) gives
    /// x̄ = 8, ȳ = 0.07, Sxx = 32, Sxy = 0.2, so slope = 0.00625 and
    /// base = 0.02 — the noisy middle point pulls the line, it does not
    /// carve a spike the way point interpolation did.
    #[test]
    fn wall_model_regression_pins_synthetic_fit() {
        let m = WallModel::from_points(vec![(4, 0.05), (8, 0.06), (12, 0.10)], 0.0, 0.0).unwrap();
        assert!((m.base_s() - 0.02).abs() < 1e-12, "base {}", m.base_s());
        assert!((m.slope_s() - 0.00625).abs() < 1e-12, "slope {}", m.slope_s());
        assert!((m.call_s(8) - 0.07).abs() < 1e-12, "fit passes the centroid, not the sample");
        assert!((m.call_s(0) - 0.02).abs() < 1e-12, "width 0 reads the fixed overhead");
    }

    #[test]
    fn wall_model_clamps_unphysical_fits() {
        // decreasing walls (measurement noise) degrade to the flat mean,
        // never a negative slope that would make every join look free
        let m = WallModel::from_points(vec![(8, 0.2), (16, 0.1)], 0.0, 0.0).unwrap();
        assert!((m.slope_s() - 0.0).abs() < 1e-12);
        assert!((m.call_s(64) - 0.15).abs() < 1e-12, "flat weighted mean");
        // superlinear data would fit base < 0: degrade through-origin
        let m = WallModel::from_points(vec![(8, 0.05), (16, 0.15)], 0.0, 0.0).unwrap();
        assert!(m.base_s() >= 0.0);
        assert!(m.call_s(1) >= 0.0);
    }

    #[test]
    fn sample_weights_saturate_with_call_count() {
        assert_eq!(super::sample_weight(0), 0.0, "no calls, no vote");
        let w1 = super::sample_weight(1);
        let w5 = super::sample_weight(5);
        let w5k = super::sample_weight(5000);
        assert!(w1 > 0.0 && w1 < w5 && w5 < w5k, "monotone in evidence");
        assert!(w5k <= 1.0 && w5k > 0.999, "hammered widths cap at ~1");
        // decayed weighting: a width with 10x the calls of another gets
        // nowhere near 10x the vote
        assert!(super::sample_weight(50) / super::sample_weight(5) < 3.0);
    }

    #[test]
    fn wall_model_calibrates_from_engine_stats() {
        use crate::runtime::CallWall;
        let mut s = EngineStats::default();
        assert!(WallModel::from_stats(&s, IntentKind::Decode, false).is_none(), "cold start");
        s.decode_wall.insert(8, CallWall { calls: 4, wall_s: 0.4 });
        assert!(
            WallModel::from_stats(&s, IntentKind::Decode, false).is_none(),
            "one width cannot separate overhead from per-slot cost; a proportional model \
             would veto every join and starve itself of wider samples forever"
        );
        s.decode_wall.insert(16, CallWall { calls: 2, wall_s: 0.4 });
        s.merge_calls = 2;
        s.merge_wall_s = 0.02;
        s.gather_calls = 4;
        s.gather_wall_s = 0.02;
        let m = WallModel::from_stats(&s, IntentKind::Decode, false).unwrap();
        assert!((m.call_s(8) - 0.1).abs() < 1e-12);
        assert!((m.call_s(16) - 0.2).abs() < 1e-12);
        assert!((m.merge_step_s - 0.01).abs() < 1e-12);
        assert!((m.split_step_s - 0.005).abs() < 1e-12);
        assert!(
            WallModel::from_stats(&s, IntentKind::Score, false).is_none(),
            "score side has no samples yet"
        );
        assert!(WallModel::from_stats(&s, IntentKind::Compact, false).is_none());
    }

    #[test]
    fn block_native_model_drops_merge_and_split_terms() {
        use crate::runtime::CallWall;
        let mut s = EngineStats::default();
        // two points pin the line exactly: base 0.1s, slope 0.00625 s/slot
        s.decode_wall.insert(8, CallWall { calls: 4, wall_s: 0.6 });
        s.decode_wall.insert(16, CallWall { calls: 2, wall_s: 0.4 });
        // heavy observed merge/gather walls (e.g. beam-divergence copies)
        s.merge_calls = 1;
        s.merge_wall_s = 0.5;
        s.gather_calls = 1;
        s.gather_wall_s = 0.5;
        let m = WallModel::from_stats(&s, IntentKind::Decode, true).unwrap();
        assert!((m.merge_step_s - 0.0).abs() < 1e-12, "table merges are free");
        assert!((m.split_step_s - 0.0).abs() < 1e-12, "table splits are free");
        // the same fitted call curve as the non-native model
        assert!((m.call_s(8) - 0.15).abs() < 1e-12);
        assert!((m.base_s() - 0.1).abs() < 1e-12);
        // with free assembly, widening 8 -> 16 costs 0.05s to save a
        // 0.15s solo call; the device-merge model's 1.5s of merge+split
        // overhead vetoes the same join
        let veto = WallModel::from_stats(&s, IntentKind::Decode, false).unwrap();
        assert!(!veto.join_pays(8, 8, 16, true), "0.5s merge dwarfs a 0.15s solo call");
        assert!(m.join_pays(8, 8, 16, true), "table edits make the same join pay");
    }

    #[test]
    fn merge_index_packs_live_slots_and_pads_with_zero() {
        assert_eq!(merge_index(4, 4, 4, 8), vec![0, 1, 2, 3, 4, 5, 6, 7]);
        // accumulator at variant 8 with 6 live slots + a b4 joiner -> b16
        assert_eq!(
            merge_index(6, 8, 4, 16),
            vec![0, 1, 2, 3, 4, 5, 8, 9, 10, 11, 0, 0, 0, 0, 0, 0]
        );
    }
}
