//! Correlation studies over *real* traces and *real* PRM scores —
//! the empirical halves of Fig. 2 (partial-vs-final scatter + R²) and
//! Fig. 4 (Pearson / Kendall-tau vs tau).
//!
//! Pipeline: sample solutions from an LM checkpoint (mix of gold and
//! corrupted traces keeps both reward tails populated), score whole
//! sequences in one `prm_fullseq` call through the Pallas prefix-score
//! kernel, then read the partial reward at any tau from the cumulative
//! outputs — no re-scoring per tau.

use crate::runtime::Engine;
use crate::tokenizer as tk;
use crate::util::error::Result;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::workload::{gen_problem, BenchSpec, Problem};

/// A scored trace: partial rewards at every prefix + the final reward.
#[derive(Debug, Clone)]
pub struct ScoredTrace {
    /// cumulative-min partial reward at each solution token index.
    pub cummin: Vec<f32>,
    /// cumulative-mean partial reward at each solution token index.
    pub cummean: Vec<f32>,
    /// solution length in tokens.
    pub len: usize,
}

impl ScoredTrace {
    /// Partial reward after `tau` solution tokens (clamped to length).
    /// Uses the cumulative-*mean* channel: the paper's additive toy model
    /// (P = sum of first tau token scores, F = sum of all) is exactly the
    /// mean aggregation up to normalization; cumulative-min pins to the
    /// noisiest early token and destroys the tau-dependence.
    pub fn partial(&self, tau: usize) -> f64 {
        self.cummean[tau.clamp(1, self.len) - 1] as f64
    }

    pub fn final_reward(&self) -> f64 {
        self.cummean[self.len - 1] as f64
    }

    /// Half-length partial (Fig. 2's x-axis).
    pub fn half(&self) -> f64 {
        self.partial((self.len / 2).max(1))
    }
}

/// Build and score a corpus of traces with a PRM checkpoint.
///
/// Traces are gold solutions and validator-labelled corruptions of
/// problems from `bench` — the same trace population the PRM was trained
/// to judge, giving both high- and low-reward tails.
pub fn score_corpus(
    engine: &Engine,
    prm_ckpt: &str,
    bench: &BenchSpec,
    n_traces: usize,
    seed: u64,
) -> Result<Vec<ScoredTrace>> {
    let fb = engine.manifest.fullseq_batch;
    let s = engine.manifest.seq_train;
    let mut rng = Rng::new(seed ^ 0xC0_55E7);
    let mut out = Vec::with_capacity(n_traces);

    let mut batch_tokens = vec![tk::PAD; fb * s];
    let mut batch_lens = vec![0i32; fb];
    let mut batch_sol_starts = vec![0usize; fb];
    let mut filled = 0usize;

    let flush = |engine: &Engine,
                     tokens: &mut Vec<i32>,
                     lens: &mut Vec<i32>,
                     starts: &mut Vec<usize>,
                     filled: &mut usize,
                     out: &mut Vec<ScoredTrace>|
     -> Result<()> {
        if *filled == 0 {
            return Ok(());
        }
        let (_, cummin, cummean) = engine.prm_fullseq(prm_ckpt, tokens, lens)?;
        for i in 0..*filled {
            let len = lens[i] as usize;
            let start = starts[i];
            out.push(ScoredTrace {
                cummin: cummin[i * s + start..i * s + len].to_vec(),
                cummean: cummean[i * s + start..i * s + len].to_vec(),
                len: len - start,
            });
        }
        tokens.iter_mut().for_each(|t| *t = tk::PAD);
        *filled = 0;
        Ok(())
    };

    while out.len() + filled < n_traces {
        let p = gen_problem(&mut rng, bench);
        let sol = synth_trace(&p, &mut rng);
        let prompt = p.prompt_tokens();
        let seq: Vec<i32> = prompt.iter().chain(sol.iter()).cloned().collect();
        if seq.len() > s {
            continue;
        }
        let row = filled;
        batch_tokens[row * s..row * s + seq.len()].copy_from_slice(&seq);
        batch_lens[row] = seq.len() as i32;
        batch_sol_starts[row] = prompt.len();
        filled += 1;
        if filled == fb {
            flush(engine, &mut batch_tokens, &mut batch_lens, &mut batch_sol_starts, &mut filled, &mut out)?;
        }
    }
    flush(engine, &mut batch_tokens, &mut batch_lens, &mut batch_sol_starts, &mut filled, &mut out)?;
    out.truncate(n_traces);
    Ok(out)
}

/// Gold or corrupted trace, 50/50 (mirrors the PRM's training population).
fn synth_trace(p: &Problem, rng: &mut Rng) -> Vec<i32> {
    let gold = p.gold_solution();
    if rng.f64() < 0.5 {
        return gold;
    }
    // corrupt one scratch value: find a digit pair inside a scratch region
    let mut toks = gold.clone();
    let digit_positions: Vec<usize> = (0..toks.len().saturating_sub(1))
        .filter(|&i| tk::is_digit(toks[i]) && tk::is_digit(toks[i + 1]))
        .collect();
    if digit_positions.is_empty() {
        return toks;
    }
    let pos = digit_positions[rng.below(digit_positions.len())];
    let delta = 1 + rng.below(3) as i64;
    let v = tk::parse_two_digits(toks[pos], toks[pos + 1]).unwrap();
    let nv = tk::two_digits(v + delta);
    toks[pos] = nv[0];
    toks[pos + 1] = nv[1];
    toks
}

/// Fig. 4 rows: (tau, pearson, kendall) over a scored corpus.
///
/// Runs through the same incremental kernels as the online calibration
/// observatory (`util::stats::{StreamingPearson, StreamingKendall}`) so
/// the offline study and the serving-time tracker are one implementation:
/// the corpus is streamed pair-by-pair exactly the way finished requests
/// stream into `obs::calibration`. With the reservoir sized to the corpus
/// the rank estimate is the exact tau-b the batch kernel computes.
pub fn correlation_vs_tau(traces: &[ScoredTrace], taus: &[usize]) -> Vec<(usize, f64, f64)> {
    taus.iter()
        .map(|&tau| {
            let mut sp = stats::StreamingPearson::new();
            let mut sk = stats::StreamingKendall::new(traces.len().max(2), 0);
            for t in traces {
                if t.len >= tau {
                    sp.push(t.partial(tau), t.final_reward());
                    sk.push(t.partial(tau), t.final_reward());
                }
            }
            (tau, sp.corr(), sk.corr())
        })
        .collect()
}

/// Fig. 2 fit: OLS of final on half-length partial rewards.
pub fn half_vs_final_fit(traces: &[ScoredTrace]) -> (stats::OlsFit, Vec<(f64, f64)>) {
    let pts: Vec<(f64, f64)> =
        traces.iter().map(|t| (t.half(), t.final_reward())).collect();
    let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
    let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
    (stats::ols(&xs, &ys), pts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scored_trace_partial_indexing() {
        let t = ScoredTrace {
            cummin: vec![0.9, 0.8, 0.7, 0.7],
            cummean: vec![0.9, 0.85, 0.8, 0.78],
            len: 4,
        };
        assert!((t.partial(1) - 0.9).abs() < 1e-6);
        assert!((t.partial(3) - 0.8).abs() < 1e-6);
        assert_eq!(t.partial(99), t.final_reward());
        assert!((t.half() - 0.85).abs() < 1e-6);
    }

    /// Satellite cross-check: the streaming kernels behind
    /// `correlation_vs_tau` reproduce the batch `stats::{pearson,
    /// kendall_tau}` on a shared corpus with mixed-quality traces.
    #[test]
    fn streaming_rows_match_batch_on_shared_corpus() {
        let mut rng = crate::util::rng::Rng::new(404);
        let traces: Vec<ScoredTrace> = (0..60)
            .map(|_| {
                let len = 8 + rng.below(24);
                let base = 0.2 + 0.6 * rng.f32();
                let cummean: Vec<f32> =
                    (0..len).map(|i| base + 0.1 * rng.f32() - 0.002 * i as f32).collect();
                ScoredTrace { cummin: cummean.clone(), cummean, len }
            })
            .collect();
        for &tau in &[2usize, 4, 8, 16] {
            let mut xs = Vec::new();
            let mut ys = Vec::new();
            for t in &traces {
                if t.len >= tau {
                    xs.push(t.partial(tau));
                    ys.push(t.final_reward());
                }
            }
            let rows = correlation_vs_tau(&traces, &[tau]);
            let (_, p, k) = rows[0];
            assert!((p - stats::pearson(&xs, &ys)).abs() < 1e-12, "tau {tau}");
            assert_eq!(k, stats::kendall_tau(&xs, &ys), "tau {tau}: reservoir covers corpus");
        }
    }

    #[test]
    fn correlation_rows_shapes() {
        // synthetic monotone traces: partial == final at every tau
        let traces: Vec<ScoredTrace> = (0..20)
            .map(|i| {
                let v = 0.5 + 0.02 * i as f32;
                ScoredTrace { cummin: vec![v; 30], cummean: vec![v; 30], len: 30 }
            })
            .collect();
        let rows = correlation_vs_tau(&traces, &[4, 8, 16]);
        assert_eq!(rows.len(), 3);
        for (_, p, k) in rows {
            assert!((p - 1.0).abs() < 1e-9);
            assert!((k - 1.0).abs() < 1e-9);
        }
    }
}
