//! Experiment harness: runs the paper's evaluation grid cells and formats
//! the same rows the paper reports (Tables 1-3, Figs. 5-7).
//!
//! A *cell* is (benchmark, LM, PRM, mode, N, tau) evaluated over a seeded
//! problem set; the output is mean accuracy plus the aggregated FLOPs
//! ledger. Problem counts scale with `ERPRM_PROBLEMS` (default keeps the
//! full `cargo bench` run tractable on this single-core testbed; the table
//! *shape* — who wins, by what factor — is stable across scales).

pub mod correlation;

use crate::config::{SearchConfig, SearchMode};
use crate::coordinator::flops::FlopsLedger;
use crate::coordinator::{solve_early_rejection, solve_vanilla};
use crate::log_info;
use crate::runtime::Engine;
use crate::util::error::Result;
use crate::workload::{problem_set, BenchSpec};

/// One grid cell's aggregate result.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub accuracy: f64,
    pub n_problems: usize,
    pub ledger: FlopsLedger,
    pub wall_s: f64,
    pub mean_steps: f64,
}

/// Experiment axes for one cell.
#[derive(Debug, Clone)]
pub struct Cell {
    pub bench: BenchSpec,
    pub lm_ckpt: String,
    pub prm_ckpt: String,
    pub mode: SearchMode,
    pub n_beams: usize,
    pub tau: usize,
}

impl Cell {
    pub fn label(&self) -> String {
        let mode = match self.mode {
            SearchMode::Vanilla => "vanilla".to_string(),
            SearchMode::EarlyRejection => format!("ER(tau={})", self.tau),
        };
        format!(
            "{}/{}/{} {} N={}",
            self.bench.name, self.lm_ckpt, self.prm_ckpt, mode, self.n_beams
        )
    }
}

/// Number of problems per cell (env-scalable).
pub fn problems_per_cell(default: usize) -> usize {
    std::env::var("ERPRM_PROBLEMS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Serving temperature per LM behaviour class (DESIGN.md substitutions).
pub fn temp_for(lm_ckpt: &str) -> f32 {
    crate::config::EngineConfig::default_temperature(lm_ckpt)
}

/// Run one cell over a seeded problem set.
pub fn run_cell(engine: &Engine, cell: &Cell, n_problems: usize, seed: u64) -> Result<CellResult> {
    let problems = problem_set(&cell.bench, n_problems, seed);
    let mut cfg = SearchConfig {
        mode: cell.mode,
        n_beams: cell.n_beams,
        tau: cell.tau.max(1),
        ..SearchConfig::default()
    };
    cfg.seed = seed;
    let temp = temp_for(&cell.lm_ckpt);

    let lm_arch = engine.manifest.arch_for_checkpoint(&cell.lm_ckpt)?;
    let prm_arch = engine.manifest.arch_for_checkpoint(&cell.prm_ckpt)?;
    let mut ledger = FlopsLedger::new(lm_arch.flops_per_token, prm_arch.flops_per_token);

    let mut correct = 0usize;
    let mut wall = 0.0;
    let mut steps = 0usize;
    for (i, p) in problems.iter().enumerate() {
        cfg.seed = seed.wrapping_add(i as u64);
        let out = match cell.mode {
            SearchMode::Vanilla => {
                solve_vanilla(engine, &cell.lm_ckpt, &cell.prm_ckpt, p, &cfg, temp)?
            }
            SearchMode::EarlyRejection => {
                solve_early_rejection(engine, &cell.lm_ckpt, &cell.prm_ckpt, p, &cfg, temp)?
            }
        };
        correct += out.correct as usize;
        wall += out.wall_s;
        steps += out.steps_executed;
        ledger.merge(&out.ledger);
    }
    let res = CellResult {
        accuracy: 100.0 * correct as f64 / n_problems.max(1) as f64,
        n_problems,
        ledger,
        wall_s: wall,
        mean_steps: steps as f64 / n_problems.max(1) as f64,
    };
    log_info!(
        "{}: acc {:.1}% flops {:.3e} ({:.1}s)",
        cell.label(),
        res.accuracy,
        res.ledger.total_flops(),
        res.wall_s
    );
    Ok(res)
}

/// Pre-warm the engine for a list of checkpoints (avoids counting PJRT
/// compilation in experiment wallclock).
pub fn warm(engine: &Engine, ckpts: &[&str], batches: &[usize]) -> Result<()> {
    for c in ckpts {
        engine.warmup(c, batches)?;
    }
    Ok(())
}
