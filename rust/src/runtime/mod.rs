//! PJRT runtime: load AOT artifacts (HLO text + weights.bin) and execute
//! them on the CPU PJRT client with device-resident KV caches.
//!
//! Layering:
//! * [`artifacts`] — manifest parsing, ABI checks against the tokenizer.
//! * [`engine`] — `Engine`: PJRT client, lazy executable compilation cache,
//!   weight buffers, and the typed call surface (`prefill`, `decode_block`,
//!   `score_block`, `gather`, `broadcast`, `fullseq`).
//! * [`kv`] — `KvSet`: the device-resident cache handles threaded between
//!   calls (never copied to host on the hot path).
//! * [`blocks`] — `BlockPool` / `BlockTable`: paged KV allocation over a
//!   shared per-shard block pool (refcounted, free-listed); `KvSet`
//!   attaches per-slot tables so beam permute/merge/split/compact become
//!   table edits and a rejected beam's blocks return to the pool in the
//!   same tick.
//!
//! The engine is deliberately *not* `Send` (the `xla` crate's client is
//! `Rc`-based): the serving front end talks to per-shard engine threads
//! via channels (`server::router::EnginePool`), each shard owning its own
//! engine and serializing its own PJRT access; `EngineStats::merge`
//! aggregates counters across shards for `/metrics`.

pub mod artifacts;
pub mod blocks;
pub mod engine;
pub mod kv;

pub use artifacts::{Manifest, ModelArch};
pub use blocks::{shared_pool, BlockId, BlockPool, BlockTable, PoolExhausted, PoolStats, SharedPool};
pub use engine::{CallWall, Engine, EngineStats, ModelKind};
pub use kv::{CompactPlan, KvSet, PagedKv};
