//! PJRT runtime: load AOT artifacts (HLO text + weights.bin) and execute
//! them on the CPU PJRT client with device-resident KV caches.
//!
//! Layering:
//! * [`artifacts`] — manifest parsing, ABI checks against the tokenizer.
//! * [`engine`] — `Engine`: PJRT client, lazy executable compilation cache,
//!   weight buffers, and the typed call surface (`prefill`, `decode_block`,
//!   `score_block`, `gather`, `broadcast`, `fullseq`).
//! * [`kv`] — `KvSet`: the device-resident cache handles threaded between
//!   calls (never copied to host on the hot path).
//!
//! The engine is deliberately *not* `Send` (the `xla` crate's client is
//! `Rc`-based): the serving front end talks to per-shard engine threads
//! via channels (`server::router::EnginePool`), each shard owning its own
//! engine and serializing its own PJRT access; `EngineStats::merge`
//! aggregates counters across shards for `/metrics`.

pub mod artifacts;
pub mod engine;
pub mod kv;

pub use artifacts::{Manifest, ModelArch};
pub use engine::{CallWall, Engine, EngineStats, ModelKind};
pub use kv::{CompactPlan, KvSet};
