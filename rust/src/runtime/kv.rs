//! Device-resident KV-cache handles.
//!
//! A `KvSet` owns the `2 * n_layers` PJRT buffers of one cache instance
//! plus the host-side bookkeeping the lockstep cache discipline needs
//! (see `python/compile/model.py` docstring): the physical write frontier,
//! per-slot logical positions, and the per-slot validity bitmask that
//! marks which physical positions are attendable (clean tokens) vs junk
//! (block overshoot past a step boundary / PAD slots).

use xla::PjRtBuffer;

/// Device KV cache + host bookkeeping for a batch of beam slots.
pub struct KvSet {
    /// `[l0.k, l0.v, l1.k, l1.v, ...]`, each `[batch, heads, cache_len, hd]`.
    pub bufs: Vec<PjRtBuffer>,
    pub batch: usize,
    pub cache_len: usize,
    /// Lockstep physical write frontier (same for every slot).
    pub pos_phys: usize,
    /// Per-slot logical sequence length (RoPE positions).
    pub pos_log: Vec<i32>,
    /// Per-slot validity bitmask, row-major `[batch, cache_len]`.
    pub valid: Vec<i32>,
}

impl KvSet {
    pub fn new(bufs: Vec<PjRtBuffer>, batch: usize, cache_len: usize) -> Self {
        KvSet {
            bufs,
            batch,
            cache_len,
            pos_phys: 0,
            pos_log: vec![0; batch],
            valid: vec![0; batch * cache_len],
        }
    }

    /// Mark `[start, start+n)` physical positions of `slot` attendable and
    /// advance its logical position by `n`.
    pub fn commit(&mut self, slot: usize, start: usize, n: usize) {
        assert!(slot < self.batch, "slot {slot} out of range {}", self.batch);
        assert!(start + n <= self.cache_len, "cache overflow: {}+{n} > {}", start, self.cache_len);
        let row = slot * self.cache_len;
        for p in start..start + n {
            self.valid[row + p] = 1;
        }
        self.pos_log[slot] += n as i32;
    }

    /// Advance the lockstep frontier after a block write of `n` positions.
    pub fn advance_frontier(&mut self, n: usize) {
        self.pos_phys += n;
        assert!(
            self.pos_phys <= self.cache_len,
            "physical frontier {} past cache_len {}",
            self.pos_phys,
            self.cache_len
        );
    }

    /// Remaining physical capacity.
    pub fn remaining(&self) -> usize {
        self.cache_len - self.pos_phys
    }

    /// Permute host bookkeeping to match a device `gather(idx)`:
    /// `new[slot] = old[idx[slot]]`.
    pub fn permute_bookkeeping(&mut self, idx: &[i32]) {
        assert_eq!(idx.len(), self.batch);
        let old_log = self.pos_log.clone();
        let old_valid = self.valid.clone();
        for (dst, &src) in idx.iter().enumerate() {
            let src = src as usize;
            assert!(src < self.batch, "gather index {src} out of range");
            self.pos_log[dst] = old_log[src];
            let (d0, s0) = (dst * self.cache_len, src * self.cache_len);
            self.valid[d0..d0 + self.cache_len]
                .copy_from_slice(&old_valid[s0..s0 + self.cache_len]);
        }
    }

    /// Resize bookkeeping after broadcast b=1 -> n (device side handled by
    /// the broadcast program).
    pub fn broadcast_bookkeeping(&self, n: usize) -> (Vec<i32>, Vec<i32>) {
        assert_eq!(self.batch, 1);
        let mut pos_log = Vec::with_capacity(n);
        let mut valid = Vec::with_capacity(n * self.cache_len);
        for _ in 0..n {
            pos_log.push(self.pos_log[0]);
            valid.extend_from_slice(&self.valid[..self.cache_len]);
        }
        (pos_log, valid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(batch: usize, cache_len: usize) -> KvSet {
        KvSet::new(Vec::new(), batch, cache_len)
    }

    #[test]
    fn commit_marks_valid_and_advances_logical() {
        let mut kv = toy(2, 8);
        kv.commit(0, 0, 3);
        kv.commit(1, 0, 2);
        assert_eq!(kv.pos_log, vec![3, 2]);
        assert_eq!(&kv.valid[0..4], &[1, 1, 1, 0]);
        assert_eq!(&kv.valid[8..12], &[1, 1, 0, 0]);
    }

    #[test]
    fn frontier_advances_lockstep() {
        let mut kv = toy(2, 8);
        kv.advance_frontier(4);
        assert_eq!(kv.pos_phys, 4);
        assert_eq!(kv.remaining(), 4);
    }

    #[test]
    #[should_panic(expected = "cache overflow")]
    fn commit_overflow_panics() {
        let mut kv = toy(1, 4);
        kv.commit(0, 2, 3);
    }

    #[test]
    fn permute_bookkeeping_matches_gather_semantics() {
        let mut kv = toy(3, 4);
        kv.commit(0, 0, 1);
        kv.commit(1, 0, 2);
        kv.commit(2, 0, 3);
        kv.permute_bookkeeping(&[2, 2, 0]);
        assert_eq!(kv.pos_log, vec![3, 3, 1]);
        assert_eq!(&kv.valid[0..4], &[1, 1, 1, 0]); // slot0 = old slot2
        assert_eq!(&kv.valid[8..12], &[1, 0, 0, 0]); // slot2 = old slot0
    }

    #[test]
    fn broadcast_replicates_slot0() {
        let mut kv = toy(1, 4);
        kv.commit(0, 0, 2);
        let (log, valid) = kv.broadcast_bookkeeping(3);
        assert_eq!(log, vec![2, 2, 2]);
        assert_eq!(valid.len(), 12);
        assert_eq!(&valid[4..8], &[1, 1, 0, 0]);
    }
}
