//! Device-resident KV-cache handles.
//!
//! A `KvSet` owns the `2 * n_layers` PJRT buffers of one cache instance
//! plus the host-side bookkeeping the lockstep cache discipline needs
//! (see `python/compile/model.py` docstring): the physical write frontier,
//! per-slot logical positions, and the per-slot validity bitmask that
//! marks which physical positions are attendable (clean tokens) vs junk
//! (block overshoot past a step boundary / PAD slots).

use xla::PjRtBuffer;

/// Device KV cache + host bookkeeping for a batch of beam slots.
pub struct KvSet {
    /// `[l0.k, l0.v, l1.k, l1.v, ...]`, each `[batch, heads, cache_len, hd]`.
    pub bufs: Vec<PjRtBuffer>,
    pub batch: usize,
    pub cache_len: usize,
    /// Lockstep physical write frontier (same for every slot).
    pub pos_phys: usize,
    /// Per-slot logical sequence length (RoPE positions).
    pub pos_log: Vec<i32>,
    /// Per-slot validity bitmask, row-major `[batch, cache_len]`.
    pub valid: Vec<i32>,
}

impl KvSet {
    pub fn new(bufs: Vec<PjRtBuffer>, batch: usize, cache_len: usize) -> Self {
        KvSet {
            bufs,
            batch,
            cache_len,
            pos_phys: 0,
            pos_log: vec![0; batch],
            valid: vec![0; batch * cache_len],
        }
    }

    /// Mark `[start, start+n)` physical positions of `slot` attendable and
    /// advance its logical position by `n`.
    pub fn commit(&mut self, slot: usize, start: usize, n: usize) {
        assert!(slot < self.batch, "slot {slot} out of range {}", self.batch);
        assert!(start + n <= self.cache_len, "cache overflow: {}+{n} > {}", start, self.cache_len);
        let row = slot * self.cache_len;
        for p in start..start + n {
            self.valid[row + p] = 1;
        }
        self.pos_log[slot] += n as i32;
    }

    /// Advance the lockstep frontier after a block write of `n` positions.
    pub fn advance_frontier(&mut self, n: usize) {
        self.pos_phys += n;
        assert!(
            self.pos_phys <= self.cache_len,
            "physical frontier {} past cache_len {}",
            self.pos_phys,
            self.cache_len
        );
    }

    /// Remaining physical capacity.
    pub fn remaining(&self) -> usize {
        self.cache_len - self.pos_phys
    }

    /// Permute host bookkeeping to match a device `gather(idx)`:
    /// `new[slot] = old[idx[slot]]`.
    pub fn permute_bookkeeping(&mut self, idx: &[i32]) {
        assert_eq!(idx.len(), self.batch);
        let old_log = self.pos_log.clone();
        let old_valid = self.valid.clone();
        for (dst, &src) in idx.iter().enumerate() {
            let src = src as usize;
            assert!(src < self.batch, "gather index {src} out of range");
            self.pos_log[dst] = old_log[src];
            let (d0, s0) = (dst * self.cache_len, src * self.cache_len);
            self.valid[d0..d0 + self.cache_len]
                .copy_from_slice(&old_valid[s0..s0 + self.cache_len]);
        }
    }

    /// Host bookkeeping for a device `merge(idx)` of two caches: dest slot
    /// `d` copies from `a[idx[d]]` when `idx[d] < a.batch`, else from
    /// `b[idx[d] - a.batch]` — the same union indexing the
    /// `merge_bA_bB_to_bC` programs apply to the device arrays. The merged
    /// frontier is the max of the two (lockstep discipline: future writes
    /// land at a common physical position; the gap below the laggard's own
    /// frontier stays junk, which its validity rows already encode).
    pub fn merge_bookkeeping(a: &KvSet, b: &KvSet, idx: &[i32]) -> (usize, Vec<i32>, Vec<i32>) {
        assert_eq!(a.cache_len, b.cache_len, "merging caches of different models");
        let s = a.cache_len;
        let mut pos_log = Vec::with_capacity(idx.len());
        let mut valid = Vec::with_capacity(idx.len() * s);
        for &i in idx {
            let i = i as usize;
            let (src, row) = if i < a.batch {
                (a, i)
            } else {
                assert!(i - a.batch < b.batch, "merge index {i} out of union range");
                (b, i - a.batch)
            };
            pos_log.push(src.pos_log[row]);
            valid.extend_from_slice(&src.valid[row * s..(row + 1) * s]);
        }
        (a.pos_phys.max(b.pos_phys), pos_log, valid)
    }

    /// Resize bookkeeping after broadcast b=1 -> n (device side handled by
    /// the broadcast program).
    pub fn broadcast_bookkeeping(&self, n: usize) -> (Vec<i32>, Vec<i32>) {
        assert_eq!(self.batch, 1);
        let mut pos_log = Vec::with_capacity(n);
        let mut valid = Vec::with_capacity(n * self.cache_len);
        for _ in 0..n {
            pos_log.push(self.pos_log[0]);
            valid.extend_from_slice(&self.valid[..self.cache_len]);
        }
        (pos_log, valid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(batch: usize, cache_len: usize) -> KvSet {
        KvSet::new(Vec::new(), batch, cache_len)
    }

    #[test]
    fn commit_marks_valid_and_advances_logical() {
        let mut kv = toy(2, 8);
        kv.commit(0, 0, 3);
        kv.commit(1, 0, 2);
        assert_eq!(kv.pos_log, vec![3, 2]);
        assert_eq!(&kv.valid[0..4], &[1, 1, 1, 0]);
        assert_eq!(&kv.valid[8..12], &[1, 1, 0, 0]);
    }

    #[test]
    fn frontier_advances_lockstep() {
        let mut kv = toy(2, 8);
        kv.advance_frontier(4);
        assert_eq!(kv.pos_phys, 4);
        assert_eq!(kv.remaining(), 4);
    }

    #[test]
    #[should_panic(expected = "cache overflow")]
    fn commit_overflow_panics() {
        let mut kv = toy(1, 4);
        kv.commit(0, 2, 3);
    }

    #[test]
    fn permute_bookkeeping_matches_gather_semantics() {
        let mut kv = toy(3, 4);
        kv.commit(0, 0, 1);
        kv.commit(1, 0, 2);
        kv.commit(2, 0, 3);
        kv.permute_bookkeeping(&[2, 2, 0]);
        assert_eq!(kv.pos_log, vec![3, 3, 1]);
        assert_eq!(&kv.valid[0..4], &[1, 1, 1, 0]); // slot0 = old slot2
        assert_eq!(&kv.valid[8..12], &[1, 0, 0, 0]); // slot2 = old slot0
    }

    #[test]
    fn merge_bookkeeping_unions_two_caches() {
        let mut a = toy(2, 4);
        a.commit(0, 0, 1);
        a.commit(1, 0, 2);
        a.pos_phys = 2;
        let mut b = toy(2, 4);
        b.commit(0, 0, 3);
        b.pos_phys = 3;
        // dest = [a0, a1, b0, b1], padding slot replays a0
        let (pos, log, valid) = KvSet::merge_bookkeeping(&a, &b, &[0, 1, 2, 3, 0]);
        assert_eq!(pos, 3, "merged frontier is the max of the two");
        assert_eq!(log, vec![1, 2, 3, 0, 1]);
        assert_eq!(&valid[0..4], &[1, 0, 0, 0]); // a0
        assert_eq!(&valid[4..8], &[1, 1, 0, 0]); // a1
        assert_eq!(&valid[8..12], &[1, 1, 1, 0]); // b0
        assert_eq!(&valid[12..16], &[0, 0, 0, 0]); // b1 (uncommitted)
        assert_eq!(&valid[16..20], &[1, 0, 0, 0]); // padding replays a0
    }

    #[test]
    #[should_panic(expected = "out of union range")]
    fn merge_bookkeeping_rejects_out_of_range() {
        let a = toy(2, 4);
        let b = toy(2, 4);
        let _ = KvSet::merge_bookkeeping(&a, &b, &[4]);
    }

    /// The gang-batching correctness core, as a property over the host
    /// model: merging two caches and then gathering a slot out of the
    /// union must read exactly the bookkeeping a per-cache gather of the
    /// source slot would have read.
    #[test]
    fn prop_merge_then_gather_equals_per_cache_gather() {
        use crate::util::propcheck::check_simple;
        check_simple(
            "merge-then-gather",
            |rng| {
                let s = 4 + rng.below(4); // cache_len
                let ba = 1 + rng.below(4);
                let bb = 1 + rng.below(4);
                let mk = |rng: &mut crate::util::rng::Rng, batch: usize| {
                    let mut kv = KvSet::new(Vec::new(), batch, s);
                    kv.pos_phys = rng.below(s);
                    for slot in 0..batch {
                        let n = rng.below(s + 1);
                        if n > 0 {
                            kv.commit(slot, 0, n);
                        }
                    }
                    (kv.pos_phys, kv.pos_log, kv.valid)
                };
                let a = mk(rng, ba);
                let b = mk(rng, bb);
                let pick = rng.below(ba + bb);
                (s, ba, bb, a, b, pick)
            },
            |&(s, ba, bb, ref a, ref b, pick)| {
                let rebuild = |batch: usize, st: &(usize, Vec<i32>, Vec<i32>)| {
                    let mut kv = KvSet::new(Vec::new(), batch, s);
                    kv.pos_phys = st.0;
                    kv.pos_log = st.1.clone();
                    kv.valid = st.2.clone();
                    kv
                };
                let ka = rebuild(ba, a);
                let kb = rebuild(bb, b);
                // merge the full union, then gather `pick`
                let idx: Vec<i32> = (0..(ba + bb) as i32).collect();
                let (pos, log, valid) = KvSet::merge_bookkeeping(&ka, &kb, &idx);
                let mut merged = KvSet::new(Vec::new(), ba + bb, s);
                merged.pos_phys = pos;
                merged.pos_log = log;
                merged.valid = valid;
                merged.permute_bookkeeping(&vec![pick as i32; ba + bb]);
                // reference: gather straight out of the source cache
                let (src, row) = if pick < ba { (&ka, pick) } else { (&kb, pick - ba) };
                if merged.pos_log[0] != src.pos_log[row] {
                    return Err(format!(
                        "pos_log {} != source {}",
                        merged.pos_log[0], src.pos_log[row]
                    ));
                }
                if merged.valid[0..s] != src.valid[row * s..(row + 1) * s] {
                    return Err("valid row diverged from per-cache gather".into());
                }
                if merged.pos_phys < src.pos_phys {
                    return Err("merged frontier went backwards".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn broadcast_replicates_slot0() {
        let mut kv = toy(1, 4);
        kv.commit(0, 0, 2);
        let (log, valid) = kv.broadcast_bookkeeping(3);
        assert_eq!(log, vec![2, 2, 2]);
        assert_eq!(valid.len(), 12);
        assert_eq!(&valid[4..8], &[1, 1, 0, 0]);
    }
}
